"""AOT lowering: JAX programs -> HLO *text* artifacts for the rust runtime.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Emits ``hash_only.hlo.txt``, ``route.hlo.txt``, ``route_probe.hlo.txt``,
``route_assign.hlo.txt``, ``route_table.hlo.txt``, ``reduce_count.hlo.txt``,
``merge_state.hlo.txt`` and ``manifest.json`` (the static shapes rust pads
batches to).

HLO **text**, not ``.serialize()``: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Static shapes — the artifact contract with rust (DESIGN.md §6).
B = 256   # route/hash/reduce batch size
W = 8     # u32 words per key (max 32-byte keys on the XLA path)
T = 512   # ring capacity (max tokens)
V = 4096  # vocab slots per reducer
P = 64    # node/position capacity (route_probe tables, route_assign loads)
K = 8     # probe capacity (route_probe unrolls this many seeded probes)
A = 4096  # sticky-assignment table capacity (route_assign)
PT = 1024  # partition-table capacity (route_table; max 2^B table entries)


def to_hlo_text(lowered, return_tuple=True) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    ``return_tuple=False`` gives an *untupled* root — required by the
    device-resident execution path (``execute_b``), whose output buffer is
    fed straight back as the next call's input and therefore must be a
    plain array, not a tuple.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def programs():
    """name -> (fn, example arg specs)."""
    u32, i32 = jnp.uint32, jnp.int32
    return {
        "hash_only": (model.hash_only, (spec((B, W), u32), spec((B,), i32))),
        "route": (
            model.route,
            (
                spec((B, W), u32),
                spec((B,), i32),
                spec((T,), u32),
                spec((T,), i32),
                spec((), i32),
            ),
        ),
        "route_probe": (
            lambda *a: model.route_probe(*a, max_probes=K),
            (
                spec((B, W), u32),
                spec((B,), i32),
                spec((P,), u32),
                spec((P,), i32),
                spec((), i32),
                spec((P,), i32),
                spec((), i32),
            ),
        ),
        "route_assign": (
            model.route_assign,
            (
                spec((B, W), u32),
                spec((B,), i32),
                spec((A,), u32),
                spec((A,), i32),
                spec((), i32),
                spec((P,), u32),
                spec((P,), i32),
                spec((), i32),
            ),
        ),
        "route_table": (
            model.route_table,
            (
                spec((B, W), u32),
                spec((B,), i32),
                spec((PT,), i32),
                spec((), i32),
            ),
        ),
        "reduce_count": (model.reduce_count, (spec((V,), u32), spec((B,), i32))),
        "merge_state": (model.merge_state, (spec((V,), u32), spec((V,), u32))),
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name, (fn, arg_specs) in programs().items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # untupled reduce_count for the device-resident state path: its output
    # buffer is reused directly as the next execution's counts input
    def reduce_count_raw(counts, ids):
        return model.reduce_count(counts, ids)[0]

    lowered = jax.jit(reduce_count_raw).lower(
        spec((V,), jnp.uint32), spec((B,), jnp.int32)
    )
    text = to_hlo_text(lowered, return_tuple=False)
    path = os.path.join(args.out, "reduce_count_raw.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    # AV = route_assign ABI version: 2 added the live-node-id tensors
    # (elastic membership); rust treats AV < 2 artifacts' route_assign as
    # unsupported and routes two-choices scalar instead of shape-erroring
    manifest = {
        "B": B, "W": W, "T": T, "V": V, "P": P, "K": K, "A": A, "AV": 2,
        "PT": PT,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    print(f"wrote {mpath}: {manifest}")


if __name__ == "__main__":
    main()
