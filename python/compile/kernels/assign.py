"""L1 Pallas kernel: sticky-assignment table lookup (two-choices router).

The rust `TwoChoicesRouter` (`rust/src/hash/router.rs`) pins each key
hash to one of its two candidate nodes in a shared sticky table — the
key-splitting guard that keeps per-key state on exactly one reducer.
This kernel is the batched, compiled lookup over a frozen snapshot of
that table: known keys return their recorded owner; misses resolve to
the two-choices first-sight rule — ``c2 if loads[c2] < loads[c1] else
c1`` — against the loads frozen into the snapshot, so the compiled
decision is a pure function of the snapshot (bit-identical to what the
scalar router records when routing the same key under the same loads;
`rust/tests/xla_parity.rs` pins the two against each other).

Contract (shared with `rust/src/runtime/programs.rs::snapshot_tensors`):

- ``keys``/``owners``: the assignment table sorted ascending by key
  hash, padded to ``A`` with ``0xFFFFFFFF``/``0``; ``live`` is the entry
  count. Lookup is a compare-and-count searchsorted (`#{keys < h}` over
  the live prefix) plus an exact-match check — no scatter, the same
  trick the histogram kernel uses.
- ``loads``: per-node **EWMA-decayed** loads frozen at snapshot time
  (``balancer::signal`` fixed point, ``FRAC_BITS = 8`` fractional bits;
  u32-saturated on the rust side), padded to ``P`` and indexed by node
  **id**. The kernel only compares them, so the fixed-point scale
  cancels — but the decayed values are what the scalar router consults
  for first sights, which is exactly why compiled and scalar routing
  stay bit-identical under the smoothed signal.
- ``live_nodes``/``n_live``: the ascending **live node id** list, padded
  to ``P`` with ``0``. Elastic membership retires ids without reusing
  them, so the id space has gaps; candidate ``i`` of a key hash is
  ``live_nodes[murmur3(hash LE bytes, seed CAND_SEEDS[i]) % n_live]`` —
  with the identity list ``[0..n)`` this reduces to the fixed-membership
  ``% nodes`` rule, bit for bit (rust:
  ``hash::router::two_choices_candidates_in``).

TPU shape notes: a ``(TB, A)`` compare + row-sum (VPU lanes, the
histogram formulation) and a handful of ``(TB,)`` gathers.
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .murmur3 import murmur3_u32x1_seeded

# candidate hash seeds — must equal rust's TWO_CHOICES_SEEDS
CAND_SEEDS = (0x517CC1B7, 0x9E3779B9)


def two_choices_candidates(h, live_nodes, n_live):
    """The two candidate nodes of a key hash over the live id list
    (vectorized): ``live_nodes[murmur_i(h) % n_live]``."""
    n = jnp.asarray(n_live, jnp.uint32)
    live_nodes = jnp.asarray(live_nodes, jnp.int32)
    i1 = (murmur3_u32x1_seeded(h, CAND_SEEDS[0]) % n).astype(jnp.int32)
    i2 = (murmur3_u32x1_seeded(h, CAND_SEEDS[1]) % n).astype(jnp.int32)
    return live_nodes[i1], live_nodes[i2]


def _kernel(hash_ref, key_ref, owner_ref, live_ref, load_ref, live_node_ref,
            nlive_ref, out_ref):
    h = hash_ref[...]                       # (TB,) uint32 key hashes
    keys = key_ref[...]                     # (A,)  uint32 sorted table keys
    owners = owner_ref[...]                 # (A,)  int32 recorded owners
    loads = load_ref[...]                   # (P,)  uint32 frozen loads (by id)
    live_nodes = live_node_ref[...]         # (P,)  int32 live node ids
    live = live_ref[0]                      # int32 table entries
    n_live = nlive_ref[0]                   # int32 live node count
    a_cap = keys.shape[0]
    in_table = jax.lax.broadcasted_iota(jnp.int32, (1, a_cap), 1) < live

    # searchsorted(side='left') as compare-and-count over the live prefix
    idx = jnp.sum(
        (in_table & (keys[None, :] < h[:, None])).astype(jnp.int32), axis=1
    )
    idx_c = jnp.minimum(idx, a_cap - 1)
    hit = (idx < live) & (keys[idx_c] == h)

    c1, c2 = two_choices_candidates(h, live_nodes, n_live)
    fresh = jnp.where(loads[c2] < loads[c1], c2, c1)
    out_ref[...] = jnp.where(hit, owners[idx_c], fresh)


@functools.partial(jax.jit, static_argnames=("block_b",))
def assign_kernel(hashes, keys, owners, live, loads, live_nodes, n_live, *,
                  block_b=64):
    """Batched sticky-table owner lookup via ``pl.pallas_call``.

    ``hashes``: (B,) uint32; ``keys``/``owners``: (A,) padded sorted
    table; ``loads``: (P,) frozen per-node loads indexed by id;
    ``live_nodes``: (P,) padded ascending live node ids; ``live``,
    ``n_live``: scalar i32. B must be a multiple of ``block_b``.
    """
    (b,) = hashes.shape
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    a_cap = keys.shape[0]
    p_cap = loads.shape[0]
    grid = (b // block_b,)
    full = lambda i: (0,)  # noqa: E731 — whole-table blocks, every step
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((a_cap,), full),
            pl.BlockSpec((a_cap,), full),
            pl.BlockSpec((1,), full),
            pl.BlockSpec((p_cap,), full),
            pl.BlockSpec((p_cap,), full),
            pl.BlockSpec((1,), full),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        hashes,
        keys,
        jnp.asarray(owners, jnp.int32),
        jnp.reshape(jnp.asarray(live, jnp.int32), (1,)),
        jnp.asarray(loads, jnp.uint32),
        jnp.asarray(live_nodes, jnp.int32),
        jnp.reshape(jnp.asarray(n_live, jnp.int32), (1,)),
    )
