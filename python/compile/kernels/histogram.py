"""L1 Pallas kernel: masked histogram — the reducer's count aggregation.

The paper's reducer state for word count is "the total count of each word
it has seen" (§2). On the XLA path that state is a dense ``u32[V]`` vector
and each batch of ``B`` interned key ids is folded in by this kernel:
``counts[v] += |{i : ids[i] == v}|``. Padding ids are ``-1`` (never match).

TPU shape notes (§Hardware-Adaptation): the grid tiles the vocab dimension
so each step updates a ``(TV,)`` slice of the state against the full id
batch — a ``(TV, B)`` compare + row-sum, all VPU lane work with a
VMEM-resident working set (TV=512, B=256 → 512 KiB of i32 compares in
bf16-free integer lanes; counts tile 2 KiB). No gather/scatter: TPUs hate
random scatter, the compare-and-sum formulation is the standard trick.
``interpret=True`` for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(counts_ref, ids_ref, out_ref, *, tile_v: int):
    base = pl.program_id(0) * tile_v
    # vocab ids covered by this tile, as a column vector
    vids = jax.lax.broadcasted_iota(jnp.int32, (tile_v, 1), 0) + base
    ids = ids_ref[...]  # (B,) int32, -1 = padding
    matches = ids[None, :] == vids  # (tile_v, B)
    add = jnp.sum(matches.astype(jnp.uint32), axis=1)
    out_ref[...] = counts_ref[...] + add


@functools.partial(jax.jit, static_argnames=("tile_v",))
def histogram_kernel(counts, ids, *, tile_v=512):
    """``counts``: (V,) uint32; ``ids``: (B,) int32 -> updated (V,) uint32.

    V must be a multiple of ``tile_v``.
    """
    (v,) = counts.shape
    assert v % tile_v == 0, f"V {v} not a multiple of tile {tile_v}"
    grid = (v // tile_v,)
    return pl.pallas_call(
        functools.partial(_kernel, tile_v=tile_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_v,), lambda i: (i,)),
            pl.BlockSpec(ids.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_v,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), jnp.uint32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(counts, ids)
