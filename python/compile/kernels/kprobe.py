"""L1 Pallas kernel: k-probe consistent-hash routing (multi-probe router).

The rust `MultiProbeRouter` (`rust/src/hash/router.rs`) places one
position per node on the 32-bit ring and probes `k` seeded points per
key; the key goes to the probe successor minimizing the lexicographic
candidate `(overloaded[node], clockwise_distance, node)` — classic MPCH
distance choice among non-overloaded owners, falling back to pure
distance when every probe lands on a shed node. This kernel is the
batched, compiled form of that exact decision and must agree bit-for-bit
with the scalar implementation (`rust/tests/xla_parity.rs` pins the two
against each other through the AOT artifact).

Contract (shared with `rust/src/runtime/programs.rs::snapshot_tensors`):

- ``pos_hashes``/``pos_nodes``: node ring positions sorted by
  ``(hash, node)``, padded to ``P`` with ``0xFFFFFFFF``/``0``; ``pos_len``
  is the live count. The clockwise successor of a probe point ``p`` is
  the live position minimizing ``pos_hash - p`` in wrapping u32
  arithmetic — for equal hashes the first (lowest-index) wins, matching
  ``clockwise_successor_by``'s first-of-equals semantics because argmin
  returns the first occurrence and the table is pre-sorted.
- ``overloaded``: per-**node** 0/1 shed flags (indexed by node id, padded
  to ``P``), frozen at the last redistribute. Since the load-signal
  subsystem these are the *hysteresis-banded* flags (sticky between the
  high/low watermarks around the decayed mean), so — unlike the old
  one-above-mean classification — several nodes can legitimately be
  frozen shed at once; the lexicographic choice below handles any flag
  pattern, including all-shed (pure-distance fallback).
- ``probes``: live probe count (≤ the static ``max_probes`` the program
  was lowered for); probe ``j`` hashes the key hash's 4 LE bytes with
  murmur3 seed ``j``.

TPU shape notes (§Hardware-Adaptation in DESIGN.md): per probe this is a
``(TB, P)`` wrapped-subtract + argmin — VPU lane work with a
VMEM-resident working set (TB=64, P=64 → 16 KiB) — plus two tiny
``(TB,)`` gathers (positions table, flag table). ``interpret=True``: the
CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .murmur3 import murmur3_u32x1_seeded

UMAX = 0xFFFFFFFF


def _kernel(hash_ref, pos_hash_ref, pos_node_ref, pos_len_ref, over_ref,
            probes_ref, out_ref, *, max_probes: int):
    h = hash_ref[...]                       # (TB,) uint32 key hashes
    pos_h = pos_hash_ref[...]               # (P,)  uint32 sorted positions
    pos_n = pos_node_ref[...]               # (P,)  int32 owners
    over = over_ref[...]                    # (P,)  int32 per-node shed flags
    n_pos = pos_len_ref[0]                  # int32 live positions
    k = probes_ref[0]                       # int32 live probes
    p_cap = pos_h.shape[0]
    live = jax.lax.broadcasted_iota(jnp.int32, (1, p_cap), 1) < n_pos

    # running lexicographic best (overloaded, distance, node); the
    # sentinel flag 2 loses to any real candidate (flags are 0/1), so
    # probe 0 always seeds the best — mirroring rust's `Option` fold
    best_ov = jnp.full(h.shape, 2, jnp.int32)
    best_dist = jnp.full(h.shape, UMAX, jnp.uint32)
    best_node = jnp.zeros(h.shape, jnp.int32)

    for j in range(max_probes):
        p = murmur3_u32x1_seeded(h, j)      # (TB,) probe points
        # clockwise successor: min wrapping distance over live positions;
        # padding is masked to the max distance and sits at the highest
        # indices, so a live tie always wins argmin's first-occurrence
        dist = jnp.where(live, pos_h[None, :] - p[:, None], jnp.uint32(UMAX))
        idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
        d = jnp.min(dist, axis=1)
        node = pos_n[idx]
        ov = over[node]
        better = (ov < best_ov) | (
            (ov == best_ov)
            & ((d < best_dist) | ((d == best_dist) & (node < best_node)))
        )
        upd = better & (j < k)
        best_ov = jnp.where(upd, ov, best_ov)
        best_dist = jnp.where(upd, d, best_dist)
        best_node = jnp.where(upd, node, best_node)

    out_ref[...] = best_node


@functools.partial(jax.jit, static_argnames=("max_probes", "block_b"))
def kprobe_kernel(hashes, pos_hashes, pos_nodes, pos_len, overloaded, probes,
                  *, max_probes=8, block_b=64):
    """Batched k-probe owner lookup via ``pl.pallas_call``.

    ``hashes``: (B,) uint32 key hashes; ``pos_hashes``/``pos_nodes``/
    ``overloaded``: (P,) padded position/flag tables; ``pos_len``,
    ``probes``: scalar i32 live counts. B must be a multiple of
    ``block_b``; ``probes`` must be ≤ ``max_probes`` (rust checks against
    the manifest's K before calling).
    """
    (b,) = hashes.shape
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    p_cap = pos_hashes.shape[0]
    grid = (b // block_b,)
    full = lambda i: (0,)  # noqa: E731 — whole-table blocks, every step
    return pl.pallas_call(
        functools.partial(_kernel, max_probes=max_probes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((p_cap,), full),
            pl.BlockSpec((p_cap,), full),
            pl.BlockSpec((1,), full),
            pl.BlockSpec((p_cap,), full),
            pl.BlockSpec((1,), full),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        hashes,
        pos_hashes,
        jnp.asarray(pos_nodes, jnp.int32),
        jnp.reshape(jnp.asarray(pos_len, jnp.int32), (1,)),
        jnp.asarray(overloaded, jnp.int32),
        jnp.reshape(jnp.asarray(probes, jnp.int32), (1,)),
    )
