"""L1 Pallas kernel: flat partition-table routing (ptable router).

The rust `PartitionTableRouter` (`rust/src/hash/ptable.rs`) routes a key
by one indexed load: the top ``bits`` bits of the 32-bit key hash select
a partition, and a flat ``2^bits``-entry table maps the partition to its
primary node — no ring walk, no probing. This kernel is the batched,
compiled form of that gather and must agree bit-for-bit with the scalar
implementation (`rust/tests/xla_parity.rs` pins the two against each
other through the AOT artifact).

Contract (shared with `rust/src/runtime/programs.rs::snapshot_tensors`):

- ``table``: the partition→node table, ``2^bits`` live entries padded to
  the static ``PT`` capacity with ``0``. Only the first ``2^bits``
  entries are ever gathered (the partition index is ``hash >> (32 -
  bits)`` < ``2^bits``), so the padding value is unobservable.
- ``bits``: scalar i32 partition bit count, ``1 ≤ bits`` and ``2^bits ≤
  PT`` (rust checks the table length against the manifest's PT before
  calling).

TPU shape notes (§Hardware-Adaptation in DESIGN.md): per block this is a
``(TB,)`` shift plus one ``(TB,)`` gather from a VMEM-resident table
(PT=1024 → 4 KiB) — strictly cheaper than any other route family.
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hash_ref, table_ref, bits_ref, out_ref):
    h = hash_ref[...]                       # (TB,) uint32 key hashes
    table = table_ref[...]                  # (PT,) int32 partition owners
    bits = bits_ref[0]                      # int32 partition bit count
    shift = jnp.uint32(32) - bits.astype(jnp.uint32)
    part = jnp.right_shift(h, shift).astype(jnp.int32)
    out_ref[...] = table[part]


@functools.partial(jax.jit, static_argnames=("block_b",))
def ktable_kernel(hashes, table, bits, *, block_b=64):
    """Batched partition-table owner lookup via ``pl.pallas_call``.

    ``hashes``: (B,) uint32 key hashes; ``table``: (PT,) padded
    partition→node table; ``bits``: scalar i32 partition bit count. B
    must be a multiple of ``block_b``.
    """
    (b,) = hashes.shape
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    pt_cap = table.shape[0]
    grid = (b // block_b,)
    full = lambda i: (0,)  # noqa: E731 — whole-table blocks, every step
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((pt_cap,), full),
            pl.BlockSpec((1,), full),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        hashes,
        jnp.asarray(table, jnp.int32),
        jnp.reshape(jnp.asarray(bits, jnp.int32), (1,)),
    )
