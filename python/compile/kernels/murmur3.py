"""L1 Pallas kernel: batched MurmurHash3_x86_32.

The paper's consistent-hash ring places tokens and keys with MurmurHash3
[Appleby, 2014]. This kernel hashes a whole batch of keys at once on the
data plane; it must agree bit-for-bit with the rust implementation
(``rust/src/hash/murmur3.rs``) — both are checked against the published
reference vectors, and ``rust/tests/xla_parity.rs`` checks them against
each other through the compiled artifact.

Layout contract (shared with ``rust/src/runtime/programs.rs::pack_key``):
a key of ``len <= 4*W`` bytes is packed into ``W`` little-endian u32 words,
zero padded. The kernel unrolls over the ``W`` static words, applying the
murmur body for full 4-byte blocks (``j < len//4``), the tail mix for the
partial word (``j == len//4`` and ``len%4 > 0``), then finalizes with the
length xor + avalanche.

TPU shape notes (§Hardware-Adaptation in DESIGN.md): the kernel is pure
u32 lane arithmetic over a ``(TB, W)`` block — VPU-friendly, no MXU, no
gather. Block sizes keep the working set (TB*W*4 bytes ≈ 2 KiB at TB=64)
trivially VMEM-resident. ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# plain python ints: weak-typed constants stay uint32 under jax numpy
# promotion and, crucially, are not captured as traced arrays by pallas
C1 = 0xCC9E2D51
C2 = 0x1B873593
M5 = 5
N1 = 0xE6546B64
F1 = 0x85EBCA6B
F2 = 0xC2B2AE35


def _rotl32(x, r):
    """Rotate-left on uint32 lanes (r is a python int)."""
    return (x << r) | (x >> (32 - r))


def _mix_k1(k1):
    """The murmur block mix."""
    k1 = k1 * jnp.uint32(C1)
    k1 = _rotl32(k1, 15)
    return k1 * jnp.uint32(C2)


def _fmix32(h):
    """Final avalanche."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(F1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(F2)
    return h ^ (h >> 16)


def murmur3_rows(words, lens):
    """Hash each row: ``words`` (N, W) uint32, ``lens`` (N,) int32 -> (N,) uint32.

    Shared by the Pallas kernel body and the pure-jnp reference — the
    *kernel* is this math staged through pallas refs/blocks; the reference
    applies it directly (see ref.py), so the two can disagree only through
    the pallas machinery, which is exactly what the tests pin down.
    """
    n, w = words.shape
    h = jnp.zeros((n,), jnp.uint32)
    nblocks = (lens // 4).astype(jnp.int32)
    rem = (lens % 4).astype(jnp.int32)
    for j in range(w):
        k = words[:, j]
        # body step for full blocks
        k1 = _mix_k1(k)
        h_block = _rotl32(h ^ k1, 13) * jnp.uint32(M5) + jnp.uint32(N1)
        h = jnp.where(j < nblocks, h_block, h)
        # tail mix for the trailing partial word
        mask = (jnp.uint32(1) << (rem.astype(jnp.uint32) * 8)) - 1
        kt = _mix_k1(k & mask)
        is_tail = jnp.logical_and(j == nblocks, rem > 0)
        h = jnp.where(is_tail, h ^ kt, h)
    h = h ^ lens.astype(jnp.uint32)
    return _fmix32(h)


def _kernel(words_ref, lens_ref, out_ref):
    out_ref[...] = murmur3_rows(words_ref[...], lens_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b",))
def murmur3_kernel(words, lens, *, block_b=64):
    """Batched murmur3 via ``pl.pallas_call``.

    ``words``: (B, W) uint32 packed key words; ``lens``: (B,) int32 byte
    lengths. B must be a multiple of ``block_b``.
    """
    b, w = words.shape
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.uint32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(words, lens)


def murmur3_u32x1_seeded(x, seed):
    """MurmurHash3_x86_32 of a single u32 (4 LE bytes) under ``seed``.

    The closed form of the full hash for exactly one 4-byte block and no
    tail: the probe-point hash of the k-probe router
    (``rust/src/hash/murmur3.rs::murmur3_x86_32_seed(&hash.to_le_bytes(),
    seed)``) and the candidate hashes of the two-choices router. ``x`` is
    a uint32 array; ``seed`` is a uint32 array or python int.
    """
    h = jnp.asarray(seed, jnp.uint32) ^ _mix_k1(jnp.asarray(x, jnp.uint32))
    h = _rotl32(h, 13) * jnp.uint32(M5) + jnp.uint32(N1)
    return _fmix32(h ^ jnp.uint32(4))


def pack_key(data: bytes, w: int):
    """Host-side packing (python mirror of rust ``pack_key``), for tests."""
    assert len(data) <= 4 * w, f"key of {len(data)} bytes exceeds {4*w}"
    words = []
    for j in range(w):
        chunk = data[4 * j : 4 * j + 4]
        words.append(int.from_bytes(chunk.ljust(4, b"\0"), "little"))
    return words, len(data)


def pack_batch(keys, b, w):
    """Pack up to ``b`` keys into (b, w) words + (b,) lens arrays."""
    assert len(keys) <= b
    import numpy as np

    words = np.zeros((b, w), dtype=np.uint32)
    lens = np.zeros((b,), dtype=np.int32)
    for i, k in enumerate(keys):
        kw, kl = pack_key(k, w)
        words[i] = kw
        lens[i] = kl
    return jnp.asarray(words), jnp.asarray(lens)
