"""Pure references the Pallas kernels are validated against.

Two independent layers of oracle:

- ``murmur3_py`` — plain-python integer MurmurHash3_x86_32, transcribed
  from the reference C. Checked against the published smhasher vectors in
  the tests; everything else is checked against it.
- ``murmur3_ref`` / ``histogram_ref`` / ``ring_lookup_ref`` — pure-jnp
  (no pallas) implementations with the same signatures as the kernels.
"""

import jax.numpy as jnp

MASK = 0xFFFFFFFF


def murmur3_py(data: bytes, seed: int = 0) -> int:
    """Reference MurmurHash3_x86_32 in plain python."""
    c1, c2 = 0xCC9E2D51, 0x1B873593
    h1 = seed
    nblocks = len(data) // 4
    for i in range(nblocks):
        k1 = int.from_bytes(data[4 * i : 4 * i + 4], "little")
        k1 = (k1 * c1) & MASK
        k1 = ((k1 << 15) | (k1 >> 17)) & MASK
        k1 = (k1 * c2) & MASK
        h1 ^= k1
        h1 = ((h1 << 13) | (h1 >> 19)) & MASK
        h1 = (h1 * 5 + 0xE6546B64) & MASK
    tail = data[nblocks * 4 :]
    k1 = 0
    if len(tail) >= 3:
        k1 ^= tail[2] << 16
    if len(tail) >= 2:
        k1 ^= tail[1] << 8
    if len(tail) >= 1:
        k1 ^= tail[0]
        k1 = (k1 * c1) & MASK
        k1 = ((k1 << 15) | (k1 >> 17)) & MASK
        k1 = (k1 * c2) & MASK
        h1 ^= k1
    h1 ^= len(data)
    h1 ^= h1 >> 16
    h1 = (h1 * 0x85EBCA6B) & MASK
    h1 ^= h1 >> 13
    h1 = (h1 * 0xC2B2AE35) & MASK
    h1 ^= h1 >> 16
    return h1


def murmur3_ref(words, lens):
    """Pure-jnp murmur3 over packed rows (no pallas)."""
    from . import murmur3

    return murmur3.murmur3_rows(jnp.asarray(words), jnp.asarray(lens))


def histogram_ref(counts, ids):
    """Pure-jnp histogram update: counts[v] += #{ids == v}; -1 skipped."""
    counts = jnp.asarray(counts, jnp.uint32)
    ids = jnp.asarray(ids, jnp.int32)
    v = counts.shape[0]
    # map padding (-1, or anything out of range) to an overflow bucket
    safe = jnp.where((ids >= 0) & (ids < v), ids, v)
    add = jnp.bincount(safe, length=v + 1)[:v].astype(jnp.uint32)
    return counts + add


def ring_lookup_ref(hashes, ring_hashes, ring_owners, ring_len):
    """Linear-scan consistent-ring lookup (oracle for searchsorted)."""
    import numpy as np

    hashes = np.asarray(hashes, dtype=np.uint64)
    rh = np.asarray(ring_hashes, dtype=np.uint64)[: int(ring_len)]
    ro = np.asarray(ring_owners)[: int(ring_len)]
    out = []
    for h in hashes:
        ge = np.nonzero(rh >= h)[0]
        idx = ge[0] if len(ge) else 0
        out.append(int(ro[idx]))
    return np.array(out, dtype=np.int32)
