"""Pure references the Pallas kernels are validated against.

Two independent layers of oracle:

- ``murmur3_py`` — plain-python integer MurmurHash3_x86_32, transcribed
  from the reference C. Checked against the published smhasher vectors in
  the tests; everything else is checked against it.
- ``murmur3_ref`` / ``histogram_ref`` / ``ring_lookup_ref`` — pure-jnp
  (no pallas) implementations with the same signatures as the kernels.
"""

import jax.numpy as jnp

MASK = 0xFFFFFFFF


def murmur3_py(data: bytes, seed: int = 0) -> int:
    """Reference MurmurHash3_x86_32 in plain python."""
    c1, c2 = 0xCC9E2D51, 0x1B873593
    h1 = seed
    nblocks = len(data) // 4
    for i in range(nblocks):
        k1 = int.from_bytes(data[4 * i : 4 * i + 4], "little")
        k1 = (k1 * c1) & MASK
        k1 = ((k1 << 15) | (k1 >> 17)) & MASK
        k1 = (k1 * c2) & MASK
        h1 ^= k1
        h1 = ((h1 << 13) | (h1 >> 19)) & MASK
        h1 = (h1 * 5 + 0xE6546B64) & MASK
    tail = data[nblocks * 4 :]
    k1 = 0
    if len(tail) >= 3:
        k1 ^= tail[2] << 16
    if len(tail) >= 2:
        k1 ^= tail[1] << 8
    if len(tail) >= 1:
        k1 ^= tail[0]
        k1 = (k1 * c1) & MASK
        k1 = ((k1 << 15) | (k1 >> 17)) & MASK
        k1 = (k1 * c2) & MASK
        h1 ^= k1
    h1 ^= len(data)
    h1 ^= h1 >> 16
    h1 = (h1 * 0x85EBCA6B) & MASK
    h1 ^= h1 >> 13
    h1 = (h1 * 0xC2B2AE35) & MASK
    h1 ^= h1 >> 16
    return h1


def murmur3_ref(words, lens):
    """Pure-jnp murmur3 over packed rows (no pallas)."""
    from . import murmur3

    return murmur3.murmur3_rows(jnp.asarray(words), jnp.asarray(lens))


def histogram_ref(counts, ids):
    """Pure-jnp histogram update: counts[v] += #{ids == v}; -1 skipped."""
    counts = jnp.asarray(counts, jnp.uint32)
    ids = jnp.asarray(ids, jnp.int32)
    v = counts.shape[0]
    # map padding (-1, or anything out of range) to an overflow bucket
    safe = jnp.where((ids >= 0) & (ids < v), ids, v)
    add = jnp.bincount(safe, length=v + 1)[:v].astype(jnp.uint32)
    return counts + add


def kprobe_ref(hashes, pos_hashes, pos_nodes, pos_len, overloaded, probes):
    """Plain-python k-probe routing — a transcription of rust's
    ``MultiProbeRouter::route`` (lexicographic ``(overloaded, clockwise
    distance, node)`` over ``probes`` seeded probe points)."""
    import numpy as np

    pos_h = [int(x) for x in np.asarray(pos_hashes)[: int(pos_len)]]
    pos_n = [int(x) for x in np.asarray(pos_nodes)[: int(pos_len)]]
    over = [int(x) for x in np.asarray(overloaded)]
    out = []
    for h in np.asarray(hashes):
        h = int(h)
        best = None
        for j in range(int(probes)):
            p = murmur3_py(h.to_bytes(4, "little"), seed=j)
            # clockwise successor: first position >= p, wrapping to 0
            ge = [i for i, ph in enumerate(pos_h) if ph >= p]
            i = ge[0] if ge else 0
            cand = (over[pos_n[i]], (pos_h[i] - p) & MASK, pos_n[i])
            if best is None or cand < best:
                best = cand
        out.append(best[2])
    return np.array(out, dtype=np.int32)


def ktable_ref(hashes, table, bits):
    """Plain-python partition-table routing — a transcription of rust's
    ``PartitionTableRouter::route`` (``table[hash >> (32 - bits)]``, one
    indexed load per key)."""
    import numpy as np

    bits = int(bits)
    tbl = [int(x) for x in np.asarray(table)]
    out = []
    for h in np.asarray(hashes):
        out.append(tbl[(int(h) & MASK) >> (32 - bits)])
    return np.array(out, dtype=np.int32)


def assign_ref(hashes, keys, owners, live, loads, live_nodes, n_live):
    """Plain-python sticky-table lookup with the two-choices first-sight
    fallback on frozen loads over the live node id list — mirrors rust's
    snapshot routing for ``TwoChoicesRouter`` under elastic membership
    (``candidate = live_nodes[murmur_i(h) % n_live]``; loads indexed by
    node id)."""
    import numpy as np

    from .assign import CAND_SEEDS

    table = {
        int(k): int(o)
        for k, o in zip(np.asarray(keys)[: int(live)], np.asarray(owners))
    }
    loads = [int(x) for x in np.asarray(loads)]
    lv = [int(x) for x in np.asarray(live_nodes)[: int(n_live)]]
    out = []
    for h in np.asarray(hashes):
        h = int(h)
        if h in table:
            out.append(table[h])
            continue
        c1 = lv[murmur3_py(h.to_bytes(4, "little"), seed=CAND_SEEDS[0]) % len(lv)]
        c2 = lv[murmur3_py(h.to_bytes(4, "little"), seed=CAND_SEEDS[1]) % len(lv)]
        out.append(c2 if loads[c2] < loads[c1] else c1)
    return np.array(out, dtype=np.int32)


def ring_lookup_ref(hashes, ring_hashes, ring_owners, ring_len):
    """Linear-scan consistent-ring lookup (oracle for searchsorted)."""
    import numpy as np

    hashes = np.asarray(hashes, dtype=np.uint64)
    rh = np.asarray(ring_hashes, dtype=np.uint64)[: int(ring_len)]
    ro = np.asarray(ring_owners)[: int(ring_len)]
    out = []
    for h in hashes:
        ge = np.nonzero(rh >= h)[0]
        idx = ge[0] if len(ge) else 0
        out.append(int(ro[idx]))
    return np.array(out, dtype=np.int32)
