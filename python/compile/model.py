"""L2: the JAX data-plane programs lowered to the rust runtime.

Four programs (shapes fixed at AOT time, see ``aot.py``):

- ``hash_only(words, lens)``                      -> (hashes,)
- ``route(words, lens, ring_hashes, ring_owners, ring_len)``
                                                  -> (hashes, owners)
- ``reduce_count(counts, ids)``                   -> (counts',)
- ``merge_state(a, b)``                           -> (a + b,)

``route`` composes the L1 murmur3 Pallas kernel with a consistent-ring
lookup. The ring is a *runtime input* (sorted token hashes padded with
``0xFFFFFFFF``, owners, live length) so one compiled executable serves
every repartition the load balancer makes — the rust side just feeds the
current ring tensors.

Tie/wraparound contract (must match ``rust/src/hash/ring.rs``): tokens are
pre-sorted by ``(hash, node, idx)`` on the rust side; lookup returns the
owner at the first index with ``token_hash >= key_hash`` (``searchsorted
side='left'``), wrapping to index 0 past the live end.
"""

import jax.numpy as jnp

from .kernels.histogram import histogram_kernel
from .kernels.murmur3 import murmur3_kernel


def ring_lookup(hashes, ring_hashes, ring_owners, ring_len):
    """Consistent-ring lookup: first token at/after each hash, wrapped.

    ``ring_hashes`` is sorted ascending with ``0xFFFFFFFF`` padding, so
    searchsorted lands either on a live token or in the pad region; the
    pad/past-end case wraps to token 0.
    """
    idx = jnp.searchsorted(ring_hashes, hashes, side="left")
    idx = jnp.where(idx >= ring_len, 0, idx).astype(jnp.int32)
    return ring_owners[idx]


def hash_only(words, lens):
    """Batched murmur3 (L1 kernel)."""
    return (murmur3_kernel(words, lens),)


def route(words, lens, ring_hashes, ring_owners, ring_len):
    """Hash + ring lookup: the mapper's routing decision, batched."""
    hashes = murmur3_kernel(words, lens)
    owners = ring_lookup(hashes, ring_hashes, ring_owners, ring_len)
    return hashes, owners


def reduce_count(counts, ids):
    """Reducer state update: histogram-add a batch of interned ids."""
    return (histogram_kernel(counts, ids),)


def merge_state(a, b):
    """§2 state merge for counts: elementwise add."""
    return (a + b,)
