"""L2: the JAX data-plane programs lowered to the rust runtime.

Seven programs (shapes fixed at AOT time, see ``aot.py``):

- ``hash_only(words, lens)``                      -> (hashes,)
- ``route(words, lens, ring_hashes, ring_owners, ring_len)``
                                                  -> (hashes, owners)
- ``route_probe(words, lens, pos_hashes, pos_nodes, pos_len, overloaded,
  probes)``                                       -> (hashes, owners)
- ``route_assign(words, lens, keys, owners, live, loads, live_nodes,
  n_live)``                                       -> (hashes, owners)
- ``route_table(words, lens, table, bits)``       -> (hashes, owners)
- ``reduce_count(counts, ids)``                   -> (counts',)
- ``merge_state(a, b)``                           -> (a + b,)

The four ``route*`` programs compose the L1 murmur3 Pallas kernel with
one lookup per router family (`rust/src/hash/router.rs`): ``route``
serves the token-ring family, ``route_probe`` the multi-probe family
(`kernels/kprobe.py`), ``route_assign`` the two-choices sticky table
(`kernels/assign.py`), ``route_table`` the flat partition table
(`kernels/ktable.py`, one gather per key). In each case the routing state is a *runtime
input* — padded tables plus live lengths — so one compiled executable
serves every epoch the load balancer publishes; the rust side
(`runtime::programs::snapshot_tensors`) just feeds the current
snapshot's tensors.

Tie/wraparound contract (must match ``rust/src/hash/ring.rs``): tokens are
pre-sorted by ``(hash, node, idx)`` on the rust side; lookup returns the
owner at the first index with ``token_hash >= key_hash`` (``searchsorted
side='left'``), wrapping to index 0 past the live end. The probe kernel
obeys the same successor semantics through its wrapped-distance argmin.
"""

import jax.numpy as jnp

from .kernels.assign import assign_kernel
from .kernels.histogram import histogram_kernel
from .kernels.kprobe import kprobe_kernel
from .kernels.ktable import ktable_kernel
from .kernels.murmur3 import murmur3_kernel


def ring_lookup(hashes, ring_hashes, ring_owners, ring_len):
    """Consistent-ring lookup: first token at/after each hash, wrapped.

    ``ring_hashes`` is sorted ascending with ``0xFFFFFFFF`` padding, so
    searchsorted lands either on a live token or in the pad region; the
    pad/past-end case wraps to token 0.
    """
    idx = jnp.searchsorted(ring_hashes, hashes, side="left")
    idx = jnp.where(idx >= ring_len, 0, idx).astype(jnp.int32)
    return ring_owners[idx]


def hash_only(words, lens):
    """Batched murmur3 (L1 kernel)."""
    return (murmur3_kernel(words, lens),)


def route(words, lens, ring_hashes, ring_owners, ring_len):
    """Hash + ring lookup: the mapper's routing decision, batched."""
    hashes = murmur3_kernel(words, lens)
    owners = ring_lookup(hashes, ring_hashes, ring_owners, ring_len)
    return hashes, owners


def route_probe(words, lens, pos_hashes, pos_nodes, pos_len, overloaded,
                probes, *, max_probes=8):
    """Hash + k-probe lookup: the multi-probe router's decision, batched."""
    hashes = murmur3_kernel(words, lens)
    owners = kprobe_kernel(
        hashes, pos_hashes, pos_nodes, pos_len, overloaded, probes,
        max_probes=max_probes,
    )
    return hashes, owners


def route_assign(words, lens, keys, owners, live, loads, live_nodes, n_live):
    """Hash + sticky-table lookup: the two-choices decision, batched.

    ``live_nodes``/``n_live`` carry the elastic membership — candidates
    hash into the live id list, so one compiled executable serves every
    node count the balancer's scaling policy produces."""
    hashes = murmur3_kernel(words, lens)
    out = assign_kernel(hashes, keys, owners, live, loads, live_nodes, n_live)
    return hashes, out


def route_table(words, lens, table, bits):
    """Hash + flat-table gather: the partition-table decision, batched.

    ``table`` is the padded partition→node table and ``bits`` the
    partition bit count; the owner is ``table[hash >> (32 - bits)]`` —
    one indexed load, no search."""
    hashes = murmur3_kernel(words, lens)
    owners = ktable_kernel(hashes, table, bits)
    return hashes, owners


def reduce_count(counts, ids):
    """Reducer state update: histogram-add a batch of interned ids."""
    return (histogram_kernel(counts, ids),)


def merge_state(a, b):
    """§2 state merge for counts: elementwise add."""
    return (a + b,)
