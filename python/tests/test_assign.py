"""Assignment-table kernel vs the plain-python transcription of the
two-choices snapshot routing: sticky hits, frozen-loads fallback on
misses, elastic (gapped) live node sets, and the edge cases (empty
table, boundary keys, load ties)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.assign import CAND_SEEDS, assign_kernel
from compile.kernels.ref import assign_ref, murmur3_py

A_CAP = 32
P_CAP = 8
BLOCK = 64


def run(hashes, table, loads, live):
    """``table``: {key_hash: owner}; ``live``: ascending live node ids;
    ``loads`` indexed by node id. Pads to kernel shapes, runs one batch."""
    items = sorted(table.items())
    keys = np.full(A_CAP, 0xFFFFFFFF, np.uint32)
    owners = np.zeros(A_CAP, np.int32)
    for i, (k, o) in enumerate(items):
        keys[i], owners[i] = k, o
    lv = np.zeros(P_CAP, np.uint32)
    lv[: len(loads)] = np.asarray(loads, np.uint32)
    ln = np.zeros(P_CAP, np.int32)
    ln[: len(live)] = np.asarray(live, np.int32)
    b = max(BLOCK, -(-len(hashes) // BLOCK) * BLOCK)
    hs = np.zeros(b, np.uint32)
    hs[: len(hashes)] = np.asarray(hashes, np.uint32)
    got = assign_kernel(
        jnp.asarray(hs), jnp.asarray(keys), jnp.asarray(owners),
        jnp.int32(len(items)), jnp.asarray(lv), jnp.asarray(ln),
        jnp.int32(len(live)),
    )
    ref = assign_ref(hs, keys, owners, len(items), lv, ln, len(live))
    return np.array(got)[: len(hashes)], ref[: len(hashes)]


def candidates(h, live):
    c1 = live[murmur3_py(int(h).to_bytes(4, "little"), seed=CAND_SEEDS[0]) % len(live)]
    c2 = live[murmur3_py(int(h).to_bytes(4, "little"), seed=CAND_SEEDS[1]) % len(live)]
    return c1, c2


def test_recorded_owners_win_over_loads():
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(20)]
    table = {h: i % 3 for i, h in enumerate(hashes)}
    # loads wildly skewed: sticky assignments must still be returned
    got, ref = run(hashes, table, [10_000, 0, 10_000, 0], live=[0, 1, 2, 3])
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(got, np.array([i % 3 for i in range(20)]))


def test_empty_table_uses_two_choices_on_frozen_loads():
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(100)]
    got, ref = run(hashes, {}, [50, 0], live=[0, 1])
    np.testing.assert_array_equal(got, ref)
    # any key whose candidates differ must land on the unloaded node 1
    for h, o in zip(hashes, got):
        c1, c2 = candidates(h, [0, 1])
        if c1 != c2:
            assert o == 1, f"hash {h:#x} ignored the frozen loads"


def test_identity_live_list_matches_fixed_membership_rule():
    # with live = [0..n) the candidate rule must reduce to the historical
    # `murmur % nodes` — the bit-compat bridge to pre-elastic snapshots
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(60)]
    got, _ = run(hashes, {}, [9, 5, 7, 3], live=[0, 1, 2, 3])
    for h, o in zip(hashes, got):
        c1 = murmur3_py(int(h).to_bytes(4, "little"), seed=CAND_SEEDS[0]) % 4
        c2 = murmur3_py(int(h).to_bytes(4, "little"), seed=CAND_SEEDS[1]) % 4
        loads = [9, 5, 7, 3]
        assert o == (c2 if loads[c2] < loads[c1] else c1)


def test_gapped_live_set_never_yields_retired_ids():
    # elastic membership: ids 1 and 3 retired — no first sight may land
    # on them, and sticky entries still win
    live = [0, 2, 4]
    keep = murmur3_py(b"sticky-one")
    table = {keep: 2}
    hashes = [keep] + [murmur3_py(f"key-{i}".encode()) for i in range(80)]
    got, ref = run(hashes, table, [5, 0, 9, 0, 1], live=live)
    np.testing.assert_array_equal(got, ref)
    assert got[0] == 2
    assert set(np.unique(got)).issubset(set(live)), "retired id produced"
    for h, o in zip(hashes[1:], got[1:]):
        c1, c2 = candidates(h, live)
        loads = [5, 0, 9, 0, 1]
        assert o == (c2 if loads[c2] < loads[c1] else c1)


def test_load_tie_keeps_first_candidate():
    # rust: `if loads[c2] < loads[c1] { c2 } else { c1 }` — ties pick c1
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(50)]
    got, ref = run(hashes, {}, [7, 7, 7], live=[0, 1, 2])
    np.testing.assert_array_equal(got, ref)
    for h, o in zip(hashes, got):
        assert o == candidates(h, [0, 1, 2])[0]


def test_miss_next_to_hit_and_boundary_keys():
    # exact-match discipline: a miss adjacent to a live key must not
    # alias onto it, and the 0x00000000 / 0xFFFFFFFF extremes work
    table = {100: 2, 0: 1, 0xFFFFFFFF: 3}
    hashes = [99, 100, 101, 0, 1, 0xFFFFFFFF, 0xFFFFFFFE]
    got, ref = run(hashes, table, [0, 0, 0, 0], live=[0, 1, 2, 3])
    np.testing.assert_array_equal(got, ref)
    assert got[1] == 2 and got[3] == 1 and got[5] == 3
    for h, o in zip([99, 101, 1, 0xFFFFFFFE], got[[0, 2, 4, 6]]):
        assert o == candidates(h, [0, 1, 2, 3])[0], "miss must use the fallback"


def test_single_node_everything_lands_on_it():
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(30)]
    got, ref = run(hashes, {hashes[0]: 0}, [9], live=[0])
    np.testing.assert_array_equal(got, ref)
    assert (got == 0).all()


def test_single_survivor_of_many_ids():
    # everything retired but id 3: every miss lands there
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(30)]
    got, ref = run(hashes, {}, [4, 4, 4, 4], live=[3])
    np.testing.assert_array_equal(got, ref)
    assert (got == 3).all()


# mirror of rust `balancer::signal::FRAC_BITS`: since the load-signal
# subsystem, the frozen loads tensor carries EWMA-decayed values in
# fixed point rather than raw queue lengths
FRAC_BITS = 8


def test_fixed_point_decayed_loads_scale_invariant():
    # the kernel only *compares* loads, so the fixed-point scale of the
    # decayed signal must not change any first-sight decision
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(80)]
    raw, _ = run(hashes, {}, [50, 3, 20, 7], live=[0, 1, 2, 3])
    fp, ref = run(
        hashes, {}, [v << FRAC_BITS for v in [50, 3, 20, 7]], live=[0, 1, 2, 3]
    )
    np.testing.assert_array_equal(fp, ref)
    np.testing.assert_array_equal(fp, raw)


def test_fractional_decayed_loads_order_correctly():
    # decayed values are rarely whole multiples of the scale; a
    # sub-unit difference (e.g. 50.30 vs 49.99 in fixed point) must
    # still pick the genuinely lighter candidate
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(80)]
    lo = (50 << FRAC_BITS) - 3  # ≈ 49.99
    hi = (50 << FRAC_BITS) + 77  # ≈ 50.30
    got, ref = run(hashes, {}, [hi, lo], live=[0, 1])
    np.testing.assert_array_equal(got, ref)
    for h, o in zip(hashes, got):
        c1, c2 = candidates(h, [0, 1])
        if c1 != c2:
            assert o == 1, f"hash {h:#x} ignored a sub-unit load difference"


@pytest.mark.parametrize("seed", range(12))
def test_matches_reference_random(seed):
    rng = np.random.default_rng(seed)
    entries = int(rng.integers(0, A_CAP + 1))
    id_space = int(rng.integers(1, P_CAP + 1))
    # random non-empty live subset of the id space (elastic gaps)
    n_live = int(rng.integers(1, id_space + 1))
    live = sorted(rng.choice(id_space, size=n_live, replace=False).tolist())
    table_keys = rng.choice(2**32, size=entries, replace=False)
    table = {int(k): int(rng.choice(live)) for k in table_keys}
    loads = rng.integers(0, 100, id_space)
    # half fresh hashes, half table hits (when the table is non-empty)
    hashes = list(rng.integers(0, 2**32, BLOCK // 2).astype(np.uint32))
    if entries:
        hashes += list(rng.choice(table_keys, size=BLOCK - len(hashes)))
    got, ref = run(hashes, table, loads, live=live)
    np.testing.assert_array_equal(got, ref)
