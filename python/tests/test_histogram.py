"""Histogram Pallas kernel vs the pure-jnp/bincount reference, with
hypothesis sweeps over values, padding and tile shapes."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.histogram import histogram_kernel
from compile.kernels.ref import histogram_ref


def run(counts, ids, tile_v):
    return np.array(
        histogram_kernel(jnp.asarray(counts, jnp.uint32), jnp.asarray(ids, jnp.int32), tile_v=tile_v)
    )


def test_empty_batch_is_identity():
    counts = np.arange(64, dtype=np.uint32)
    ids = np.full(16, -1, np.int32)
    np.testing.assert_array_equal(run(counts, ids, 32), counts)


def test_single_id_increments_once():
    counts = np.zeros(64, np.uint32)
    ids = np.full(16, -1, np.int32)
    ids[0] = 7
    out = run(counts, ids, 32)
    assert out[7] == 1
    assert out.sum() == 1


def test_duplicate_ids_accumulate():
    counts = np.zeros(64, np.uint32)
    ids = np.array([3] * 10 + [5] * 6, np.int32)
    out = run(counts, ids, 16)
    assert out[3] == 10
    assert out[5] == 6


def test_matches_reference_dense():
    rng = np.random.default_rng(1)
    counts = rng.integers(0, 1000, 256).astype(np.uint32)
    ids = rng.integers(0, 256, 128).astype(np.int32)
    np.testing.assert_array_equal(run(counts, ids, 64), np.array(histogram_ref(counts, ids)))


@settings(max_examples=40, deadline=None)
@given(
    st.sampled_from([(64, 16), (64, 64), (256, 32), (512, 512), (1024, 128)]),
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=1, max_value=128),
)
def test_shape_and_value_sweep(vt, seed, b):
    """Kernel == reference for every (V, tile) pairing, batch size, random
    padding mix."""
    v, tile = vt
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 2**20, v).astype(np.uint32)
    # mix of valid ids and -1 padding
    ids = rng.integers(-1, v, b).astype(np.int32)
    out = run(counts, ids, tile)
    ref = np.array(histogram_ref(counts, ids))
    np.testing.assert_array_equal(out, ref)


def test_saturation_behaviour_documented():
    # u32 wrap-around on overflow (documented; counts in practice are
    # bounded by total input size which rust caps far below 2^32)
    counts = np.array([0xFFFFFFFF] + [0] * 15, np.uint32)
    ids = np.zeros(1, np.int32)
    out = run(counts, ids, 16)
    assert out[0] == 0  # wrapped
