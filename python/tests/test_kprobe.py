"""k-probe kernel vs the plain-python transcription of rust's
``MultiProbeRouter::route``: seeded-probe points, successor/tie
semantics, overload shedding, and the edge cases (all owners frozen,
k > node count)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.kprobe import kprobe_kernel
from compile.kernels.murmur3 import murmur3_u32x1_seeded
from compile.kernels.ref import kprobe_ref, murmur3_py

P_CAP = 16
BLOCK = 64


def run(hashes, pos_hashes, pos_nodes, overloaded, probes, max_probes=8):
    """Pad inputs to kernel shapes and run one batch."""
    n = len(pos_hashes)
    ph = np.full(P_CAP, 0xFFFFFFFF, np.uint32)
    pn = np.zeros(P_CAP, np.int32)
    ov = np.zeros(P_CAP, np.int32)
    # rust pre-sorts positions by (hash, node)
    order = np.lexsort((np.asarray(pos_nodes), np.asarray(pos_hashes, np.uint32)))
    ph[:n] = np.asarray(pos_hashes, np.uint32)[order]
    pn[:n] = np.asarray(pos_nodes, np.int32)[order]
    ov[: len(overloaded)] = np.asarray(overloaded, np.int32)
    b = max(BLOCK, -(-len(hashes) // BLOCK) * BLOCK)
    hs = np.zeros(b, np.uint32)
    hs[: len(hashes)] = np.asarray(hashes, np.uint32)
    got = kprobe_kernel(
        jnp.asarray(hs), jnp.asarray(ph), jnp.asarray(pn), jnp.int32(n),
        jnp.asarray(ov), jnp.int32(probes), max_probes=max_probes,
    )
    ref = kprobe_ref(hs, ph, pn, n, ov, probes)
    return np.array(got)[: len(hashes)], ref[: len(hashes)]


def node_positions(nodes):
    """Rust's position placement: murmur3(\"node-{n}\") per node."""
    return [murmur3_py(f"node-{n}".encode()) for n in range(nodes)]


def test_seeded_u32_hash_matches_reference():
    for x in [0, 1, 0xDEADBEEF, 0xFFFFFFFF, 12345]:
        for seed in [0, 1, 7, 0x9E3779B9]:
            got = int(murmur3_u32x1_seeded(jnp.uint32(x), seed))
            assert got == murmur3_py(x.to_bytes(4, "little"), seed=seed), (
                f"x={x:#x} seed={seed:#x}"
            )


def test_matches_reference_uniform_flags():
    pos = node_positions(4)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(60)]
    got, ref = run(hashes, pos, list(range(4)), [0, 0, 0, 0], probes=5)
    np.testing.assert_array_equal(got, ref)
    assert len(set(got.tolist())) > 1, "probe routing collapsed to one node"


def test_overloaded_owner_is_avoided():
    pos = node_positions(4)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(200)]
    base, _ = run(hashes, pos, list(range(4)), [0, 0, 0, 0], probes=5)
    hot = int(np.bincount(base, minlength=4).argmax())
    flags = [1 if n == hot else 0 for n in range(4)]
    got, ref = run(hashes, pos, list(range(4)), flags, probes=5)
    np.testing.assert_array_equal(got, ref)
    # keys with any non-overloaded probe owner must shed the hot node
    assert np.sum(got == hot) < np.sum(base == hot)
    # and nobody moved ONTO the hot node
    assert not np.any((base != hot) & (got == hot))


def test_all_owners_frozen_falls_back_to_distance():
    # every node overloaded: the lexicographic choice degenerates to the
    # classic closest-probe pick, identical to the no-flags route
    pos = node_positions(5)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(100)]
    none_over, _ = run(hashes, pos, list(range(5)), [0] * 5, probes=4)
    all_over, ref = run(hashes, pos, list(range(5)), [1] * 5, probes=4)
    np.testing.assert_array_equal(all_over, ref)
    np.testing.assert_array_equal(all_over, none_over)


def test_more_probes_than_nodes():
    # k > node count: probes collide on the few nodes; still valid + exact
    pos = node_positions(2)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(50)]
    got, ref = run(hashes, pos, [0, 1], [0, 0], probes=8)
    np.testing.assert_array_equal(got, ref)
    assert set(got.tolist()) <= {0, 1}


def test_single_probe_is_plain_consistent_hashing():
    pos = node_positions(6)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(64)]
    got, ref = run(hashes, pos, list(range(6)), [0] * 6, probes=1)
    np.testing.assert_array_equal(got, ref)


def test_probe_count_masking():
    # probes beyond the live count must not contribute: k=2 under
    # max_probes=8 equals k=2 under max_probes=2
    pos = node_positions(4)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(40)]
    a, _ = run(hashes, pos, list(range(4)), [0] * 4, probes=2, max_probes=8)
    b, _ = run(hashes, pos, list(range(4)), [0] * 4, probes=2, max_probes=2)
    np.testing.assert_array_equal(a, b)


def test_hysteresis_flag_sets_shed_multiple_nodes():
    # since ISSUE 4 the frozen flags come from the hysteresis band, which
    # (being sticky) can legitimately freeze SEVERAL reducers shed at
    # once — a state the old one-above-mean classification never
    # produced; routing must shed every flagged owner while any
    # unflagged probe owner exists
    pos = node_positions(4)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(200)]
    base, _ = run(hashes, pos, list(range(4)), [0, 0, 0, 0], probes=5)
    got, ref = run(hashes, pos, list(range(4)), [1, 1, 0, 0], probes=5)
    np.testing.assert_array_equal(got, ref)
    # keys already owned by an unflagged node never move
    unflagged_before = np.isin(base, [2, 3])
    np.testing.assert_array_equal(got[unflagged_before], base[unflagged_before])
    # flagged nodes shed together
    assert np.sum(np.isin(got, [0, 1])) < np.sum(np.isin(base, [0, 1]))
    # and nothing moved ONTO a flagged node
    assert not np.any(np.isin(got, [0, 1]) & ~np.isin(base, [0, 1]))


@pytest.mark.parametrize("seed", range(12))
def test_matches_reference_random(seed):
    rng = np.random.default_rng(seed)
    nodes = int(rng.integers(1, 13))
    probes = int(rng.integers(1, 9))
    pos = rng.choice(2**32, size=nodes, replace=False).astype(np.uint32)
    flags = rng.integers(0, 2, nodes).astype(np.int32)
    hashes = rng.integers(0, 2**32, BLOCK).astype(np.uint32)
    got, ref = run(hashes, pos, list(range(nodes)), flags, probes=probes)
    np.testing.assert_array_equal(got, ref)
