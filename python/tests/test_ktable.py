"""Partition-table kernel vs the plain-python transcription of rust's
``PartitionTableRouter::route``: boundary partitions, non-default bit
counts, stale-epoch tables, gapped live node ids."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ktable import ktable_kernel
from compile.kernels.ref import ktable_ref, murmur3_py

PT_CAP = 1024
BLOCK = 64


def run(hashes, table, bits, pt_cap=PT_CAP):
    """Pad inputs to kernel shapes and run one batch."""
    tbl = np.zeros(pt_cap, np.int32)
    tbl[: len(table)] = np.asarray(table, np.int32)
    b = max(BLOCK, -(-len(hashes) // BLOCK) * BLOCK)
    hs = np.zeros(b, np.uint32)
    hs[: len(hashes)] = np.asarray(hashes, np.uint32)
    got = ktable_kernel(jnp.asarray(hs), jnp.asarray(tbl), jnp.int32(bits))
    ref = ktable_ref(hs, tbl, bits)
    return np.array(got)[: len(hashes)], ref[: len(hashes)]


def round_robin_table(bits, nodes):
    """Rust's fresh-table layout: partition p starts on node p % n."""
    return [p % nodes for p in range(1 << bits)]


def test_matches_reference_default_bits():
    table = round_robin_table(10, 7)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(200)]
    got, ref = run(hashes, table, bits=10)
    np.testing.assert_array_equal(got, ref)
    assert len(set(got.tolist())) > 1, "table routing collapsed to one node"


def test_partition_boundaries_are_exact():
    # hashes straddling every partition edge: hash >> (32-B) must floor
    # into the lower partition at edge-1 and the upper at the edge
    bits = 6
    table = round_robin_table(bits, 5)
    width = 1 << (32 - bits)
    hashes = []
    for p in range(1 << bits):
        edge = p * width
        hashes += [edge, edge + 1, edge + width - 1]
    got, ref = run(hashes, table, bits=bits)
    np.testing.assert_array_equal(got, ref)
    # first/last hash of partition p land on table[p]
    expect = np.repeat(np.asarray(table, np.int32), 3)
    np.testing.assert_array_equal(got, expect)


@pytest.mark.parametrize("bits", [1, 2, 4, 8, 10])
def test_non_default_bit_counts(bits):
    table = round_robin_table(bits, 3)
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(100)]
    got, ref = run(hashes, table, bits=bits)
    np.testing.assert_array_equal(got, ref)
    assert set(got.tolist()) <= {0, 1, 2}


def test_extreme_hashes():
    table = round_robin_table(10, 4)
    got, ref = run([0, 1, 0x7FFFFFFF, 0x80000000, 0xFFFFFFFE, 0xFFFFFFFF],
                   table, bits=10)
    np.testing.assert_array_equal(got, ref)
    assert got[0] == table[0], "hash 0 is partition 0"
    assert got[-1] == table[-1], "hash MAX is the last partition"


def test_stale_epoch_table_still_gathers_exactly():
    # a rebalanced (non-round-robin) table from an older epoch: the
    # kernel must gather whatever owners the snapshot froze, not recompute
    rng = np.random.default_rng(7)
    table = rng.integers(0, 9, 1 << 10).astype(np.int32)
    hashes = rng.integers(0, 2**32, 2 * BLOCK).astype(np.uint32)
    got, ref = run(hashes, table, bits=10)
    np.testing.assert_array_equal(got, ref)


def test_gapped_live_node_ids():
    # after retire_node the table holds non-contiguous ids (e.g. node 1
    # retired): routing must surface the exact surviving ids
    table = [[0, 2, 3][p % 3] for p in range(1 << 8)]
    hashes = [murmur3_py(f"key-{i}".encode()) for i in range(150)]
    got, ref = run(hashes, table, bits=8)
    np.testing.assert_array_equal(got, ref)
    assert set(got.tolist()) <= {0, 2, 3}
    assert 1 not in got.tolist()


def test_padding_is_unobservable():
    # entries past 2^bits can hold anything — no hash reaches them
    bits = 4
    table = np.full(PT_CAP, 99, np.int32)
    table[: 1 << bits] = round_robin_table(bits, 3)
    hashes = np.asarray(
        [murmur3_py(f"key-{i}".encode()) for i in range(100)]
        + [0xFFFFFFFF], np.uint32)
    got, ref = run(hashes, table, bits=bits)
    np.testing.assert_array_equal(got, ref)
    assert not np.any(got == 99), "gather escaped the live table prefix"


@pytest.mark.parametrize("seed", range(12))
def test_matches_reference_random(seed):
    rng = np.random.default_rng(seed)
    bits = int(rng.integers(1, 11))
    nodes = int(rng.integers(1, 17))
    table = rng.integers(0, nodes, 1 << bits).astype(np.int32)
    hashes = rng.integers(0, 2**32, BLOCK).astype(np.uint32)
    got, ref = run(hashes, table, bits=bits)
    np.testing.assert_array_equal(got, ref)
