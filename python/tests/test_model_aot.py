"""L2 model programs + the AOT pipeline: route end-to-end vs references,
program shapes/dtypes, and HLO-text emission."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.murmur3 import pack_batch
from compile.kernels.ref import assign_ref, kprobe_ref, murmur3_py, ring_lookup_ref


def mini_ring(n_tokens, t, seed=7):
    rng = np.random.default_rng(seed)
    th = rng.choice(2**32, size=n_tokens, replace=False).astype(np.uint32)
    ow = rng.integers(0, 4, n_tokens).astype(np.int32)
    order = np.argsort(th, kind="stable")
    rh = np.full(t, 0xFFFFFFFF, np.uint32)
    ro = np.zeros(t, np.int32)
    rh[:n_tokens] = th[order]
    ro[:n_tokens] = ow[order]
    return rh, ro


def test_route_composes_hash_and_lookup():
    keys = [f"word-{i}".encode() for i in range(40)]
    b, w, t = 64, 8, 32
    words, lens = pack_batch(keys, b, w)
    rh, ro = mini_ring(12, t)
    hashes, owners = model.route(words, lens, jnp.asarray(rh), jnp.asarray(ro), jnp.int32(12))
    hashes, owners = np.array(hashes), np.array(owners)
    for i, k in enumerate(keys):
        assert int(hashes[i]) == murmur3_py(k)
    ref_owners = ring_lookup_ref(hashes[: len(keys)], rh, ro, 12)
    np.testing.assert_array_equal(owners[: len(keys)], ref_owners)


def test_route_probe_composes_hash_and_kprobe():
    keys = [f"word-{i}".encode() for i in range(40)]
    b, w = 64, 8
    words, lens = pack_batch(keys, b, w)
    rng = np.random.default_rng(3)
    ph = np.full(aot.P, 0xFFFFFFFF, np.uint32)
    pn = np.zeros(aot.P, np.int32)
    raw = np.sort(rng.choice(2**32, size=6, replace=False).astype(np.uint32))
    ph[:6] = raw
    pn[:6] = np.arange(6)
    over = np.zeros(aot.P, np.int32)
    over[2] = 1
    hashes, owners = model.route_probe(
        words, lens, jnp.asarray(ph), jnp.asarray(pn), jnp.int32(6),
        jnp.asarray(over), jnp.int32(4), max_probes=aot.K,
    )
    hashes, owners = np.array(hashes), np.array(owners)
    for i, k in enumerate(keys):
        assert int(hashes[i]) == murmur3_py(k)
    ref = kprobe_ref(hashes[: len(keys)], ph, pn, 6, over, 4)
    np.testing.assert_array_equal(owners[: len(keys)], ref)


def test_route_assign_composes_hash_and_table():
    keys = [f"word-{i}".encode() for i in range(40)]
    b, w = 64, 8
    words, lens = pack_batch(keys, b, w)
    tk = np.full(aot.A, 0xFFFFFFFF, np.uint32)
    to = np.zeros(aot.A, np.int32)
    # pin half of the keys in the table
    pinned = sorted(murmur3_py(k) for k in keys[:20])
    tk[:20] = np.asarray(pinned, np.uint32)
    to[:20] = np.arange(20) % 3
    loads = np.zeros(aot.P, np.uint32)
    loads[0] = 50
    # elastic membership: id 1 retired — candidates hash into the live list
    live = np.zeros(aot.P, np.int32)
    live[:3] = [0, 2, 3]
    hashes, owners = model.route_assign(
        words, lens, jnp.asarray(tk), jnp.asarray(to), jnp.int32(20),
        jnp.asarray(loads), jnp.asarray(live), jnp.int32(3),
    )
    hashes, owners = np.array(hashes), np.array(owners)
    ref = assign_ref(hashes[: len(keys)], tk, to, 20, loads, live, 3)
    np.testing.assert_array_equal(owners[: len(keys)], ref)


def test_reduce_count_and_merge_agree_with_semantics():
    counts = jnp.zeros(aot.V, jnp.uint32)
    ids = jnp.asarray([1, 1, 2, -1] + [-1] * 12, jnp.int32)
    (updated,) = model.reduce_count(counts, ids)
    updated = np.array(updated)
    assert updated[1] == 2 and updated[2] == 1 and updated.sum() == 3
    (merged,) = model.merge_state(jnp.asarray(updated), jnp.asarray(updated))
    assert np.array(merged)[1] == 4


def test_program_specs_lower_and_emit_hlo_text():
    for name, (fn, arg_specs) in aot.programs().items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert len(text) > 100, name
        # route programs expose 2 outputs, others 1 (tuple convention)
        n_out = len(jax.eval_shape(fn, *arg_specs))
        assert n_out == (2 if name.startswith("route") else 1)


def test_aot_writes_artifacts(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    for f in ["hash_only.hlo.txt", "route.hlo.txt", "route_probe.hlo.txt",
              "route_assign.hlo.txt", "reduce_count.hlo.txt",
              "merge_state.hlo.txt", "manifest.json"]:
        assert (out / f).exists(), f
    manifest = (out / "manifest.json").read_text()
    assert '"B": 256' in manifest and '"V": 4096' in manifest
    assert '"P": 64' in manifest and '"K": 8' in manifest and '"A": 4096' in manifest
    assert '"AV": 2' in manifest, "route_assign ABI version (elastic live list)"


def test_manifest_constants_are_consistent():
    assert aot.B % 64 == 0, "B must tile the murmur block"
    assert aot.V % 512 == 0, "V must tile the histogram block"
    assert aot.W * 4 == 32, "packed key limit documented as 32 bytes"
    # ring capacity covers the saturation cap: 4 nodes * 128 max tokens
    assert aot.T >= 4 * 128
