"""Murmur3 Pallas kernel vs references: published vectors, pure-python
oracle, pure-jnp reference, and hypothesis sweeps over key bytes/lengths
and kernel block shapes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.murmur3 import murmur3_kernel, pack_batch, pack_key
from compile.kernels.ref import murmur3_py, murmur3_ref

# Published MurmurHash3_x86_32 vectors (seed 0) — same set the rust tests
# pin (rust/src/hash/murmur3.rs).
VECTORS = [
    (b"", 0x00000000),
    (b"a", 0x3C2569B2),
    (b"abc", 0xB3DD93FA),
    (b"test", 0xBA6BD213),
    (b"hello", 0x248BFA47),
    (b"Hello, world!", 0xC0363E43),
    (b"The quick brown fox jumps over the lazy dog", None),  # 44 bytes > 32: py-ref only
]


def kernel_hash(keys, b=64, w=8, block_b=32):
    words, lens = pack_batch(keys, b, w)
    return np.array(murmur3_kernel(words, lens, block_b=block_b))[: len(keys)]


def test_python_reference_matches_published_vectors():
    for data, expect in VECTORS:
        if expect is not None:
            assert murmur3_py(data) == expect, data
    # non-zero seeds from the smhasher verification suite
    assert murmur3_py(b"", 1) == 0x514E28B7
    assert murmur3_py(b"", 0xFFFFFFFF) == 0x81F16F39
    assert murmur3_py(b"aaaa", 0x9747B28C) == 0x5A97808A


def test_kernel_matches_published_vectors():
    keys = [k for k, _ in VECTORS if len(k) <= 32]
    got = kernel_hash(keys)
    for k, h in zip(keys, got):
        assert int(h) == murmur3_py(k), k


def test_kernel_all_lengths_0_to_32():
    keys = [bytes(range(1, n + 1)) for n in range(33)]
    got = kernel_hash(keys, b=64)
    for k, h in zip(keys, got):
        assert int(h) == murmur3_py(k), f"len {len(k)}"


def test_kernel_matches_jnp_reference():
    keys = [f"key-{i}".encode() for i in range(50)]
    words, lens = pack_batch(keys, 64, 8)
    kern = np.array(murmur3_kernel(words, lens, block_b=32))
    ref = np.array(murmur3_ref(words, lens))
    np.testing.assert_array_equal(kern, ref)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=32), min_size=1, max_size=64))
def test_kernel_matches_python_on_random_bytes(keys):
    got = kernel_hash(keys, b=64)
    for k, h in zip(keys, got):
        assert int(h) == murmur3_py(k)


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(8, 2), (16, 4), (32, 8), (64, 16), (128, 8)]),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_kernel_shape_sweep(shape, seed):
    """The kernel is correct for any (B, block_b) divisible pairing and any
    W big enough for the keys."""
    b, block_b = shape
    rng = np.random.default_rng(seed)
    keys = [bytes(rng.integers(0, 256, rng.integers(0, 33)).astype(np.uint8)) for _ in range(b)]
    w = 8
    words, lens = pack_batch(keys, b, w)
    got = np.array(murmur3_kernel(words, lens, block_b=block_b))
    for k, h in zip(keys, got):
        assert int(h) == murmur3_py(k)


def test_pack_key_layout_matches_rust_contract():
    words, ln = pack_key(b"abcdef", 8)
    assert ln == 6
    assert words[0] == int.from_bytes(b"abcd", "little")
    assert words[1] == int.from_bytes(b"ef\0\0", "little")
    assert all(w == 0 for w in words[2:])
    with pytest.raises(AssertionError):
        pack_key(b"x" * 33, 8)


def test_hash_dispersion_over_token_names():
    # the ring hashes "token-{i}-{j}" strings; they must not collide
    names = [f"token-{i}-{j}".encode() for i in range(4) for j in range(8)]
    hashes = set(kernel_hash(names, b=64).tolist())
    assert len(hashes) == len(names)
