"""Ring lookup (L2 searchsorted path) vs the linear-scan oracle:
boundaries, wraparound, padding and hypothesis sweeps."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ring_lookup_ref
from compile.model import ring_lookup


def run(hashes, ring_hashes, owners, live):
    return np.array(
        ring_lookup(
            jnp.asarray(hashes, jnp.uint32),
            jnp.asarray(ring_hashes, jnp.uint32),
            jnp.asarray(owners, jnp.int32),
            jnp.int32(live),
        )
    )


def padded_ring(token_hashes, owners, t):
    rh = np.full(t, 0xFFFFFFFF, np.uint32)
    ro = np.zeros(t, np.int32)
    order = np.argsort(token_hashes, kind="stable")
    rh[: len(token_hashes)] = np.asarray(token_hashes, np.uint32)[order]
    ro[: len(token_hashes)] = np.asarray(owners, np.int32)[order]
    return rh, ro


def test_exact_and_adjacent_hashes():
    rh, ro = padded_ring([100, 200, 300], [0, 1, 2], 8)
    live = 3
    # exactly at a token -> that token
    assert run([100], rh, ro, live)[0] == 0
    assert run([200], rh, ro, live)[0] == 1
    # just above -> next clockwise
    assert run([101], rh, ro, live)[0] == 1
    # below the smallest -> first token
    assert run([5], rh, ro, live)[0] == 0


def test_wraparound_past_largest_token():
    rh, ro = padded_ring([100, 200, 300], [0, 1, 2], 8)
    assert run([301], rh, ro, 3)[0] == 0
    assert run([0xFFFFFFFF], rh, ro, 3)[0] == 0


def test_padding_never_selected():
    rh, ro = padded_ring([100], [3], 16)
    got = run(np.linspace(0, 2**32 - 1, 50, dtype=np.uint64).astype(np.uint32), rh, ro, 1)
    assert (got == 3).all(), "single-token ring owns everything"


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=1, max_value=30),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_linear_oracle(live, seed):
    rng = np.random.default_rng(seed)
    token_hashes = rng.choice(2**32, size=live, replace=False).astype(np.uint32)
    owners = rng.integers(0, 4, live).astype(np.int32)
    rh, ro = padded_ring(token_hashes, owners, 32)
    hashes = rng.integers(0, 2**32, 200).astype(np.uint32)
    got = run(hashes, rh, ro, live)
    ref = ring_lookup_ref(hashes, rh, ro, live)
    np.testing.assert_array_equal(got, ref)


def test_duplicate_token_hashes_take_first():
    # tie contract: rust pre-sorts by (hash, node, idx); lookup must take
    # the first of equals (searchsorted side='left')
    rh, ro = padded_ring([100, 100, 200], [2, 1, 0], 8)
    # after the stable sort by hash the order of owners at 100 is (2, 1)
    # as given; side='left' returns index of the first
    assert run([100], rh, ro, 3)[0] == ro[0]
    assert run([99], rh, ro, 3)[0] == ro[0]
