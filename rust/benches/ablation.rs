//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! - trigger policy: the paper's Eq. 1 (max vs second-max) vs a
//!   mean-ratio variant vs never;
//! - τ sensitivity (the paper's §6.3 discussion of threshold trade-offs);
//! - load-report cadence (staleness vs trigger latency);
//! - cost model: mapper/reducer speed ratio — demonstrates the
//!   premature-trigger pathology the paper attributes to distributed
//!   indeterminism;
//! - consistency mode: merge-at-end vs §7 state forwarding overhead.
//!
//! ```sh
//! cargo bench --bench ablation
//! ```

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::balancer::policy::{MeanRatioPolicy, NeverPolicy, ThresholdPolicy};
use dpa::balancer::state_forward::ConsistencyMode;
use dpa::balancer::BalancerCore;
use dpa::exec::builtin::{IdentityMap, WordCount};
use dpa::hash::{RouterHandle, Strategy};
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::sim::{SimDriver, SimParams};
use dpa::util::stats::Summary;
use dpa::util::table::{f2, Table};
use dpa::workload::{generators, paperwl};
use std::sync::Arc;

fn base_cfg(strategy: Strategy) -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.strategy = strategy;
    cfg.initial_tokens = Some(strategy.initial_tokens(8));
    cfg.max_rounds = 2;
    cfg
}

fn mean_skew_with(cfg: &PipelineConfig, items: &[String], seeds: &[u64]) -> f64 {
    let p = Pipeline::wordcount(cfg.clone());
    let reports = p.run_seeds(items, seeds).unwrap();
    Summary::from_slice(&reports.iter().map(|r| r.skew()).collect::<Vec<_>>()).mean()
}

fn main() {
    dpa::util::logger::init();
    let seeds: Vec<u64> = (0..5).collect();

    // ---- A. policy ablation (direct BalancerCore wiring) -----------------
    println!("== A. trigger policy (WL4, doubling layout, 5 seeds) ==");
    let w = paperwl::wl4();
    let mut t = Table::new(["policy", "mean S", "mean LB events"]);
    type PolicyCtor = Box<dyn Fn() -> Box<dyn dpa::balancer::policy::LbPolicy + Send>>;
    let policies: Vec<(&str, PolicyCtor)> = vec![
        ("eq1 (paper)", Box::new(|| Box::new(ThresholdPolicy::new(0.2, 8)))),
        (
            "mean-ratio",
            Box::new(|| Box::new(MeanRatioPolicy::new(0.2, 8))),
        ),
        ("never", Box::new(|| Box::new(NeverPolicy))),
    ];
    for (name, ctor) in &policies {
        let mut skews = Summary::new();
        let mut events = Summary::new();
        for &seed in &seeds {
            let router = RouterHandle::new(Strategy::Doubling.build_router(4, 8, None));
            let balancer = BalancerCore::new(router, Strategy::Doubling, 0.2, 8, 2, 50)
                .with_policy(ctor());
            let driver = SimDriver::new(SimParams { seed, ..Default::default() });
            let factory: dpa::exec::ReduceFactory =
                Arc::new(|_| Box::new(WordCount::new()) as _);
            let r = driver.run(Arc::new(IdentityMap), &factory, 4, balancer, w.items.clone());
            skews.push(r.skew());
            events.push(r.lb_events.len() as f64);
        }
        t.row([name.to_string(), f2(skews.mean()), f2(events.mean())]);
    }
    t.print();

    // ---- B. τ sweep -------------------------------------------------------
    println!("\n== B. τ sensitivity (WL1, doubling; paper fixes τ=0.2) ==");
    let w = paperwl::wl1();
    let mut t = Table::new(["τ", "mean S (doubling)"]);
    for tau in [0.0, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0] {
        let mut cfg = base_cfg(Strategy::Doubling);
        cfg.tau = tau;
        t.row([format!("{tau:.1}"), f2(mean_skew_with(&cfg, &w.items, &seeds))]);
    }
    t.print();

    // ---- C. report cadence -------------------------------------------------
    println!("\n== C. load-report interval (WL4, halving) ==");
    let w = paperwl::wl4();
    let mut t = Table::new(["report every N msgs", "mean S (halving)"]);
    for interval in [1u64, 2, 4, 8, 16, 64] {
        let mut cfg = base_cfg(Strategy::Halving);
        cfg.report_interval = interval;
        t.row([interval.to_string(), f2(mean_skew_with(&cfg, &w.items, &seeds))]);
    }
    t.print();

    // ---- D. cost-model: premature triggers ---------------------------------
    println!("\n== D. mapper speed vs premature triggers (WL2 — uniform!) ==");
    println!("fast mappers flood queues; stale load reports then satisfy Eq.1");
    println!("on a workload with NO real skew (the paper's §6.3 anomaly):");
    let w = paperwl::wl2();
    let mut t = Table::new(["map_cost (reduce=5)", "mean S halving", "mean S doubling"]);
    for map_cost in [1u64, 2, 4] {
        let mut row = vec![map_cost.to_string()];
        for strategy in Strategy::methods() {
            let mut cfg = base_cfg(strategy);
            cfg.sim_costs.map_cost = map_cost;
            row.push(f2(mean_skew_with(&cfg, &w.items, &seeds)));
        }
        t.row(row);
    }
    t.print();

    // ---- E. consistency-mode overhead ---------------------------------------
    println!("\n== E. merge-at-end vs §7 state forwarding (zipf 2k, doubling) ==");
    let w = generators::zipf(2000, 150, 1.3, 3);
    let mut t = Table::new(["mode", "mean S", "mean virtual end (ticks)"]);
    for (name, mode) in [
        ("merge-at-end", ConsistencyMode::MergeAtEnd),
        ("state-forward", ConsistencyMode::StateForward),
    ] {
        let mut skews = Summary::new();
        let mut vtime = Summary::new();
        for &seed in &seeds {
            let mut cfg = base_cfg(Strategy::Doubling);
            cfg.mode = mode;
            cfg.seed = seed;
            let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
            skews.push(r.skew());
            vtime.push(r.virtual_end as f64);
        }
        t.row([name.to_string(), f2(skews.mean()), format!("{:.0}", vtime.mean())]);
    }
    t.print();
}
