//! Bench target for **Figure 3 / Experiment 2**: skew S as a function of
//! the maximum LB rounds allowed per reducer (1..=4), per workload ×
//! method. The paper's qualitative findings to check against:
//!
//! 1. extra rounds help at least one method on every workload;
//! 2. WL1/WL2 can recover in round 2 from skew introduced in round 1;
//! 3. extra rounds never hurt halving, but can hurt doubling (token
//!    reshuffling reintroduces skew).
//!
//! ```sh
//! cargo bench --bench fig3
//! ```

use dpa::cli::mean_skew;
use dpa::hash::Strategy;
use dpa::util::table::f2;
use dpa::util::table::Table;
use dpa::workload::paperwl;

fn main() {
    dpa::util::logger::init();
    let max_rounds = 4u32;
    let seeds = 3;
    println!("Experiment 2 (Figure 3): S vs max LB rounds/reducer (τ=0.2, {seeds} seeds)\n");

    let mut header = vec!["Workload".to_string(), "Method".to_string(), "r=0 (noLB)".to_string()];
    for r in 1..=max_rounds {
        header.push(format!("r={r}"));
    }
    let mut t = Table::new(header);

    let mut halving_monotone = true;
    let mut doubling_hurt_somewhere = false;
    for w in paperwl::all() {
        for strategy in Strategy::methods() {
            let mut row = vec![w.name.clone(), strategy.to_string()];
            let (s0, _) = mean_skew(&w, strategy, false, 1, seeds).unwrap();
            row.push(f2(s0));
            let mut series = Vec::new();
            for rounds in 1..=max_rounds {
                let (s, _) = mean_skew(&w, strategy, true, rounds, seeds).unwrap();
                series.push(s);
                row.push(f2(s));
            }
            t.row(row);
            for win in series.windows(2) {
                match strategy {
                    Strategy::Halving if win[1] > win[0] + 0.02 => halving_monotone = false,
                    Strategy::Doubling if win[1] > win[0] + 0.02 => doubling_hurt_somewhere = true,
                    _ => {}
                }
            }
        }
    }
    t.print();
    println!("\npaper-shape checks:");
    println!(
        "- additional rounds never hurt halving: {}",
        if halving_monotone { "HOLDS" } else { "violated (see table)" }
    );
    println!(
        "- additional rounds can hurt doubling: {}",
        if doubling_hurt_somewhere { "observed" } else { "not observed on these seeds" }
    );
}
