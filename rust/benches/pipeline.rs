//! End-to-end pipeline throughput benchmarks: sim-driver execution speed
//! (events/s of the DES itself), threads-driver wall throughput across
//! reducer counts and queue capacities, and queue op costs.
//!
//! ```sh
//! cargo bench --bench pipeline
//! ```

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use std::sync::Arc;
use std::time::Duration;

use dpa::benchkit::{black_box, Bench};
use dpa::exec::Record;
use dpa::hash::Strategy;
use dpa::pipeline::{DriverKind, Pipeline, PipelineConfig};
use dpa::queue::DataQueue;
use dpa::workload::generators;

fn main() {
    dpa::util::logger::init();
    let mut bench = Bench::quick();

    // --- queue substrate -------------------------------------------------
    let q = DataQueue::new(1 << 16);
    bench.run("queue push+pop 10k", Some(10_000), || {
        for i in 0..10_000 {
            q.push(Record::new("k", i));
        }
        while q.try_pop().is_some() {}
    });

    // --- sim driver ------------------------------------------------------
    // inputs are Arc-shared: re-running a pipeline costs zero input copies
    let items: Arc<[String]> = generators::zipf(10_000, 300, 1.2, 5).items.into();
    for strategy in [Strategy::None, Strategy::Doubling] {
        let mut cfg = PipelineConfig::default();
        cfg.strategy = strategy;
        cfg.initial_tokens = Some(1);
        cfg.max_rounds = 2;
        let p = Pipeline::wordcount(cfg);
        let name = format!("sim 10k items ({strategy})");
        bench.run(&name, Some(10_000), || {
            black_box(p.run(items.clone()).unwrap());
        });
    }

    // --- threads driver: scaling in reducers ------------------------------
    let items: Arc<[String]> = generators::zipf(20_000, 300, 1.2, 6).items.into();
    for reducers in [2usize, 4, 8] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = DriverKind::Threads;
        cfg.reducers = reducers;
        cfg.mappers = 4;
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(1);
        cfg.reduce_delay_us = 0;
        let p = Pipeline::wordcount(cfg);
        let name = format!("threads 20k items, {reducers} reducers");
        bench.run(&name, Some(20_000), || {
            black_box(p.run(items.clone()).unwrap());
        });
    }

    // --- threads driver: report-heavy regime ------------------------------
    // report_interval=1 sends a load report for every handled message —
    // the worst case for the old Mutex<BalancerCore> design, now a
    // lock-free channel drained by a dedicated balancer thread. Compare
    // against the interval=2 default runs above: throughput must not
    // regress when reporting saturates.
    let items: Arc<[String]> = generators::zipf(20_000, 300, 1.2, 6).items.into();
    for interval in [1u64, 2] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = DriverKind::Threads;
        cfg.reducers = 4;
        cfg.mappers = 4;
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(1);
        cfg.reduce_delay_us = 0;
        cfg.report_interval = interval;
        let p = Pipeline::wordcount(cfg);
        let name = format!("threads 20k items, report_interval={interval}");
        bench.run(&name, Some(20_000), || {
            black_box(p.run(items.clone()).unwrap());
        });
    }

    // --- threads driver: compute-heavy regime (the paper's target) --------
    let items: Arc<[String]> = generators::zipf(2_000, 300, 1.2, 7).items.into();
    for (label, delay) in [("5µs", 5u64), ("50µs", 50)] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = DriverKind::Threads;
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(1);
        cfg.reduce_delay_us = delay;
        let p = Pipeline::wordcount(cfg);
        let name = format!("threads 2k items, reduce={label}");
        bench.run(&name, Some(2_000), || {
            black_box(p.run(items.clone()).unwrap());
        });
    }

    // --- chunk-size ablation ----------------------------------------------
    let items: Arc<[String]> = generators::zipf(10_000, 300, 1.2, 8).items.into();
    for chunk in [1usize, 10, 100] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = DriverKind::Threads;
        cfg.chunk_size = chunk;
        cfg.reduce_delay_us = 0;
        let p = Pipeline::wordcount(cfg);
        let name = format!("threads 10k items, chunk={chunk}");
        bench.run(&name, Some(10_000), || {
            black_box(p.run(items.clone()).unwrap());
        });
    }

    bench.print();
    // give the condvar-parked reducer threads a beat to exit cleanly
    std::thread::sleep(Duration::from_millis(50));
}
