//! Microbenchmarks of the routing substrate: murmur3, ring lookup at
//! various token counts, redistribution cost, and the shared-ring access
//! paths (RwLock vs epoch-cached snapshot) that the §Perf pass compares.
//!
//! ```sh
//! cargo bench --bench ring
//! ```

use dpa::benchkit::{black_box, Bench};
use dpa::hash::ring::RingCache;
use dpa::hash::{murmur3_x86_32, Ring, SharedRing};
use dpa::util::prng::Xoshiro256;

fn main() {
    dpa::util::logger::init();
    let mut bench = Bench::new();

    // keys of realistic routing size
    let mut rng = Xoshiro256::new(1);
    let keys: Vec<String> = (0..10_000)
        .map(|i| format!("key-{}-{}", i, rng.next_u64() % 1000))
        .collect();

    bench.run("murmur3 10k keys", Some(10_000), || {
        let mut acc = 0u32;
        for k in &keys {
            acc ^= murmur3_x86_32(k.as_bytes());
        }
        black_box(acc);
    });

    for tokens_per_node in [1u32, 8, 32, 128] {
        let ring = Ring::new(4, tokens_per_node);
        let name = format!("ring lookup 10k keys, T={}", ring.total_tokens());
        bench.run(&name, Some(10_000), || {
            let mut acc = 0usize;
            for k in &keys {
                acc ^= ring.lookup(k.as_bytes());
            }
            black_box(acc);
        });
    }

    // pre-hashed lookup isolates the binary search
    let ring = Ring::new(4, 32);
    let hashes: Vec<u32> = keys.iter().map(|k| murmur3_x86_32(k.as_bytes())).collect();
    bench.run("ring lookup_hash 10k (T=128)", Some(10_000), || {
        let mut acc = 0usize;
        for &h in &hashes {
            acc ^= ring.lookup_hash(h);
        }
        black_box(acc);
    });

    // shared-ring access paths
    let shared = SharedRing::new(Ring::new(4, 32));
    bench.run("SharedRing (RwLock) 10k lookups", Some(10_000), || {
        let mut acc = 0usize;
        for k in &keys {
            acc ^= shared.lookup(k.as_bytes());
        }
        black_box(acc);
    });
    let mut cache = RingCache::new(shared.clone());
    bench.run("RingCache (epoch) 10k lookups", Some(10_000), || {
        let mut acc = 0usize;
        for k in &keys {
            acc ^= cache.lookup(k.as_bytes());
        }
        black_box(acc);
    });

    // redistribution cost (rebuild + sort)
    bench.run("halve+rebuild (T=512)", None, || {
        let mut ring = Ring::new(4, 128);
        black_box(ring.halve(2));
    });
    bench.run("double_others+rebuild (1->2 tokens)", None, || {
        let mut ring = Ring::new(4, 1);
        black_box(ring.double_others(0));
    });

    bench.print();
}
