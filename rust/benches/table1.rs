//! Bench target for **Table 1 / Experiment 1**: regenerates the paper's
//! skew table (5 workloads × {halving, doubling} × {no LB, LB}, τ = 0.2,
//! ≤ 1 LB round per reducer, mean of 3 seeded runs) and prints the paper's
//! published values next to ours.
//!
//! ```sh
//! cargo bench --bench table1
//! ```

use dpa::cli::mean_skew;
use dpa::hash::Strategy;
use dpa::util::table::{delta2, f2, Table};
use dpa::workload::paperwl;

/// The paper's published Table 1, for side-by-side comparison.
/// (workload, method) -> (no_lb, with_lb)
fn paper_values(wl: &str, m: Strategy) -> (f64, f64) {
    match (wl, m) {
        ("WL1", Strategy::Halving) => (0.00, 0.08),
        ("WL1", Strategy::Doubling) => (1.00, 0.20),
        ("WL2", Strategy::Halving) => (0.00, 0.00),
        ("WL2", Strategy::Doubling) => (0.00, 0.08),
        ("WL3", Strategy::Halving) => (1.00, 1.00),
        ("WL3", Strategy::Doubling) => (1.00, 0.75),
        ("WL4", Strategy::Halving) => (0.80, 0.52),
        ("WL4", Strategy::Doubling) => (0.49, 0.11),
        ("WL5", Strategy::Halving) => (0.20, 0.20),
        ("WL5", Strategy::Doubling) => (0.55, 0.12),
        _ => (f64::NAN, f64::NAN),
    }
}

fn main() {
    dpa::util::logger::init();
    let seeds = 3;
    println!("Experiment 1 (Table 1): S with/without LB — ours vs paper");
    println!("setup: 4 mappers, 4 reducers, τ=0.2, ≤1 round/reducer, {seeds} seeds\n");

    let mut t = Table::new([
        "Workload", "Method", "No LB", "(paper)", "With LB", "(paper)", "Δ", "(paper Δ)",
    ]);
    let mut shape_ok = 0usize;
    let mut shape_total = 0usize;
    for w in paperwl::all() {
        for strategy in Strategy::methods() {
            let (p_nolb, p_lb) = paper_values(&w.name, strategy);
            let (s_nolb, _) = mean_skew(&w, strategy, false, 1, seeds).unwrap();
            let (s_lb, _) = mean_skew(&w, strategy, true, 1, seeds).unwrap();
            let ours_delta = s_nolb - s_lb;
            let paper_delta = p_nolb - p_lb;
            // "shape" agreement: Δ sign matches (or both negligible)
            shape_total += 1;
            if (ours_delta.abs() < 0.15 && paper_delta.abs() < 0.15)
                || (ours_delta.signum() == paper_delta.signum()
                    && ours_delta.abs() >= 0.1
                    && paper_delta.abs() >= 0.1)
            {
                shape_ok += 1;
            }
            t.row([
                w.name.clone(),
                strategy.to_string(),
                f2(s_nolb),
                f2(p_nolb),
                f2(s_lb),
                f2(p_lb),
                delta2(ours_delta),
                delta2(paper_delta),
            ]);
        }
    }
    t.print();
    println!(
        "\nshape agreement (Δ direction/magnitude class): {shape_ok}/{shape_total}"
    );
}
