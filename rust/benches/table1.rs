//! Bench target for **Table 1 / Experiment 1**: regenerates the paper's
//! skew table (5 workloads × {halving, doubling} × {no LB, LB}, τ = 0.2,
//! ≤ 1 LB round per reducer, mean of 3 seeded runs) and prints the paper's
//! published values next to ours. The partition-table family rides along
//! as an extra row block (no published paper column — its cells bootstrap
//! un-gated until a baseline containing them is committed).
//!
//! ```sh
//! cargo bench --bench table1
//! ```
//!
//! CI smoke knobs (all via environment, used by the `bench-smoke` job):
//!
//! - `DPA_BENCH_SEEDS=N`     — seeded runs per cell (default 3; CI uses 1)
//! - `DPA_BENCH_JSON=PATH`   — write the measured cells as flat JSON:
//!   skew (`"WL1/halving/no_lb": 0.00`, `"…/with_lb": …`) and
//!   redistribution counts (`"…/migrations": …` for the LB run,
//!   `"…/migrations_no_lb": …` — provably 0 — for the no-LB run)
//! - `DPA_BENCH_BASELINE=PATH` — compare against a checked-in baseline
//!   JSON of the same shape; exit non-zero if any cell drifts more than
//!   its tolerance. A cell-less baseline skips the gate (bootstrap:
//!   commit a CI-produced `BENCH_table1.json` as the baseline — the sim
//!   is deterministic per seed, so values reproduce across machines); a
//!   *partial* baseline gates exactly the cells it contains.
//! - `DPA_BENCH_TOLERANCE=F` — max |S - baseline| per skew cell
//!   (default 0.05)
//! - `DPA_BENCH_MIG_TOLERANCE=F` — max |migrations - baseline| per
//!   migration cell (default 0: the sim is deterministic, so any drift
//!   in how often the balancer repartitions is a behavior change)

use std::collections::BTreeMap;
use std::fmt::Write as _;

use dpa::cli::cell_stats;
use dpa::hash::Strategy;
use dpa::pipeline::DriverKind;
use dpa::util::table::{delta2, f2, Table};
use dpa::workload::paperwl;

/// The paper's published Table 1, for side-by-side comparison.
/// (workload, method) -> (no_lb, with_lb)
fn paper_values(wl: &str, m: Strategy) -> (f64, f64) {
    match (wl, m) {
        ("WL1", Strategy::Halving) => (0.00, 0.08),
        ("WL1", Strategy::Doubling) => (1.00, 0.20),
        ("WL2", Strategy::Halving) => (0.00, 0.00),
        ("WL2", Strategy::Doubling) => (0.00, 0.08),
        ("WL3", Strategy::Halving) => (1.00, 1.00),
        ("WL3", Strategy::Doubling) => (1.00, 0.75),
        ("WL4", Strategy::Halving) => (0.80, 0.52),
        ("WL4", Strategy::Doubling) => (0.49, 0.11),
        ("WL5", Strategy::Halving) => (0.20, 0.20),
        ("WL5", Strategy::Doubling) => (0.55, 0.12),
        _ => (f64::NAN, f64::NAN),
    }
}

fn env_parse<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Serialize the measured cells as flat JSON (BTreeMap: stable order).
fn to_json(seeds: usize, cells: &BTreeMap<String, f64>) -> String {
    let mut out = String::from("{\n");
    let _ = writeln!(out, "  \"seeds\": {seeds},");
    let n = cells.len();
    for (i, (k, v)) in cells.iter().enumerate() {
        let comma = if i + 1 == n { "" } else { "," };
        let _ = writeln!(out, "  \"{k}\": {v:.6}{comma}");
    }
    out.push_str("}\n");
    out
}

/// Parse flat `{"key": float, ...}` JSON (the format `to_json` writes).
fn parse_json(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let inner = text
        .trim()
        .strip_prefix('{')
        .and_then(|t| t.strip_suffix('}'))
        .ok_or("baseline is not a JSON object")?;
    let mut map = BTreeMap::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        // split on the LAST ':' — cell keys may themselves contain one
        // (the `multiprobe:K` strategy spelling), values never do
        let (k, v) = part.rsplit_once(':').ok_or("expected \"key\": value")?;
        let v: f64 = v.trim().parse().map_err(|e| format!("bad value for {k}: {e}"))?;
        map.insert(k.trim().trim_matches('"').to_string(), v);
    }
    Ok(map)
}

/// Gate the measured cells against a baseline. Returns drift messages
/// (empty = pass). Only `workload/method/column` keys participate;
/// migration-count cells are bounded by their own (tighter) tolerance.
fn compare_baseline(
    baseline: &BTreeMap<String, f64>,
    cells: &BTreeMap<String, f64>,
    tol: f64,
    mig_tol: f64,
) -> Vec<String> {
    let mut drifts = Vec::new();
    for (k, &base) in baseline.iter().filter(|(k, _)| k.contains('/')) {
        let (bound, what) = if k.contains("/migrations") {
            (mig_tol, "migrations")
        } else {
            (tol, "S")
        };
        match cells.get(k) {
            None => drifts.push(format!("cell '{k}' missing from this run")),
            Some(&cur) if (cur - base).abs() > bound => drifts.push(format!(
                "{k}: {what} = {cur:.3} drifted from baseline {base:.3} (±{bound})"
            )),
            Some(_) => {}
        }
    }
    drifts
}

fn main() {
    dpa::util::logger::init();
    let seeds: usize = env_parse("DPA_BENCH_SEEDS", 3).max(1);
    println!("Experiment 1 (Table 1): S with/without LB — ours vs paper");
    println!("setup: 4 mappers, 4 reducers, τ=0.2, ≤1 round/reducer, {seeds} seeds\n");

    let mut t = Table::new([
        "Workload", "Method", "No LB", "(paper)", "With LB", "(paper)", "Δ", "(paper Δ)", "migr",
    ]);
    let mut cells: BTreeMap<String, f64> = BTreeMap::new();
    let mut shape_ok = 0usize;
    let mut shape_total = 0usize;
    let extended = [Strategy::Ptable {
        bits: dpa::hash::DEFAULT_PTABLE_BITS,
        replicas: dpa::hash::DEFAULT_PTABLE_REPLICAS,
    }];
    for w in paperwl::all() {
        for strategy in Strategy::methods().into_iter().chain(extended) {
            let (p_nolb, p_lb) = paper_values(&w.name, strategy);
            let nolb = cell_stats(&w, strategy, DriverKind::Sim, false, 1, seeds).unwrap();
            let lb = cell_stats(&w, strategy, DriverKind::Sim, true, 1, seeds).unwrap();
            let (s_nolb, s_lb) = (nolb.skew, lb.skew);
            cells.insert(format!("{}/{strategy}/no_lb", w.name), s_nolb);
            cells.insert(format!("{}/{strategy}/with_lb", w.name), s_lb);
            cells.insert(format!("{}/{strategy}/migrations", w.name), lb.migrations);
            cells.insert(
                format!("{}/{strategy}/migrations_no_lb", w.name),
                nolb.migrations,
            );
            let ours_delta = s_nolb - s_lb;
            let paper_delta = p_nolb - p_lb;
            // "shape" agreement: Δ sign matches (or both negligible) —
            // only for cells the paper actually published
            if paper_delta.is_finite() {
                shape_total += 1;
                if (ours_delta.abs() < 0.15 && paper_delta.abs() < 0.15)
                    || (ours_delta.signum() == paper_delta.signum()
                        && ours_delta.abs() >= 0.1
                        && paper_delta.abs() >= 0.1)
                {
                    shape_ok += 1;
                }
            }
            let paper_col = |v: f64| if v.is_finite() { f2(v) } else { "—".into() };
            t.row([
                w.name.clone(),
                strategy.to_string(),
                f2(s_nolb),
                paper_col(p_nolb),
                f2(s_lb),
                paper_col(p_lb),
                delta2(ours_delta),
                if paper_delta.is_finite() { delta2(paper_delta) } else { "—".into() },
                format!("{:.1}", lb.migrations),
            ]);
        }
    }
    t.print();
    println!(
        "\nshape agreement (Δ direction/magnitude class): {shape_ok}/{shape_total}"
    );

    if let Ok(path) = std::env::var("DPA_BENCH_JSON") {
        std::fs::write(&path, to_json(seeds, &cells)).expect("writing bench JSON");
        println!("wrote {path}");
    }

    if let Ok(path) = std::env::var("DPA_BENCH_BASELINE") {
        let tol: f64 = env_parse("DPA_BENCH_TOLERANCE", 0.05);
        let mig_tol: f64 = env_parse("DPA_BENCH_MIG_TOLERANCE", 0.0);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading baseline {path}: {e}"));
        let baseline = parse_json(&text).expect("parsing baseline JSON");
        // cells are per-seed-count means: comparing across different
        // DPA_BENCH_SEEDS would gate on cross-seed variance, not drift
        if let Some(&bs) = baseline.get("seeds") {
            if bs as usize != seeds {
                eprintln!(
                    "bench gate FAILED: baseline was recorded with seeds={} but this \
                     run used seeds={seeds} — regenerate the baseline with matching \
                     DPA_BENCH_SEEDS",
                    bs as usize
                );
                std::process::exit(1);
            }
        }
        if !baseline.keys().any(|k| k.contains('/')) {
            println!(
                "baseline {path} has no cells — bootstrap run, gate skipped \
                 (commit a produced BENCH_table1.json as the baseline to arm it)"
            );
            return;
        }
        let drifts = compare_baseline(&baseline, &cells, tol, mig_tol);
        if drifts.is_empty() {
            let n = baseline.keys().filter(|k| k.contains('/')).count();
            println!(
                "bench gate: all {n} baseline cells within tolerance \
                 (S ±{tol}, migrations ±{mig_tol})"
            );
        } else {
            eprintln!("bench gate FAILED (S ±{tol}, migrations ±{mig_tol}):");
            for d in &drifts {
                eprintln!("  {d}");
            }
            std::process::exit(1);
        }
    }
}
