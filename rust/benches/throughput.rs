//! Bench target for the **lock-free batched hot path**: records/sec and
//! p50/p99 per-record latency for every router family × both drivers ×
//! a uniform and a Zipf-skewed stream. This is the throughput axis the
//! hot-path work (epoch-published router snapshots, lock-free sticky
//! table, batched queue drain) is proved on — the table1 bench gates the
//! *quality* metric (skew S), this one gates the *speed* metric.
//!
//! ```sh
//! cargo bench --bench throughput
//! ```
//!
//! Per cell: the pipeline runs with busy-work delays zeroed (the routing
//! and queue machinery IS the workload), wall time is measured on the
//! host clock around the whole run — for the sim driver too, so
//! records/sec is always real-time event-processing rate — and per-record
//! latency (map-enqueue → reduce) comes from the run report's bucketed
//! histogram (µs on threads, virtual ticks on the sim).
//!
//! CI smoke knobs (all via environment, used by the `bench-smoke` job):
//!
//! - `DPA_BENCH_SEEDS=N`   — seeded runs per cell (default 3; CI uses 1)
//! - `DPA_BENCH_ITEMS=N`   — stream length per run (default 40000)
//! - `DPA_BENCH_JSON=PATH` — write the measured cells as flat JSON:
//!   `"family/driver/workload/rps"` plus `/p50` and `/p99`
//! - `DPA_BENCH_BASELINE=PATH` — compare against a checked-in baseline of
//!   the same shape; exit non-zero if any `rps` cell regresses more than
//!   the relative tolerance. Latency cells are recorded but not gated
//!   (units differ across machines and drivers). A cell-less baseline
//!   skips the gate (bootstrap: commit a CI-produced
//!   `BENCH_throughput.json` as the baseline to arm it).
//! - `DPA_BENCH_RPS_TOLERANCE=F` — max relative records/sec regression
//!   before the gate fails (default 0.10 = 10%)

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

use dpa::hash::Strategy;
use dpa::pipeline::{DriverKind, Pipeline, PipelineConfig};
use dpa::util::table::Table;
use dpa::workload::{generators, Workload};

fn env_parse<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Serialize the measured cells as flat JSON (BTreeMap: stable order).
fn to_json(seeds: usize, cells: &BTreeMap<String, f64>) -> String {
    let mut out = String::from("{\n");
    let _ = writeln!(out, "  \"seeds\": {seeds},");
    let n = cells.len();
    for (i, (k, v)) in cells.iter().enumerate() {
        let comma = if i + 1 == n { "" } else { "," };
        let _ = writeln!(out, "  \"{k}\": {v:.6}{comma}");
    }
    out.push_str("}\n");
    out
}

/// Parse flat `{"key": float, ...}` JSON (the format `to_json` writes).
fn parse_json(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let inner = text
        .trim()
        .strip_prefix('{')
        .and_then(|t| t.strip_suffix('}'))
        .ok_or("baseline is not a JSON object")?;
    let mut map = BTreeMap::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        // split on the LAST ':' — cell keys may themselves contain one
        // (the `multiprobe:K` strategy spelling), values never do
        let (k, v) = part.rsplit_once(':').ok_or("expected \"key\": value")?;
        let v: f64 = v.trim().parse().map_err(|e| format!("bad value for {k}: {e}"))?;
        map.insert(k.trim().trim_matches('"').to_string(), v);
    }
    Ok(map)
}

/// Gate the measured `rps` cells against a baseline, RELATIVELY: a cell
/// fails when it regresses below `baseline * (1 - tol)`. Faster-than-
/// baseline never fails (refresh the baseline to bank an improvement).
fn compare_baseline(
    baseline: &BTreeMap<String, f64>,
    cells: &BTreeMap<String, f64>,
    tol: f64,
) -> Vec<String> {
    let mut drifts = Vec::new();
    for (k, &base) in baseline.iter().filter(|(k, _)| k.ends_with("/rps")) {
        match cells.get(k) {
            None => drifts.push(format!("cell '{k}' missing from this run")),
            Some(&cur) if cur < base * (1.0 - tol) => drifts.push(format!(
                "{k}: {cur:.0} rec/s regressed from baseline {base:.0} \
                 ({:.1}% below, tolerance {:.0}%)",
                (1.0 - cur / base) * 100.0,
                tol * 100.0
            )),
            Some(_) => {}
        }
    }
    drifts
}

/// One throughput cell's configuration: LB on (≤1 round), artificial
/// busy-work zeroed so the hot path (hash → route → enqueue → drain →
/// reduce) dominates the measurement.
fn cell_cfg(strategy: Strategy, driver: DriverKind) -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.strategy = strategy;
    if strategy.is_token_ring() {
        cfg.initial_tokens = Some(strategy.initial_tokens(cfg.halving_init_tokens));
    }
    cfg.driver = driver;
    cfg.max_rounds = 1;
    cfg.map_delay_us = 0;
    cfg.reduce_delay_us = 0;
    cfg.chunk_size = 64;
    cfg
}

fn fmt_rps(rps: f64) -> String {
    if rps > 1e6 {
        format!("{:.2} M rec/s", rps / 1e6)
    } else {
        format!("{:.0} rec/s", rps)
    }
}

fn main() {
    dpa::util::logger::init();
    let seeds: usize = env_parse("DPA_BENCH_SEEDS", 3).max(1);
    let n_items: usize = env_parse("DPA_BENCH_ITEMS", 40_000).max(1);

    let families = [
        Strategy::Halving,
        Strategy::Doubling,
        Strategy::MultiProbe { probes: dpa::hash::DEFAULT_PROBES },
        Strategy::TwoChoices,
        Strategy::Ptable {
            bits: dpa::hash::DEFAULT_PTABLE_BITS,
            replicas: dpa::hash::DEFAULT_PTABLE_REPLICAS,
        },
    ];
    // uniform vs skew: same length, same synthetic key space — the skewed
    // stream hammers one reducer's queue and the sticky table's hot keys,
    // which is exactly where lock contention used to live
    let workloads: Vec<(&str, Workload)> = vec![
        ("uniform", generators::uniform(n_items, 200, 42)),
        ("zipf", generators::zipf_keyspace(n_items, 1_000_000, 1.2, 42)),
    ];

    println!("Throughput: records/sec + p50/p99 per-record latency, hot-path bench");
    println!(
        "setup: 4 mappers, 4 reducers, LB ≤1 round, no busy-work delays, \
         {n_items} items/run, {seeds} seeds (latency: µs on threads, ticks on sim)\n"
    );

    let mut t = Table::new(["Family", "Driver", "Workload", "rec/s", "p50", "p99"]);
    let mut cells: BTreeMap<String, f64> = BTreeMap::new();
    for &strategy in &families {
        for driver in [DriverKind::Sim, DriverKind::Threads] {
            let dname = match driver {
                DriverKind::Sim => "sim",
                DriverKind::Threads => "threads",
            };
            for (wname, w) in &workloads {
                let pipeline = Pipeline::wordcount(cell_cfg(strategy, driver));
                let mut rps_sum = 0.0;
                let mut p50_sum = 0.0;
                let mut p99_sum = 0.0;
                let mut lat_runs = 0usize;
                for seed in 0..seeds as u64 {
                    let t0 = Instant::now();
                    let reports = pipeline
                        .run_seeds(&w.items, &[seed])
                        .unwrap_or_else(|e| panic!("{strategy}/{dname}/{wname}: {e:#}"));
                    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
                    let r = &reports[0];
                    rps_sum += r.total_processed() as f64 / elapsed;
                    if let Some(lat) = r.latency {
                        p50_sum += lat.p50 as f64;
                        p99_sum += lat.p99 as f64;
                        lat_runs += 1;
                    }
                }
                let rps = rps_sum / seeds as f64;
                let (p50, p99) = if lat_runs > 0 {
                    (p50_sum / lat_runs as f64, p99_sum / lat_runs as f64)
                } else {
                    (0.0, 0.0)
                };
                let key = format!("{strategy}/{dname}/{wname}");
                cells.insert(format!("{key}/rps"), rps);
                cells.insert(format!("{key}/p50"), p50);
                cells.insert(format!("{key}/p99"), p99);
                t.row([
                    strategy.to_string(),
                    dname.to_string(),
                    wname.to_string(),
                    fmt_rps(rps),
                    format!("{p50:.0}"),
                    format!("{p99:.0}"),
                ]);
            }
        }
    }
    t.print();

    if let Ok(path) = std::env::var("DPA_BENCH_JSON") {
        std::fs::write(&path, to_json(seeds, &cells)).expect("writing bench JSON");
        println!("wrote {path}");
    }

    if let Ok(path) = std::env::var("DPA_BENCH_BASELINE") {
        let tol: f64 = env_parse("DPA_BENCH_RPS_TOLERANCE", 0.10);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading baseline {path}: {e}"));
        let baseline = parse_json(&text).expect("parsing baseline JSON");
        // rps cells are per-seed-count means: comparing across different
        // DPA_BENCH_SEEDS would gate on cross-seed variance, not drift
        if let Some(&bs) = baseline.get("seeds") {
            if bs as usize != seeds {
                eprintln!(
                    "bench gate FAILED: baseline was recorded with seeds={} but this \
                     run used seeds={seeds} — regenerate the baseline with matching \
                     DPA_BENCH_SEEDS",
                    bs as usize
                );
                std::process::exit(1);
            }
        }
        if !baseline.keys().any(|k| k.contains('/')) {
            println!(
                "baseline {path} has no cells — bootstrap run, gate skipped \
                 (commit a produced BENCH_throughput.json as the baseline to arm it)"
            );
            return;
        }
        let drifts = compare_baseline(&baseline, &cells, tol);
        if drifts.is_empty() {
            let n = baseline.keys().filter(|k| k.ends_with("/rps")).count();
            println!(
                "bench gate: all {n} baseline rps cells within {:.0}% of baseline",
                tol * 100.0
            );
        } else {
            eprintln!("bench gate FAILED (rps tolerance {:.0}%):", tol * 100.0);
            for d in &drifts {
                eprintln!("  {d}");
            }
            std::process::exit(1);
        }
    }
}
