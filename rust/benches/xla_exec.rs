//! XLA data-plane benchmarks: latency/throughput of the compiled
//! `hash_only`, `route`, `reduce_count` and `merge_state` programs through
//! PJRT, side by side with the bit-identical rust-native equivalents.
//!
//! This quantifies the batch-size economics the runtime design is built
//! on: per-execution PJRT overhead is amortized over B=256 records, so
//! the XLA lane wins only for batch-level work — which is exactly how the
//! `XlaWordCount` executor uses it (one execution per 256 records).
//!
//! Requires `make artifacts`. Run: `cargo bench --bench xla_exec`.

use dpa::benchkit::{black_box, Bench};
use dpa::exec::builtin::WordCount;
use dpa::exec::xla::{Interner, XlaWordCount};
use dpa::exec::{Record, ReduceExecutor};
use dpa::hash::{murmur3_x86_32, Ring};
use dpa::runtime::programs::SharedRuntime;
use dpa::util::prng::Xoshiro256;
use std::sync::Arc;

fn main() {
    dpa::util::logger::init();
    let rt = match SharedRuntime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping xla_exec bench: {e:#}\nrun `make artifacts` first");
            return;
        }
    };
    let m = rt.manifest();
    println!("platform: {}  B={} W={} T={} V={}\n", rt.platform(), m.b, m.w, m.t, m.v);
    let mut bench = Bench::quick();

    let mut rng = Xoshiro256::new(3);
    let keys: Vec<Vec<u8>> = (0..m.b)
        .map(|i| format!("key-{i}-{}", rng.next_u64() % 997).into_bytes())
        .collect();
    let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
    let ring = Ring::new(4, 8);

    // --- hashing: XLA batch vs native loop --------------------------------
    bench.run("XLA hash_only (256 keys)", Some(m.b as u64), || {
        black_box(rt.hash_batch(&key_refs).unwrap());
    });
    bench.run("native murmur3 (256 keys)", Some(m.b as u64), || {
        let mut acc = 0u32;
        for k in &key_refs {
            acc ^= murmur3_x86_32(k);
        }
        black_box(acc);
    });

    // --- routing -----------------------------------------------------------
    bench.run("XLA route (256 keys)", Some(m.b as u64), || {
        black_box(rt.route_batch(&key_refs, &ring).unwrap());
    });
    bench.run("native hash+lookup (256 keys)", Some(m.b as u64), || {
        let mut acc = 0usize;
        for k in &key_refs {
            acc ^= ring.lookup(k);
        }
        black_box(acc);
    });

    // --- reduce: histogram batch vs HashMap --------------------------------
    let ids: Vec<i32> = (0..m.b).map(|_| rng.index(1000) as i32).collect();
    let counts = vec![0u32; m.v];
    bench.run("XLA reduce_count (256 ids)", Some(m.b as u64), || {
        black_box(rt.reduce_counts(&counts, &ids).unwrap());
    });
    let skeys: Vec<String> = ids.iter().map(|i| format!("k{i}")).collect();
    bench.run("native HashMap reduce (256 recs)", Some(m.b as u64), || {
        let mut wc = WordCount::new();
        for k in &skeys {
            wc.reduce(Record::new(k.clone(), 1));
        }
        black_box(wc);
    });

    // --- merge --------------------------------------------------------------
    let a: Vec<u32> = (0..m.v).map(|_| rng.index(100) as u32).collect();
    let b: Vec<u32> = (0..m.v).map(|_| rng.index(100) as u32).collect();
    bench.run("XLA merge_state (V=4096)", Some(m.v as u64), || {
        black_box(rt.merge_states(&a, &b).unwrap());
    });
    bench.run("native vec add (V=4096)", Some(m.v as u64), || {
        let out: Vec<u32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        black_box(out);
    });

    // --- host-literal vs device-resident state (§Perf iteration 2 A/B) ------
    bench.run("reduce 16 batches, host-literal state", Some(16 * m.b as u64), || {
        let mut c = vec![0u32; m.v];
        for _ in 0..16 {
            c = rt.reduce_counts(&c, &ids).unwrap();
        }
        black_box(c[0]);
    });
    bench.run("reduce 16 batches, device-resident state", Some(16 * m.b as u64), || {
        let h = rt.counts_create().unwrap();
        for _ in 0..16 {
            rt.counts_update(h, &ids).unwrap();
        }
        let c = rt.counts_read(h).unwrap();
        rt.counts_free(h);
        black_box(c[0]);
    });

    // --- the actual executor hot path ---------------------------------------
    let interner = Arc::new(Interner::new(m.v));
    let pool = dpa::workload::generators::key_pool();
    let stream: Vec<String> = (0..4096).map(|_| pool[rng.index(400)].clone()).collect();
    bench.run("XlaWordCount 4096 records (16 flushes)", Some(4096), || {
        let mut wc = XlaWordCount::new(rt.clone(), interner.clone());
        for k in &stream {
            wc.reduce(Record::new(k.clone(), 1));
        }
        wc.flush();
        black_box(wc.dense_records);
    });
    bench.run("WordCount 4096 records", Some(4096), || {
        let mut wc = WordCount::new();
        for k in &stream {
            wc.reduce(Record::new(k.clone(), 1));
        }
        black_box(wc.snapshot().len());
    });

    bench.print();
}
