// Declare `--cfg loom` (set via RUSTFLAGS by the loom CI job and the
// model suite's docs) as an expected cfg, so `unexpected_cfgs` stays
// clean under `-D warnings` on modern toolchains. Older cargos (the MSRV
// leg) treat the unknown directive as inert metadata.
fn main() {
    println!("cargo:rustc-check-cfg=cfg(loom)");
}
