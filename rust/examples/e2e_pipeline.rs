//! End-to-end driver: the full three-layer system on a real (synthetic)
//! corpus workload.
//!
//! - L3: rust actor pipeline (threads driver — real OS threads, real
//!   queues, real wall-clock), token-doubling load balancer.
//! - L2/L1: the reducers' aggregation state is updated by the AOT-compiled
//!   Pallas histogram kernel through PJRT; the final state merge runs the
//!   compiled `merge_state` program; routing parity with the compiled
//!   `route` program is asserted on a sample.
//!
//! Requires `make artifacts`. Run:
//!
//! ```sh
//! cargo run --release --example e2e_pipeline
//! ```
//!
//! Reports the paper's headline metric (skew S with vs without LB) plus
//! wall-clock throughput; the run is recorded in EXPERIMENTS.md.

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use std::sync::Arc;
use std::time::Instant;

use dpa::exec::builtin::TokenizeMap;
use dpa::exec::xla::xla_wordcount_factory;
use dpa::hash::Strategy;
use dpa::pipeline::{DriverKind, Pipeline, PipelineConfig};
use dpa::runtime::programs::SharedRuntime;
use dpa::workload::corpus;

fn main() -> dpa::Result<()> {
    dpa::util::logger::init();

    // ---- the workload: a zipf-distributed English-like corpus ----------
    let n_words = 40_000;
    let text = corpus::generate(n_words, 1.0, 7);
    let lines: Vec<String> = text.lines().map(str::to_string).collect();
    println!(
        "corpus: {} words in {} lines (zipf s=1.0 over {} distinct words)",
        n_words,
        lines.len(),
        corpus::WORDS.len()
    );

    // ---- load the compiled data plane ----------------------------------
    let t0 = Instant::now();
    let runtime = SharedRuntime::load_default()?;
    println!(
        "PJRT {} — artifacts compiled+loaded in {:?} (B={}, V={})",
        runtime.platform(),
        t0.elapsed(),
        runtime.manifest().b,
        runtime.manifest().v,
    );

    // routing parity spot-check: rust ring vs compiled route program
    let ring = dpa::hash::Ring::new(4, 1);
    let sample: Vec<&[u8]> = corpus::WORDS.iter().take(64).map(|w| w.as_bytes()).collect();
    let routed = runtime.route_batch(&sample, &ring)?;
    for (w, (h, owner)) in sample.iter().zip(&routed) {
        assert_eq!(*h, dpa::hash::murmur3_x86_32(w));
        assert_eq!(*owner, ring.lookup(w));
    }
    println!("route parity OK on {} sampled words", sample.len());

    // ---- run: no-LB baseline vs token doubling -------------------------
    let mut cfg = PipelineConfig::default();
    cfg.driver = DriverKind::Threads;
    cfg.strategy = Strategy::None;
    cfg.initial_tokens = Some(1);
    cfg.reduce_delay_us = 0; // the XLA batch execution IS the reduce cost
    cfg.chunk_size = 16;

    let runs = [
        ("no LB", Strategy::None, 0u32),
        ("doubling, ≤2 rounds", Strategy::Doubling, 2u32),
    ];
    let mut results = Vec::new();
    for (label, strategy, rounds) in runs {
        let mut c = cfg.clone();
        c.strategy = strategy;
        c.max_rounds = rounds.max(1);
        let pipeline = Pipeline::new(
            c,
            Arc::new(TokenizeMap),
            xla_wordcount_factory(runtime.clone()),
        );
        let report = pipeline.run(lines.clone())?;
        println!(
            "\n=== {label} ===\n{}throughput: {:.0} words/s (wall {:?})",
            report.render(),
            report.throughput(),
            report.wall
        );
        results.push((label, report));
    }

    let (_, base) = &results[0];
    let (_, lb) = &results[1];
    assert_eq!(base.result, lb.result, "LB must not change the answer");
    assert_eq!(base.total_processed(), n_words as u64);
    println!(
        "\nheadline: skew S {:.3} -> {:.3} (Δ {:+.3}); LB events: {}",
        base.skew(),
        lb.skew(),
        base.skew() - lb.skew(),
        lb.lb_events.len()
    );

    let mut top = lb.result.clone();
    top.sort_by(|a, b| b.1.cmp(&a.1));
    top.truncate(8);
    println!("top words: {top:?}");
    Ok(())
}
