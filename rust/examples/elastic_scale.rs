//! §7 extension, now for real: **elastic reducer membership at runtime**.
//!
//! "Our scheme can easily be extended to add new reducers on new
//! machines. They can simply claim tokens in the consistent hashing
//! scheme, and our forwarding mechanism will forward inputs to these new
//! reducers appropriately. Their state has to be merged with the state of
//! all the existing reducers at the end."
//!
//! Earlier revisions of this example faked the join with a hand-rolled
//! driver. It is now the actual runtime path: the balancer's
//! `balancer::elastic` policy watches the decayed load signal and — when
//! the mean crosses the scale-up watermark — adds a brand-new reducer
//! through `Router::add_node` (token claim), the driver spawns its actor
//! mid-run, stale-routed records reach it through the ordinary ownership
//! check, and §7 state forwarding ships each re-owned key's state ahead
//! of data. When the hot phase drains, the mean sinks below the
//! scale-down watermark and the coldest reducer retires
//! (`Router::retire_node`): its keys re-home minimally, its backlog
//! drains by forwarding, and its state merges exactly once.
//!
//! ```sh
//! cargo run --release --example elastic_scale
//! ```

use dpa::balancer::elastic::ElasticConfig;
use dpa::balancer::state_forward::ConsistencyMode;
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::workload::generators;

fn main() -> dpa::Result<()> {
    dpa::util::logger::init();

    // hot phase: a heavily skewed zipf stream builds queues fast;
    // cool tail: uniform trickle lets the decayed mean sink again
    let hot = generators::zipf(1500, 40, 1.4, 9);
    let tail = generators::uniform(1500, 200, 17);
    let mut items = hot.items;
    items.extend(tail.items);
    let oracle = {
        let mut m = std::collections::HashMap::new();
        for i in &items {
            *m.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut v: Vec<(String, i64)> = m.into_iter().collect();
        v.sort();
        v
    };

    let mut cfg = PipelineConfig::default();
    cfg.reducers = 2; // start at the elastic floor
    cfg.strategy = dpa::hash::Strategy::Doubling;
    cfg.initial_tokens = Some(1);
    cfg.mode = ConsistencyMode::StateForward;
    cfg.cooldown = 20;
    *cfg.elastic_mut() = ElasticConfig {
        scale_up: 2.0,
        scale_down: 1.0,
        min_reducers: 2,
        max_reducers: 8,
    };

    let report = Pipeline::wordcount(cfg).run(items.clone())?;
    let (added, retired) = report.scale_counts();

    println!(
        "run over {} items: {} reducer ids in the end ({} scale-ups, {} retires)",
        items.len(),
        report.processed.len(),
        added,
        retired
    );
    for e in report.membership_events() {
        println!(
            "  @{:>8} {:?}  epoch {}  qlens {:?}",
            e.at,
            e.membership.unwrap(),
            e.epoch,
            e.qlens
        );
    }
    println!("processed per reducer: {:?}", report.processed);
    println!("forwarded per reducer: {:?}", report.forwarded);

    // §7: "their state has to be merged with the state of all the
    // existing reducers at the end" — and under state forwarding the
    // merge is a disjoint union, asserted inside the runtime
    assert_eq!(report.result, oracle, "elastic run matches the serial oracle");
    report.check_conservation().expect("message conservation");
    assert!(added > 0, "the hot phase should trip the scale-up watermark");
    println!(
        "\nmerged {} distinct keys — result identical to serial word count ✓",
        report.result.len()
    );
    println!(
        "skew S = {:.3} across {} reducer ids",
        report.skew(),
        report.processed.len()
    );
    Ok(())
}
