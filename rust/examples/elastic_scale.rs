//! §7 future-work extension: **elastic scale-out**. "Our scheme can easily
//! be extended to add new reducers on new machines. They can simply claim
//! tokens in the consistent hashing scheme, and our forwarding mechanism
//! will forward inputs to these new reducers appropriately. Their state
//! has to be merged with the state of all the existing reducers at the
//! end."
//!
//! This example composes the library's building blocks (ring, queues,
//! reducer cores, merge) in a hand-rolled driver: mid-stream a fifth
//! reducer joins, claims tokens, stale-queued records get forwarded to it
//! by the ownership check, and its state merges in at the end.
//!
//! ```sh
//! cargo run --release --example elastic_scale
//! ```

use std::collections::VecDeque;
use std::sync::Arc;

use dpa::coordinator::merge_states;
use dpa::exec::builtin::{IdentityMap, WordCount};
use dpa::exec::{MapExecutor, MergeOp, Record};
use dpa::hash::{Ring, RingOp, RouterHandle};
use dpa::mapper::MapperCore;
use dpa::reducer::{Handled, ReducerCore};
use dpa::workload::generators;

fn main() -> dpa::Result<()> {
    dpa::util::logger::init();

    let workload = generators::zipf(3000, 150, 1.1, 9);
    let items = workload.items;
    let oracle = {
        let mut m = std::collections::HashMap::new();
        for i in &items {
            *m.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut v: Vec<(String, i64)> = m.into_iter().collect();
        v.sort();
        v
    };

    // start with 4 reducers, 8 tokens each (token ring behind the Router
    // trait; the elastic extension claims tokens through the escape hatch)
    let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
    let mut mapper =
        MapperCore::new(0, Arc::new(IdentityMap) as Arc<dyn MapExecutor>, router.clone());
    let mut reducers: Vec<ReducerCore> = (0..4)
        .map(|i| ReducerCore::new(i, Box::new(WordCount::new()), router.clone()))
        .collect();
    let mut queues: Vec<VecDeque<Record>> = (0..4).map(|_| VecDeque::new()).collect();

    // drain helper: reducers check ownership and forward (the paper's
    // mechanism — stale records find their new owner)
    let drain = |reducers: &mut Vec<ReducerCore>, queues: &mut Vec<VecDeque<Record>>| {
        let mut active = true;
        while active {
            active = false;
            for i in 0..reducers.len() {
                if let Some(rec) = queues[i].pop_front() {
                    active = true;
                    if let Handled::Forward(dest, rec) = reducers[i].handle(rec) {
                        queues[dest].push_back(rec);
                    }
                }
            }
        }
    };

    // phase 1: route the first half onto 4 reducers, drain half the queues
    let (first, second) = items.split_at(items.len() / 2);
    for item in first {
        for (dest, rec) in mapper.process_item(item) {
            queues[dest].push_back(rec);
        }
    }
    // leave some records queued so the new reducer sees stale routing
    for (i, q) in queues.iter().enumerate() {
        println!("phase 1: reducer {i} queue = {}", q.len());
    }

    // phase 2: ELASTIC JOIN — reducer 4 claims 8 tokens on the live ring
    let new_id = router.update_ring(|r| r.add_node(8)).expect("token-ring router");
    println!(
        "\nreducer {new_id} joined: ring now has {} tokens",
        router.with_ring(|r| r.total_tokens()).unwrap()
    );
    reducers.push(ReducerCore::new(new_id, Box::new(WordCount::new()), router.clone()));
    queues.push(VecDeque::new());

    // phase 3: route the second half (mappers see the new ring instantly)
    for item in second {
        for (dest, rec) in mapper.process_item(item) {
            queues[dest].push_back(rec);
        }
    }
    drain(&mut reducers, &mut queues);

    let processed: Vec<u64> = reducers.iter().map(|r| r.processed).collect();
    let forwarded: Vec<u64> = reducers.iter().map(|r| r.forwarded).collect();
    println!("\nprocessed per reducer: {processed:?}");
    println!("forwarded per reducer: {forwarded:?}");
    assert!(
        processed[new_id] > 0,
        "the new reducer claimed and processed keys"
    );
    assert_eq!(processed.iter().sum::<u64>(), items.len() as u64);

    // phase 4: §7 — "their state has to be merged with the state of all
    // the existing reducers at the end"
    let snaps: Vec<Vec<(String, i64)>> = reducers.iter_mut().map(|r| r.final_snapshot()).collect();
    let merged = merge_states(snaps, MergeOp::Sum, false);
    assert_eq!(merged, oracle, "elastic run matches the serial oracle");
    println!(
        "\nmerged {} distinct keys — result identical to serial word count ✓",
        merged.len()
    );
    println!(
        "skew S = {:.3} across {} reducers",
        dpa::metrics::skew(&processed),
        reducers.len()
    );
    Ok(())
}
