//! Reproduce the paper's full evaluation section in one shot: Table 1
//! (Experiment 1) and Figure 3 (Experiment 2), plus the workload
//! inventory with its designed no-LB skews.
//!
//! ```sh
//! cargo run --release --example paper_experiments
//! ```
//!
//! Output is markdown; EXPERIMENTS.md records a captured run alongside
//! the paper's published numbers.

fn main() -> dpa::Result<()> {
    dpa::util::logger::init();

    println!("== workloads (constructed against the actual initial rings) ==");
    let (rh, rd) = dpa::workload::paperwl::initial_rings();
    for w in dpa::workload::paperwl::all() {
        println!(
            "- {}: {} items, {} distinct keys; no-LB S: halving {:.2}, doubling {:.2}\n    {}",
            w.name,
            w.len(),
            w.distinct_keys().len(),
            w.static_skew(&rh),
            w.static_skew(&rd),
            w.description
        );
    }

    println!();
    print!("{}", dpa::cli::table1(3, &dpa::hash::Strategy::methods())?);
    println!();
    print!("{}", dpa::cli::fig3(4)?);

    println!("\npaper reference (Table 1): WL1 doubling Δ+0.80; WL3 doubling Δ+0.25;");
    println!("WL4 halving Δ+0.28, doubling Δ+0.38; WL5 doubling Δ+0.43; others ~0.");
    Ok(())
}
