
// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::hash::Strategy;
use dpa::pipeline::{DriverKind, Pipeline, PipelineConfig};
use dpa::workload::generators;
fn main() {
    let w = generators::zipf(200_000, 300, 1.2, 6);
    let mut cfg = PipelineConfig::default();
    cfg.driver = DriverKind::Threads;
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(1);
    cfg.reduce_delay_us = 0;
    cfg.chunk_size = 100;
    let p = Pipeline::wordcount(cfg);
    for _ in 0..5 {
        let r = p.run(w.items.clone()).unwrap();
        match r.latency {
            Some(lat) => println!(
                "{:.0} items/s  latency p50 = {} µs  p99 = {} µs",
                r.throughput(),
                lat.p50,
                lat.p99
            ),
            None => println!("{:.0} items/s", r.throughput()),
        }
    }
}
