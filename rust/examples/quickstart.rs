//! Quickstart: count words in a skewed stream, with and without runtime
//! load balancing.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::hash::Strategy;
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::workload::generators;

fn main() -> dpa::Result<()> {
    dpa::util::logger::init();

    // a zipf-skewed stream of 2000 short keys ("h is a lot more common
    // than z")
    let workload = generators::zipf(2000, 100, 1.4, 42);
    println!("workload: {} ({} items)", workload.name, workload.len());

    // 1) baseline: hash-partitioned reducers, no load balancing
    let mut cfg = PipelineConfig::default();
    cfg.strategy = Strategy::None;
    cfg.initial_tokens = Some(1); // doubling-style initial layout
    let baseline = Pipeline::wordcount(cfg.clone()).run(workload.items.clone())?;
    println!("\n--- no load balancing ---");
    print!("{}", baseline.render());

    // 2) with the paper's token-doubling load balancer (τ = 0.2)
    cfg.strategy = Strategy::Doubling;
    cfg.max_rounds = 2;
    let balanced = Pipeline::wordcount(cfg).run(workload.items.clone())?;
    println!("\n--- with token-doubling LB ---");
    print!("{}", balanced.render());

    println!(
        "\nskew S: {:.3} -> {:.3}  (Δ = {:+.3})",
        baseline.skew(),
        balanced.skew(),
        baseline.skew() - balanced.skew()
    );

    // results are identical regardless of balancing — the state merge
    // step guarantees it
    assert_eq!(baseline.result, balanced.result);
    let top: Vec<_> = {
        let mut r = balanced.result.clone();
        r.sort_by(|a, b| b.1.cmp(&a.1));
        r.truncate(5);
        r
    };
    println!("top-5 keys: {top:?}");
    Ok(())
}
