//! Skewed-stream study: how strategy, τ and round budget interact on
//! zipf / hot-key streams (the workloads the paper's introduction
//! motivates: "some letters (e.g. h) are a lot more common than others").
//!
//! ```sh
//! cargo run --release --example skewed_stream
//! ```

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::hash::Strategy;
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::util::table::{delta2, f2, Table};
use dpa::workload::{generators, Workload};

fn mean_skew(w: &Workload, strategy: Strategy, tau: f64, rounds: u32) -> dpa::Result<f64> {
    let mut cfg = PipelineConfig::default();
    cfg.strategy = strategy;
    cfg.initial_tokens = Some(strategy.initial_tokens(8));
    cfg.tau = tau;
    cfg.max_rounds = rounds.max(1);
    if rounds == 0 {
        cfg.strategy = Strategy::None;
    }
    let p = Pipeline::wordcount(cfg);
    let reports = p.run_seeds(&w.items, &[0, 1, 2])?;
    Ok(reports.iter().map(|r| r.skew()).sum::<f64>() / reports.len() as f64)
}

fn main() -> dpa::Result<()> {
    dpa::util::logger::init();

    let workloads = vec![
        generators::zipf(1000, 100, 0.8, 1),
        generators::zipf(1000, 100, 1.2, 1),
        generators::zipf(1000, 100, 1.6, 1),
        generators::hot_key(1000, 0.4, 50, 1),
        generators::hot_key(1000, 0.8, 50, 1),
        generators::uniform(1000, 100, 1),
    ];

    println!("== strategies on skewed streams (τ=0.2, ≤2 rounds, 3 seeds) ==");
    let mut t = Table::new(["workload", "S no-LB", "S halving", "S doubling", "Δ best"]);
    for w in &workloads {
        let s0 = mean_skew(w, Strategy::None, 0.2, 0)?;
        let sh = mean_skew(w, Strategy::Halving, 0.2, 2)?;
        let sd = mean_skew(w, Strategy::Doubling, 0.2, 2)?;
        t.row([
            w.name.clone(),
            f2(s0),
            f2(sh),
            f2(sd),
            delta2(s0 - sh.min(sd)),
        ]);
    }
    t.print();

    println!("\n== τ sensitivity (doubling, zipf s=1.6, ≤2 rounds) ==");
    let w = &workloads[2];
    let mut t = Table::new(["τ", "S", "LB events (seed 0)"]);
    for tau in [0.0, 0.1, 0.2, 0.5, 1.0, 2.0] {
        let s = mean_skew(w, Strategy::Doubling, tau, 2)?;
        let mut cfg = PipelineConfig::default();
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(1);
        cfg.tau = tau;
        cfg.max_rounds = 2;
        let events = Pipeline::wordcount(cfg).run(w.items.clone())?.lb_rounds();
        t.row([format!("{tau:.1}"), f2(s), events.to_string()]);
    }
    t.print();

    println!("\n== round budget (doubling, hot-key 80%) ==");
    let w = &workloads[4];
    let mut t = Table::new(["max rounds", "S"]);
    for rounds in 0..=4u32 {
        t.row([rounds.to_string(), f2(mean_skew(w, Strategy::Doubling, 0.2, rounds)?)]);
    }
    t.print();
    Ok(())
}
