//! Streaming hash join under runtime load balancing — the paper's §7
//! correctness discussion, runnable.
//!
//! A join reducer's state is its *build table*; probe records that find
//! no local build row are dropped (inner join). When the balancer moves a
//! key mid-run, the paper's base design (merge state at the end) cannot
//! repair probes that reached the key's new owner before any build state
//! existed there. The §7 *state forwarding* algorithm ships the build
//! state ahead of data in a synchronized stage, keeping the join exact.
//!
//! ```sh
//! cargo run --release --example stream_join
//! ```

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use std::sync::Arc;

use dpa::balancer::state_forward::ConsistencyMode;
use dpa::exec::join::{join_oracle, HashJoin, JoinMap};
use dpa::hash::{Ring, Strategy};
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::workload::generators::key_pool;

fn main() -> dpa::Result<()> {
    dpa::util::logger::init();

    // solve for 4 join keys that share one owner on the doubling-layout
    // ring AND relocate after one doubling event — so the LB will both
    // fire and actually move them
    let ring = Ring::new(4, 1);
    let pool = key_pool();
    let mut hot: Vec<String> = Vec::new();
    for node in 0..4 {
        let mut after = ring.clone();
        after.double_others(node);
        let movable: Vec<String> = pool
            .iter()
            .filter(|k| ring.lookup(k.as_bytes()) == node && after.lookup(k.as_bytes()) != node)
            .take(4)
            .cloned()
            .collect();
        if movable.len() == 4 {
            hot = movable;
            break;
        }
    }
    println!("join keys (hot + movable): {hot:?}");

    // build rows → ballast (lets the builds finish processing) → a probe
    // flood that triggers the balancer mid-stream
    let ballast: Vec<String> = pool
        .iter()
        .filter(|k| {
            !hot.contains(k) && ring.lookup(k.as_bytes()) != ring.lookup(hot[0].as_bytes())
        })
        .take(10)
        .cloned()
        .collect();
    let mut items = Vec::new();
    for (i, k) in hot.iter().enumerate() {
        items.push(format!("B:{k}:{}", 100 + i));
    }
    for _ in 0..4 {
        for k in &ballast {
            items.push(format!("B:{k}:1"));
        }
    }
    for round in 0..30 {
        for k in &hot {
            items.push(format!("P:{k}:{round}"));
        }
    }
    let (oracle, _) = join_oracle(&items);
    let oracle_matches: i64 = oracle.iter().map(|(_, v)| v).sum();
    println!("serial oracle: {} keys, total match weight {oracle_matches}", oracle.len());

    for (label, mode) in [
        ("merge-at-end (paper's base design)", ConsistencyMode::MergeAtEnd),
        ("state forwarding (paper §7)", ConsistencyMode::StateForward),
    ] {
        let mut cfg = PipelineConfig::default();
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(1);
        cfg.max_rounds = 2;
        cfg.mappers = 1; // preserve stream order into the queues
        cfg.mode = mode;
        let p = Pipeline::new(
            cfg,
            Arc::new(JoinMap),
            Arc::new(|_| Box::new(HashJoin::new()) as _),
        );
        let r = p.run(items.clone())?;
        let got: i64 = r.result.iter().map(|(_, v)| v).sum();
        println!(
            "\n=== {label} ===\nLB events: {}  match weight: {got} / {oracle_matches}  {}",
            r.lb_events.len(),
            if got == oracle_matches {
                "EXACT ✓"
            } else {
                "probes lost ✗ (dropped at the key's new owner)"
            }
        );
    }
    println!(
        "\nthe state-forwarding run is exact because every repartition runs a\n\
         synchronized stage: reducers ship disowned build state first and only\n\
         then resume forwarding data (balancer::state_forward)."
    );
    Ok(())
}
