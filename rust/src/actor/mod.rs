//! Actor plumbing shared by the drivers: queue envelopes, shutdown
//! accounting and named-thread helpers. The paper implements every
//! component (coordinator, queues, reducers, mappers, load balancer) as a
//! Ray actor; here each is either a thread (threads driver) or a
//! deterministically-scheduled state machine (sim driver) over the same
//! core logic.

#![forbid(unsafe_code)]

use std::thread;

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::Arc;

use crate::exec::Record;

/// What travels through a reducer queue. `Data` is a routed record;
/// `State` is a §7 state-forwarding transfer (key + extracted state) that
/// must be applied before any data processing.
/// `Checkpoint` is a replicated-state snapshot (testkit::chaos) riding
/// the same priority lane as `State`: it installs into the run's chaos
/// controller at the receiving peer and is never folded into a reducer.
#[derive(Clone, Debug)]
pub enum Envelope {
    Data(Record),
    State(Record),
    Checkpoint {
        /// Reducer whose state this snapshot replicates.
        origin: usize,
        /// Checkpoint sequence number (higher wins at install time).
        seq: u64,
        /// Full (key, partial) snapshot covering WAL tags `< seq`.
        state: Vec<(String, i64)>,
    },
}

impl Envelope {
    /// The routed record inside a `Data`/`State` envelope. `Checkpoint`
    /// envelopes carry replicated state, not a record — no caller routes
    /// them by key, so asking is a logic error.
    pub fn record(&self) -> &Record {
        match self {
            Envelope::Data(r) | Envelope::State(r) => r,
            Envelope::Checkpoint { origin, .. } => {
                unreachable!("checkpoint from reducer {origin} carries no record")
            }
        }
    }
}

/// Shutdown accounting (§2.3): "a reducer can never stop on its own ...
/// the coordinator tracks all the reducers and ensures that they shutdown
/// once all of them are done processing the data."
///
/// A record becomes *in flight* when a mapper enqueues it and stops being
/// in flight when a reducer *reduces* it (forwarding keeps it in flight).
/// Reducers may stop exactly when all mappers are done **and** nothing is
/// in flight — at that point no queue holds data and no forward can ever
/// arrive, so the condition is stable.
#[derive(Debug)]
pub struct ShutdownMonitor {
    mappers_running: AtomicUsize,
    in_flight: AtomicU64,
}

// manual (not derived): loom's atomics don't implement `Default`
impl Default for ShutdownMonitor {
    fn default() -> Self {
        Self::new(0)
    }
}

impl ShutdownMonitor {
    pub fn new(mappers: usize) -> Self {
        ShutdownMonitor {
            mappers_running: AtomicUsize::new(mappers),
            in_flight: AtomicU64::new(0),
        }
    }

    /// A mapper enqueued `n` records.
    #[inline]
    pub fn produced(&self, n: u64) {
        self.in_flight.fetch_add(n, Ordering::SeqCst);
    }

    /// A reducer reduced one record (forwards do NOT call this).
    #[inline]
    pub fn consumed(&self) {
        let prev = self.in_flight.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "consumed more records than produced");
    }

    /// A mapper exhausted its tasks.
    pub fn mapper_done(&self) {
        let prev = self.mappers_running.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0);
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::SeqCst)
    }

    pub fn mappers_running(&self) -> usize {
        self.mappers_running.load(Ordering::SeqCst)
    }

    /// Stable termination condition for reducers.
    pub fn drained(&self) -> bool {
        // order matters: check mappers first so a concurrent
        // produce-then-mapper-done cannot slip between the two loads
        self.mappers_running() == 0 && self.in_flight() == 0
    }
}

/// Spawn a named worker thread.
pub fn spawn_named<F>(name: String, f: F) -> thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    thread::Builder::new()
        .name(name)
        .spawn(f)
        .expect("failed to spawn actor thread")
}

/// A cancellation flag shared across actors (error propagation: any actor
/// hitting a fatal error trips it so the others unwind promptly).
#[derive(Clone)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

// manual (not derived): loom's atomics don't implement `Default`
impl Default for CancelToken {
    fn default() -> Self {
        CancelToken { flag: Arc::new(AtomicBool::new(false)) }
    }
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shutdown_monitor_lifecycle() {
        let m = ShutdownMonitor::new(2);
        assert!(!m.drained());
        m.produced(3);
        m.mapper_done();
        m.mapper_done();
        assert!(!m.drained(), "records still in flight");
        m.consumed();
        m.consumed();
        m.consumed();
        assert!(m.drained());
    }

    #[test]
    fn forwarding_keeps_record_in_flight() {
        let m = ShutdownMonitor::new(1);
        m.produced(1);
        m.mapper_done();
        // a forward happens here — no consumed() call — still not drained
        assert!(!m.drained());
        m.consumed();
        assert!(m.drained());
    }

    #[test]
    fn cancel_token() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t.is_cancelled());
        t2.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn envelope_accessors() {
        let e = Envelope::Data(Record::new("k", 1));
        assert_eq!(e.record().key, "k");
        let s = Envelope::State(Record::new("j", 2));
        assert_eq!(s.record().value, 2);
    }
}
