//! Elastic reducer membership: the scaling policy that decides when the
//! reducer set itself should grow or shrink.
//!
//! The paper chose consistent hashing precisely because membership
//! changes move a minimal number of keys (§7 sketches reducers "simply
//! claiming tokens"), yet its evaluation runs a fixed reducer count.
//! AutoFlow (arXiv:2103.08888) argues a hotspot-aware balancer must also
//! change *parallelism*, not just re-route: when every reducer is hot,
//! redistribution only reshuffles the overload. [`ElasticController`] is
//! that second control loop. It watches the same decayed
//! [`LoadSignal`](crate::balancer::signal::LoadSignal) the routers
//! consume — not raw queue lengths, which would flap on every burst — and
//! compares the **mean decayed queue length over the live reducers**
//! against two watermarks:
//!
//! * mean above `scale_up` and live count below `max_reducers` →
//!   **scale up** (a brand-new reducer joins via
//!   [`Router::add_node`](crate::hash::Router::add_node));
//! * mean below `scale_down` and live count above `min_reducers` →
//!   **scale down** (the coldest live reducer retires via
//!   [`Router::retire_node`](crate::hash::Router::retire_node)).
//!
//! Requiring `scale_up > scale_down` makes the pair a hysteresis band of
//! its own, and a dedicated cooldown rate-limits membership churn the
//! same way the LB cooldown rate-limits repartitions (right after a
//! membership change the queue lengths are stale). Scale events flow
//! through the exact §7 machinery a redistribution uses: the epoch bump
//! opens a synchronization window, survivors extract state the new
//! membership disowns, and a retiring reducer drains by the ordinary
//! ownership-check forwarding.
//!
//! [`ElasticController::from_schedule`] is the deterministic test
//! harness: instead of watermarks it applies a fixed scale-op sequence
//! every N evaluated reports, so cross-driver parity suites can run an
//! identical scale-up + scale-down schedule on the sim and the threads
//! driver.

// Scaling decisions ripple through every lane (router membership, queue
// pre-allocation, §7 state transfer) — the public policy surface must
// say exactly what it promises.
#![warn(missing_docs)]

use crate::balancer::signal::FRAC_BITS;
use crate::hash::Loads;

/// User-facing elastic knobs (TOML `[balancer]` keys `scale_up`,
/// `scale_down`, `min_reducers`, `max_reducers`; CLI `--scale-up`,
/// `--scale-down`, `--min-reducers`, `--max-reducers`). The cooldown
/// rides the existing `balancer.cooldown` knob — one trigger-hygiene
/// setting for both control loops.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticConfig {
    /// Scale up when the mean decayed queue length (over live reducers)
    /// exceeds this.
    pub scale_up: f64,
    /// Scale down when the mean decayed queue length falls below this.
    /// Must be strictly less than `scale_up` (the watermark pair is a
    /// hysteresis band).
    pub scale_down: f64,
    /// Never retire below this many live reducers.
    pub min_reducers: usize,
    /// Never grow beyond this many reducer ids (live ∪ retired slots are
    /// bounded by it too — it is the pre-allocation capacity for queues,
    /// tracker slots and load-signal slots).
    pub max_reducers: usize,
}

impl Default for ElasticConfig {
    /// Watermarks in queue-length units: grow when reducers average eight
    /// queued records, shrink when they average less than one.
    fn default() -> Self {
        ElasticConfig { scale_up: 8.0, scale_down: 1.0, min_reducers: 1, max_reducers: 16 }
    }
}

impl ElasticConfig {
    /// Reject watermark pairs that cannot form a hysteresis band and
    /// bounds that cannot hold a live reducer set. Run before the
    /// controller is built — a bad config caught here is a one-line
    /// error instead of a run that flaps membership forever.
    ///
    /// ```
    /// use dpa::balancer::elastic::ElasticConfig;
    /// assert!(ElasticConfig::default().validate().is_ok());
    /// let inverted = ElasticConfig { scale_up: 1.0, scale_down: 4.0, ..Default::default() };
    /// assert!(inverted.validate().unwrap_err().contains("hysteresis"));
    /// ```
    pub fn validate(&self) -> Result<(), String> {
        if self.scale_up.is_nan() || self.scale_down.is_nan() {
            return Err("balancer.scale_up/scale_down must not be NaN".into());
        }
        if self.scale_down < 0.0 {
            return Err(format!(
                "balancer.scale_down must be non-negative, got {}",
                self.scale_down
            ));
        }
        if self.scale_up <= self.scale_down {
            return Err(format!(
                "balancer.scale_up ({}) must exceed scale_down ({}) — the watermark \
                 pair is a hysteresis band",
                self.scale_up, self.scale_down
            ));
        }
        if self.min_reducers == 0 {
            return Err("balancer.min_reducers must be at least 1".into());
        }
        if self.max_reducers < self.min_reducers {
            return Err(format!(
                "balancer.max_reducers ({}) must be >= min_reducers ({})",
                self.max_reducers, self.min_reducers
            ));
        }
        Ok(())
    }
}

/// A membership decision the balancer should apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleOp {
    /// Spawn one brand-new reducer.
    Up,
    /// Retire the given live reducer (watermark mode picks the coldest).
    Down(usize),
}

/// The scaling controller the balancer owns: policy + cooldown state.
#[derive(Debug)]
pub struct ElasticController {
    policy: PolicyState,
    /// Min driver-time between membership changes (same units as the LB
    /// cooldown: sim ticks or µs).
    cooldown: u64,
    last_scale_at: Option<u64>,
    reports_seen: u64,
}

#[derive(Debug)]
enum PolicyState {
    Watermarks {
        cfg: ElasticConfig,
        /// Watermarks pre-scaled to the signal's fixed point.
        up_fp: u64,
        down_fp: u64,
    },
    Schedule {
        ops: std::vec::IntoIter<ScaleOp>,
        every_reports: u64,
        min: usize,
        max: usize,
    },
}

impl ElasticController {
    /// Watermark-driven controller (the `dpa elastic` production mode).
    pub fn from_watermarks(cfg: ElasticConfig, cooldown: u64) -> Self {
        let fp = |v: f64| (v * f64::from(1u32 << FRAC_BITS)).round() as u64;
        ElasticController {
            policy: PolicyState::Watermarks {
                up_fp: fp(cfg.scale_up),
                down_fp: fp(cfg.scale_down),
                cfg,
            },
            cooldown,
            last_scale_at: None,
            reports_seen: 0,
        }
    }

    /// Deterministic schedule controller (cross-driver parity tests).
    pub fn from_schedule(ops: Vec<ScaleOp>, every_reports: u64, min: usize, max: usize) -> Self {
        ElasticController {
            policy: PolicyState::Schedule {
                ops: ops.into_iter(),
                every_reports: every_reports.max(1),
                min,
                max,
            },
            cooldown: 0,
            last_scale_at: None,
            reports_seen: 0,
        }
    }

    /// The configured ceiling on reducer ids (pre-allocation capacity).
    pub fn max_reducers(&self) -> usize {
        match &self.policy {
            PolicyState::Watermarks { cfg, .. } => cfg.max_reducers,
            PolicyState::Schedule { max, .. } => *max,
        }
    }

    /// Evaluate the policy for one load report. `loads` is the shared
    /// decayed signal, `live` the currently routable reducer count,
    /// `id_space` the total ids ever allocated (live ∪ retired — the
    /// scale-up bound, since retired slots are not reusable), `now` the
    /// driver clock. Returns the membership op to apply, if any.
    pub fn decide(
        &mut self,
        loads: &Loads,
        live: usize,
        id_space: usize,
        now: u64,
    ) -> Option<ScaleOp> {
        self.reports_seen += 1;
        if let Some(last) = self.last_scale_at {
            if now.saturating_sub(last) < self.cooldown {
                return None;
            }
        }
        let op = match &mut self.policy {
            PolicyState::Watermarks { cfg, up_fp, down_fp } => {
                let mean = loads.decayed_mean_fp();
                if mean > *up_fp && id_space < cfg.max_reducers {
                    Some(ScaleOp::Up)
                } else if mean < *down_fp && live > cfg.min_reducers {
                    loads.coldest_live().map(ScaleOp::Down)
                } else {
                    None
                }
            }
            PolicyState::Schedule { ops, every_reports, min, max } => {
                if self.reports_seen % *every_reports != 0 {
                    return None;
                }
                match ops.as_slice().first().copied() {
                    Some(ScaleOp::Up) if id_space < *max => ops.next(),
                    Some(ScaleOp::Down(_)) if live > *min => {
                        ops.next();
                        loads.coldest_live().map(ScaleOp::Down)
                    }
                    Some(_) => {
                        ops.next(); // bound hit: drop the op, keep draining
                        None
                    }
                    None => None,
                }
            }
        };
        if op.is_some() {
            self.last_scale_at = Some(now);
        }
        op
    }

    /// Arm the cooldown without a decision (a membership change applied
    /// by someone else, e.g. a no-op retire retried later).
    pub fn arm_cooldown(&mut self, now: u64) {
        self.last_scale_at = Some(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::signal::{LoadSignal, SignalConfig};

    fn signal(qlens: &[u64]) -> LoadSignal {
        let s = LoadSignal::with_capacity(qlens.len(), 8, &SignalConfig::legacy());
        for (n, &q) in qlens.iter().enumerate() {
            s.set(n, q);
        }
        s
    }

    #[test]
    fn config_validation() {
        assert!(ElasticConfig::default().validate().is_ok());
        let bad = |f: fn(&mut ElasticConfig)| {
            let mut c = ElasticConfig::default();
            f(&mut c);
            c.validate().is_err()
        };
        assert!(bad(|c| c.scale_up = c.scale_down));
        assert!(bad(|c| c.scale_down = -1.0));
        assert!(bad(|c| c.scale_up = f64::NAN));
        assert!(bad(|c| c.min_reducers = 0));
        assert!(bad(|c| c.max_reducers = 0));
    }

    #[test]
    fn watermarks_scale_up_on_hot_mean() {
        let cfg =
            ElasticConfig { scale_up: 4.0, scale_down: 1.0, min_reducers: 2, max_reducers: 6 };
        let mut c = ElasticController::from_watermarks(cfg, 10);
        let loads = signal(&[20, 2]); // mean 11 > 4
        assert_eq!(c.decide(&loads, 2, 2, 0), Some(ScaleOp::Up));
        // cooldown suppresses an immediate second decision
        assert_eq!(c.decide(&loads, 3, 3, 5), None);
        assert_eq!(c.decide(&loads, 3, 3, 20), Some(ScaleOp::Up));
        // the ceiling is on the id space, not the live count
        assert_eq!(c.decide(&loads, 4, 6, 40), None, "max_reducers reached");
    }

    #[test]
    fn watermarks_scale_down_to_coldest() {
        let cfg =
            ElasticConfig { scale_up: 8.0, scale_down: 2.0, min_reducers: 2, max_reducers: 6 };
        let mut c = ElasticController::from_watermarks(cfg, 0);
        let loads = signal(&[1, 0, 2]); // mean 1 < 2, node 1 coldest
        assert_eq!(c.decide(&loads, 3, 3, 0), Some(ScaleOp::Down(1)));
        assert_eq!(c.decide(&loads, 2, 3, 1), None, "min_reducers floor");
    }

    #[test]
    fn watermarks_quiet_inside_the_band() {
        let cfg =
            ElasticConfig { scale_up: 8.0, scale_down: 1.0, min_reducers: 1, max_reducers: 8 };
        let mut c = ElasticController::from_watermarks(cfg, 0);
        let loads = signal(&[4, 4]); // mean 4: inside (1, 8)
        assert_eq!(c.decide(&loads, 2, 2, 0), None);
    }

    #[test]
    fn schedule_fires_every_n_reports_in_order() {
        let mut c = ElasticController::from_schedule(
            vec![ScaleOp::Up, ScaleOp::Down(0)],
            3,
            1,
            8,
        );
        let loads = signal(&[5, 1]);
        let mut fired = Vec::new();
        for now in 0..12u64 {
            if let Some(op) = c.decide(&loads, 2, 2, now) {
                fired.push(op);
            }
        }
        // node 1 is the coldest live node, so the scheduled Down retargets
        assert_eq!(fired, vec![ScaleOp::Up, ScaleOp::Down(1)]);
    }

    #[test]
    fn schedule_respects_bounds() {
        let mut c = ElasticController::from_schedule(vec![ScaleOp::Up], 1, 1, 2);
        let loads = signal(&[5, 1]);
        assert_eq!(c.decide(&loads, 2, 2, 0), None, "id space at max: op dropped");
        assert_eq!(c.decide(&loads, 2, 2, 1), None, "schedule drained");
    }
}
