//! The load balancer — "the heart of the system" (§2.4).
//!
//! It owns the routing/redistribution layer (a boxed
//! [`Router`](crate::hash::Router) behind a shared
//! [`RouterHandle`]), maintains the last-reported load state (queue size)
//! of every reducer, and repartitions the keyspace when the §4.1 policy
//! fires. [`policy`] holds the trigger predicate, [`signal`] the adaptive
//! load-signal subsystem (EWMA decay, hysteresis overload flags and the
//! migration-gain guard the probe routers consume — every
//! [`BalancerCore::observe`] feeds it), [`elastic`] the scaling policy
//! that grows/shrinks the reducer set itself off the same decayed signal,
//! [`BalancerCore`] the actor state shared by both drivers, and
//! [`state_forward`] the §7 staged state-forwarding extension.

pub mod elastic;
pub mod policy;
pub mod signal;
pub mod state_forward;

use crate::hash::{RouterHandle, StrategySpec};
use crate::metrics::{LbEvent, MembershipChange};

use elastic::{ElasticController, ScaleOp};
use policy::{LbPolicy, ThresholdPolicy};

/// Balancer actor state. The threads driver gives it to a dedicated
/// balancer thread; the sim driver calls it directly. Reducers report
/// load via [`Self::report`]; mappers/reducers route via the
/// [`RouterHandle`] it updates.
pub struct BalancerCore {
    router: RouterHandle,
    spec: StrategySpec,
    policy: Box<dyn LbPolicy + Send>,
    /// Last reported queue length per reducer.
    qlens: Vec<usize>,
    /// Which reducers have reported at least once. Until everyone has, the
    /// policy is not evaluated: a cold balancer seeing one busy reducer
    /// before the others check in would fire on `Q_s = 0` noise — the
    /// "premature LB" the paper blames for the small skew *increases* on
    /// WL1/WL2. Disable via [`Self::without_warmup`] to study that effect.
    reported: Vec<bool>,
    /// LB rounds already spent per reducer (Experiment 2 caps this).
    rounds: Vec<u32>,
    /// Max rounds *per reducer* (§6.4: "maximum allowable number of
    /// rounds per reducer").
    max_rounds: u32,
    /// Minimum virtual-time/µs gap between consecutive LB events; right
    /// after a repartition the queue lengths are stale (old-scheme records
    /// are still being forwarded), so immediate re-triggering would act on
    /// noise. The paper's periodic check has the same effect implicitly.
    cooldown: u64,
    last_event_at: Option<u64>,
    /// Elastic membership controller (`None` = fixed reducer set).
    elastic: Option<ElasticController>,
    events: Vec<LbEvent>,
}

impl BalancerCore {
    pub fn new(
        router: RouterHandle,
        spec: StrategySpec,
        tau: f64,
        min_trigger_qlen: usize,
        max_rounds: u32,
        cooldown: u64,
    ) -> Self {
        let reducers = router.nodes();
        BalancerCore {
            router,
            spec,
            policy: Box::new(ThresholdPolicy::new(tau, min_trigger_qlen)),
            qlens: vec![0; reducers],
            reported: vec![false; reducers],
            rounds: vec![0; reducers],
            max_rounds,
            cooldown,
            last_event_at: None,
            elastic: None,
            events: Vec::new(),
        }
    }

    /// Swap in a custom policy (ablations).
    pub fn with_policy(mut self, policy: Box<dyn LbPolicy + Send>) -> Self {
        self.policy = policy;
        self
    }

    /// Attach the elastic membership controller: scale decisions are then
    /// evaluated on every policy-eligible report, before Eq. 1 (changing
    /// parallelism beats reshuffling a keyspace every reducer of which is
    /// hot — and vice versa for a drained pipeline).
    pub fn with_elastic(mut self, controller: ElasticController) -> Self {
        self.elastic = Some(controller);
        self
    }

    /// Disable warm-up gating: evaluate Eq. 1 even before every reducer
    /// has reported (reproduces the cold-start premature triggers).
    pub fn without_warmup(mut self) -> Self {
        self.reported.iter_mut().for_each(|r| *r = true);
        self
    }

    /// The shared routing layer this balancer updates.
    pub fn router(&self) -> &RouterHandle {
        &self.router
    }

    pub fn spec(&self) -> StrategySpec {
        self.spec
    }

    pub fn events(&self) -> &[LbEvent] {
        &self.events
    }

    pub fn take_events(&mut self) -> Vec<LbEvent> {
        std::mem::take(&mut self.events)
    }

    pub fn rounds(&self) -> &[u32] {
        &self.rounds
    }

    /// A reducer (or the driver on its behalf) reports its current queue
    /// length (§3: reducers "periodically call a remote method on the load
    /// balancer to update their current load state"). The balancer checks
    /// the policy on every report and repartitions if it fires. Returns
    /// the event if the routing changed.
    pub fn report(&mut self, reducer: usize, qlen: usize, now: u64) -> Option<LbEvent> {
        self.observe(reducer, qlen);
        self.maybe_rebalance(now)
    }

    /// Update the load state *without* evaluating the policy — used while
    /// the §7 state-forwarding protocol is mid-stage (updates must be
    /// atomic and infrequent) and by idle-poll reports. Also feeds the
    /// observation into the router's shared [`Loads`](crate::hash::Loads)
    /// signal (raw + EWMA + hysteresis flags), which load-aware routers
    /// consult at route and redistribute time. The policy itself keeps
    /// triggering on the *raw* `qlens` — Eq. 1 is the paper's semantics;
    /// the smoothed signal shapes what a triggered redistribute does.
    pub fn observe(&mut self, reducer: usize, qlen: usize) {
        if reducer >= self.qlens.len() {
            // a reducer added at runtime (elastic extension)
            self.qlens.resize(reducer + 1, 0);
            self.rounds.resize(reducer + 1, 0);
            self.reported.resize(reducer + 1, false);
        }
        self.qlens[reducer] = qlen;
        self.reported[reducer] = true;
        self.router.loads().set(reducer, qlen as u64);
    }

    /// Evaluate the scaling policy (if attached) and Eq. 1 over the
    /// current load vector, and apply the router's membership change or
    /// redistribution if either fires.
    pub fn maybe_rebalance(&mut self, now: u64) -> Option<LbEvent> {
        if !self.reported.iter().all(|&r| r) {
            return None; // warm-up: wait until every reducer has reported
        }
        // parallelism first: when the whole pipeline is hot (or drained),
        // re-routing only reshuffles the problem — membership changes it
        if let Some(e) = self.maybe_scale(now) {
            return Some(e);
        }
        if self.spec == StrategySpec::None {
            return None;
        }
        if let Some(last) = self.last_event_at {
            if now.saturating_sub(last) < self.cooldown {
                return None;
            }
        }
        let target = self.policy.pick_target(&self.qlens)?;
        if self.rounds[target] >= self.max_rounds {
            return None;
        }
        if !self.router.is_live(target) {
            // a retired reducer draining its backlog is not a rebalance
            // target — its keys are already being forwarded away
            return None;
        }
        let delta = self.router.redistribute(target);
        if !delta.changed {
            if self.spec.is_token_ring() {
                // halving exhausted / doubling saturated — permanent for
                // the token ops: burn the rounds so we stop retrying
                self.rounds[target] = self.max_rounds;
            } else {
                // probe routers: a no-op redistribute is transient (loads
                // froze unchanged, or nothing was movable right now) —
                // rate-limit the retry with the normal cooldown instead of
                // disabling LB for this node for the rest of the run
                self.last_event_at = Some(now);
            }
            return None;
        }
        self.rounds[target] += 1;
        self.last_event_at = Some(now);
        let event = LbEvent {
            at: now,
            target: target as u32,
            qlens: self.qlens.clone(),
            epoch: self.router.epoch(),
            strategy: self.spec,
            delta,
            membership: None,
        };
        log::info!(
            "LB fired at {now}: target reducer {target}, qlens {:?}, strategy {}",
            event.qlens,
            self.spec
        );
        self.events.push(event.clone());
        Some(event)
    }

    /// Crash recovery (testkit::chaos): retire the dead reducer's slot
    /// and bring a replacement up in a fresh slot, as one membership
    /// surgery over the elastic `retire_node`/`add_node` lifecycle — so
    /// every router family's minimal-movement paths apply and the
    /// victim's keyspace re-homes exactly like a scale-down.
    ///
    /// Must be called from `Synchronized` (the driver gates recovery on
    /// it). The victim's *state* is not this method's business: the
    /// caller re-injects it from the replication lane after the routing
    /// has settled. Returns the respawn's reducer id, or `None` when the
    /// victim was already retired or the id space is exhausted — the
    /// recovery then re-homes onto the survivors alone.
    pub fn replace_faulted(&mut self, victim: usize, now: u64) -> Option<usize> {
        let retire = self.router.retire_node(victim);
        if !retire.changed {
            return None;
        }
        // the corpse's last reported backlog is being re-routed; leaving
        // it in the load vector would steer the policy at a ghost
        if victim < self.qlens.len() {
            self.qlens[victim] = 0;
        }
        self.router.loads().set(victim, 0);
        self.events.push(LbEvent {
            at: now,
            target: victim as u32,
            qlens: self.qlens.clone(),
            epoch: self.router.epoch(),
            strategy: self.spec,
            delta: retire,
            membership: Some(MembershipChange::Retired { id: victim as u32 }),
        });
        // stale queue lengths either way: arm the cooldowns like any
        // membership change
        self.last_event_at = Some(now);
        if let Some(e) = self.elastic.as_mut() {
            e.arm_cooldown(now);
        }
        let (id, delta) = self.router.add_node()?;
        self.qlens.resize(id + 1, 0);
        self.rounds.resize(id + 1, 0);
        // the respawn joins cold: warm-up gating holds until it reports
        self.reported.resize(id + 1, false);
        let event = LbEvent {
            at: now,
            target: id as u32,
            qlens: self.qlens.clone(),
            epoch: self.router.epoch(),
            strategy: self.spec,
            delta,
            membership: Some(MembershipChange::Added { id: id as u32 }),
        };
        log::info!(
            "crash recovery at {now}: reducer {victim} fail-stopped, respawned as {id}"
        );
        self.events.push(event);
        Some(id)
    }

    /// Evaluate the elastic membership policy and apply the scale
    /// decision through the router. Returns the membership event when the
    /// routable set changed.
    fn maybe_scale(&mut self, now: u64) -> Option<LbEvent> {
        let elastic = self.elastic.as_mut()?;
        let live = self.router.live_count();
        let id_space = self.router.nodes();
        let op = elastic.decide(self.router.loads(), live, id_space, now)?;
        let (target, delta, membership) = match op {
            ScaleOp::Up => {
                let (id, delta) = self.router.add_node()?; // capacity guard
                // the joiner must report before anything else may fire —
                // the same warm-up rule a cold start obeys
                self.qlens.resize(id + 1, 0);
                self.rounds.resize(id + 1, 0);
                self.reported.resize(id + 1, false);
                (id, delta, MembershipChange::Added { id: id as u32 })
            }
            ScaleOp::Down(id) => {
                let delta = self.router.retire_node(id);
                if !delta.changed {
                    // already retired / last live node: nothing to apply,
                    // the controller's cooldown already rate-limits retries
                    return None;
                }
                (id, delta, MembershipChange::Retired { id: id as u32 })
            }
        };
        debug_assert!(delta.changed);
        // a membership change also arms the LB cooldown — queue lengths
        // are stale until the new routing has had time to act
        self.last_event_at = Some(now);
        let event = LbEvent {
            at: now,
            target: target as u32,
            qlens: self.qlens.clone(),
            epoch: self.router.epoch(),
            strategy: self.spec,
            delta,
            membership: Some(membership),
        };
        log::info!(
            "elastic scaling at {now}: {membership:?}, {} live reducers, qlens {:?}",
            self.router.live_count(),
            event.qlens
        );
        self.events.push(event.clone());
        Some(event)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Strategy;

    fn mk(strategy: Strategy, max_rounds: u32) -> BalancerCore {
        let router = RouterHandle::new(strategy.build_router(4, 8, None));
        // tests drive reports for a subset of reducers; disable warm-up
        // gating except where it is the behaviour under test
        BalancerCore::new(router, strategy, 0.2, 4, max_rounds, 10).without_warmup()
    }

    #[test]
    fn fires_on_skewed_reports() {
        let mut b = mk(Strategy::Doubling, 1);
        assert!(b.report(0, 2, 0).is_none(), "below min trigger");
        assert!(b.report(1, 1, 1).is_none());
        let e = b.report(0, 20, 2).expect("should fire");
        assert_eq!(e.target, 0);
        assert_eq!(b.rounds()[0], 1);
        assert!(e.delta.changed);
        assert_eq!(e.delta.tokens_added, 3, "doubling grew the other 3 nodes");
    }

    #[test]
    fn respects_round_cap() {
        let mut b = mk(Strategy::Doubling, 1);
        assert!(b.report(0, 20, 0).is_some());
        // well past cooldown, still overloaded — but round cap hit
        assert!(b.report(0, 40, 100).is_none());
    }

    #[test]
    fn second_round_allowed_when_cap_is_two() {
        let mut b = mk(Strategy::Doubling, 2);
        assert!(b.report(0, 20, 0).is_some());
        assert!(b.report(0, 40, 100).is_some());
        assert!(b.report(0, 80, 200).is_none(), "cap 2 exhausted");
    }

    #[test]
    fn cooldown_suppresses_imm_retrigger() {
        let mut b = mk(Strategy::Doubling, 4);
        assert!(b.report(0, 20, 0).is_some());
        assert!(b.report(0, 40, 5).is_none(), "within cooldown of 10");
        assert!(b.report(0, 40, 20).is_some(), "after cooldown");
    }

    #[test]
    fn none_strategy_never_fires() {
        let mut b = mk(Strategy::None, 4);
        assert!(b.report(0, 1000, 0).is_none());
        assert!(b.events().is_empty());
    }

    #[test]
    fn uniform_load_never_fires() {
        let mut b = mk(Strategy::Halving, 4);
        // all reducers known-busy first (a cold balancer seeing one busy
        // reducer before the others report WOULD fire — that is exactly
        // the paper's "premature trigger" observation)
        for r in 0..4 {
            b.observe(r, 20);
        }
        for t in 0..50 {
            for r in 0..4 {
                assert!(b.report(r, 20, t * 4 + r as u64).is_none());
            }
        }
    }

    #[test]
    fn cold_start_first_report_can_fire_prematurely() {
        // documents the §6.3 effect: with only one reducer reported, Qs=0
        // and Eq.1 fires as soon as Qmax clears the floor
        let mut b = mk(Strategy::Doubling, 1);
        assert!(b.report(2, 10, 0).is_some());
    }

    #[test]
    fn warmup_gates_until_all_reported() {
        let router = RouterHandle::new(Strategy::Doubling.build_router(4, 8, None));
        let mut b = BalancerCore::new(router, Strategy::Doubling, 0.2, 4, 1, 10);
        assert!(b.report(0, 100, 0).is_none(), "3 reducers still unheard");
        b.observe(1, 0);
        b.observe(2, 0);
        assert!(b.report(0, 100, 1).is_none(), "one reducer still unheard");
        b.observe(3, 0);
        assert!(b.report(0, 100, 2).is_some(), "warm-up complete");
    }

    #[test]
    fn halving_exhaustion_burns_rounds() {
        // node with 1 token cannot halve: the balancer must not spin
        let router = RouterHandle::new(Strategy::Halving.build_router(4, 8, Some(1)));
        let mut b =
            BalancerCore::new(router, Strategy::Halving, 0.2, 4, 4, 0).without_warmup();
        assert!(b.report(2, 100, 0).is_none(), "halving impossible");
        assert_eq!(b.rounds()[2], 4, "rounds burned to stop retry loop");
    }

    #[test]
    fn ring_actually_changes_on_event() {
        let mut b = mk(Strategy::Doubling, 1);
        let tokens_of =
            |b: &BalancerCore, n: usize| b.router().with_ring(|r| r.tokens_of(n)).unwrap();
        let tokens_before: Vec<u32> = (0..4).map(|n| tokens_of(&b, n)).collect();
        b.report(3, 50, 0).unwrap();
        assert_eq!(tokens_of(&b, 3), tokens_before[3]);
        for n in 0..3 {
            assert_eq!(tokens_of(&b, n), tokens_before[n] * 2);
        }
    }

    #[test]
    fn multiprobe_event_has_zero_token_churn() {
        let mut b = mk(Strategy::MultiProbe { probes: 5 }, 1);
        b.observe(1, 1);
        b.observe(2, 1);
        b.observe(3, 1);
        let e = b.report(0, 50, 0).expect("skew fires on multi-probe too");
        assert!(e.delta.changed);
        assert!(e.delta.zero_token_churn());
        assert_eq!(e.delta.keys_reassigned, 0);
    }

    #[test]
    fn two_choices_event_reassigns_keys() {
        let mut b = mk(Strategy::TwoChoices, 1);
        // pin some keys by routing them, with reducer 0 the cold choice
        for i in 0..200u32 {
            b.router().route_key(format!("k{i}").as_bytes());
        }
        let e = b.report(0, 50, 0).expect("two-choices redistribute fires");
        assert!(e.delta.changed);
        assert!(e.delta.zero_token_churn());
        assert!(e.delta.keys_reassigned > 0, "keys were re-homed");
    }

    #[test]
    fn probe_router_noop_redistribute_is_not_exhaustion() {
        // a no-op redistribute means "nothing to re-freeze right now",
        // not "this node can never be relieved" — unlike halving
        // exhaustion it must not burn the round budget
        let mut b = mk(Strategy::MultiProbe { probes: 5 }, 4);
        b.observe(1, 1);
        b.observe(2, 1);
        b.observe(3, 1);
        assert!(b.report(0, 50, 0).is_some(), "first freeze fires");
        // identical loads past the cooldown: redistribute is a no-op
        assert!(b.report(0, 50, 20).is_none());
        assert_eq!(b.rounds()[0], 1, "no-op must not exhaust the target");
        // the shed set changes (a different node overloads) and the no-op
        // armed the cooldown: LB resumes instead of staying disabled
        assert!(b.report(1, 90, 40).is_some());
    }

    #[test]
    fn elastic_scale_up_and_down_through_reports() {
        use super::elastic::{ElasticConfig, ElasticController};
        use crate::metrics::MembershipChange;
        let cfg =
            ElasticConfig { scale_up: 4.0, scale_down: 1.0, min_reducers: 2, max_reducers: 4 };
        let router = RouterHandle::builder(Strategy::Doubling.build_router(2, 8, None))
            .signal(&crate::balancer::signal::SignalConfig::legacy())
            .capacity(cfg.max_reducers)
            .build();
        let mut b = BalancerCore::new(router, Strategy::Doubling, 0.2, 4, 1, 10)
            .with_elastic(ElasticController::from_watermarks(cfg, 10))
            .without_warmup();
        // hot mean (20+2)/2 = 11 > 4 → a brand-new reducer joins
        b.observe(1, 2);
        let e = b.report(0, 20, 0).expect("scale-up fires");
        assert_eq!(e.membership, Some(MembershipChange::Added { id: 2 }));
        assert!(e.delta.changed);
        assert_eq!(e.delta.nodes_added, 1);
        assert_eq!(b.router().live_count(), 3);
        // warm-up: nothing else may fire until the joiner reports
        assert!(b.report(0, 50, 30).is_none(), "joiner unheard");
        b.observe(2, 0);
        // cooled pipeline → the coldest reducer retires
        b.observe(0, 0);
        let e = b.report(1, 1, 40).expect("scale-down fires");
        assert!(matches!(e.membership, Some(MembershipChange::Retired { .. })));
        assert_eq!(e.delta.nodes_retired, 1);
        assert_eq!(b.router().live_count(), 2);
        // floor: no retire below min_reducers
        assert!(b.report(1, 0, 80).is_none());
        assert_eq!(b.router().live_count(), 2);
    }

    #[test]
    fn replace_faulted_retires_and_respawns_in_one_surgery() {
        use crate::metrics::MembershipChange;
        let router = RouterHandle::builder(Strategy::Doubling.build_router(4, 8, Some(1)))
            .signal(&crate::balancer::signal::SignalConfig::default())
            .capacity(6)
            .build();
        let mut b =
            BalancerCore::new(router, Strategy::Doubling, 0.2, 4, 1, 10).without_warmup();
        b.observe(2, 50);
        let id = b.replace_faulted(2, 5).expect("capacity for the respawn");
        assert_eq!(id, 4, "respawn takes the next fresh slot");
        assert!(!b.router().is_live(2), "the corpse left the routable set");
        assert!(b.router().is_live(4));
        assert_eq!(b.router().live_count(), 4);
        assert_eq!(b.router().loads().get(2), 0, "ghost load cleared");
        let memberships: Vec<_> =
            b.events().iter().filter_map(|e| e.membership).collect();
        assert_eq!(
            memberships,
            vec![
                MembershipChange::Retired { id: 2 },
                MembershipChange::Added { id: 4 },
            ]
        );
        // a second fail-stop of the same slot is a no-op
        assert!(b.replace_faulted(2, 6).is_none());
    }

    #[test]
    fn replace_faulted_without_capacity_still_retires() {
        // id space exhausted: the victim retires (keys re-home onto the
        // survivors) but no respawn joins
        let router = RouterHandle::new(Strategy::Doubling.build_router(4, 8, Some(1)));
        let mut b =
            BalancerCore::new(router, Strategy::Doubling, 0.2, 4, 1, 10).without_warmup();
        assert!(b.replace_faulted(1, 0).is_none());
        assert!(!b.router().is_live(1));
        assert_eq!(b.router().live_count(), 3);
    }

    #[test]
    fn retired_reducer_is_not_a_rebalance_target() {
        let mut b = mk(Strategy::Doubling, 4);
        b.observe(1, 1);
        b.observe(2, 1);
        b.observe(3, 1);
        assert!(b.router().retire_node(0).changed);
        // reducer 0's drain backlog looks huge, but it is retired: no event
        assert!(b.report(0, 500, 0).is_none());
        // a live hot reducer still triggers normally
        assert!(b.report(1, 500, 20).is_some());
    }

    #[test]
    fn observe_publishes_loads_to_router() {
        let b = {
            let mut b = mk(Strategy::TwoChoices, 1);
            b.observe(2, 17);
            b
        };
        assert_eq!(b.router().loads().get(2), 17);
    }

    #[test]
    fn observe_feeds_the_decayed_signal() {
        use crate::balancer::signal::{FRAC_BITS, SignalConfig};
        let cfg = SignalConfig { decay_alpha: 0.5, hysteresis: 0.0, min_gain: 0.0 };
        let router = RouterHandle::builder(Strategy::TwoChoices.build_router(4, 8, None))
            .signal(&cfg)
            .build();
        let mut b =
            BalancerCore::new(router, Strategy::TwoChoices, 0.2, 4, 1, 10).without_warmup();
        b.observe(2, 100);
        b.observe(2, 100);
        let loads = b.router().loads();
        assert_eq!(loads.get(2), 100, "raw lane mirrors the report");
        assert_eq!(loads.decayed(2), 75 << FRAC_BITS, "EWMA after two samples");
        assert!(loads.overloaded(2), "sole loaded reducer is flagged");
    }
}
