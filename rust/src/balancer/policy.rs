//! Load-balancing trigger policies.
//!
//! The paper's policy (§4.1, Eq. 1): with `Q_max` the largest queue and
//! `Q_s` the second largest, repartition when `Q_max > Q_s * (1 + τ)`.
//! [`ThresholdPolicy`] implements exactly that (plus a small absolute
//! floor so empty pipelines don't trigger on `1 > 0`). Alternative
//! policies are provided for the ablation benches.

/// A policy inspects the last-reported queue lengths and either picks an
/// overloaded reducer to relieve or stays quiet.
pub trait LbPolicy {
    fn pick_target(&self, qlens: &[usize]) -> Option<usize>;
    fn name(&self) -> &'static str;
}

/// Eq. 1 of the paper: trigger on `Q_max > Q_s * (1 + τ)`.
///
/// `min_trigger_qlen` is an implementation guard the paper leaves
/// implicit: `Q_s` can be 0 (e.g. while queues are still filling), making
/// the raw predicate fire on a single enqueued record. Requiring
/// `Q_max >= min_trigger_qlen` keeps the trigger meaningful; set it to 1
/// to recover the literal predicate.
#[derive(Clone, Debug)]
pub struct ThresholdPolicy {
    pub tau: f64,
    pub min_trigger_qlen: usize,
}

impl ThresholdPolicy {
    pub fn new(tau: f64, min_trigger_qlen: usize) -> Self {
        assert!(tau >= 0.0, "τ must be non-negative (§4.1)");
        ThresholdPolicy {
            tau,
            min_trigger_qlen: min_trigger_qlen.max(1),
        }
    }

    /// Indices of the max and second-max queue lengths.
    fn argmax2(qlens: &[usize]) -> Option<(usize, usize)> {
        if qlens.len() < 2 {
            return None;
        }
        let mut x = 0usize;
        for i in 1..qlens.len() {
            if qlens[i] > qlens[x] {
                x = i;
            }
        }
        let mut s = usize::from(x == 0);
        for i in 0..qlens.len() {
            if i != x && qlens[i] > qlens[s] {
                s = i;
            }
        }
        Some((x, s))
    }
}

impl LbPolicy for ThresholdPolicy {
    fn pick_target(&self, qlens: &[usize]) -> Option<usize> {
        let (x, s) = Self::argmax2(qlens)?;
        let qmax = qlens[x] as f64;
        let qs = qlens[s] as f64;
        if qlens[x] >= self.min_trigger_qlen && qmax > qs * (1.0 + self.tau) {
            Some(x)
        } else {
            None
        }
    }

    fn name(&self) -> &'static str {
        "threshold(eq1)"
    }
}

/// Ablation: trigger when `Q_max` exceeds the *mean* of the other queues
/// by factor `(1 + τ)` — less sensitive to a single other busy reducer.
///
/// Construct via [`MeanRatioPolicy::new`], which validates like
/// [`ThresholdPolicy::new`]: τ ≥ 0 and a floor of at least 1 on
/// `min_trigger_qlen`, enforced once at construction instead of ad hoc
/// per evaluation.
#[derive(Clone, Debug)]
pub struct MeanRatioPolicy {
    tau: f64,
    min_trigger_qlen: usize,
}

impl MeanRatioPolicy {
    pub fn new(tau: f64, min_trigger_qlen: usize) -> Self {
        assert!(tau >= 0.0, "τ must be non-negative (§4.1)");
        MeanRatioPolicy {
            tau,
            min_trigger_qlen: min_trigger_qlen.max(1),
        }
    }
}

impl LbPolicy for MeanRatioPolicy {
    fn pick_target(&self, qlens: &[usize]) -> Option<usize> {
        if qlens.len() < 2 {
            return None;
        }
        let x = (0..qlens.len()).max_by_key(|&i| qlens[i])?;
        if qlens[x] < self.min_trigger_qlen {
            return None;
        }
        let rest: f64 = qlens
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != x)
            .map(|(_, &q)| q as f64)
            .sum::<f64>()
            / (qlens.len() - 1) as f64;
        if qlens[x] as f64 > rest * (1.0 + self.tau) {
            Some(x)
        } else {
            None
        }
    }

    fn name(&self) -> &'static str {
        "mean-ratio"
    }
}

/// Ablation: never trigger (equivalent to Strategy::None but at the
/// policy layer, for harness symmetry).
#[derive(Clone, Debug)]
pub struct NeverPolicy;

impl LbPolicy for NeverPolicy {
    fn pick_target(&self, _qlens: &[usize]) -> Option<usize> {
        None
    }

    fn name(&self) -> &'static str {
        "never"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_fires_exactly_per_paper() {
        // τ = 0.2: fire iff Qmax > 1.2 * Qs
        let p = ThresholdPolicy::new(0.2, 1);
        assert_eq!(p.pick_target(&[13, 10, 2, 1]), Some(0)); // 13 > 12
        assert_eq!(p.pick_target(&[12, 10, 2, 1]), None); // 12 !> 12
        assert_eq!(p.pick_target(&[5, 5, 5, 5]), None);
        assert_eq!(p.pick_target(&[0, 0, 0, 7]), Some(3)); // Qs = 0
    }

    #[test]
    fn tau_zero_is_maximally_sensitive() {
        let p = ThresholdPolicy::new(0.0, 1);
        assert_eq!(p.pick_target(&[2, 1, 1, 1]), Some(0));
        assert_eq!(p.pick_target(&[1, 1, 1, 1]), None, "no strict excess");
    }

    #[test]
    fn min_trigger_floor() {
        let p = ThresholdPolicy::new(0.2, 8);
        assert_eq!(p.pick_target(&[7, 0, 0, 0]), None);
        assert_eq!(p.pick_target(&[8, 0, 0, 0]), Some(0));
    }

    #[test]
    fn argmax2_handles_max_at_zero() {
        let p = ThresholdPolicy::new(0.2, 1);
        assert_eq!(p.pick_target(&[50, 1, 1, 42]), None); // 50 !> 50.4
        assert_eq!(p.pick_target(&[50, 1, 1, 30]), Some(0)); // 50 > 36
    }

    #[test]
    fn too_few_reducers_never_fire() {
        let p = ThresholdPolicy::new(0.2, 1);
        assert_eq!(p.pick_target(&[100]), None);
        assert_eq!(p.pick_target(&[]), None);
    }

    #[test]
    fn mean_ratio_differs_from_eq1() {
        // second-max 10 suppresses eq1; mean of others (10+2+0)/3 = 4
        // lets mean-ratio fire
        let eq1 = ThresholdPolicy::new(0.2, 1);
        let mr = MeanRatioPolicy::new(0.2, 1);
        let q = [11, 10, 2, 0];
        assert_eq!(eq1.pick_target(&q), None);
        assert_eq!(mr.pick_target(&q), Some(0));
    }

    #[test]
    fn mean_ratio_constructor_validates() {
        // zero floor is clamped to 1 at construction, not per evaluation
        let mr = MeanRatioPolicy::new(0.2, 0);
        assert_eq!(mr.pick_target(&[0, 0, 0, 0]), None, "empty queues never fire");
        assert_eq!(mr.pick_target(&[1, 0, 0, 0]), Some(0), "floor behaves as 1");
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn mean_ratio_rejects_negative_tau() {
        MeanRatioPolicy::new(-0.1, 1);
    }

    #[test]
    fn never_policy() {
        assert_eq!(NeverPolicy.pick_target(&[1000, 0, 0, 0]), None);
    }
}
