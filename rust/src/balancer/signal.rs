//! The adaptive load-signal subsystem.
//!
//! The paper triggers redistribution off raw instantaneous queue lengths
//! (§4.1 Eq. 1), and the probe routers used to freeze those same raw
//! values into their routing state — the shed flags of
//! [`MultiProbeRouter`](crate::hash::MultiProbeRouter) and the first-sight
//! loads of [`TwoChoicesRouter`](crate::hash::TwoChoicesRouter). Raw
//! instantaneous loads ping-pong keys on adversarial skew (one hot key
//! drags its queue wherever it is routed, so every redistribution makes
//! the *previous* owner look cold and the new owner hot — WL3): AutoFlow
//! and "When Two Choices Are not Enough" both smooth the signal and guard
//! migrations behind a minimum improvement so repeated migrations
//! converge instead of oscillating.
//!
//! [`LoadSignal`] is that smoothed view. It is the lock-free per-reducer
//! load store shared between the balancer (the only writer — reports
//! arrive over the existing [`LoadReport`](crate::runtime::exec::LoadReport)
//! channel and land here via `BalancerCore::observe`) and the load-aware
//! routers (readers). Per reducer it maintains:
//!
//! * the **raw** last-reported queue length (what Eq. 1 keeps triggering
//!   on — the paper's policy semantics are untouched);
//! * an **EWMA-decayed** queue length in integer fixed point
//!   (`decayed' = α·raw + (1-α)·decayed`, [`FRAC_BITS`] fractional bits,
//!   exact integer arithmetic so every lane — scalar routers, snapshot
//!   tensors, compiled kernels — sees bit-identical values);
//! * a **hysteresis-banded overload flag**: the flag turns on only when
//!   the decayed load crosses `mean·(1+hysteresis)` and back off only
//!   below `mean·(1-hysteresis)` — inside the band it keeps its state,
//!   so a reducer must cross *distinct* watermarks to flip.
//!
//! [`SignalConfig::min_gain`] is the migration-gain guard:
//! [`LoadSignal::migration_gain_ok`] admits a key re-home only when the
//! destination's decayed load undercuts the source's by at least that
//! fraction, which is what stops `TwoChoicesRouter::redistribute` from
//! bouncing a hot key between its two candidates.
//!
//! [`SignalConfig::legacy()`] (α = 1, no band, no gain guard) reproduces
//! the pre-signal behavior bit for bit: the decayed value is exactly the
//! raw value in fixed point, the flag is the old strictly-above-mean
//! classification, and the gain guard is disabled.

#![forbid(unsafe_code)]
// Every public item here is a contract some other lane (router, snapshot
// tensor, kernel) must replay bit-for-bit — undocumented surface is how
// those lanes drift.
#![warn(missing_docs)]

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::Arc;

/// Fractional bits of the fixed-point decayed load. Every consumer of the
/// decayed signal (routers, snapshot tensors, the compiled kernels'
/// frozen-load inputs) sees values scaled by `1 << FRAC_BITS`.
pub const FRAC_BITS: u32 = 8;

/// Resolution of the `decay_alpha` / `hysteresis` / `min_gain` knobs once
/// converted to integer fixed point.
pub const KNOB_SCALE: u64 = 1 << 16;

/// User-facing signal knobs (TOML `[balancer]` keys `decay_alpha`,
/// `hysteresis`, `min_gain`; CLI `--decay-alpha`, `--hysteresis`,
/// `--min-gain`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SignalConfig {
    /// EWMA weight of the newest sample, in `(0, 1]`. `1.0` = no
    /// smoothing (the decayed signal mirrors the raw queue length).
    pub decay_alpha: f64,
    /// Half-width of the overload band around the mean decayed load, as a
    /// fraction of the mean: flag on above `mean·(1+hysteresis)`, off
    /// below `mean·(1-hysteresis)`. `0.0` = the legacy strictly-above-mean
    /// classification; values ≥ 1 never release a flag once set.
    pub hysteresis: f64,
    /// Minimum fractional improvement a key migration must promise:
    /// re-home from `a` to `b` only when
    /// `decayed(b) ≤ decayed(a)·(1 - min_gain)`. `0.0` disables the guard
    /// (legacy unconditional re-homing); must be < 1.
    pub min_gain: f64,
}

impl SignalConfig {
    /// The pre-signal behavior: undecayed loads, above-mean flags, no
    /// migration guard. Bit-compatible with the PR 2/3 routers.
    pub fn legacy() -> Self {
        SignalConfig { decay_alpha: 1.0, hysteresis: 0.0, min_gain: 0.0 }
    }

    /// Range-check the knobs; the `Err` message names the offending
    /// TOML key so config typos fail loudly.
    ///
    /// ```
    /// use dpa::balancer::signal::SignalConfig;
    ///
    /// assert!(SignalConfig::default().validate().is_ok());
    /// let bad = SignalConfig { decay_alpha: 0.0, ..SignalConfig::default() };
    /// assert!(bad.validate().unwrap_err().contains("decay_alpha"));
    /// ```
    pub fn validate(&self) -> Result<(), String> {
        // NaN fails every branch explicitly — a NaN knob must not slip
        // through as "not less than zero"
        if self.decay_alpha.is_nan() || self.decay_alpha <= 0.0 || self.decay_alpha > 1.0 {
            return Err(format!(
                "balancer.decay_alpha must be in (0, 1], got {}",
                self.decay_alpha
            ));
        }
        if self.hysteresis.is_nan() || self.hysteresis < 0.0 {
            return Err(format!(
                "balancer.hysteresis must be non-negative, got {}",
                self.hysteresis
            ));
        }
        if self.min_gain.is_nan() || self.min_gain < 0.0 || self.min_gain >= 1.0 {
            return Err(format!(
                "balancer.min_gain must be in [0, 1), got {}",
                self.min_gain
            ));
        }
        Ok(())
    }
}

impl Default for SignalConfig {
    /// The recommended smoothing: enough memory that one redistribution's
    /// load shift does not immediately invert the signal, a band wide
    /// enough that border reducers keep their classification, and a gain
    /// guard that rejects near-lateral key moves.
    fn default() -> Self {
        SignalConfig { decay_alpha: 0.5, hysteresis: 0.25, min_gain: 0.1 }
    }
}

#[derive(Debug)]
struct SignalInner {
    raw: Vec<AtomicU64>,
    /// EWMA-decayed loads, `FRAC_BITS` fixed point, saturated at
    /// `u32::MAX` — the compiled route programs carry loads as u32, so
    /// saturating *in the signal* keeps the scalar (u64) and compiled
    /// (u32) comparisons literally identical in every regime.
    decayed: Vec<AtomicU64>,
    /// Hysteresis-banded overload flags.
    flags: Vec<AtomicBool>,
    /// Which nodes have reported at least once. Until all have, flags
    /// use the total above-mean rule: the sticky band would otherwise
    /// freeze warm-up-order transients (the first reporter carries all
    /// observed load for an instant) that uniform steady load could
    /// never release.
    seen: Vec<AtomicBool>,
    /// Elastic membership: which slots participate in the mean/flag
    /// computation. Slots are pre-allocated to a fixed capacity so the
    /// store stays lock-free; scale-up activates a slot, scale-down
    /// retires it (retired slots read as zero and are never flagged).
    live: Vec<AtomicBool>,
    /// EWMA new-sample weight, `KNOB_SCALE` fixed point (`KNOB_SCALE` =
    /// no smoothing).
    alpha: u64,
    /// Flag-on threshold `KNOB_SCALE·(1+hysteresis)`.
    high: u64,
    /// Flag-off threshold `KNOB_SCALE·(1-hysteresis)`, floored at 0.
    low: u64,
    /// Migration-gain guard, `KNOB_SCALE` fixed point (0 = disabled).
    min_gain: u64,
}

/// Lock-free per-reducer load signal: raw + EWMA-decayed queue lengths
/// and hysteresis overload flags, shared between the balancer (writer)
/// and the load-aware routers (readers). Clones share state.
///
/// This type *is* the `hash::Loads` view the [`Router`](crate::hash::Router)
/// trait routes against — `Loads` is an alias for it.
///
/// ```
/// use dpa::balancer::signal::{LoadSignal, SignalConfig, FRAC_BITS};
///
/// let cfg = SignalConfig { decay_alpha: 0.5, hysteresis: 0.0, min_gain: 0.0 };
/// let s = LoadSignal::with_config(2, &cfg);
/// s.set(0, 100);
/// // half-weight EWMA in FRAC_BITS fixed point
/// assert_eq!(s.decayed(0), 50u64 << FRAC_BITS);
/// assert_eq!(s.get(0), 100, "the raw lane keeps the instantaneous value");
/// ```
#[derive(Clone, Debug)]
pub struct LoadSignal {
    inner: Arc<SignalInner>,
}

impl LoadSignal {
    /// A legacy (unsmoothed) signal — see [`SignalConfig::legacy`]. This
    /// is what bare `RouterHandle::new` constructs, keeping router unit
    /// semantics bit-compatible with the raw-load era.
    pub fn new(nodes: usize) -> Self {
        Self::with_config(nodes, &SignalConfig::legacy())
    }

    /// A signal with explicit smoothing knobs (the pipeline threads the
    /// `[balancer]` config here). Capacity equals the initial node count
    /// (the fixed-membership case); elastic runs use
    /// [`Self::with_capacity`].
    pub fn with_config(nodes: usize, cfg: &SignalConfig) -> Self {
        Self::with_capacity(nodes, nodes, cfg)
    }

    /// A signal with `capacity` pre-allocated slots of which the first
    /// `nodes` start live. Elastic membership changes go through
    /// [`Self::activate`] / [`Self::retire`]; pre-allocation (rather than
    /// growth) is what keeps the store lock-free.
    pub fn with_capacity(nodes: usize, capacity: usize, cfg: &SignalConfig) -> Self {
        let capacity = capacity.max(nodes);
        let knob = |v: f64| (v * KNOB_SCALE as f64).round() as u64;
        let h = knob(cfg.hysteresis);
        LoadSignal {
            inner: Arc::new(SignalInner {
                raw: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
                decayed: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
                flags: (0..capacity).map(|_| AtomicBool::new(false)).collect(),
                seen: (0..capacity).map(|_| AtomicBool::new(false)).collect(),
                live: (0..capacity).map(|i| AtomicBool::new(i < nodes)).collect(),
                alpha: knob(cfg.decay_alpha).clamp(1, KNOB_SCALE),
                high: KNOB_SCALE + h,
                low: KNOB_SCALE.saturating_sub(h),
                min_gain: knob(cfg.min_gain).min(KNOB_SCALE - 1),
            }),
        }
    }

    /// Slot capacity (the id space the signal can ever track).
    pub fn nodes(&self) -> usize {
        self.inner.raw.len()
    }

    /// Nodes currently participating in the mean/flag computation.
    pub fn live_count(&self) -> usize {
        self.inner.live.iter().filter(|l| l.load(Ordering::Relaxed)).count()
    }

    /// Is `node` a live (participating) slot?
    pub fn is_live(&self, node: usize) -> bool {
        self.inner.live.get(node).is_some_and(|l| l.load(Ordering::Relaxed))
    }

    /// Elastic scale-up: slot `node` joins the mean/flag computation with
    /// a clean history. It re-enters warm-up (`seen = false`), so the
    /// hysteresis band disengages until the new node has reported — the
    /// mean just shifted regime, and freezing a pre-shift classification
    /// would be exactly the warm-up transient the total rule exists for.
    pub fn activate(&self, node: usize) {
        let i = &*self.inner;
        let (Some(live), Some(seen)) = (i.live.get(node), i.seen.get(node)) else {
            return;
        };
        i.raw[node].store(0, Ordering::Relaxed);
        i.decayed[node].store(0, Ordering::Relaxed);
        i.flags[node].store(false, Ordering::Relaxed);
        seen.store(false, Ordering::Relaxed);
        live.store(true, Ordering::Relaxed);
        self.refresh_flags();
    }

    /// Elastic scale-down: slot `node` leaves the computation. Its load
    /// reads as zero, it is never flagged, and the remaining nodes' flags
    /// are refreshed against the shrunken mean.
    pub fn retire(&self, node: usize) {
        let i = &*self.inner;
        let Some(live) = i.live.get(node) else {
            return;
        };
        live.store(false, Ordering::Relaxed);
        i.raw[node].store(0, Ordering::Relaxed);
        i.decayed[node].store(0, Ordering::Relaxed);
        i.flags[node].store(false, Ordering::Relaxed);
        self.refresh_flags();
    }

    /// Record one load observation: stores the raw queue length, folds it
    /// into the EWMA and refreshes every node's hysteresis flag (the mean
    /// moved). Out-of-range nodes (elastic scale-out beyond the initial
    /// topology) are ignored — token routing never consults loads.
    pub fn set(&self, node: usize, qlen: u64) {
        let i = &*self.inner;
        let (Some(raw), Some(dec)) = (i.raw.get(node), i.decayed.get(node)) else {
            return;
        };
        raw.store(qlen, Ordering::Relaxed);
        // decayed values saturate at the compiled-lane width (u32): the
        // route_assign tensor carries them as u32, and saturating here —
        // rather than at tensor-packing time — keeps the scalar router's
        // comparisons identical to the kernel's even when both operands
        // are pinned at the ceiling
        let q_fp = qlen.saturating_mul(1 << FRAC_BITS).min(u32::MAX as u64);
        let next = if i.alpha == KNOB_SCALE {
            q_fp
        } else {
            // convex combination of two values ≤ u32::MAX stays ≤ u32::MAX
            ((i.alpha as u128 * q_fp as u128
                + (KNOB_SCALE - i.alpha) as u128 * dec.load(Ordering::Relaxed) as u128)
                / KNOB_SCALE as u128) as u64
        };
        dec.store(next, Ordering::Relaxed);
        self.refresh_flags();
        // marked only after the refresh: the refresh that completes
        // warm-up must itself still use the total rule, so the band
        // engages on a clean full-view slate
        i.seen[node].store(true, Ordering::Relaxed);
    }

    /// Re-evaluate the overload flags against the current decayed mean.
    ///
    /// Until every node has reported once: the total above-mean rule
    /// (`d·n > Σd`), exactly the pre-signal classification — the band
    /// must not freeze warm-up-order transients. Afterwards: on above
    /// `mean·(1+h)`, off at or below `mean·(1-h)`, kept inside the band.
    /// With `h = 0` the two rules coincide (on iff strictly above the
    /// mean), which is what makes [`SignalConfig::legacy`] bit-compatible
    /// with the old per-redistribute flag computation. Exact integer
    /// comparisons (`d·n·S` vs `Σd·(S±h)`), no float rounding.
    fn refresh_flags(&self) {
        let i = &*self.inner;
        let lv: Vec<bool> = i.live.iter().map(|l| l.load(Ordering::Relaxed)).collect();
        let n = lv.iter().filter(|&&l| l).count() as u128;
        if n == 0 {
            return;
        }
        let ds: Vec<u64> = i.decayed.iter().map(|d| d.load(Ordering::Relaxed)).collect();
        let sum: u128 = ds.iter().zip(&lv).filter(|&(_, &l)| l).map(|(&d, _)| d as u128).sum();
        // the band engages only once every LIVE node has reported; a
        // freshly activated node re-opens warm-up (see `activate`)
        let banded = i
            .seen
            .iter()
            .zip(&lv)
            .all(|(s, &l)| !l || s.load(Ordering::Relaxed));
        for (node, &d) in ds.iter().enumerate() {
            if !lv[node] {
                i.flags[node].store(false, Ordering::Relaxed);
                continue;
            }
            let lhs = d as u128 * n * KNOB_SCALE as u128;
            if !banded {
                i.flags[node].store(lhs > sum * KNOB_SCALE as u128, Ordering::Relaxed);
            } else if lhs > sum * i.high as u128 {
                i.flags[node].store(true, Ordering::Relaxed);
            } else if lhs <= sum * i.low as u128 {
                i.flags[node].store(false, Ordering::Relaxed);
            }
        }
    }

    /// Last raw reported queue length.
    pub fn get(&self, node: usize) -> u64 {
        self.inner.raw.get(node).map_or(0, |a| a.load(Ordering::Relaxed))
    }

    /// Raw queue lengths.
    pub fn to_vec(&self) -> Vec<u64> {
        self.inner.raw.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    /// EWMA-decayed load, `FRAC_BITS` fixed point, saturated at
    /// `u32::MAX` (the compiled-lane width). Under the legacy config
    /// this is exactly `get(node) << FRAC_BITS` for any realistic qlen.
    pub fn decayed(&self, node: usize) -> u64 {
        self.inner.decayed.get(node).map_or(0, |a| a.load(Ordering::Relaxed))
    }

    /// Decayed loads (fixed point).
    pub fn decayed_vec(&self) -> Vec<u64> {
        self.inner.decayed.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    /// Current hysteresis overload flag of `node`.
    pub fn overloaded(&self, node: usize) -> bool {
        self.inner.flags.get(node).is_some_and(|f| f.load(Ordering::Relaxed))
    }

    /// All hysteresis overload flags.
    pub fn flags_vec(&self) -> Vec<bool> {
        self.inner.flags.iter().map(|f| f.load(Ordering::Relaxed)).collect()
    }

    /// Mean EWMA-decayed load over the live nodes (`FRAC_BITS` fixed
    /// point) — the watermark input of the elastic scaling policy.
    pub fn decayed_mean_fp(&self) -> u64 {
        let i = &*self.inner;
        let mut sum = 0u128;
        let mut n = 0u128;
        for (d, l) in i.decayed.iter().zip(&i.live) {
            if l.load(Ordering::Relaxed) {
                sum += d.load(Ordering::Relaxed) as u128;
                n += 1;
            }
        }
        if n == 0 {
            0
        } else {
            (sum / n) as u64
        }
    }

    /// The live node with the smallest decayed load (ties to the lowest
    /// id) — the scale-down victim choice. `None` when nothing is live.
    pub fn coldest_live(&self) -> Option<usize> {
        let i = &*self.inner;
        i.decayed
            .iter()
            .zip(&i.live)
            .enumerate()
            .filter(|(_, (_, l))| l.load(Ordering::Relaxed))
            .min_by_key(|(n, (d, _))| (d.load(Ordering::Relaxed), *n))
            .map(|(n, _)| n)
    }

    /// The migration-gain guard: may a key move from `from` to `to`?
    /// `true` when the guard is disabled (`min_gain = 0`, the legacy
    /// unconditional re-homing) or when `to`'s decayed load undercuts
    /// `from`'s by at least the configured fraction.
    pub fn migration_gain_ok(&self, from: usize, to: usize) -> bool {
        let g = self.inner.min_gain;
        if g == 0 {
            return true;
        }
        let df = self.decayed(from) as u128;
        let dt = self.decayed(to) as u128;
        dt * KNOB_SCALE as u128 <= df * (KNOB_SCALE - g) as u128
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FP: u64 = 1 << FRAC_BITS;

    #[test]
    fn legacy_signal_mirrors_raw_loads() {
        let s = LoadSignal::new(4);
        for (n, q) in [(0u64, 40u64), (1, 7), (2, 6), (3, 5)].map(|(n, q)| (n as usize, q)) {
            s.set(n, q);
        }
        assert_eq!(s.to_vec(), vec![40, 7, 6, 5]);
        assert_eq!(s.decayed_vec(), vec![40 * FP, 7 * FP, 6 * FP, 5 * FP]);
        // above-mean classification, exactly like the old overload_flags
        assert_eq!(s.flags_vec(), vec![true, false, false, false]);
        // legacy guard is disabled: any move is admissible
        assert!(s.migration_gain_ok(3, 0));
    }

    #[test]
    fn ewma_decays_toward_observations() {
        let cfg = SignalConfig { decay_alpha: 0.5, ..SignalConfig::legacy() };
        let s = LoadSignal::with_config(2, &cfg);
        s.set(0, 100);
        assert_eq!(s.decayed(0), 50 * FP, "first sample: α·q");
        s.set(0, 100);
        assert_eq!(s.decayed(0), 75 * FP, "converging toward 100");
        s.set(0, 0);
        assert_eq!(s.decayed(0), 75 * FP / 2, "decaying back down");
        assert_eq!(s.get(0), 0, "raw lane tracks the instantaneous value");
    }

    #[test]
    fn ewma_contracts_toward_the_observed_value() {
        // |d' - q_fp| <= |d - q_fp| for every update, including with
        // integer truncation — the property props.rs fuzzes
        let cfg = SignalConfig { decay_alpha: 0.3, ..SignalConfig::legacy() };
        let s = LoadSignal::with_config(1, &cfg);
        s.set(0, 1000);
        let mut prev = s.decayed(0);
        for _ in 0..50 {
            s.set(0, 10);
            let d = s.decayed(0);
            let target = 10 * FP;
            assert!(d.abs_diff(target) <= prev.abs_diff(target));
            prev = d;
        }
    }

    #[test]
    fn hysteresis_band_keeps_flags_inside() {
        let cfg = SignalConfig {
            decay_alpha: 1.0,
            hysteresis: 0.5,
            min_gain: 0.0,
        };
        let s = LoadSignal::with_config(4, &cfg);
        for n in 0..4 {
            s.set(n, 10);
        }
        // warm-up uses the total above-mean rule (the band would freeze
        // reporting-order transients), so uniform load ends all-clear
        assert_eq!(s.flags_vec(), vec![false; 4]);
        s.set(0, 28); // mean 14.5: 28 > 21.75 → on (band is live now)
        assert!(s.overloaded(0));
        s.set(0, 12); // mean 10.5: 12 inside (5.25, 15.75] → stays on
        assert!(s.overloaded(0), "inside the band the flag must stick");
        s.set(0, 4); // mean 8.5: 4 < 4.25 → off
        assert!(!s.overloaded(0));
        s.set(0, 12); // back inside the band → stays off
        assert!(!s.overloaded(0), "re-entering the band must not re-flag");
    }

    #[test]
    fn migration_gain_guard_blocks_lateral_moves() {
        let cfg = SignalConfig {
            decay_alpha: 1.0,
            hysteresis: 0.0,
            min_gain: 0.25,
        };
        let s = LoadSignal::with_config(2, &cfg);
        s.set(0, 100);
        s.set(1, 80);
        assert!(!s.migration_gain_ok(0, 1), "80 > 75 = 100·(1-0.25)");
        s.set(1, 75);
        assert!(s.migration_gain_ok(0, 1), "exactly the promised gain");
        s.set(1, 100);
        assert!(!s.migration_gain_ok(0, 1), "lateral move rejected");
    }

    #[test]
    fn saturating_arithmetic_on_huge_loads() {
        // the decayed lane saturates at the compiled route programs' u32
        // width, so scalar and compiled comparisons agree even pinned at
        // the ceiling
        let s = LoadSignal::new(2);
        s.set(0, u64::MAX);
        assert_eq!(s.decayed(0), u32::MAX as u64, "saturates at the compiled width");
        assert_eq!(s.get(0), u64::MAX, "raw lane keeps the full value");
        let cfg = SignalConfig { decay_alpha: 0.5, ..SignalConfig::legacy() };
        let s = LoadSignal::with_config(1, &cfg);
        s.set(0, u64::MAX);
        s.set(0, u64::MAX);
        let d = s.decayed(0);
        assert!(d > 0 && d <= u32::MAX as u64, "no overflow wraparound");
    }

    #[test]
    fn warmup_uses_total_rule_until_everyone_reported() {
        // with the band live from the start, the first reporter (briefly
        // carrying ALL observed load) would be flagged and uniform load
        // could never release it — warm-up must classify totally
        let cfg = SignalConfig {
            decay_alpha: 1.0,
            hysteresis: 0.5,
            min_gain: 0.0,
        };
        let s = LoadSignal::with_config(3, &cfg);
        s.set(0, 10);
        assert!(s.overloaded(0), "sole reporter carries all observed load");
        s.set(1, 10);
        s.set(2, 10);
        assert_eq!(s.flags_vec(), vec![false; 3], "full uniform view is clear");
        // the band engages only after the completing refresh: a later
        // in-band wobble no longer rewrites flags
        s.set(0, 13);
        assert!(!s.overloaded(0), "13 is inside the band (5.5, 16.5]");
    }

    #[test]
    fn out_of_range_nodes_ignored() {
        let s = LoadSignal::new(2);
        s.set(7, 100); // elastic scale-out beyond the initial topology
        assert_eq!(s.to_vec(), vec![0, 0]);
        assert_eq!(s.get(7), 0);
        assert_eq!(s.decayed(7), 0);
        assert!(!s.overloaded(7));
    }

    #[test]
    fn config_validation() {
        assert!(SignalConfig::default().validate().is_ok());
        assert!(SignalConfig::legacy().validate().is_ok());
        let bad = |f: fn(&mut SignalConfig)| {
            let mut c = SignalConfig::default();
            f(&mut c);
            c.validate().is_err()
        };
        assert!(bad(|c| c.decay_alpha = 0.0));
        assert!(bad(|c| c.decay_alpha = 1.5));
        assert!(bad(|c| c.hysteresis = -0.1));
        assert!(bad(|c| c.min_gain = 1.0));
        assert!(bad(|c| c.min_gain = -0.1));
    }

    #[test]
    fn capacity_slots_join_and_leave_the_mean() {
        let s = LoadSignal::with_capacity(2, 4, &SignalConfig::legacy());
        assert_eq!(s.nodes(), 4, "slots pre-allocated to capacity");
        assert_eq!(s.live_count(), 2);
        s.set(0, 30);
        s.set(1, 10);
        // inactive slots never flag and never drag the mean down
        assert_eq!(s.flags_vec(), vec![true, false, false, false]);
        assert_eq!(s.decayed_mean_fp(), 20 * FP);

        s.activate(2);
        assert_eq!(s.live_count(), 3);
        assert!(!s.overloaded(2), "fresh slot starts clear");
        s.set(2, 2);
        assert_eq!(s.decayed_mean_fp(), 14 * FP);
        assert_eq!(s.coldest_live(), Some(2));

        s.retire(2);
        assert_eq!(s.live_count(), 2);
        assert!(!s.is_live(2));
        assert_eq!(s.decayed(2), 0, "retired slot reads as zero");
        assert_eq!(s.decayed_mean_fp(), 20 * FP, "mean back over the survivors");
        // flags were refreshed against the shrunken membership
        assert_eq!(s.flags_vec(), vec![true, false, false, false]);
    }

    #[test]
    fn activate_reopens_warmup_for_the_band() {
        let cfg = SignalConfig { decay_alpha: 1.0, hysteresis: 0.5, min_gain: 0.0 };
        let s = LoadSignal::with_capacity(2, 3, &cfg);
        s.set(0, 10);
        s.set(1, 10);
        assert_eq!(s.flags_vec(), vec![false, false, false]);
        s.activate(2);
        // the new node has not reported: the warm-up total rule is back
        s.set(0, 11);
        assert!(s.overloaded(0), "warm-up total rule while the new node is unheard");
        s.set(2, 10); // completes warm-up (node 0 still above the total mean)
        // band re-engaged: an in-band dip keeps the sticky flag...
        s.set(0, 6); // mean 8.67, off-watermark 4.33: 6 is inside the band
        assert!(s.overloaded(0), "inside the band the flag sticks");
        // ...and only crossing the low watermark releases it
        s.set(0, 2); // mean 7.33, off-watermark 3.67
        assert!(!s.overloaded(0));
    }

    #[test]
    fn clones_share_the_signal() {
        let a = LoadSignal::new(2);
        let b = a.clone();
        a.set(1, 9);
        assert_eq!(b.get(1), 9);
        assert_eq!(b.decayed(1), 9 * FP);
    }
}
