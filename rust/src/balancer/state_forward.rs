//! §7 extension — **state forwarding** instead of merge-at-end.
//!
//! In the base design, inputs for one key may be reduced on several
//! reducers over the run, so per-key state is distributed and must be
//! merged at the end — fine for commutative/associative reductions, not in
//! general. The Discussion section sketches an alternative the authors
//! planned for Quokka: keep each key's state resident on exactly one
//! reducer by *forwarding state* ahead of data, with processing broken
//! into synchronized stages:
//!
//! 1. the balancer publishes a new partitioning (atomically, infrequently);
//! 2. **substage 1**: every reducer extracts the state of keys it no
//!    longer owns and ships it to the new owners; *no data may be
//!    forwarded* — data that would need forwarding is put back into the
//!    local queue;
//! 3. **substage 2**: once all state transfers have landed, reducers
//!    resume and may forward data freely — the destination is guaranteed
//!    to hold the state for any key the current partitioning assigns it.
//!
//! [`StageTracker`] implements the stage machinery: it counts outstanding
//! state transfers for the current ring epoch and tells reducers whether
//! the pipeline is `Synchronizing` (substage 1) or `Synchronized`
//! (substage 2). It is **thread-safe**: all counters are atomics, so the
//! threads driver's reducers consult and advance the protocol concurrently
//! while the deterministic sim drives the very same type single-threaded.
//! The invariant it buys — *at shutdown every key's state lives on exactly
//! one reducer* — is asserted in `rust/tests/lb_behavior.rs` and exercised
//! on both drivers by `rust/tests/driver_parity.rs`.
//!
//! That single-homing invariant only holds under a
//! [`MergeContract::Disjoint`](crate::hash::MergeContract) router. The
//! split-key family relaxes it: a promoted key keeps a partial on each of
//! its `d` candidate homes (the reducer-side may-own check deliberately
//! leaves shards resident through substage 1), and the final merge folds
//! those partials associatively instead of asserting disjointness. See
//! `docs/ARCHITECTURE.md` §"merge contracts".

#![forbid(unsafe_code)]

use crate::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};

/// How the pipeline keeps per-key state consistent across repartitions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsistencyMode {
    /// Base paper design: reducers keep whatever state they accumulated;
    /// the coordinator merges all snapshots at the end (§2, word count:
    /// add the counts).
    MergeAtEnd,
    /// §7 extension: state moves with the partitioning; the final merge
    /// is a disjoint union.
    StateForward,
}

/// Stage the pipeline is in (only meaningful under
/// [`ConsistencyMode::StateForward`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Substage 1: state transfers in flight; reducers must not forward
    /// data (they re-queue it locally) and must apply incoming state
    /// transfers before anything else.
    Synchronizing,
    /// Substage 2: all transfers landed; normal processing + forwarding.
    Synchronized,
}

/// Tracks the state-forwarding protocol across a repartition.
///
/// Concurrency model: [`Self::begin_epoch`] is only ever called by the
/// balancer's owner (the sim loop, or the threads driver's balancer
/// thread) and only from `Synchronized` — the §7 "updates are very
/// infrequent and atomic" rule, which the balancer enforces by gating
/// rebalances on the stage. Reducers call `needs_extraction` /
/// `extraction_done` / `transfer_landed` concurrently. `begin_epoch`
/// publishes the pending epoch *last* (release), so a reducer that
/// observes it also observes the reset extraction flags; `outstanding`
/// may go transiently negative when a transfer lands before its sender's
/// `extraction_done` increment, which is why completion additionally
/// requires every reducer to have extracted.
#[derive(Debug)]
pub struct StageTracker {
    /// Ring epoch the reducers are synchronized to.
    synced_epoch: AtomicU64,
    /// Epoch currently being synchronized to (0 = none; ring epochs start
    /// at 1, so 0 is free as the sentinel).
    pending_epoch: AtomicU64,
    /// Outstanding state-transfer messages for the in-progress epoch.
    /// Signed: a transfer may land at its destination before the sender
    /// books it, so the count can dip below zero transiently.
    outstanding: AtomicI64,
    /// Per-reducer flag: has it run its substage-1 extraction for the
    /// in-progress epoch? Slots are pre-allocated to the elastic
    /// capacity; inactive slots are permanently `true`.
    extracted: Vec<AtomicBool>,
    /// Which pre-allocated slots carry a spawned reducer. Scale-up flips
    /// a slot on ([`Self::activate`]); slots never deactivate — a retired
    /// reducer keeps draining (and keeps its extraction duty, trivially
    /// empty) until the run ends.
    active: Vec<AtomicBool>,
    /// Count of active slots (the extraction quorum).
    active_count: AtomicUsize,
    /// How many active reducers have extracted for the in-progress epoch.
    extracted_count: AtomicUsize,
    /// Total state transfers performed (metrics).
    transfers: AtomicU64,
    /// Per-slot fail-stop flag (testkit::chaos). A faulted slot leaves the
    /// extraction quorum immediately — a dead reducer can never extract —
    /// and stays faulted forever (respawns take a fresh slot). The drivers'
    /// drain/quorum checks consult this set so a stalled-but-alive reducer
    /// is counted and a dead one is not.
    faulted: Vec<AtomicBool>,
    /// Failure-domain map (slot → zone;
    /// [`effective_zone`](crate::hash::effective_zone) resolves slots the
    /// map does not name). Installed once by [`Self::set_zones`] before
    /// the tracker is shared; empty = no zones configured.
    zones: Vec<u32>,
}

impl StageTracker {
    pub fn new(reducers: usize, initial_epoch: u64) -> Self {
        Self::with_capacity(reducers, reducers, initial_epoch)
    }

    /// A tracker with `capacity` pre-allocated reducer slots of which the
    /// first `reducers` start active — elastic runs activate the rest via
    /// [`Self::activate`] as reducers spawn.
    pub fn with_capacity(reducers: usize, capacity: usize, initial_epoch: u64) -> Self {
        let capacity = capacity.max(reducers);
        StageTracker {
            synced_epoch: AtomicU64::new(initial_epoch),
            pending_epoch: AtomicU64::new(0),
            outstanding: AtomicI64::new(0),
            extracted: (0..capacity).map(|_| AtomicBool::new(true)).collect(),
            active: (0..capacity).map(|i| AtomicBool::new(i < reducers)).collect(),
            active_count: AtomicUsize::new(reducers),
            extracted_count: AtomicUsize::new(reducers),
            transfers: AtomicU64::new(0),
            faulted: (0..capacity).map(|_| AtomicBool::new(false)).collect(),
            zones: Vec::new(),
        }
    }

    /// Install the failure-domain map (`&mut` — called once at build
    /// time, before the tracker is shared across threads). The
    /// checkpoint-to-peer destination pick ([`Self::next_live_peer`])
    /// then prefers a cross-zone replica.
    pub fn set_zones(&mut self, zone_of: &[u32]) {
        self.zones = zone_of.to_vec();
    }

    pub fn stage(&self) -> Stage {
        if self.pending_epoch.load(Ordering::SeqCst) != 0 {
            Stage::Synchronizing
        } else {
            Stage::Synchronized
        }
    }

    pub fn synced_epoch(&self) -> u64 {
        self.synced_epoch.load(Ordering::SeqCst)
    }

    /// Total state transfers performed so far (metrics).
    pub fn transfers(&self) -> u64 {
        self.transfers.load(Ordering::SeqCst)
    }

    /// The balancer published a new partitioning: enter substage 1. Every
    /// reducer must now run its extraction exactly once.
    ///
    /// The §7 algorithm assumes updates are "very infrequent and atomic";
    /// we enforce it — a new epoch may only start from `Synchronized`.
    pub fn begin_epoch(&self, epoch: u64) {
        assert!(epoch != 0, "ring epochs are 1-based");
        assert!(epoch > self.synced_epoch.load(Ordering::SeqCst));
        // reset the extraction slate *before* publishing the epoch: a
        // reducer that sees the pending epoch must also see its cleared
        // flag, or it would skip its substage-1 duty. Only active slots
        // owe an extraction — inactive slots have no reducer to run one.
        for (e, a) in self.extracted.iter().zip(&self.active) {
            if a.load(Ordering::SeqCst) {
                e.store(false, Ordering::SeqCst);
            }
        }
        self.extracted_count.store(0, Ordering::SeqCst);
        let prev = self.pending_epoch.swap(epoch, Ordering::SeqCst);
        assert!(
            prev == 0,
            "repartition while still synchronizing (updates must be atomic + infrequent)"
        );
    }

    /// Reducer `i` finished extracting and sending its non-owned state,
    /// having emitted `sent` transfer messages.
    ///
    /// Ordering matters: `outstanding` is credited *before* the reducer is
    /// marked extracted, so no observer can see "everyone extracted" while
    /// this reducer's transfers are still unbooked.
    pub fn extraction_done(&self, reducer: usize, sent: u64) {
        assert!(self.pending_epoch.load(Ordering::SeqCst) != 0);
        self.outstanding.fetch_add(sent as i64, Ordering::SeqCst);
        self.transfers.fetch_add(sent, Ordering::SeqCst);
        let was = self.extracted[reducer].swap(true, Ordering::SeqCst);
        assert!(!was, "double extraction");
        self.extracted_count.fetch_add(1, Ordering::SeqCst);
        self.maybe_finish();
    }

    /// A state-transfer message was applied at its destination.
    pub fn transfer_landed(&self) {
        self.outstanding.fetch_sub(1, Ordering::SeqCst);
        self.maybe_finish();
    }

    /// True once every active reducer extracted for the pending epoch.
    pub fn all_extracted(&self) -> bool {
        self.extracted_count.load(Ordering::SeqCst) == self.active_count.load(Ordering::SeqCst)
    }

    fn maybe_finish(&self) {
        // once every reducer has extracted, `outstanding` only decreases;
        // whichever thread performs the final operation observes the
        // (all-extracted, zero-outstanding) state and retires the epoch
        if self.all_extracted() && self.outstanding.load(Ordering::SeqCst) == 0 {
            let e = self.pending_epoch.load(Ordering::SeqCst);
            if e != 0
                && self
                    .pending_epoch
                    .compare_exchange(e, 0, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                self.synced_epoch.store(e, Ordering::SeqCst);
            }
        }
    }

    /// Does reducer `i` still owe its substage-1 extraction?
    pub fn needs_extraction(&self, reducer: usize) -> bool {
        self.pending_epoch.load(Ordering::SeqCst) != 0
            && !self.extracted[reducer].load(Ordering::SeqCst)
    }

    /// Elastic §7: a reducer spawned at runtime joins the protocol in its
    /// pre-allocated slot. Must be called from `Synchronized` — the
    /// balancer activates the slot *before* opening the membership
    /// change's synchronization epoch, so the joiner (which has no state)
    /// runs its trivial extraction with everyone else.
    pub fn activate(&self, reducer: usize) {
        assert!(
            self.pending_epoch.load(Ordering::SeqCst) == 0,
            "activating a reducer mid-synchronization (membership changes are \
             gated on Synchronized)"
        );
        assert!(reducer < self.active.len(), "reducer {reducer} beyond tracker capacity");
        let was = self.active[reducer].swap(true, Ordering::SeqCst);
        if !was {
            self.active_count.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Fail-stop: reducer `i` died (testkit::chaos `Kill`) and leaves the
    /// protocol *now*, even mid-epoch — a dead reducer can never run its
    /// extraction, so waiting on it would wedge the pending epoch (and
    /// with it the recovery, which is gated on `Synchronized`).
    ///
    /// Unlike [`Self::activate`] this is legal from `Synchronizing`:
    /// * if the victim had already extracted this epoch, its contribution
    ///   is removed from both sides of the quorum equality;
    /// * if it had not, it is excused (its un-extracted state is rebuilt
    ///   from the replication lane at recovery and re-homed to whichever
    ///   reducer owns each key *then*);
    /// * either way the quorum shrinks, which may complete the epoch —
    ///   so the finish check runs.
    pub fn retire_faulted(&self, reducer: usize) {
        assert!(reducer < self.active.len(), "reducer {reducer} beyond tracker capacity");
        let was_faulted = self.faulted[reducer].swap(true, Ordering::SeqCst);
        assert!(!was_faulted, "reducer {reducer} fail-stopped twice");
        if self.active[reducer].swap(false, Ordering::SeqCst) {
            self.active_count.fetch_sub(1, Ordering::SeqCst);
        }
        if self.pending_epoch.load(Ordering::SeqCst) != 0 {
            if self.extracted[reducer].swap(true, Ordering::SeqCst) {
                // its extraction was counted; the quorum shrank, so the
                // count must shrink with it or equality can never hold
                self.extracted_count.fetch_sub(1, Ordering::SeqCst);
            }
            self.maybe_finish();
        } else {
            self.extracted[reducer].store(true, Ordering::SeqCst);
        }
    }

    /// Has reducer `i` been fail-stopped?
    pub fn is_faulted(&self, reducer: usize) -> bool {
        self.faulted[reducer].load(Ordering::SeqCst)
    }

    /// Book `sent` state transfers outside an extraction epoch — the
    /// recovery path re-homes a rebuilt victim state as ordinary transfer
    /// envelopes from `Synchronized`, and each will call
    /// [`Self::transfer_landed`] when absorbed; crediting `outstanding`
    /// first keeps the counter zero-sum so the *next* epoch's completion
    /// check still starts from a clean slate.
    pub fn transfers_booked(&self, sent: u64) {
        self.outstanding.fetch_add(sent as i64, Ordering::SeqCst);
        self.transfers.fetch_add(sent, Ordering::SeqCst);
    }

    /// All booked transfers have landed. Recovery re-homes rebuilt state
    /// *outside* an epoch; the balancer must not open a new epoch until
    /// those transfers settle, or a reducer could run its extraction
    /// before absorbing a re-homed key it no longer owns and strand the
    /// state at a non-owner.
    pub fn transfers_settled(&self) -> bool {
        self.outstanding.load(Ordering::SeqCst) == 0
    }

    /// Live (active, not faulted) slot other than `i` to hold `i`'s
    /// checkpoint replica: the smallest live slot in a *different*
    /// failure domain when one exists (a zone outage then cannot take
    /// both the primary and its replica), else the smallest live slot.
    /// With no zones configured every slot is its own singleton domain,
    /// so the preference degrades exactly to the historical
    /// smallest-live-peer pick. `None` when `i` is the only survivor
    /// (the checkpoint then installs locally).
    pub fn next_live_peer(&self, i: usize) -> Option<usize> {
        let live = |j: usize| {
            j != i
                && self.active[j].load(Ordering::SeqCst)
                && !self.faulted[j].load(Ordering::SeqCst)
        };
        let zone_i = crate::hash::effective_zone(&self.zones, i);
        (0..self.active.len())
            .find(|&j| live(j) && crate::hash::effective_zone(&self.zones, j) != zone_i)
            .or_else(|| (0..self.active.len()).find(|&j| live(j)))
    }

    /// Number of active (spawned) reducer slots.
    pub fn active_count(&self) -> usize {
        self.active_count.load(Ordering::SeqCst)
    }

    /// Slot capacity the tracker was pre-allocated for.
    pub fn capacity(&self) -> usize {
        self.active.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let t = StageTracker::new(4, 1);
        assert_eq!(t.stage(), Stage::Synchronized);

        t.begin_epoch(2);
        assert_eq!(t.stage(), Stage::Synchronizing);
        assert!(t.needs_extraction(0));

        t.extraction_done(0, 2);
        t.extraction_done(1, 0);
        t.extraction_done(2, 0);
        assert_eq!(t.stage(), Stage::Synchronizing, "reducer 3 not extracted");
        t.extraction_done(3, 1);
        assert_eq!(t.stage(), Stage::Synchronizing, "3 transfers outstanding");

        t.transfer_landed();
        t.transfer_landed();
        t.transfer_landed();
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.synced_epoch(), 2);
        assert_eq!(t.transfers(), 3);
    }

    #[test]
    fn zero_transfer_epoch_finishes_immediately() {
        let t = StageTracker::new(2, 5);
        t.begin_epoch(6);
        t.extraction_done(0, 0);
        assert_eq!(t.stage(), Stage::Synchronizing);
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.synced_epoch(), 6);
    }

    #[test]
    fn transfer_landing_before_senders_bookkeeping_is_tolerated() {
        // threads interleaving: the destination absorbs a state envelope
        // before the sender calls extraction_done — outstanding dips
        // negative but the epoch still retires exactly once
        let t = StageTracker::new(2, 1);
        t.begin_epoch(2);
        t.transfer_landed(); // lands "early"
        assert_eq!(t.stage(), Stage::Synchronizing);
        t.extraction_done(0, 1);
        assert_eq!(t.stage(), Stage::Synchronizing, "reducer 1 not extracted");
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.synced_epoch(), 2);
    }

    #[test]
    #[should_panic(expected = "atomic")]
    fn overlapping_epochs_panic() {
        let t = StageTracker::new(2, 1);
        t.begin_epoch(2);
        t.begin_epoch(3);
    }

    #[test]
    #[should_panic(expected = "double extraction")]
    fn double_extraction_panics() {
        let t = StageTracker::new(2, 1);
        t.begin_epoch(2);
        t.extraction_done(0, 0);
        t.extraction_done(0, 0);
    }

    #[test]
    fn elastic_activate_joins_the_quorum() {
        let t = StageTracker::with_capacity(2, 4, 1);
        assert_eq!(t.active_count(), 2);
        assert_eq!(t.capacity(), 4);
        t.activate(2);
        assert_eq!(t.active_count(), 3);
        t.begin_epoch(2);
        // all three active reducers must now extract; slot 3 owes nothing
        assert!(t.needs_extraction(0));
        assert!(t.needs_extraction(2));
        assert!(!t.needs_extraction(3), "inactive slot owes no extraction");
        t.extraction_done(0, 0);
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronizing);
        t.extraction_done(2, 0);
        assert_eq!(t.stage(), Stage::Synchronized);
        // re-activating an active slot is idempotent
        t.activate(2);
        assert_eq!(t.active_count(), 3);
    }

    #[test]
    fn faulted_reducer_leaves_the_quorum_before_extracting() {
        // the victim dies mid-epoch having NOT extracted: the epoch must
        // still retire on the survivors' extractions alone
        let t = StageTracker::new(3, 1);
        t.begin_epoch(2);
        t.extraction_done(0, 1);
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronizing, "reducer 2 still owed");
        t.retire_faulted(2);
        assert!(t.is_faulted(2));
        assert!(!t.needs_extraction(2), "dead reducers owe nothing");
        assert_eq!(t.stage(), Stage::Synchronizing, "1 transfer outstanding");
        t.transfer_landed();
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.active_count(), 2);
    }

    #[test]
    fn faulted_reducer_after_extracting_shrinks_both_counts() {
        // the victim extracted, then died: its counted extraction must
        // leave with it or extracted_count == active_count never holds
        let t = StageTracker::new(3, 1);
        t.begin_epoch(2);
        t.extraction_done(2, 0);
        t.retire_faulted(2);
        assert_eq!(t.stage(), Stage::Synchronizing);
        t.extraction_done(0, 0);
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronized);
    }

    #[test]
    fn fault_completing_the_quorum_retires_the_epoch() {
        // everyone else already extracted; the kill itself is the event
        // that completes the round
        let t = StageTracker::new(2, 1);
        t.begin_epoch(2);
        t.extraction_done(0, 0);
        t.retire_faulted(1);
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.synced_epoch(), 2);
    }

    #[test]
    fn faulted_slot_is_excused_from_later_epochs() {
        let t = StageTracker::new(2, 1);
        t.retire_faulted(1);
        t.begin_epoch(2);
        assert!(!t.needs_extraction(1), "dead slot must stay excused");
        t.extraction_done(0, 0);
        assert_eq!(t.stage(), Stage::Synchronized);
    }

    #[test]
    fn next_live_peer_skips_the_dead_and_inactive() {
        let t = StageTracker::with_capacity(3, 4, 1);
        assert_eq!(t.next_live_peer(0), Some(1));
        t.retire_faulted(1);
        assert_eq!(t.next_live_peer(0), Some(2));
        t.retire_faulted(2);
        assert_eq!(t.next_live_peer(0), None, "slot 3 never activated");
        t.activate(3);
        assert_eq!(t.next_live_peer(0), Some(3));
    }

    #[test]
    fn next_live_peer_prefers_a_cross_zone_replica() {
        // zones {0,1} and {2,3}: reducer 0's checkpoint must leave its
        // failure domain even though slot 1 is the smaller live peer
        let mut t = StageTracker::with_capacity(4, 4, 1);
        t.set_zones(&[0, 0, 1, 1]);
        assert_eq!(t.next_live_peer(0), Some(2));
        assert_eq!(t.next_live_peer(2), Some(0));
        // the whole other zone dies → fall back to the same-zone peer
        t.retire_faulted(2);
        t.retire_faulted(3);
        assert_eq!(t.next_live_peer(0), Some(1), "same-zone beats no replica");
        // a slot beyond the zone map gets a singleton domain, so it
        // counts as cross-zone for everyone
        let mut t = StageTracker::with_capacity(2, 3, 1);
        t.set_zones(&[0, 0]);
        t.activate(2);
        assert_eq!(t.next_live_peer(0), Some(2));
    }

    #[test]
    fn recovery_transfers_keep_outstanding_zero_sum() {
        let t = StageTracker::new(2, 1);
        // recovery re-homes 3 rebuilt records from Synchronized
        t.transfers_booked(3);
        t.transfer_landed();
        t.transfer_landed();
        t.transfer_landed();
        assert_eq!(t.transfers(), 3);
        // a later epoch still completes on its own arithmetic
        t.begin_epoch(2);
        t.extraction_done(0, 1);
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronizing);
        t.transfer_landed();
        assert_eq!(t.stage(), Stage::Synchronized);
    }

    #[test]
    #[should_panic(expected = "mid-synchronization")]
    fn activate_mid_sync_panics() {
        let t = StageTracker::with_capacity(2, 4, 1);
        t.begin_epoch(2);
        t.activate(2);
    }

    #[test]
    fn concurrent_protocol_round_converges() {
        use std::sync::Arc;
        let n = 8usize;
        let t = Arc::new(StageTracker::new(n, 1));
        t.begin_epoch(2);
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let t = t.clone();
                std::thread::spawn(move || {
                    // each reducer "sends" i transfers, then lands i
                    // transfers on behalf of its peers
                    t.extraction_done(i, i as u64);
                    for _ in 0..i {
                        t.transfer_landed();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.synced_epoch(), 2);
        assert_eq!(t.transfers(), (0..n as u64).sum::<u64>());
    }
}
