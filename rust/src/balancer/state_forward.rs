//! §7 extension — **state forwarding** instead of merge-at-end.
//!
//! In the base design, inputs for one key may be reduced on several
//! reducers over the run, so per-key state is distributed and must be
//! merged at the end — fine for commutative/associative reductions, not in
//! general. The Discussion section sketches an alternative the authors
//! planned for Quokka: keep each key's state resident on exactly one
//! reducer by *forwarding state* ahead of data, with processing broken
//! into synchronized stages:
//!
//! 1. the balancer publishes a new partitioning (atomically, infrequently);
//! 2. **substage 1**: every reducer extracts the state of keys it no
//!    longer owns and ships it to the new owners; *no data may be
//!    forwarded* — data that would need forwarding is put back into the
//!    local queue;
//! 3. **substage 2**: once all state transfers have landed, reducers
//!    resume and may forward data freely — the destination is guaranteed
//!    to hold the state for any key the current partitioning assigns it.
//!
//! [`StageTracker`] implements the stage machinery: it counts outstanding
//! state transfers for the current ring epoch and tells reducers whether
//! the pipeline is `Synchronizing` (substage 1) or `Synchronized`
//! (substage 2). The deterministic sim driver wires it in when
//! [`ConsistencyMode::StateForward`] is selected; the invariant it buys —
//! *at shutdown every key's state lives on exactly one reducer* — is
//! asserted in `rust/tests/lb_behavior.rs`.

/// How the pipeline keeps per-key state consistent across repartitions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsistencyMode {
    /// Base paper design: reducers keep whatever state they accumulated;
    /// the coordinator merges all snapshots at the end (§2, word count:
    /// add the counts).
    MergeAtEnd,
    /// §7 extension: state moves with the partitioning; the final merge
    /// is a disjoint union.
    StateForward,
}

/// Stage the pipeline is in (only meaningful under
/// [`ConsistencyMode::StateForward`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Substage 1: state transfers in flight; reducers must not forward
    /// data (they re-queue it locally) and must apply incoming state
    /// transfers before anything else.
    Synchronizing,
    /// Substage 2: all transfers landed; normal processing + forwarding.
    Synchronized,
}

/// Tracks the state-forwarding protocol across a repartition.
#[derive(Debug)]
pub struct StageTracker {
    /// Ring epoch the reducers are synchronized to.
    synced_epoch: u64,
    /// Outstanding state-transfer messages for the in-progress epoch.
    outstanding: u64,
    /// Per-reducer flag: has it run its substage-1 extraction for the
    /// in-progress epoch?
    extracted: Vec<bool>,
    /// Epoch currently being synchronized to (if any).
    pending_epoch: Option<u64>,
    /// Total state transfers performed (metrics).
    pub transfers: u64,
}

impl StageTracker {
    pub fn new(reducers: usize, initial_epoch: u64) -> Self {
        StageTracker {
            synced_epoch: initial_epoch,
            outstanding: 0,
            extracted: vec![true; reducers],
            pending_epoch: None,
            transfers: 0,
        }
    }

    pub fn stage(&self) -> Stage {
        if self.pending_epoch.is_some() {
            Stage::Synchronizing
        } else {
            Stage::Synchronized
        }
    }

    pub fn synced_epoch(&self) -> u64 {
        self.synced_epoch
    }

    /// The balancer published a new partitioning: enter substage 1. Every
    /// reducer must now run its extraction exactly once.
    ///
    /// The §7 algorithm assumes updates are "very infrequent and atomic";
    /// we enforce it — a new epoch may only start from `Synchronized`.
    pub fn begin_epoch(&mut self, epoch: u64) {
        assert!(
            self.pending_epoch.is_none(),
            "repartition while still synchronizing (updates must be atomic + infrequent)"
        );
        assert!(epoch > self.synced_epoch);
        self.pending_epoch = Some(epoch);
        self.extracted.iter_mut().for_each(|e| *e = false);
    }

    /// Reducer `i` finished extracting and sending its non-owned state,
    /// having emitted `sent` transfer messages.
    pub fn extraction_done(&mut self, reducer: usize, sent: u64) {
        assert!(self.pending_epoch.is_some());
        assert!(!self.extracted[reducer], "double extraction");
        self.extracted[reducer] = true;
        self.outstanding += sent;
        self.transfers += sent;
        self.maybe_finish();
    }

    /// A state-transfer message was applied at its destination.
    pub fn transfer_landed(&mut self) {
        assert!(self.outstanding > 0, "transfer landed with none outstanding");
        self.outstanding -= 1;
        self.maybe_finish();
    }

    /// True once every reducer extracted for the pending epoch.
    pub fn all_extracted(&self) -> bool {
        self.extracted.iter().all(|&e| e)
    }

    fn maybe_finish(&mut self) {
        if self.all_extracted() && self.outstanding == 0 {
            if let Some(e) = self.pending_epoch.take() {
                self.synced_epoch = e;
            }
        }
    }

    /// Does reducer `i` still owe its substage-1 extraction?
    pub fn needs_extraction(&self, reducer: usize) -> bool {
        self.pending_epoch.is_some() && !self.extracted[reducer]
    }

    /// Grow tracking when a reducer is added at runtime (elastic §7).
    pub fn add_reducer(&mut self) {
        // a brand-new reducer has no state to extract
        self.extracted.push(true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut t = StageTracker::new(4, 1);
        assert_eq!(t.stage(), Stage::Synchronized);

        t.begin_epoch(2);
        assert_eq!(t.stage(), Stage::Synchronizing);
        assert!(t.needs_extraction(0));

        t.extraction_done(0, 2);
        t.extraction_done(1, 0);
        t.extraction_done(2, 0);
        assert_eq!(t.stage(), Stage::Synchronizing, "reducer 3 not extracted");
        t.extraction_done(3, 1);
        assert_eq!(t.stage(), Stage::Synchronizing, "3 transfers outstanding");

        t.transfer_landed();
        t.transfer_landed();
        t.transfer_landed();
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.synced_epoch(), 2);
        assert_eq!(t.transfers, 3);
    }

    #[test]
    fn zero_transfer_epoch_finishes_immediately() {
        let mut t = StageTracker::new(2, 5);
        t.begin_epoch(6);
        t.extraction_done(0, 0);
        assert_eq!(t.stage(), Stage::Synchronizing);
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronized);
        assert_eq!(t.synced_epoch(), 6);
    }

    #[test]
    #[should_panic(expected = "atomic")]
    fn overlapping_epochs_panic() {
        let mut t = StageTracker::new(2, 1);
        t.begin_epoch(2);
        t.begin_epoch(3);
    }

    #[test]
    #[should_panic(expected = "double extraction")]
    fn double_extraction_panics() {
        let mut t = StageTracker::new(2, 1);
        t.begin_epoch(2);
        t.extraction_done(0, 0);
        t.extraction_done(0, 0);
    }

    #[test]
    fn elastic_add_reducer() {
        let mut t = StageTracker::new(2, 1);
        t.add_reducer();
        t.begin_epoch(2);
        // all three must now extract
        t.extraction_done(0, 0);
        t.extraction_done(1, 0);
        assert_eq!(t.stage(), Stage::Synchronizing);
        t.extraction_done(2, 0);
        assert_eq!(t.stage(), Stage::Synchronized);
    }
}
