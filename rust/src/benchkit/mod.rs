//! A small benchmark harness (offline build: no criterion).
//!
//! [`Bench`] runs a closure repeatedly with warmup, measures per-iteration
//! wall time, and reports mean/median/p95 + throughput. Output is
//! markdown-friendly so `cargo bench` results paste into EXPERIMENTS.md.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;
use crate::util::table::Table;

/// One benchmark case result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration seconds.
    pub per_iter: Summary,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.per_iter.mean() * 1e9
    }

    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n as f64 / self.per_iter.mean())
    }
}

/// Format a nanosecond quantity human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner: measures a closure until `target_time` is spent or
/// `max_iters` reached, after `warmup` iterations.
pub struct Bench {
    pub warmup: usize,
    pub max_iters: usize,
    pub target_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 3,
            max_iters: 200,
            target_time: Duration::from_secs(2),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bench {
            warmup: 1,
            max_iters: 30,
            target_time: Duration::from_millis(500),
            results: Vec::new(),
        }
    }

    /// Time `f`; `items` is the per-iteration work amount for throughput.
    pub fn run<F: FnMut()>(&mut self, name: &str, items: Option<u64>, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut per_iter = Summary::new();
        let t0 = Instant::now();
        let mut iters = 0;
        while iters < self.max_iters && (iters < 5 || t0.elapsed() < self.target_time) {
            let it0 = Instant::now();
            f();
            per_iter.push(it0.elapsed().as_secs_f64());
            iters += 1;
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters,
            per_iter,
            items_per_iter: items,
        });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all results as a markdown table.
    pub fn render(&self) -> String {
        let mut t = Table::new(["bench", "iters", "mean", "median", "p95", "throughput"]);
        for r in &self.results {
            let thr = r
                .throughput()
                .map(|x| {
                    if x > 1e6 {
                        format!("{:.2} M items/s", x / 1e6)
                    } else {
                        format!("{:.0} items/s", x)
                    }
                })
                .unwrap_or_else(|| "-".into());
            t.row([
                r.name.clone(),
                r.iters.to_string(),
                fmt_ns(r.per_iter.mean() * 1e9),
                fmt_ns(r.per_iter.median() * 1e9),
                fmt_ns(r.per_iter.percentile(95.0) * 1e9),
                thr,
            ]);
        }
        t.render()
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench {
            warmup: 1,
            max_iters: 10,
            target_time: Duration::from_millis(50),
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.run("spin", Some(1000), || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        let r = &b.results()[0];
        assert!(r.iters >= 5);
        assert!(r.per_iter.mean() > 0.0);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(b.render().contains("spin"));
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(12_500.0), "12.50 µs");
        assert_eq!(fmt_ns(3_200_000.0), "3.20 ms");
        assert_eq!(fmt_ns(2.5e9), "2.50 s");
    }
}
