//! Minimal argv parser: positionals, `--flag` booleans and `--key value`
//! options, with unknown-argument detection at `finish()`.

use std::collections::HashMap;

use anyhow::bail;

/// Tokenized argv with taken/untaken tracking.
pub struct Args {
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    taken_flags: Vec<String>,
}

impl Args {
    /// Tokenize. `--key value` and `--key=value` both work; a `--key`
    /// followed by another `--...` (or end of argv) is a boolean flag.
    pub fn new(argv: &[String]) -> crate::Result<Self> {
        let mut positional = Vec::new();
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(name.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args {
            positional,
            opts,
            flags,
            taken_flags: Vec::new(),
        })
    }

    /// Take a `--key value` option as a string.
    pub fn take_opt(&mut self, key: &str) -> Option<String> {
        self.opts.remove(key)
    }

    /// Take and parse a `--key value` option.
    pub fn take_opt_parse<T: std::str::FromStr>(&mut self, key: &str) -> crate::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opts.remove(key) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("invalid value '{v}' for --{key}: {e}"),
            },
        }
    }

    /// Take a boolean `--flag`.
    pub fn take_flag(&mut self, name: &str) -> bool {
        if let Some(pos) = self.flags.iter().position(|f| f == name) {
            self.flags.remove(pos);
            self.taken_flags.push(name.to_string());
            true
        } else {
            false
        }
    }

    /// Error on any un-consumed options/flags (catches typos).
    pub fn finish(self) -> crate::Result<()> {
        if let Some(k) = self.opts.keys().next() {
            bail!("unknown option --{k}");
        }
        if let Some(f) = self.flags.first() {
            bail!("unknown flag --{f}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positionals_opts_flags() {
        let mut a = Args::new(&sv(&["run", "--k", "v", "--flag", "--x=y"])).unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.take_opt("k").as_deref(), Some("v"));
        assert_eq!(a.take_opt("x").as_deref(), Some("y"));
        assert!(a.take_flag("flag"));
        assert!(!a.take_flag("flag"), "flags are consumed");
        a.finish().unwrap();
    }

    #[test]
    fn parse_typed_values() {
        let mut a = Args::new(&sv(&["--n", "42", "--f", "0.5"])).unwrap();
        assert_eq!(a.take_opt_parse::<u32>("n").unwrap(), Some(42));
        assert_eq!(a.take_opt_parse::<f64>("f").unwrap(), Some(0.5));
        assert_eq!(a.take_opt_parse::<u32>("missing").unwrap(), None);
    }

    #[test]
    fn bad_typed_value_errors() {
        let mut a = Args::new(&sv(&["--n", "notanumber"])).unwrap();
        assert!(a.take_opt_parse::<u32>("n").is_err());
    }

    #[test]
    fn leftover_args_error_at_finish() {
        let a = Args::new(&sv(&["--unknown", "1"])).unwrap();
        assert!(a.finish().is_err());
        let a = Args::new(&sv(&["--mystery-flag"])).unwrap();
        assert!(a.finish().is_err());
    }
}
