//! Hand-rolled CLI argument parsing (offline build: no clap) and the
//! `dpa` binary's subcommand surface.

pub mod args;

use std::path::PathBuf;

use anyhow::{bail, Context};

use crate::balancer::state_forward::ConsistencyMode;
use crate::hash::Strategy;
use crate::metrics::RunReport;
use crate::pipeline::{DriverKind, ExecutorKind, Pipeline, PipelineConfig};
use crate::testkit::chaos::ChaosPlan;
use crate::util::stats::Summary;
use crate::util::table::{delta2, f2, Table};
use crate::workload::{generators, paperwl, trace, Workload};

use args::Args;

pub const USAGE: &str = "\
dpa — DPA Load Balancer (paper reproduction)

USAGE:
  dpa run [--workload WL] [--strategy S] [--rounds N] [--tau F] [options]
  dpa table1 [--seeds N] [--strategies a,b,c]
                                 reproduce Table 1 (Experiment 1) on both
                                 drivers, with forwarded-message counts
  dpa fig3 [--max-rounds N]      reproduce Figure 3 (Experiment 2)
  dpa elastic [--strategy S] [--items N]
                                 elastic-membership demo: a WL1-style hot
                                 phase scales the reducer set up, the cool
                                 tail scales it back down — run on BOTH
                                 drivers, parity-checked against the oracle
  dpa chaos [--seeds N] [--items N] [--faults a,b] [--strategies a,b,c]
            [--json PATH]
                                 chaos acceptance matrix: seeded fault plans
                                 (kill/slow/stall/drop) injected into reducers
                                 mid-run on BOTH drivers under §7 state
                                 forwarding — kills recover via retire +
                                 respawn with checkpoint/WAL restore, and
                                 every cell is checked against the serial
                                 oracle and for sim/threads parity
  dpa workloads                  describe the five paper workloads
  dpa help

OPTIONS (table1):
  --seeds N         runs per cell (mean)                     [default: 3]
  --strategies L    comma list of strategies to compare
                    (halving|doubling|multiprobe[:K]|twochoices|splitkey[:D]|
                     ptable[:B][:R]; unknown names are a hard error)
                                                  [default: halving,doubling]
  --throughput      add hot-path columns to the LB runs: records/sec
                    (host wall clock) and p50/p99 per-record latency
                    (sim: virtual ticks, threads: µs)

OPTIONS (chaos):
  --seeds N         fault plans per (strategy, fault) cell     [default: 2]
  --items N         uniform workload size per run              [default: 400]
  --faults L        comma list of kill|slow|stall|drop         [default: kill,slow,stall]
  --strategies L    router families under test
                                      [default: doubling,multiprobe,twochoices]
  --zones SPEC      failure-domain map, `;`-separated zone groups of
                    `,`-separated reducer ids (e.g. \"0,1;2,3\");
                    checkpoint replicas prefer a cross-zone peer
  --json PATH       also write the matrix as flat JSON

OPTIONS (run):
  --workload WL     wl1|wl2|wl3|wl4|wl5|zipf|uniform|corpus|hot or a trace
                    file path                                [default: wl4]
  --strategy S      none|halving|doubling|multiprobe[:K]|twochoices|
                    splitkey[:D]|ptable[:B][:R]              [default: doubling]
  --rounds N        max LB rounds per reducer                [default: 1]
  --tau F           Eq.1 threshold τ                         [default: 0.2]
  --split-watermark F
                    splitkey only: decayed load a single key
                    must carry before it splits d-way        [default: 4.0]
  --decay-alpha F   EWMA weight of new load samples (0,1]    [default: 0.5]
  --hysteresis F    overload-flag band around the mean       [default: 0.25]
  --min-gain F      min fractional gain to re-home a key     [default: 0.1]
  --scale-up F      mean decayed qlen above which a reducer
                    is ADDED (any --scale-*/--*-reducers flag
                    enables elastic membership)               [default: 8.0]
  --scale-down F    mean decayed qlen below which the coldest
                    reducer RETIRES                           [default: 1.0]
  --min-reducers N  elastic floor                             [default: 1]
  --max-reducers N  elastic ceiling (id-space pre-allocation) [default: 16]
  --mappers N / --reducers N                                 [default: 4/4]
  --driver D        sim|threads                              [default: sim]
  --seed N          sim schedule seed                        [default: 0]
  --items N         generated workload size                  [default: 100]
  --executor E      wordcount|tokenized|sum|distinct|topk    [default: wordcount]
  --state-forward   use §7 state forwarding (sim or threads driver)
  --chaos SPEC      fault plan, e.g. \"kill@1:40,slow:4@0:20\" (kill
                    events need --state-forward and 2+ reducers)
  --checkpoint-interval N
                    chaos replication cadence: checkpoint to a peer
                    every N folded records per reducer     [default: 16]
  --zones SPEC      failure-domain map (see chaos above); zone-aware
                    strategies (ptable[:B][:R]) place replicas across
                    distinct zones
  --config PATH     TOML config file (see configs/)
  --save-trace PATH write the workload to a trace file
  --quiet           one-line report
";

/// Parsed top-level command.
pub enum Command {
    Run(Box<RunOpts>),
    Table1 { seeds: usize, strategies: Vec<Strategy>, throughput: bool },
    Fig3 { max_rounds: u32 },
    Elastic { strategy: Strategy, items: usize },
    Chaos {
        seeds: usize,
        items: usize,
        faults: Vec<String>,
        strategies: Vec<Strategy>,
        zones: Option<String>,
        json: Option<PathBuf>,
    },
    Workloads,
    Help,
}

/// Options for `dpa run`.
pub struct RunOpts {
    pub workload: String,
    pub items: usize,
    pub cfg: PipelineConfig,
    pub executor: ExecutorKind,
    pub save_trace: Option<PathBuf>,
    pub quiet: bool,
}

/// Parse argv (without the program name).
pub fn parse(argv: &[String]) -> crate::Result<Command> {
    let mut args = Args::new(argv)?;
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "" | "help" | "--help" | "-h" => Ok(Command::Help),
        "workloads" => Ok(Command::Workloads),
        "table1" => {
            let seeds = args.take_opt_parse("seeds")?.unwrap_or(3usize);
            let strategies = match args.take_opt("strategies") {
                Some(list) => Strategy::parse_list(&list).map_err(anyhow::Error::msg)?,
                None => Strategy::methods().to_vec(),
            };
            if strategies.is_empty() {
                bail!("--strategies needs at least one strategy");
            }
            let throughput = args.take_flag("throughput");
            args.finish()?;
            Ok(Command::Table1 { seeds, strategies, throughput })
        }
        "fig3" => {
            let max_rounds = args.take_opt_parse("max-rounds")?.unwrap_or(4u32);
            args.finish()?;
            Ok(Command::Fig3 { max_rounds })
        }
        "elastic" => {
            let strategy = args
                .take_opt("strategy")
                .map(|s| s.parse::<Strategy>())
                .transpose()
                .map_err(anyhow::Error::msg)?
                .unwrap_or(Strategy::Doubling);
            let items = args.take_opt_parse("items")?.unwrap_or(400usize);
            args.finish()?;
            Ok(Command::Elastic { strategy, items })
        }
        "chaos" => {
            let seeds = args.take_opt_parse("seeds")?.unwrap_or(2usize);
            let items = args.take_opt_parse("items")?.unwrap_or(400usize);
            let faults: Vec<String> = args
                .take_opt("faults")
                .unwrap_or_else(|| "kill,slow,stall".into())
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if faults.is_empty() {
                bail!("--faults needs at least one fault kind");
            }
            for f in &faults {
                // seeded() owns the fault-name registry; probe it so
                // typos die at parse time, not mid-matrix
                ChaosPlan::seeded(f, 0, 1).map_err(anyhow::Error::msg)?;
            }
            let strategies = match args.take_opt("strategies") {
                Some(list) => Strategy::parse_list(&list).map_err(anyhow::Error::msg)?,
                None => vec![
                    Strategy::Doubling,
                    Strategy::MultiProbe { probes: crate::hash::DEFAULT_PROBES },
                    Strategy::TwoChoices,
                ],
            };
            if strategies.is_empty() {
                bail!("--strategies needs at least one strategy");
            }
            let zones = args.take_opt("zones");
            if let Some(z) = &zones {
                crate::hash::parse_zone_spec(z).map_err(anyhow::Error::msg)?;
            }
            let json = args.take_opt("json").map(PathBuf::from);
            args.finish()?;
            Ok(Command::Chaos { seeds, items, faults, strategies, zones, json })
        }
        "run" => {
            let mut cfg = PipelineConfig::default();
            if let Some(path) = args.take_opt("config") {
                cfg = PipelineConfig::from_toml_file(std::path::Path::new(&path))?;
            }
            cfg.strategy = args
                .take_opt("strategy")
                .map(|s| s.parse::<Strategy>())
                .transpose()
                .map_err(anyhow::Error::msg)?
                .unwrap_or(Strategy::Doubling);
            if let Some(v) = args.take_opt_parse("rounds")? {
                cfg.max_rounds = v;
            }
            if let Some(v) = args.take_opt_parse("tau")? {
                cfg.tau = v;
            }
            if let Some(v) = args.take_opt_parse("split-watermark")? {
                cfg.split_watermark = v;
            }
            if let Some(v) = args.take_opt_parse("decay-alpha")? {
                cfg.signal.decay_alpha = v;
            }
            if let Some(v) = args.take_opt_parse("hysteresis")? {
                cfg.signal.hysteresis = v;
            }
            if let Some(v) = args.take_opt_parse("min-gain")? {
                cfg.signal.min_gain = v;
            }
            if let Some(v) = args.take_opt_parse("scale-up")? {
                cfg.elastic_mut().scale_up = v;
            }
            if let Some(v) = args.take_opt_parse("scale-down")? {
                cfg.elastic_mut().scale_down = v;
            }
            if let Some(v) = args.take_opt_parse("min-reducers")? {
                cfg.elastic_mut().min_reducers = v;
            }
            if let Some(v) = args.take_opt_parse("max-reducers")? {
                cfg.elastic_mut().max_reducers = v;
            }
            if let Some(v) = args.take_opt_parse("mappers")? {
                cfg.mappers = v;
            }
            if let Some(v) = args.take_opt_parse("reducers")? {
                cfg.reducers = v;
            }
            if let Some(v) = args.take_opt("driver") {
                cfg.driver = v.parse::<DriverKind>().map_err(anyhow::Error::msg)?;
            }
            if let Some(v) = args.take_opt_parse("seed")? {
                cfg.seed = v;
            }
            if args.take_flag("state-forward") {
                cfg.mode = ConsistencyMode::StateForward;
            }
            if let Some(v) = args.take_opt("chaos") {
                cfg.chaos = Some(v);
            }
            if let Some(v) = args.take_opt_parse("checkpoint-interval")? {
                cfg.checkpoint_interval = v;
            }
            if let Some(v) = args.take_opt("zones") {
                cfg.zones = Some(v);
            }
            let executor = match args.take_opt("executor").as_deref() {
                None | Some("wordcount") => ExecutorKind::WordCount,
                Some("tokenized") => ExecutorKind::TokenizedWordCount,
                Some("sum") => ExecutorKind::KeyedSum,
                Some("distinct") => ExecutorKind::Distinct,
                Some("topk") => ExecutorKind::TopK(10),
                Some(other) => bail!("unknown executor '{other}'"),
            };
            let opts = RunOpts {
                workload: args.take_opt("workload").unwrap_or_else(|| "wl4".into()),
                items: args.take_opt_parse("items")?.unwrap_or(100),
                cfg,
                executor,
                save_trace: args.take_opt("save-trace").map(PathBuf::from),
                quiet: args.take_flag("quiet"),
            };
            args.finish()?;
            Ok(Command::Run(Box::new(opts)))
        }
        other => bail!("unknown command '{other}' (try `dpa help`)"),
    }
}

/// Resolve a workload name (or trace path) to items.
pub fn resolve_workload(name: &str, items: usize, seed: u64) -> crate::Result<Workload> {
    Ok(match name {
        "wl1" => paperwl::wl1(),
        "wl2" => paperwl::wl2(),
        "wl3" => paperwl::wl3(),
        "wl4" => paperwl::wl4(),
        "wl5" => paperwl::wl5(),
        "zipf" => generators::zipf(items, 200, 1.2, seed),
        "uniform" => generators::uniform(items, 200, seed),
        "hot" => generators::hot_key(items, 0.6, 50, seed),
        "corpus" => crate::workload::corpus::workload(items, 1.0, seed),
        path => {
            let p = std::path::Path::new(path);
            if !p.exists() {
                bail!(
                    "unknown workload '{name}' (expected wl1..wl5|zipf|uniform|hot|corpus \
                     or a trace file path)"
                );
            }
            trace::load(p).context("loading workload trace")?
        }
    })
}

/// Execute a parsed command; returns the process exit code.
pub fn execute(cmd: Command) -> crate::Result<i32> {
    match cmd {
        Command::Help => {
            println!("{USAGE}");
            Ok(0)
        }
        Command::Workloads => {
            let (rh, rd) = paperwl::initial_rings();
            let mut t = Table::new([
                "workload", "items", "distinct", "S halving", "S doubling", "construction",
            ]);
            for w in paperwl::all() {
                t.row([
                    w.name.clone(),
                    w.len().to_string(),
                    w.distinct_keys().len().to_string(),
                    f2(w.static_skew(&rh)),
                    f2(w.static_skew(&rd)),
                    w.description.clone(),
                ]);
            }
            t.print();
            Ok(0)
        }
        Command::Run(opts) => {
            let w = resolve_workload(&opts.workload, opts.items, opts.cfg.seed)?;
            if let Some(path) = &opts.save_trace {
                trace::save(&w, path)?;
            }
            let pipeline = Pipeline::builtin(opts.cfg.clone(), opts.executor);
            let report = pipeline.run(w.items.clone())?;
            if opts.quiet {
                println!("{}", report.one_line());
            } else {
                println!("workload: {} ({} items)", w.name, w.len());
                if !w.description.is_empty() {
                    println!("  {}", w.description);
                }
                print!("{}", report.render());
            }
            Ok(0)
        }
        Command::Table1 { seeds, strategies, throughput } => {
            print!("{}", table1_opts(seeds, &strategies, throughput)?);
            Ok(0)
        }
        Command::Fig3 { max_rounds } => {
            print!("{}", fig3(max_rounds)?);
            Ok(0)
        }
        Command::Elastic { strategy, items } => {
            let (out, ok) = elastic_demo(strategy, items)?;
            print!("{out}");
            Ok(i32::from(!ok))
        }
        Command::Chaos { seeds, items, faults, strategies, zones, json } => {
            let (out, report_json, ok) =
                chaos_demo(seeds, items, &faults, &strategies, zones.as_deref())?;
            print!("{out}");
            if let Some(path) = json {
                std::fs::write(&path, report_json)
                    .with_context(|| format!("writing {}", path.display()))?;
            }
            Ok(i32::from(!ok))
        }
    }
}

/// The `dpa elastic` acceptance run: a WL1-style hot phase (every item on
/// one doubling-layout reducer) drives the decayed mean over the scale-up
/// watermark, then a uniform cool tail sinks it below the scale-down
/// watermark — on BOTH drivers, with every membership change flowing
/// through the §7 state-forwarding machinery. Returns the rendered
/// timeline and whether the acceptance held: identical merged output on
/// both drivers (equal to the serial oracle) and, on the deterministic
/// sim, at least one scale-up AND one scale-down.
pub fn elastic_demo(strategy: Strategy, items: usize) -> crate::Result<(String, bool)> {
    let hot = paperwl::wl1();
    let tail = generators::uniform(items.max(100), 60, 11);
    let mut all: Vec<String> = hot.items.clone();
    all.extend(tail.items.iter().cloned());
    let oracle = {
        let mut m = std::collections::HashMap::new();
        for i in &all {
            *m.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut v: Vec<(String, i64)> = m.into_iter().collect();
        v.sort();
        v
    };

    let mk_cfg = |driver| {
        let mut cfg = PipelineConfig::default();
        cfg.driver = driver;
        cfg.strategy = strategy;
        if strategy.is_token_ring() {
            cfg.initial_tokens = Some(strategy.initial_tokens(cfg.halving_init_tokens));
        }
        cfg.mode = ConsistencyMode::StateForward;
        cfg.max_rounds = 2;
        cfg.cooldown = 20;
        if driver == DriverKind::Threads {
            cfg.reduce_delay_us = 150;
        }
        *cfg.elastic_mut() = crate::balancer::elastic::ElasticConfig {
            scale_up: 2.0,
            scale_down: 1.0,
            min_reducers: 4,
            max_reducers: 8,
        };
        cfg
    };

    let mut out = format!(
        "elastic membership demo — strategy {strategy}, {} hot + {} tail items, \
         reducers 4..=8 (watermarks: up >2.0, down <1.0 mean decayed qlen)\n\n",
        hot.items.len(),
        tail.items.len()
    );
    let mut ok = true;
    let mut results = Vec::new();
    for driver in [DriverKind::Sim, DriverKind::Threads] {
        let name = match driver {
            DriverKind::Sim => "sim",
            DriverKind::Threads => "threads",
        };
        let r = Pipeline::wordcount(mk_cfg(driver)).run(all.clone())?;
        let (added, retired) = r.scale_counts();
        out.push_str(&format!(
            "[{name}] S = {} | {} reducer ids ({} scale-ups, {} retires) | \
             processed = {:?}\n",
            f2(r.skew()),
            r.processed.len(),
            added,
            retired,
            r.processed
        ));
        for e in r.membership_events() {
            out.push_str(&format!(
                "  @{:>8} {:?} (epoch {}, qlens {:?})\n",
                e.at, e.membership.unwrap(), e.epoch, e.qlens
            ));
        }
        if r.result != oracle {
            out.push_str(&format!("[{name}] FAIL: merged output != serial oracle\n"));
            ok = false;
        }
        if driver == DriverKind::Sim && (added == 0 || retired == 0) {
            out.push_str(
                "[sim] FAIL: expected at least one scale-up and one scale-down\n",
            );
            ok = false;
        }
        results.push(r.result);
    }
    if results[0] == results[1] {
        out.push_str("\nsim and threads merged outputs identical, equal to the oracle ✓\n");
    } else {
        out.push_str("\nFAIL: sim and threads merged outputs differ\n");
        ok = false;
    }
    Ok((out, ok))
}

/// The `dpa chaos` acceptance matrix: for every router family × fault
/// kind × seed, derive a deterministic fault plan
/// ([`ChaosPlan::seeded`]), inject it mid-run on BOTH drivers under §7
/// state forwarding (checkpoint-to-peer every 8 folds), and hold the
/// line on exactness:
///
/// * each driver's merged output equals the serial oracle — a kill loses
///   zero state (checkpoint restore + WAL tail replay), slow/stall/drop
///   perturb only the schedule;
/// * sim and threads agree with each other;
/// * the scheduled fault actually fired (a plan that never triggers
///   would make the cell vacuous);
/// * a kill cell recovered: every kill produced exactly one respawn.
///
/// Returns the rendered table, a flat-JSON matrix (for CI artifacts) and
/// whether every cell held.
pub fn chaos_demo(
    seeds: usize,
    items: usize,
    faults: &[String],
    strategies: &[Strategy],
    zones: Option<&str>,
) -> crate::Result<(String, String, bool)> {
    let mut ok = true;
    let mut out = format!(
        "chaos acceptance — {} router families × {} fault kinds × {} seeds, \
         both drivers, §7 state forwarding, checkpoint interval 8\n\n",
        strategies.len(),
        faults.len(),
        seeds
    );
    let mut t = Table::new([
        "strategy", "fault", "seed", "plan", "driver", "kills", "respawns", "ckpts", "requeued",
        "rec p99", "oracle",
    ]);
    let mut fail_lines = Vec::new();
    let mut entries: Vec<(String, String)> = Vec::new();
    let mut cells = 0u64;
    let mut failures = 0u64;
    for &strategy in strategies {
        for fault in faults {
            for seed in 0..seeds as u64 {
                let mut base = PipelineConfig::default();
                let plan = ChaosPlan::seeded(fault, seed, base.reducers)
                    .map_err(anyhow::Error::msg)?;
                base.strategy = strategy;
                if strategy.is_token_ring() {
                    // dense halving layout: every reducer owns enough of
                    // the ring that the seed-derived trigger point (a
                    // per-victim folded-record count) is reliably reached
                    base.initial_tokens = Some(base.halving_init_tokens);
                }
                base.mode = ConsistencyMode::StateForward;
                base.max_rounds = 2;
                base.seed = seed;
                base.chaos = Some(plan.spec());
                base.checkpoint_interval = 8;
                base.zones = zones.map(str::to_string);
                let w = generators::uniform(items, 60, seed);
                let oracle = {
                    let mut m = std::collections::HashMap::new();
                    for i in &w.items {
                        *m.entry(i.clone()).or_insert(0i64) += 1;
                    }
                    let mut v: Vec<(String, i64)> = m.into_iter().collect();
                    v.sort();
                    v
                };
                cells += 1;
                let mut results = Vec::new();
                let mut cell_ok = true;
                for driver in [DriverKind::Sim, DriverKind::Threads] {
                    let name = match driver {
                        DriverKind::Sim => "sim",
                        DriverKind::Threads => "threads",
                    };
                    let mut cfg = base.clone();
                    cfg.driver = driver;
                    if driver == DriverKind::Threads {
                        cfg.reduce_delay_us = 150;
                    }
                    let r = Pipeline::wordcount(cfg).run(w.items.clone())?;
                    let oracle_ok = r.result == oracle;
                    if !oracle_ok {
                        fail_lines.push(format!(
                            "FAIL [{strategy}/{fault}/s{seed}/{name}] merged \
                             output != serial oracle"
                        ));
                    }
                    if r.fault_events.is_empty() {
                        fail_lines.push(format!(
                            "FAIL [{strategy}/{fault}/s{seed}/{name}] plan \
                             '{}' never fired",
                            plan.spec()
                        ));
                        cell_ok = false;
                    }
                    if fault == "kill"
                        && (r.recovery.kills < 1 || r.recovery.respawns != r.recovery.kills)
                    {
                        fail_lines.push(format!(
                            "FAIL [{strategy}/{fault}/s{seed}/{name}] kill did \
                             not recover (kills {}, respawns {})",
                            r.recovery.kills, r.recovery.respawns
                        ));
                        cell_ok = false;
                    }
                    cell_ok &= oracle_ok;
                    t.row([
                        strategy.to_string(),
                        fault.clone(),
                        seed.to_string(),
                        plan.spec(),
                        name.to_string(),
                        r.recovery.kills.to_string(),
                        r.recovery.respawns.to_string(),
                        r.recovery.checkpoints.to_string(),
                        r.recovery.requeued.to_string(),
                        r.recovery_latency.map_or_else(|| "-".into(), |l| l.p99.to_string()),
                        if oracle_ok { "ok".into() } else { "FAIL".to_string() },
                    ]);
                    let pfx = format!("{strategy}.{fault}.s{seed}.{name}");
                    entries.push((format!("{pfx}.kills"), r.recovery.kills.to_string()));
                    entries.push((format!("{pfx}.respawns"), r.recovery.respawns.to_string()));
                    entries
                        .push((format!("{pfx}.checkpoints"), r.recovery.checkpoints.to_string()));
                    entries.push((format!("{pfx}.requeued"), r.recovery.requeued.to_string()));
                    entries.push((format!("{pfx}.ok"), u8::from(oracle_ok).to_string()));
                    results.push(r.result);
                }
                if results[0] != results[1] {
                    fail_lines.push(format!(
                        "FAIL [{strategy}/{fault}/s{seed}] sim and threads \
                         merged outputs differ"
                    ));
                    cell_ok = false;
                }
                if !cell_ok {
                    failures += 1;
                    ok = false;
                }
            }
        }
    }
    out.push_str(&t.render());
    for line in &fail_lines {
        out.push_str(line);
        out.push('\n');
    }
    if ok {
        out.push_str(
            "\nall cells oracle-exact and driver-identical; every kill \
             recovered with zero state loss ✓\n",
        );
    }
    entries.push(("cells".into(), cells.to_string()));
    entries.push(("failures".into(), failures.to_string()));
    entries.push(("ok".into(), u8::from(ok).to_string()));
    let mut json = String::from("{\n");
    for (i, (k, v)) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        json.push_str(&format!("  \"{k}\": {v}{comma}\n"));
    }
    json.push_str("}\n");
    Ok((out, json, ok))
}

/// One experiment cell's configuration under `strategy` on `driver`.
/// `lb = false` runs the *same router* with the trigger disabled
/// (`max_rounds = 0`). For token-ring and multi-probe routers a
/// never-firing policy leaves routing untouched, so the no-LB column is
/// the fixed-layout baseline (identical to the old
/// `Strategy::None`-on-the-method's-layout runs). Two-choices is
/// different by design: its route-time less-loaded-candidate placement
/// is intrinsic to the router and still active (reducers keep publishing
/// loads), so its "No LB" column measures the router *without
/// redistribution* — the Δ column isolates `redistribute`'s marginal
/// contribution, not the whole balancing mechanism.
fn cell_cfg(strategy: Strategy, driver: DriverKind, lb: bool, max_rounds: u32) -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.driver = driver;
    cfg.strategy = strategy;
    if strategy.is_token_ring() {
        cfg.initial_tokens = Some(strategy.initial_tokens(cfg.halving_init_tokens));
    }
    cfg.max_rounds = if lb { max_rounds } else { 0 };
    if driver == DriverKind::Threads {
        // compute-heavy enough that skewed queues build and LB can fire,
        // light enough that a full table stays interactive
        cfg.reduce_delay_us = 150;
    }
    cfg
}

/// Run one cell over seeds `0..seeds` (the paper's 3-run protocol).
fn seed_sweep(
    cfg: PipelineConfig,
    items: &[String],
    seeds: usize,
) -> crate::Result<Vec<RunReport>> {
    let seed_list: Vec<u64> = (0..seeds as u64).collect();
    Pipeline::wordcount(cfg).run_seeds(items, &seed_list)
}

/// Everything one experiment cell measures: mean skew (with variance),
/// mean forwarded messages and mean redistribution (migration) count —
/// the column the WL3 ping-pong reduction is gated on — plus the
/// hot-path throughput axis: host wall-clock records/sec over the sweep
/// and mean per-record latency percentiles (map-enqueue → reduce; the
/// sim reports virtual ticks, threads report µs; 0 when no run recorded
/// latency).
#[derive(Clone, Copy, Debug)]
pub struct CellStats {
    pub skew: f64,
    pub skew_var: f64,
    pub forwarded: f64,
    pub migrations: f64,
    pub rps: f64,
    pub p50: f64,
    pub p99: f64,
}

/// Run one experiment cell and collect its [`CellStats`].
pub fn cell_stats(
    w: &Workload,
    strategy: Strategy,
    driver: DriverKind,
    lb: bool,
    max_rounds: u32,
    seeds: usize,
) -> crate::Result<CellStats> {
    let t0 = std::time::Instant::now();
    let reports = seed_sweep(cell_cfg(strategy, driver, lb, max_rounds), &w.items, seeds)?;
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let s = Summary::from_slice(&reports.iter().map(RunReport::skew).collect::<Vec<_>>());
    let n = reports.len().max(1) as f64;
    let mean = |f: fn(&RunReport) -> u64| reports.iter().map(|r| f(r) as f64).sum::<f64>() / n;
    let processed: u64 = reports.iter().map(RunReport::total_processed).sum();
    let lat: Vec<_> = reports.iter().filter_map(|r| r.latency).collect();
    let ln = lat.len().max(1) as f64;
    Ok(CellStats {
        skew: s.mean(),
        skew_var: s.variance(),
        forwarded: mean(RunReport::total_forwarded),
        migrations: mean(RunReport::migrations),
        rps: processed as f64 / elapsed,
        p50: lat.iter().map(|l| l.p50 as f64).sum::<f64>() / ln,
        p99: lat.iter().map(|l| l.p99 as f64).sum::<f64>() / ln,
    })
}

/// Mean skew (and variance) of a workload under a strategy / rounds cap
/// over `seeds` seeded sim runs.
pub fn mean_skew(
    w: &Workload,
    strategy: Strategy,
    lb: bool,
    max_rounds: u32,
    seeds: usize,
) -> crate::Result<(f64, f64)> {
    let c = cell_stats(w, strategy, DriverKind::Sim, lb, max_rounds, seeds)?;
    Ok((c.skew, c.skew_var))
}

/// One table1 cell: mean skew plus mean forwarded-message count.
pub fn strategy_stats(
    w: &Workload,
    strategy: Strategy,
    driver: DriverKind,
    lb: bool,
    max_rounds: u32,
    seeds: usize,
) -> crate::Result<(f64, f64)> {
    let c = cell_stats(w, strategy, driver, lb, max_rounds, seeds)?;
    Ok((c.skew, c.forwarded))
}

/// Reproduce Table 1 (Experiment 1): S with/without LB for WL1–WL5 ×
/// the selected strategies × both drivers, ≤ 1 LB round, mean over
/// seeds, with the mean forwarded-message count and the redistribution
/// (migration) count of the LB runs — the latter is how the WL3
/// ping-pong reduction from the decayed+hysteresis signal is measured.
pub fn table1(seeds: usize, strategies: &[Strategy]) -> crate::Result<String> {
    table1_opts(seeds, strategies, false)
}

/// [`table1`] with the hot-path axis: `throughput = true` appends
/// records/sec (host wall clock over the LB sweep) and p50/p99
/// per-record latency columns for the LB runs.
pub fn table1_opts(
    seeds: usize,
    strategies: &[Strategy],
    throughput: bool,
) -> crate::Result<String> {
    let mut out = String::from(
        "Experiment 1 (Table 1): skew S, forwarded messages and migrations, \
         no-LB vs LB (≤1 round/reducer)\n",
    );
    if throughput {
        out.push_str(
            "throughput columns measure the LB runs: rec/s on the host wall \
             clock; p50/p99 per-record latency in virtual ticks (sim) or µs \
             (threads)\n",
        );
    }
    let mut header = vec![
        "Workload".to_string(),
        "Method".to_string(),
        "Driver".to_string(),
        "No LB".to_string(),
        "With LB".to_string(),
        "Δ".to_string(),
        "fwd (LB)".to_string(),
        "migr (LB)".to_string(),
    ];
    if throughput {
        header.extend(["rec/s".to_string(), "p50".to_string(), "p99".to_string()]);
    }
    let mut t = Table::new(header);
    for w in paperwl::all() {
        for &strategy in strategies {
            for driver in [DriverKind::Sim, DriverKind::Threads] {
                let nolb = cell_stats(&w, strategy, driver, false, 1, seeds)?;
                let lb = cell_stats(&w, strategy, driver, true, 1, seeds)?;
                let mut row = vec![
                    w.name.clone(),
                    strategy.to_string(),
                    match driver {
                        DriverKind::Sim => "sim".to_string(),
                        DriverKind::Threads => "threads".to_string(),
                    },
                    f2(nolb.skew),
                    f2(lb.skew),
                    delta2(nolb.skew - lb.skew),
                    format!("{:.1}", lb.forwarded),
                    format!("{:.1}", lb.migrations),
                ];
                if throughput {
                    row.extend([
                        format!("{:.0}", lb.rps),
                        format!("{:.0}", lb.p50),
                        format!("{:.0}", lb.p99),
                    ]);
                }
                t.row(row);
            }
        }
    }
    out.push_str(&t.render());
    Ok(out)
}

/// Reproduce Figure 3 (Experiment 2): S as a function of the max LB
/// rounds per reducer.
pub fn fig3(max_rounds: u32) -> crate::Result<String> {
    let mut out = String::from("Experiment 2 (Figure 3): skew S vs max LB rounds per reducer\n");
    let mut header: Vec<String> = vec!["Workload".into(), "Method".into(), "rounds=0".into()];
    for r in 1..=max_rounds {
        header.push(format!("rounds={r}"));
    }
    let mut t = Table::new(header);
    for w in paperwl::all() {
        for strategy in Strategy::methods() {
            let mut row = vec![w.name.clone(), strategy.to_string()];
            let (s0, _) = mean_skew(&w, strategy, false, 1, 3)?;
            row.push(f2(s0));
            for rounds in 1..=max_rounds {
                let (s, _) = mean_skew(&w, strategy, true, rounds, 3)?;
                row.push(f2(s));
            }
            t.row(row);
        }
    }
    out.push_str(&t.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_help_and_unknown() {
        assert!(matches!(parse(&sv(&["help"])).unwrap(), Command::Help));
        assert!(matches!(parse(&sv(&[])).unwrap(), Command::Help));
        assert!(parse(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn parse_run_options() {
        let cmd = parse(&sv(&[
            "run",
            "--workload",
            "wl1",
            "--strategy",
            "halving",
            "--rounds",
            "3",
            "--tau",
            "0.5",
            "--quiet",
        ]))
        .unwrap();
        match cmd {
            Command::Run(o) => {
                assert_eq!(o.workload, "wl1");
                assert_eq!(o.cfg.strategy, Strategy::Halving);
                assert_eq!(o.cfg.max_rounds, 3);
                assert!((o.cfg.tau - 0.5).abs() < 1e-12);
                assert!(o.quiet);
            }
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn parse_rejects_unknown_flag() {
        assert!(parse(&sv(&["run", "--bogus", "1"])).is_err());
    }

    #[test]
    fn parse_table1_strategies_filter() {
        let cmd = parse(&sv(&["table1", "--strategies", "halving,doubling,multiprobe"])).unwrap();
        match cmd {
            Command::Table1 { seeds, strategies, throughput } => {
                assert_eq!(seeds, 3);
                assert!(!throughput, "--throughput must be opt-in");
                assert_eq!(
                    strategies,
                    vec![
                        Strategy::Halving,
                        Strategy::Doubling,
                        Strategy::MultiProbe { probes: crate::hash::DEFAULT_PROBES },
                    ]
                );
            }
            _ => panic!("expected Table1"),
        }
        // default: the paper's two methods
        match parse(&sv(&["table1"])).unwrap() {
            Command::Table1 { strategies, .. } => {
                assert_eq!(strategies, Strategy::methods().to_vec());
            }
            _ => panic!("expected Table1"),
        }
        assert!(parse(&sv(&["table1", "--strategies", "bogus"])).is_err());
    }

    #[test]
    fn parse_table1_throughput_flag() {
        match parse(&sv(&["table1", "--seeds", "1", "--throughput"])).unwrap() {
            Command::Table1 { seeds, throughput, .. } => {
                assert_eq!(seeds, 1);
                assert!(throughput);
            }
            _ => panic!("expected Table1"),
        }
    }

    #[test]
    fn parse_run_probe_strategy() {
        match parse(&sv(&["run", "--strategy", "twochoices", "--quiet"])).unwrap() {
            Command::Run(o) => assert_eq!(o.cfg.strategy, Strategy::TwoChoices),
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn parse_run_split_key_strategy() {
        let cmd = parse(&sv(&[
            "run",
            "--strategy",
            "splitkey:4",
            "--split-watermark",
            "1.5",
            "--quiet",
        ]))
        .unwrap();
        match cmd {
            Command::Run(o) => {
                assert_eq!(o.cfg.strategy, Strategy::SplitKey { d: 4 });
                assert!((o.cfg.split_watermark - 1.5).abs() < 1e-12);
            }
            _ => panic!("expected Run"),
        }
        // d outside 2..=8 is rejected at parse time
        assert!(parse(&sv(&["run", "--strategy", "splitkey:1", "--quiet"])).is_err());
    }

    #[test]
    fn parse_run_signal_knobs() {
        let cmd = parse(&sv(&[
            "run",
            "--decay-alpha",
            "0.3",
            "--hysteresis",
            "0.4",
            "--min-gain",
            "0.2",
            "--quiet",
        ]))
        .unwrap();
        match cmd {
            Command::Run(o) => {
                assert!((o.cfg.signal.decay_alpha - 0.3).abs() < 1e-12);
                assert!((o.cfg.signal.hysteresis - 0.4).abs() < 1e-12);
                assert!((o.cfg.signal.min_gain - 0.2).abs() < 1e-12);
            }
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn parse_elastic_command_and_run_knobs() {
        match parse(&sv(&["elastic", "--strategy", "halving", "--items", "200"])).unwrap() {
            Command::Elastic { strategy, items } => {
                assert_eq!(strategy, Strategy::Halving);
                assert_eq!(items, 200);
            }
            _ => panic!("expected Elastic"),
        }
        match parse(&sv(&["elastic"])).unwrap() {
            Command::Elastic { strategy, items } => {
                assert_eq!(strategy, Strategy::Doubling);
                assert_eq!(items, 400);
            }
            _ => panic!("expected Elastic"),
        }
        let cmd = parse(&sv(&[
            "run",
            "--scale-up",
            "6.0",
            "--scale-down",
            "0.5",
            "--min-reducers",
            "2",
            "--max-reducers",
            "8",
            "--quiet",
        ]))
        .unwrap();
        match cmd {
            Command::Run(o) => {
                let e = o.cfg.elastic.expect("scale flags enable elastic");
                assert!((e.scale_up - 6.0).abs() < 1e-12);
                assert!((e.scale_down - 0.5).abs() < 1e-12);
                assert_eq!((e.min_reducers, e.max_reducers), (2, 8));
            }
            _ => panic!("expected Run"),
        }
        // no scale flag → elastic stays off
        match parse(&sv(&["run", "--quiet"])).unwrap() {
            Command::Run(o) => assert!(o.cfg.elastic.is_none()),
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn parse_chaos_command() {
        match parse(&sv(&["chaos"])).unwrap() {
            Command::Chaos { seeds, items, faults, strategies, zones, json } => {
                assert_eq!(seeds, 2);
                assert!(zones.is_none());
                assert_eq!(items, 400);
                assert_eq!(faults, vec!["kill", "slow", "stall"]);
                assert_eq!(
                    strategies,
                    vec![
                        Strategy::Doubling,
                        Strategy::MultiProbe { probes: crate::hash::DEFAULT_PROBES },
                        Strategy::TwoChoices,
                    ],
                    "default matrix spans three router families"
                );
                assert!(json.is_none());
            }
            _ => panic!("expected Chaos"),
        }
        let cmd = parse(&sv(&[
            "chaos",
            "--seeds",
            "1",
            "--items",
            "200",
            "--faults",
            "drop",
            "--strategies",
            "halving",
            "--json",
            "out.json",
        ]))
        .unwrap();
        match cmd {
            Command::Chaos { seeds, items, faults, strategies, zones, json } => {
                assert_eq!((seeds, items), (1, 200));
                assert!(zones.is_none());
                assert_eq!(faults, vec!["drop"]);
                assert_eq!(strategies, vec![Strategy::Halving]);
                assert_eq!(json, Some(PathBuf::from("out.json")));
            }
            _ => panic!("expected Chaos"),
        }
        // typo'd fault kinds die at parse time, not mid-matrix
        assert!(parse(&sv(&["chaos", "--faults", "explode"])).is_err());
        assert!(parse(&sv(&["chaos", "--faults", ","])).is_err());
        // `dpa run` carries the plan + replication cadence knobs too
        let cmd = parse(&sv(&[
            "run",
            "--chaos",
            "slow:2@0:5",
            "--checkpoint-interval",
            "4",
            "--quiet",
        ]))
        .unwrap();
        match cmd {
            Command::Run(o) => {
                assert_eq!(o.cfg.chaos.as_deref(), Some("slow:2@0:5"));
                assert_eq!(o.cfg.checkpoint_interval, 4);
            }
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn chaos_demo_single_cell_passes() {
        // one slow-fault cell on the doubling family, both drivers: the
        // answer must match the oracle and the fault must actually fire
        let faults = vec!["slow".to_string()];
        let (out, json, ok) =
            chaos_demo(1, 300, &faults, &[Strategy::Doubling], None).unwrap();
        assert!(ok, "{out}");
        assert!(json.contains("\"cells\": 1"), "{json}");
        assert!(json.contains("\"failures\": 0"), "{json}");
        assert!(json.contains("\"doubling.slow.s0.sim.ok\": 1"), "{json}");
    }

    #[test]
    fn resolve_known_workloads() {
        assert_eq!(resolve_workload("wl3", 0, 0).unwrap().len(), 100);
        assert_eq!(resolve_workload("zipf", 50, 1).unwrap().len(), 50);
        assert!(resolve_workload("nope", 0, 0).is_err());
    }

    #[test]
    fn run_command_executes() {
        let cmd = parse(&sv(&["run", "--workload", "wl2", "--quiet"])).unwrap();
        assert_eq!(execute(cmd).unwrap(), 0);
    }
}
