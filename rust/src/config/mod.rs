//! Configuration: a typed [`pipeline::PipelineConfig`](crate::pipeline::PipelineConfig)
//! loaded from a minimal TOML-subset file ([`toml_lite`]) and/or CLI
//! overrides. The offline build carries no `serde`/`toml`, so we parse the
//! subset we need ourselves: `[sections]`, `key = value` with string, int,
//! float and bool values, `#` comments.

pub mod toml_lite;

pub use toml_lite::{parse, Document, Value};
