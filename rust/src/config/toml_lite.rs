//! A minimal TOML-subset parser (offline build: no `toml` crate).
//!
//! Supported:
//! - `# comments` and blank lines
//! - `[section]` headers (one level)
//! - `key = "string"`, `key = 'string'`, `key = 123`, `key = 1.5`,
//!   `key = true|false`
//!
//! Lookup is by `"section.key"` (or bare `"key"` for the root section).

use std::collections::BTreeMap;
use std::fmt;

use thiserror::Error;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// Parse error with line information.
#[derive(Debug, Error)]
#[error("config parse error at line {line}: {msg}")]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

/// A parsed document: flat `section.key -> value` map.
#[derive(Clone, Debug, Default)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_int)
    }

    pub fn get_float(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_float)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (in_str, c) {
            (None, '#') => return &line[..i],
            (None, '"') => in_str = Some('"'),
            (None, '\'') => in_str = Some('\''),
            (Some(q), c) if c == q => in_str = None,
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str, line: usize) -> Result<Value, ParseError> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(ParseError {
            line,
            msg: "missing value".into(),
        });
    }
    if (raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2)
        || (raw.starts_with('\'') && raw.ends_with('\'') && raw.len() >= 2)
    {
        return Ok(Value::Str(raw[1..raw.len() - 1].to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError {
        line,
        msg: format!("cannot parse value '{raw}'"),
    })
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (lineno, raw_line) in text.lines().enumerate() {
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        let lineno = lineno + 1;
        if line.starts_with('[') {
            if !line.ends_with(']') || line.len() < 3 {
                return Err(ParseError {
                    line: lineno,
                    msg: format!("malformed section header '{line}'"),
                });
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.is_empty() {
                return Err(ParseError {
                    line: lineno,
                    msg: "empty section name".into(),
                });
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(ParseError {
                line: lineno,
                msg: format!("expected 'key = value', got '{line}'"),
            });
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError {
                line: lineno,
                msg: "empty key".into(),
            });
        }
        let value = parse_value(&line[eq + 1..], lineno)?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(full_key, value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = parse(
            r#"
# top comment
name = "dpa"   # trailing comment
count = 4
tau = 0.2
enabled = true

[balancer]
strategy = 'doubling'
max_rounds = 2
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("dpa"));
        assert_eq!(doc.get_int("count"), Some(4));
        assert_eq!(doc.get_float("tau"), Some(0.2));
        assert_eq!(doc.get_bool("enabled"), Some(true));
        assert_eq!(doc.get_str("balancer.strategy"), Some("doubling"));
        assert_eq!(doc.get_int("balancer.max_rounds"), Some(2));
        assert_eq!(doc.len(), 6);
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("tau = 1").unwrap();
        assert_eq!(doc.get_float("tau"), Some(1.0));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse(r##"s = "a#b""##).unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("[unclosed\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("x = \n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("x = what?\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn later_keys_override_earlier() {
        let doc = parse("a = 1\na = 2\n").unwrap();
        assert_eq!(doc.get_int("a"), Some(2));
    }
}
