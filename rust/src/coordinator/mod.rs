//! The coordinator (§2.3): creates and launches mappers/reducers,
//! initializes the load balancer, assigns tasks to mappers, tracks reducer
//! lifetimes (shutdown protocol in [`crate::actor::ShutdownMonitor`]) and
//! runs the final state-merge step.

pub mod tasks;

use crate::exec::{merge_snapshots, MergeOp};

pub use tasks::{chunk_items, TaskPool};

/// Final state merge (§2): combine all reducer snapshots into the result.
///
/// For [`ConsistencyMode::StateForward`](crate::balancer::state_forward::ConsistencyMode)
/// runs the snapshots are key-disjoint and this is a plain union; the
/// `expect_disjoint` flag asserts that invariant.
///
/// Callers must pass `expect_disjoint = false` when the router carries
/// [`MergeContract::Associative`](crate::hash::MergeContract) (the
/// split-key family): a promoted key deliberately has partials on up to
/// `d` reducers, and the merge folds them associatively instead of
/// asserting single-homing. `ExecCore::finish` derives the flag from
/// the contract captured at build time.
pub fn merge_states(
    snaps: Vec<Vec<(String, i64)>>,
    op: MergeOp,
    expect_disjoint: bool,
) -> Vec<(String, i64)> {
    if expect_disjoint {
        let total: usize = snaps.iter().map(Vec::len).sum();
        let merged = merge_snapshots(snaps, op);
        assert_eq!(
            merged.len(),
            total,
            "state-forwarding invariant violated: some key had state on \
             more than one reducer"
        );
        merged
    } else {
        merge_snapshots(snaps, op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_overlapping_counts() {
        let merged = merge_states(
            vec![vec![("a".into(), 2)], vec![("a".into(), 3), ("b".into(), 1)]],
            MergeOp::Sum,
            false,
        );
        assert_eq!(merged, vec![("a".into(), 5), ("b".into(), 1)]);
    }

    #[test]
    fn disjoint_union_passes_assertion() {
        let merged = merge_states(
            vec![vec![("a".into(), 2)], vec![("b".into(), 1)]],
            MergeOp::Sum,
            true,
        );
        assert_eq!(merged.len(), 2);
    }

    #[test]
    #[should_panic(expected = "state-forwarding invariant")]
    fn overlap_fails_disjoint_assertion() {
        merge_states(
            vec![vec![("a".into(), 2)], vec![("a".into(), 3)]],
            MergeOp::Sum,
            true,
        );
    }
}
