//! Task management: the coordinator chunks the input into tasks and hands
//! them to mappers on request (§3: "mapper actors fetch tasks or data
//! items from the coordinator by means of a remote method call").
//!
//! The input lives in one shared `Arc<[String]>`; tasks are range views
//! ([`TaskItems`]) into it, so chunking — and re-running the same input
//! across seeds — never copies a string.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::exec::{Task, TaskItems};

/// Split input items into fixed-size tasks (range views, zero-copy).
pub fn chunk_items(items: impl Into<Arc<[String]>>, chunk_size: usize) -> Vec<Task> {
    assert!(chunk_size > 0);
    let src: Arc<[String]> = items.into();
    let mut tasks = Vec::with_capacity(src.len().div_ceil(chunk_size));
    let mut id = 0u64;
    let mut start = 0usize;
    while start < src.len() {
        let end = (start + chunk_size).min(src.len());
        tasks.push(Task { id, items: TaskItems::new(src.clone(), start, end) });
        id += 1;
        start = end;
    }
    tasks
}

/// The coordinator's task queue; mappers pull until it is empty. Shared
/// across mapper threads in the threads driver (the "remote method call"
/// becomes a mutex-protected pop).
pub struct TaskPool {
    tasks: Mutex<VecDeque<Task>>,
    total: usize,
}

impl TaskPool {
    pub fn new(tasks: Vec<Task>) -> Self {
        let total = tasks.len();
        TaskPool {
            tasks: Mutex::new(tasks.into()),
            total,
        }
    }

    pub fn from_items(items: impl Into<Arc<[String]>>, chunk_size: usize) -> Self {
        Self::new(chunk_items(items, chunk_size))
    }

    /// Next task, or `None` when the input is exhausted.
    pub fn fetch(&self) -> Option<Task> {
        self.tasks.lock().unwrap().pop_front()
    }

    pub fn remaining(&self) -> usize {
        self.tasks.lock().unwrap().len()
    }

    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunking_covers_all_items_in_order() {
        let items: Vec<String> = (0..25).map(|i| format!("i{i}")).collect();
        let tasks = chunk_items(items.clone(), 10);
        assert_eq!(tasks.len(), 3);
        assert_eq!(tasks[0].items.len(), 10);
        assert_eq!(tasks[2].items.len(), 5);
        let flat: Vec<String> = tasks.into_iter().flat_map(|t| t.items.to_vec()).collect();
        assert_eq!(flat, items);
    }

    #[test]
    fn chunking_shares_the_input_allocation() {
        let items: Arc<[String]> = (0..20).map(|i| format!("i{i}")).collect::<Vec<_>>().into();
        let tasks = chunk_items(items.clone(), 8);
        // zero-copy: task items point into the same allocation
        assert!(std::ptr::eq(&items[8], &tasks[1].items[0]));
    }

    #[test]
    fn chunk_ids_are_sequential() {
        let tasks = chunk_items((0..30).map(|i| i.to_string()).collect::<Vec<_>>(), 7);
        let ids: Vec<u64> = tasks.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_input_no_tasks() {
        assert!(chunk_items(Vec::<String>::new(), 10).is_empty());
    }

    #[test]
    fn pool_fetch_drains() {
        let pool = TaskPool::from_items((0..5).map(|i| i.to_string()).collect::<Vec<_>>(), 2);
        assert_eq!(pool.total(), 3);
        let mut fetched = 0;
        while pool.fetch().is_some() {
            fetched += 1;
        }
        assert_eq!(fetched, 3);
        assert!(pool.fetch().is_none());
        assert_eq!(pool.remaining(), 0);
    }

    #[test]
    fn pool_is_thread_safe() {
        let pool = std::sync::Arc::new(TaskPool::from_items(
            (0..100).map(|i| i.to_string()).collect::<Vec<_>>(),
            1,
        ));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                let mut n = 0;
                while p.fetch().is_some() {
                    n += 1;
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 100);
    }
}
