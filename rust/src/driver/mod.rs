//! Threads driver: the deployment-shaped execution mode, rebuilt as a thin
//! *scheduler* over the shared [`ExecCore`] runtime. Every mapper and
//! reducer is an OS thread stepping the same core state-machine the sim
//! drives deterministically; queues are the bounded envelope
//! [`DataQueue`](crate::queue::DataQueue)s whose priority lane carries §7
//! state transfers; routing goes through lock-free epoch-cached ring
//! snapshots.
//!
//! The balancer never sits on the reducer hot path: reducers emit
//! [`LoadReport`]s into an mpsc channel and a dedicated balancer thread
//! applies them, fires repartitions, opens §7 synchronization epochs, and
//! — once the drain condition is globally stable — releases the reducers
//! (coordinated stop closes the race between a late rebalance and an
//! already-exited reducer that could strand un-forwarded state).
//!
//! Nondeterministic by nature — this is the mode that exhibits the paper's
//! "indeterminate" behaviours (premature LB triggers, run-to-run
//! variance). The deterministic counterpart is [`crate::sim`].

use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::actor::Envelope;
use crate::balancer::state_forward::ConsistencyMode;
use crate::balancer::BalancerCore;
use crate::exec::{MapExecutor, ReduceFactory};
use crate::mapper::MapperCore;
use crate::metrics::{MembershipChange, RunReport};
use crate::reducer::ReducerCore;
use crate::runtime::exec::{ExecCore, ExecParams, LoadReport, ReducerStep};
use crate::testkit::chaos::{ChaosConfig, ChaosController, FaultAction};

/// Threads-driver parameters.
#[derive(Clone, Debug)]
pub struct ThreadParams {
    /// Load report every N handled messages.
    pub report_interval: u64,
    pub chunk_size: usize,
    /// Per-reducer queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Busy-wait per mapped item (µs) — simulates map cost.
    pub map_delay_us: u64,
    /// Busy-wait per reduced record (µs) — simulates the compute-heavy
    /// reducers of the paper's target regime.
    pub reduce_delay_us: u64,
    /// Reducer queue-poll timeout.
    pub pop_timeout: Duration,
    /// Max envelopes a reducer drains per queue-lock acquisition (the
    /// batched hot path); 1 degenerates to the old single-pop loop.
    pub batch_max: usize,
    /// Post-repartition consistency: merge-at-end (§2) or state
    /// forwarding (§7).
    pub mode: ConsistencyMode,
    /// Compiled data plane for the mappers' batched route path (one XLA
    /// call hashes + routes a whole task; every router family). `None` =
    /// the epoch-cached router's batch path (still one staleness check
    /// per task, just scalar per-record lookups).
    pub route_runtime: Option<Arc<crate::runtime::programs::SharedRuntime>>,
    /// Elastic reducer-id ceiling (0 = fixed membership). The balancer
    /// thread spawns a new reducer thread when it applies an `Added`
    /// membership event.
    pub max_reducers: usize,
    /// Fault-injection plan + checkpoint cadence (testkit::chaos).
    /// `None` = no chaos hooks on the step loop at all.
    pub chaos: Option<ChaosConfig>,
}

impl Default for ThreadParams {
    fn default() -> Self {
        ThreadParams {
            report_interval: 2,
            chunk_size: 10,
            queue_capacity: 1 << 16,
            map_delay_us: 0,
            reduce_delay_us: 200,
            pop_timeout: Duration::from_millis(2),
            batch_max: 32,
            mode: ConsistencyMode::MergeAtEnd,
            route_runtime: None,
            max_reducers: 0,
            chaos: None,
        }
    }
}

#[inline]
fn spin_us(us: u64) {
    if us == 0 {
        return;
    }
    let deadline = Instant::now() + Duration::from_micros(us);
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}

/// One pipeline execution on OS threads.
pub struct ThreadDriver {
    pub params: ThreadParams,
}

impl ThreadDriver {
    pub fn new(params: ThreadParams) -> Self {
        ThreadDriver { params }
    }

    pub fn run(
        &self,
        map_exec: Arc<dyn MapExecutor>,
        reduce_factory: &ReduceFactory,
        n_mappers: usize,
        balancer: BalancerCore,
        items: impl Into<Arc<[String]>>,
    ) -> RunReport {
        let p = self.params.clone();
        let router = balancer.router().clone();
        let n_reducers = router.nodes();

        let mut core = ExecCore::build(
            &router,
            n_mappers,
            items,
            ExecParams {
                chunk_size: p.chunk_size,
                queue_capacity: p.queue_capacity,
                report_interval: p.report_interval,
                mode: p.mode,
                coordinated_stop: true,
                max_reducers: p.max_reducers,
            },
        );
        if let Some(cfg) = &p.chaos {
            // one WAL/slot per pre-allocated queue, so respawns and
            // elastic joiners log from their first step
            let cap = core.queues.len();
            core = core.with_chaos(Arc::new(ChaosController::new(cfg, cap)));
        }
        let core = Arc::new(core);
        let (report_tx, report_rx) = mpsc::channel::<LoadReport>();
        let t0 = Instant::now();

        // mappers: fetch → map → route → enqueue (staged per destination:
        // one queue lock per task per destination instead of one per
        // record)
        let mut mapper_handles = Vec::with_capacity(n_mappers);
        for i in 0..n_mappers {
            let core = core.clone();
            let exec = map_exec.clone();
            let router = router.clone();
            let map_delay = p.map_delay_us;
            let route_runtime = p.route_runtime.clone();
            mapper_handles.push(
                std::thread::Builder::new()
                    .name(format!("dpa-mapper-{i}"))
                    .spawn(move || {
                        let mut mc = MapperCore::new(i, exec, router);
                        if let Some(rt) = route_runtime {
                            mc = mc.with_route_runtime(rt);
                        }
                        let mut staged: Vec<Vec<crate::exec::Record>> =
                            (0..core.queues.len()).map(|_| Vec::new()).collect();
                        while let Some(task) = core.pool.fetch() {
                            // whole-task routing: one compiled XLA call (route
                            // runtime attached) or one RouterCache batch —
                            // either way a single epoch/staleness check per
                            // task; the map cost is charged for the whole
                            // task at once
                            let items = task.items.len() as u64;
                            for (dest, rec) in mc.process_task(&task) {
                                staged[dest].push(rec);
                            }
                            spin_us(map_delay.saturating_mul(items));
                            for (dest, recs) in staged.iter_mut().enumerate() {
                                if recs.is_empty() {
                                    continue;
                                }
                                // stamp the whole slice with one clock read;
                                // latency = this enqueue → final reduce
                                let now = (t0.elapsed().as_micros() as u64).max(1);
                                for r in recs.iter() {
                                    r.set_stamp(now);
                                }
                                core.push_mapped_batch(dest, std::mem::take(recs));
                            }
                        }
                        core.monitor.mapper_done();
                        mc
                    })
                    .expect("spawn mapper"),
            );
        }

        // reducers: step the shared state-machine; reports go through the
        // channel — the hot path takes no balancer lock. The spawner is
        // shared with the balancer thread, which uses it to bring up
        // brand-new reducers on elastic scale-up events; handles live in
        // a shared vec (appended in reducer-id order) joined at the end.
        let reducer_handles: Arc<Mutex<Vec<std::thread::JoinHandle<ReducerCore>>>> =
            Arc::new(Mutex::new(Vec::with_capacity(n_reducers)));
        let spawn_reducer = {
            let core = core.clone();
            let router = router.clone();
            let report_tx = report_tx.clone();
            let factory = reduce_factory.clone();
            let reduce_delay = p.reduce_delay_us;
            let pop_timeout = p.pop_timeout;
            let batch_max = p.batch_max.max(1);
            move |i: usize| -> std::thread::JoinHandle<ReducerCore> {
                let core = core.clone();
                let tx = report_tx.clone();
                let router = router.clone();
                let factory = factory.clone();
                std::thread::Builder::new()
                    .name(format!("dpa-reducer-{i}"))
                    .spawn(move || {
                        let mut rc = ReducerCore::new(i, factory(i), router);
                        // batched drain: refill `pending` with one queue
                        // lock per `batch_max` envelopes; the core still
                        // steps one envelope at a time, so its §7 logic is
                        // untouched
                        let mut pending: std::collections::VecDeque<Envelope> =
                            std::collections::VecDeque::with_capacity(batch_max);
                        let mut batching = true;
                        loop {
                            if let Some(ch) = core.chaos() {
                                match ch.poll_fault(i, t0.elapsed().as_micros() as u64) {
                                    Some(FaultAction::Kill) => {
                                        // fail-stop at the step boundary:
                                        // hand batched leftovers back (they
                                        // were never processed), then exit —
                                        // executor state dies with the actor;
                                        // the checkpoint + WAL lane is now
                                        // the only copy
                                        let mut data = Vec::with_capacity(pending.len());
                                        for env in pending.drain(..) {
                                            match env {
                                                Envelope::Data(_) => data.push(env),
                                                env => core.queues[i].push_priority(env),
                                            }
                                        }
                                        core.queues[i].requeue_front_batch(data);
                                        core.chaos_fail_stop(i);
                                        rc.exec = factory(i);
                                        break;
                                    }
                                    Some(FaultAction::Stall(ms)) => {
                                        std::thread::sleep(Duration::from_millis(ms));
                                    }
                                    None => {}
                                }
                            }
                            let step = core.reducer_step(
                                &mut rc,
                                i,
                                t0.elapsed().as_micros() as u64,
                                |q| {
                                    if let Some(env) = pending.pop_front() {
                                        return Some(env);
                                    }
                                    if batching {
                                        pending.extend(q.pop_batch(batch_max, pop_timeout));
                                        pending.pop_front()
                                    } else {
                                        q.pop_timeout(pop_timeout)
                                    }
                                },
                            );
                            match step {
                                ReducerStep::Reduced | ReducerStep::Forwarded => {
                                    batching = true; // data processing resumed
                                    if matches!(step, ReducerStep::Reduced) {
                                        // a Slow fault multiplies the
                                        // per-record compute cost
                                        let slow = core
                                            .chaos()
                                            .map_or(1, |c| c.slow_factor(i));
                                        spin_us(reduce_delay.saturating_mul(slow));
                                    }
                                    if rc.due_report(core.report_interval) {
                                        let _ = tx.send(LoadReport {
                                            reducer: i,
                                            // pending counts: it is load this
                                            // reducer still has to handle
                                            qlen: core.queues[i].len() + pending.len(),
                                            at: t0.elapsed().as_micros() as u64,
                                            evaluate: true,
                                        });
                                    }
                                }
                                ReducerStep::StateExtracted { .. }
                                | ReducerStep::StateAbsorbed => {}
                                ReducerStep::Deferred => {
                                    // substage 1: the core just requeued the
                                    // deferred record; hand any batched
                                    // leftovers back too (state → priority
                                    // lane, data → queue front) and fall
                                    // back to single pops until the window
                                    // closes
                                    if !pending.is_empty() {
                                        let mut data = Vec::with_capacity(pending.len());
                                        for env in pending.drain(..) {
                                            match env {
                                                Envelope::State(_)
                                                | Envelope::Checkpoint { .. } => {
                                                    core.queues[i].push_priority(env)
                                                }
                                                Envelope::Data(_) => data.push(env),
                                            }
                                        }
                                        core.queues[i].requeue_front_batch(data);
                                    }
                                    batching = false;
                                    // nothing to do but wait for the
                                    // slowest extractor
                                    std::thread::yield_now();
                                }
                                ReducerStep::Idle { stop } => {
                                    // pending is empty here: the pop closure
                                    // always serves it before reporting None
                                    let _ = tx.send(LoadReport {
                                        reducer: i,
                                        qlen: 0,
                                        at: t0.elapsed().as_micros() as u64,
                                        evaluate: false,
                                    });
                                    if stop {
                                        break;
                                    }
                                }
                            }
                        }
                        rc
                    })
                    .expect("spawn reducer")
            }
        };
        {
            let mut handles = reducer_handles.lock().unwrap();
            for i in 0..n_reducers {
                handles.push(spawn_reducer(i));
            }
        }
        drop(report_tx);

        // balancer thread: owns the BalancerCore outright — no mutex.
        // Applies reports, fires repartitions, spawns reducers on elastic
        // scale-up, and (once the pipeline is drained, synchronized and
        // every queue empty) issues the coordinated stop. Because the
        // same thread rebalances, scales and stops, no repartition or
        // membership change can start after a reducer was released.
        let bal_core = core.clone();
        let bal_handles = reducer_handles.clone();
        let bal_factory = reduce_factory.clone();
        let balancer_handle = std::thread::Builder::new()
            .name("dpa-balancer".into())
            .spawn(move || {
                let mut balancer = balancer;
                loop {
                    match report_rx.recv_timeout(Duration::from_micros(500)) {
                        Ok(r) => {
                            let event = bal_core.apply_report(&mut balancer, r);
                            if let Some(MembershipChange::Added { id }) =
                                event.and_then(|e| e.membership)
                            {
                                // the queue (pre-allocated) may already be
                                // receiving records at the new epoch; the
                                // thread starts draining it now
                                bal_handles.lock().unwrap().push(spawn_reducer(id as usize));
                            }
                        }
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                    // crash recovery: a queued kill retires-and-respawns
                    // once the §7 tracker is synchronized and no prior
                    // re-homed transfer is still in flight; while waiting,
                    // keep settling the corpses' queues so a mid-kill
                    // epoch cannot wedge on them
                    if let Some(ch) = bal_core.chaos() {
                        if ch.recovery_queued() {
                            for v in 0..bal_core.queues.len() {
                                if ch.was_killed(v) {
                                    bal_core.chaos_drain_dead(v);
                                }
                            }
                            if bal_core.synced() && bal_core.tracker.transfers_settled() {
                                if let Some(rec) = ch.take_recovery() {
                                    let now = t0.elapsed().as_micros() as u64;
                                    if let Some(id) =
                                        balancer.replace_faulted(rec.victim, now)
                                    {
                                        bal_core.tracker.activate(id);
                                        bal_handles.lock().unwrap().push(spawn_reducer(id));
                                    }
                                    if bal_core.mode == ConsistencyMode::StateForward {
                                        // survivors may now hold state the
                                        // respawn owns: re-home it the §7 way
                                        bal_core.tracker.begin_epoch(balancer.router().epoch());
                                    }
                                    bal_core.chaos_requeue_dead(rec.victim, balancer.router());
                                    bal_core.chaos_rehome(
                                        rec.victim,
                                        balancer.router(),
                                        &bal_factory,
                                    );
                                    ch.recovery_done(rec.at, now);
                                }
                            }
                        } else {
                            // post-recovery stragglers: a mapper holding a
                            // stale route cache may still land data on a
                            // corpse's queue — sweep it to the live owners
                            for v in 0..bal_core.queues.len() {
                                if ch.was_killed(v) {
                                    bal_core.chaos_requeue_dead(v, balancer.router());
                                }
                            }
                        }
                    }
                    if bal_core.monitor.drained()
                        && bal_core.synced()
                        && bal_core.chaos().map_or(true, |c| c.quiescent())
                        && bal_core.all_queues_empty()
                    {
                        bal_core.request_stop();
                        break;
                    }
                    // a reducer may only exit after request_stop — or by
                    // chaos fail-stop — so a finished handle that was NOT
                    // killed means it PANICKED. Holding the spawner (and
                    // its report sender) in this thread makes the
                    // channel-disconnect fallback unreachable, so this
                    // liveness check is what turns a dead reducer into a
                    // propagated panic at join() instead of a silent hang
                    // of the drain condition. Handles sit at their reducer
                    // id (spawn order = dense id order), so the index is
                    // the id the kill check needs.
                    let panicked = bal_handles.lock().unwrap().iter().enumerate().any(
                        |(id, h)| {
                            h.is_finished()
                                && !bal_core.chaos().is_some_and(|c| c.was_killed(id))
                        },
                    );
                    if panicked {
                        bal_core.request_stop(); // release the survivors
                        break;
                    }
                }
                balancer
            })
            .expect("spawn balancer");

        let mappers: Vec<MapperCore> = mapper_handles
            .into_iter()
            .map(|h| h.join().expect("mapper panicked"))
            .collect();
        // join the balancer FIRST: after it exits, no further reducer can
        // be spawned, so taking the handle vec is race-free
        let mut balancer = balancer_handle.join().expect("balancer panicked");
        let handles = std::mem::take(&mut *reducer_handles.lock().unwrap());
        // handles were appended in id order, so the collected cores are too
        let mut reducers: Vec<ReducerCore> = handles
            .into_iter()
            .map(|h| h.join().expect("reducer panicked"))
            .collect();
        debug_assert!(reducers.iter().enumerate().all(|(i, rc)| rc.id == i));
        let wall = t0.elapsed();

        core.finish(&mappers, &mut reducers, &mut balancer, reduce_factory, wall, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::{IdentityMap, WordCount};
    use crate::hash::{RouterHandle, Strategy};

    fn wordcount_factory() -> ReduceFactory {
        Arc::new(|_| Box::new(WordCount::new()) as Box<dyn crate::exec::ReduceExecutor>)
    }

    fn balancer(strategy: Strategy) -> BalancerCore {
        let router = RouterHandle::new(strategy.build_router(4, 8, None));
        BalancerCore::new(router, strategy, 0.2, 8, 1, 20_000)
    }

    fn oracle(items: &[String]) -> Vec<(String, i64)> {
        let mut m = std::collections::HashMap::new();
        for i in items {
            *m.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut v: Vec<(String, i64)> = m.into_iter().collect();
        v.sort();
        v
    }

    #[test]
    fn threaded_wordcount_is_exact() {
        let items: Vec<String> = (0..500).map(|i| format!("k{}", i % 13)).collect();
        let d = ThreadDriver::new(ThreadParams {
            reduce_delay_us: 0,
            ..Default::default()
        });
        let r = d.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(Strategy::None),
            items.clone(),
        );
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.result, oracle(&items));
        assert_eq!(r.total_processed(), 500);
        assert!(r.wall > Duration::ZERO);
    }

    #[test]
    fn threaded_lb_run_stays_correct() {
        let w = crate::workload::paperwl::wl1();
        let d = ThreadDriver::new(ThreadParams {
            reduce_delay_us: 500, // compute-heavy so queues build
            ..Default::default()
        });
        let r = d.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(Strategy::Doubling),
            w.items.clone(),
        );
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.result, oracle(&w.items));
    }

    #[test]
    fn threaded_state_forwarding_stays_exact_and_disjoint() {
        // §7 on real threads: merge_states() inside finish() asserts the
        // key-disjoint snapshot invariant whenever mode = StateForward
        let w = crate::workload::paperwl::wl1();
        let d = ThreadDriver::new(ThreadParams {
            reduce_delay_us: 400, // queues build → LB can fire mid-run
            mode: ConsistencyMode::StateForward,
            ..Default::default()
        });
        let r = d.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(Strategy::Doubling),
            w.items.clone(),
        );
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.result, oracle(&w.items));
    }

    #[test]
    fn empty_input_terminates_quickly() {
        let d = ThreadDriver::new(ThreadParams::default());
        let r = d.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            2,
            balancer(Strategy::None),
            Vec::<String>::new(),
        );
        assert_eq!(r.total_processed(), 0);
    }
}
