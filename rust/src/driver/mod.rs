//! Threads driver: the deployment-shaped execution mode. Every mapper and
//! reducer is an OS thread; queues are the bounded [`DataQueue`]s; the
//! balancer is shared behind a mutex (reports are rare relative to data
//! ops); routing goes through lock-free epoch-cached ring snapshots.
//!
//! Nondeterministic by nature — this is the mode that exhibits the paper's
//! "indeterminate" behaviours (premature LB triggers, run-to-run
//! variance). The deterministic counterpart is [`crate::sim`].

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::actor::ShutdownMonitor;
use crate::balancer::BalancerCore;
use crate::coordinator::{merge_states, TaskPool};
use crate::exec::{MapExecutor, ReduceFactory};
use crate::mapper::MapperCore;
use crate::metrics::RunReport;
use crate::queue::DataQueue;
use crate::reducer::{Handled, ReducerCore};

/// Threads-driver parameters.
#[derive(Clone, Debug)]
pub struct ThreadParams {
    /// Load report every N handled messages.
    pub report_interval: u64,
    pub chunk_size: usize,
    /// Per-reducer queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Busy-wait per mapped item (µs) — simulates map cost.
    pub map_delay_us: u64,
    /// Busy-wait per reduced record (µs) — simulates the compute-heavy
    /// reducers of the paper's target regime.
    pub reduce_delay_us: u64,
    /// Reducer queue-poll timeout.
    pub pop_timeout: Duration,
}

impl Default for ThreadParams {
    fn default() -> Self {
        ThreadParams {
            report_interval: 2,
            chunk_size: 10,
            queue_capacity: 1 << 16,
            map_delay_us: 0,
            reduce_delay_us: 200,
            pop_timeout: Duration::from_millis(2),
        }
    }
}

#[inline]
fn spin_us(us: u64) {
    if us == 0 {
        return;
    }
    let deadline = Instant::now() + Duration::from_micros(us);
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}

/// One pipeline execution on OS threads.
pub struct ThreadDriver {
    pub params: ThreadParams,
}

impl ThreadDriver {
    pub fn new(params: ThreadParams) -> Self {
        ThreadDriver { params }
    }

    pub fn run(
        &self,
        map_exec: Arc<dyn MapExecutor>,
        reduce_factory: &ReduceFactory,
        n_mappers: usize,
        balancer: BalancerCore,
        items: Vec<String>,
    ) -> RunReport {
        let p = self.params.clone();
        let ring = balancer.ring().clone();
        let n_reducers = ring.nodes();
        let input_items = items.len() as u64;

        let pool = Arc::new(TaskPool::from_items(items, p.chunk_size));
        let queues: Vec<Arc<DataQueue>> = (0..n_reducers)
            .map(|_| Arc::new(DataQueue::new(p.queue_capacity)))
            .collect();
        let monitor = Arc::new(ShutdownMonitor::new(n_mappers));
        let balancer = Arc::new(Mutex::new(balancer));
        let t0 = Instant::now();

        // mappers: fetch → map → route → enqueue
        let mut mapper_handles = Vec::with_capacity(n_mappers);
        for i in 0..n_mappers {
            let pool = pool.clone();
            let queues = queues.clone();
            let monitor = monitor.clone();
            let exec = map_exec.clone();
            let ring = ring.clone();
            let map_delay = p.map_delay_us;
            mapper_handles.push(
                std::thread::Builder::new()
                    .name(format!("dpa-mapper-{i}"))
                    .spawn(move || {
                        let mut core = MapperCore::new(i, exec, ring);
                        let n_queues = queues.len();
                        // per-destination staging, reused across tasks
                        // (§Perf iteration 3: one queue lock per task per
                        // destination instead of one per record)
                        let mut staged: Vec<Vec<crate::exec::Record>> =
                            (0..n_queues).map(|_| Vec::new()).collect();
                        while let Some(task) = pool.fetch() {
                            for item in &task.items {
                                for (dest, rec) in core.process_item(item) {
                                    staged[dest].push(rec);
                                }
                                spin_us(map_delay);
                            }
                            for (dest, recs) in staged.iter_mut().enumerate() {
                                if recs.is_empty() {
                                    continue;
                                }
                                // produced() strictly before push so
                                // in_flight never undercounts
                                monitor.produced(recs.len() as u64);
                                queues[dest].push_batch(std::mem::take(recs));
                            }
                        }
                        monitor.mapper_done();
                        core
                    })
                    .expect("spawn mapper"),
            );
        }

        // reducers: poll → ownership check → reduce / forward → report
        let mut reducer_handles = Vec::with_capacity(n_reducers);
        for i in 0..n_reducers {
            let queues = queues.clone();
            let monitor = monitor.clone();
            let balancer = balancer.clone();
            let ring = ring.clone();
            let exec = reduce_factory(i);
            let report_interval = p.report_interval;
            let reduce_delay = p.reduce_delay_us;
            let pop_timeout = p.pop_timeout;
            reducer_handles.push(
                std::thread::Builder::new()
                    .name(format!("dpa-reducer-{i}"))
                    .spawn(move || {
                        let mut core = ReducerCore::new(i, exec, ring);
                        loop {
                            match queues[i].pop_timeout(pop_timeout) {
                                Some(rec) => {
                                    match core.handle(rec) {
                                        Handled::Reduced => {
                                            spin_us(reduce_delay);
                                            monitor.consumed();
                                        }
                                        Handled::Forward(dest, rec) => {
                                            queues[dest].push(rec);
                                        }
                                    }
                                    if core.due_report(report_interval) {
                                        let now_us = t0.elapsed().as_micros() as u64;
                                        balancer.lock().unwrap().report(
                                            i,
                                            queues[i].len(),
                                            now_us,
                                        );
                                    }
                                }
                                None => {
                                    balancer.lock().unwrap().observe(i, 0);
                                    // §2.3: a reducer can never stop on its
                                    // own — only when the coordinator-level
                                    // drain condition holds
                                    if monitor.drained() && queues[i].is_empty() {
                                        break;
                                    }
                                }
                            }
                        }
                        core
                    })
                    .expect("spawn reducer"),
            );
        }

        let mappers: Vec<MapperCore> = mapper_handles
            .into_iter()
            .map(|h| h.join().expect("mapper panicked"))
            .collect();
        let mut reducers: Vec<ReducerCore> = reducer_handles
            .into_iter()
            .map(|h| h.join().expect("reducer panicked"))
            .collect();
        let wall = t0.elapsed();

        // final state merge (§2)
        let snaps: Vec<Vec<(String, i64)>> =
            reducers.iter_mut().map(|r| r.final_snapshot()).collect();
        let op = reduce_factory(0).merge_op();
        let result = merge_states(snaps, op, false);

        let mut balancer = Arc::try_unwrap(balancer)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|_| panic!("balancer still shared after join"));

        RunReport {
            processed: reducers.iter().map(|r| r.processed).collect(),
            forwarded: reducers.iter().map(|r| r.forwarded).collect(),
            mapped: mappers.iter().map(|m| m.emitted).collect(),
            lb_events: balancer.take_events(),
            result,
            wall,
            virtual_end: 0,
            peak_qlen: queues.iter().map(|q| q.peak()).collect(),
            input_items,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::{IdentityMap, WordCount};
    use crate::hash::{Ring, SharedRing, Strategy};

    fn wordcount_factory() -> ReduceFactory {
        Arc::new(|_| Box::new(WordCount::new()) as Box<dyn crate::exec::ReduceExecutor>)
    }

    fn balancer(strategy: Strategy) -> BalancerCore {
        let ring = SharedRing::new(Ring::for_strategy(4, strategy, 8));
        BalancerCore::new(ring, strategy, 0.2, 8, 1, 20_000)
    }

    fn oracle(items: &[String]) -> Vec<(String, i64)> {
        let mut m = std::collections::HashMap::new();
        for i in items {
            *m.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut v: Vec<(String, i64)> = m.into_iter().collect();
        v.sort();
        v
    }

    #[test]
    fn threaded_wordcount_is_exact() {
        let items: Vec<String> = (0..500).map(|i| format!("k{}", i % 13)).collect();
        let d = ThreadDriver::new(ThreadParams {
            reduce_delay_us: 0,
            ..Default::default()
        });
        let r = d.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(Strategy::None),
            items.clone(),
        );
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.result, oracle(&items));
        assert_eq!(r.total_processed(), 500);
        assert!(r.wall > Duration::ZERO);
    }

    #[test]
    fn threaded_lb_run_stays_correct() {
        let w = crate::workload::paperwl::wl1();
        let d = ThreadDriver::new(ThreadParams {
            reduce_delay_us: 500, // compute-heavy so queues build
            ..Default::default()
        });
        let r = d.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(Strategy::Doubling),
            w.items.clone(),
        );
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.result, oracle(&w.items));
    }

    #[test]
    fn empty_input_terminates_quickly() {
        let d = ThreadDriver::new(ThreadParams::default());
        let r = d.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            2,
            balancer(Strategy::None),
            vec![],
        );
        assert_eq!(r.total_processed(), 0);
    }
}
