//! Builtin executors: word count (the paper's running example), keyed sum,
//! distinct, top-k, and a configurable-cost wrapper that makes reducers
//! compute-heavy (the regime the paper's pipelined parallelism targets).

use std::collections::HashMap;

use super::{MapExecutor, MergeOp, Record, ReduceExecutor};

/// Identity mapper: each input item is a key with weight 1 (word count
/// over a pre-split stream of letters/words).
pub struct IdentityMap;

impl MapExecutor for IdentityMap {
    fn map(&self, item: &str) -> Vec<Record> {
        vec![Record::new(item, 1)]
    }
}

/// Tokenizing mapper: splits an input line into whitespace-separated,
/// lowercased words — the e2e corpus pipeline's map function.
pub struct TokenizeMap;

impl MapExecutor for TokenizeMap {
    fn map(&self, item: &str) -> Vec<Record> {
        item.split_whitespace()
            .map(|w| Record::new(w.to_ascii_lowercase(), 1))
            .collect()
    }
}

/// Parsing mapper for `key:value` items (keyed-sum pipelines).
pub struct KeyValueMap;

impl MapExecutor for KeyValueMap {
    fn map(&self, item: &str) -> Vec<Record> {
        match item.split_once(':') {
            Some((k, v)) => match v.trim().parse::<i64>() {
                Ok(value) => vec![Record::new(k.trim(), value)],
                Err(_) => {
                    log::warn!("dropping unparsable item '{item}'");
                    vec![]
                }
            },
            None => vec![Record::new(item, 1)],
        }
    }
}

/// The paper's reducer: tally per-key counts in a dictionary.
#[derive(Default)]
pub struct WordCount {
    counts: HashMap<String, i64>,
}

impl WordCount {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ReduceExecutor for WordCount {
    fn reduce(&mut self, rec: Record) {
        *self.counts.entry(rec.key).or_insert(0) += rec.value;
    }

    fn snapshot(&mut self) -> Vec<(String, i64)> {
        let mut v: Vec<(String, i64)> = self.counts.iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort();
        v
    }

    fn merge_op(&self) -> MergeOp {
        MergeOp::Sum
    }

    fn extract_key(&mut self, key: &str) -> Option<i64> {
        self.counts.remove(key)
    }
}

/// Keyed sum — same state shape as word count, different map side.
pub type KeyedSum = WordCount;

/// Distinct: state is "have I seen this key" (value pinned to 1).
#[derive(Default)]
pub struct Distinct {
    seen: HashMap<String, i64>,
}

impl Distinct {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ReduceExecutor for Distinct {
    fn reduce(&mut self, rec: Record) {
        self.seen.insert(rec.key, 1);
    }

    fn snapshot(&mut self) -> Vec<(String, i64)> {
        let mut v: Vec<(String, i64)> = self.seen.iter().map(|(k, _)| (k.clone(), 1)).collect();
        v.sort();
        v
    }

    fn merge_op(&self) -> MergeOp {
        MergeOp::Max
    }

    fn extract_key(&mut self, key: &str) -> Option<i64> {
        self.seen.remove(key)
    }
}

/// Top-K by count. State is a full count map (so snapshots stay mergeable
/// across reducers — a truncated state would not merge associatively,
/// exactly the paper's caveat about non-commutative merges); the K cut is
/// applied by [`TopK::top`] after the global merge.
pub struct TopK {
    pub k: usize,
    counts: HashMap<String, i64>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k, counts: HashMap::new() }
    }

    /// Post-merge selection: top-k entries by (count desc, key asc).
    pub fn top(merged: &[(String, i64)], k: usize) -> Vec<(String, i64)> {
        let mut v = merged.to_vec();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }
}

impl ReduceExecutor for TopK {
    fn reduce(&mut self, rec: Record) {
        *self.counts.entry(rec.key).or_insert(0) += rec.value;
    }

    fn snapshot(&mut self) -> Vec<(String, i64)> {
        let mut v: Vec<(String, i64)> = self.counts.iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort();
        v
    }

    fn merge_op(&self) -> MergeOp {
        MergeOp::Sum
    }

    fn extract_key(&mut self, key: &str) -> Option<i64> {
        self.counts.remove(key)
    }
}

/// Wraps any reducer with a busy-wait of `cost_us` per record, simulating
/// the compute-heavy reducers the paper's straggler analysis assumes.
/// Used by the threads driver; the sim driver models cost in virtual time.
pub struct CostlyReduce<E: ReduceExecutor> {
    inner: E,
    cost_us: u64,
}

impl<E: ReduceExecutor> CostlyReduce<E> {
    pub fn new(inner: E, cost_us: u64) -> Self {
        CostlyReduce { inner, cost_us }
    }
}

impl<E: ReduceExecutor> ReduceExecutor for CostlyReduce<E> {
    fn reduce(&mut self, rec: Record) {
        if self.cost_us > 0 {
            let deadline =
                std::time::Instant::now() + std::time::Duration::from_micros(self.cost_us);
            while std::time::Instant::now() < deadline {
                std::hint::spin_loop();
            }
        }
        self.inner.reduce(rec);
    }

    fn flush(&mut self) {
        self.inner.flush();
    }

    fn snapshot(&mut self) -> Vec<(String, i64)> {
        self.inner.snapshot()
    }

    fn merge_op(&self) -> MergeOp {
        self.inner.merge_op()
    }

    fn extract_key(&mut self, key: &str) -> Option<i64> {
        self.inner.extract_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::merge_snapshots;

    #[test]
    fn identity_map() {
        assert_eq!(IdentityMap.map("h"), vec![Record::new("h", 1)]);
    }

    #[test]
    fn tokenize_map_splits_and_lowercases() {
        let recs = TokenizeMap.map("The quick  the");
        assert_eq!(
            recs,
            vec![
                Record::new("the", 1),
                Record::new("quick", 1),
                Record::new("the", 1)
            ]
        );
    }

    #[test]
    fn keyvalue_map_parses() {
        assert_eq!(KeyValueMap.map("x: 7"), vec![Record::new("x", 7)]);
        assert_eq!(KeyValueMap.map("bare"), vec![Record::new("bare", 1)]);
        assert!(KeyValueMap.map("x:notanint").is_empty());
    }

    #[test]
    fn wordcount_counts() {
        let mut wc = WordCount::new();
        for k in ["a", "b", "a"] {
            wc.reduce(Record::new(k, 1));
        }
        assert_eq!(wc.snapshot(), vec![("a".into(), 2), ("b".into(), 1)]);
    }

    #[test]
    fn wordcount_merge_matches_paper_example() {
        // "foo" first processed by reducer A then by reducer B: merge adds
        let mut a = WordCount::new();
        let mut b = WordCount::new();
        a.reduce(Record::new("foo", 1));
        a.reduce(Record::new("foo", 1));
        b.reduce(Record::new("foo", 1));
        let merged = merge_snapshots(vec![a.snapshot(), b.snapshot()], MergeOp::Sum);
        assert_eq!(merged, vec![("foo".into(), 3)]);
    }

    #[test]
    fn distinct_is_idempotent_under_merge() {
        let mut a = Distinct::new();
        let mut b = Distinct::new();
        a.reduce(Record::new("x", 1));
        b.reduce(Record::new("x", 1));
        b.reduce(Record::new("y", 1));
        let merged = merge_snapshots(vec![a.snapshot(), b.snapshot()], MergeOp::Max);
        assert_eq!(merged, vec![("x".into(), 1), ("y".into(), 1)]);
    }

    #[test]
    fn topk_selection() {
        let merged = vec![
            ("a".into(), 5),
            ("b".into(), 9),
            ("c".into(), 5),
            ("d".into(), 1),
        ];
        assert_eq!(
            TopK::top(&merged, 2),
            vec![("b".into(), 9), ("a".into(), 5)]
        );
    }

    #[test]
    fn extract_key_removes_state() {
        let mut wc = WordCount::new();
        wc.reduce(Record::new("k", 1));
        wc.reduce(Record::new("k", 1));
        assert_eq!(wc.extract_key("k"), Some(2));
        assert_eq!(wc.extract_key("k"), None);
        assert!(wc.snapshot().is_empty());
    }

    #[test]
    fn costly_reduce_delegates() {
        let mut c = CostlyReduce::new(WordCount::new(), 0);
        c.reduce(Record::new("z", 1));
        assert_eq!(c.snapshot(), vec![("z".into(), 1)]);
        assert_eq!(c.merge_op(), MergeOp::Sum);
    }
}
