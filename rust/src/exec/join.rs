//! Streaming hash-join executor — the paper's motivating example for why
//! merge-at-end is not universal (§7):
//!
//! > "Depending on reducer B's execution semantics it might decide to
//! > throw away such inputs (e.g. hash join not matching on build table),
//! > leading to incorrect execution behavior."
//!
//! The reducer state is the *build side* (key → build value). Probe
//! records match against the local build state; a probe that finds no
//! build row is **dropped** (inner-join semantics) — so if a repartition
//! separates a key's build state from its probe records, merge-at-end
//! CANNOT repair the loss. The §7 state-forwarding mode can: the build
//! state moves to the key's new owner *before* any probe is processed
//! there. `rust/tests/lb_behavior.rs` demonstrates both behaviours.
//!
//! Input encoding (see [`JoinMap`]): `B:key:value` for build rows,
//! `P:key:value` for probe rows. Join results are accumulated as a count
//! of matched (probe, build) value-sums per key so they stay in the
//! `(String, i64)` snapshot shape.

use std::collections::HashMap;

use super::{MapExecutor, MergeOp, Record, ReduceExecutor};

/// Tags build vs probe rows through the `value` channel: build records
/// carry `BUILD_BIT | value`, probes carry the plain value. Values are
/// limited to 31 bits by this encoding (asserted).
const BUILD_BIT: i64 = 1 << 40;

/// Mapper for `B:key:v` / `P:key:v` items.
pub struct JoinMap;

impl MapExecutor for JoinMap {
    fn map(&self, item: &str) -> Vec<Record> {
        let mut parts = item.splitn(3, ':');
        let (tag, key, v) = (parts.next(), parts.next(), parts.next());
        match (tag, key, v.and_then(|v| v.trim().parse::<i64>().ok())) {
            (Some("B"), Some(k), Some(v)) => {
                assert!(v.abs() < BUILD_BIT, "join values limited to 40 bits");
                vec![Record::new(k, BUILD_BIT | v)]
            }
            (Some("P"), Some(k), Some(v)) => {
                assert!(v.abs() < BUILD_BIT, "join values limited to 40 bits");
                vec![Record::new(k, v)]
            }
            _ => {
                log::warn!("join: dropping malformed item '{item}'");
                vec![]
            }
        }
    }
}

/// Inner hash join: build rows install state; probe rows that match emit
/// `build_value + probe_value` into the per-key result accumulator, and
/// probe rows that do NOT match are dropped (the §7 hazard).
pub struct HashJoin {
    /// Build side: key -> build value (last write wins).
    build: HashMap<String, i64>,
    /// Join output: key -> sum of (build_value + probe_value) matches.
    matched: HashMap<String, i64>,
    /// Probes that found no local build state (the §7 correctness hazard
    /// under merge-at-end; zero under state forwarding).
    pub dropped_probes: u64,
}

impl Default for HashJoin {
    fn default() -> Self {
        Self::new()
    }
}

impl HashJoin {
    pub fn new() -> Self {
        HashJoin {
            build: HashMap::new(),
            matched: HashMap::new(),
            dropped_probes: 0,
        }
    }
}

impl ReduceExecutor for HashJoin {
    fn reduce(&mut self, rec: Record) {
        if rec.value & BUILD_BIT != 0 {
            self.build.insert(rec.key, rec.value & !BUILD_BIT);
        } else {
            match self.build.get(&rec.key) {
                Some(&b) => {
                    *self.matched.entry(rec.key).or_insert(0) += b + rec.value;
                }
                None => {
                    self.dropped_probes += 1;
                    log::debug!("join: probe for '{}' found no build state", rec.key);
                }
            }
        }
    }

    /// Snapshot: join results, plus the build state tagged so
    /// `extract_key`/state forwarding can move it.
    fn snapshot(&mut self) -> Vec<(String, i64)> {
        let mut out: Vec<(String, i64)> =
            self.matched.iter().map(|(k, v)| (k.clone(), *v)).collect();
        out.sort();
        out
    }

    fn merge_op(&self) -> MergeOp {
        MergeOp::Sum
    }

    /// State forwarding moves the *build* state (what probes need).
    fn extract_key(&mut self, key: &str) -> Option<i64> {
        self.build.remove(key).map(|v| BUILD_BIT | v)
    }

    /// Match sums are output, not state: they stay where they were
    /// produced and merge additively across reducers.
    fn snapshot_is_state(&self) -> bool {
        false
    }

    /// Absorb forwarded build state (or, defensively, a forwarded match
    /// accumulation).
    fn absorb_key(&mut self, key: &str, value: i64) {
        if value & BUILD_BIT != 0 {
            self.build.insert(key.to_string(), value & !BUILD_BIT);
        } else {
            *self.matched.entry(key.to_string()).or_insert(0) += value;
        }
    }
}

/// Serial oracle for a join input stream (what a single reducer computes).
pub fn join_oracle(items: &[String]) -> (Vec<(String, i64)>, u64) {
    let mut j = HashJoin::new();
    for item in items {
        for rec in JoinMap.map(item) {
            j.reduce(rec);
        }
    }
    (j.snapshot(), j.dropped_probes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_parses_build_and_probe() {
        let b = JoinMap.map("B:user1:10");
        assert_eq!(b[0].key, "user1");
        assert_eq!(b[0].value, BUILD_BIT | 10);
        let p = JoinMap.map("P:user1:5");
        assert_eq!(p[0].value, 5);
        assert!(JoinMap.map("garbage").is_empty());
        assert!(JoinMap.map("X:k:1").is_empty());
    }

    #[test]
    fn probe_after_build_matches() {
        let mut j = HashJoin::new();
        j.reduce(Record::new("k", BUILD_BIT | 10));
        j.reduce(Record::new("k", 5));
        j.reduce(Record::new("k", 7));
        assert_eq!(j.snapshot(), vec![("k".into(), 32)]); // (10+5)+(10+7)
        assert_eq!(j.dropped_probes, 0);
    }

    #[test]
    fn probe_without_build_is_dropped() {
        let mut j = HashJoin::new();
        j.reduce(Record::new("k", 5));
        assert!(j.snapshot().is_empty());
        assert_eq!(j.dropped_probes, 1);
    }

    #[test]
    fn extract_moves_build_state() {
        let mut j = HashJoin::new();
        j.reduce(Record::new("k", BUILD_BIT | 10));
        let state = j.extract_key("k").unwrap();
        assert_eq!(state, BUILD_BIT | 10);
        // the state is gone: probes now drop
        j.reduce(Record::new("k", 5));
        assert_eq!(j.dropped_probes, 1);
        // absorbing restores it
        let mut other = HashJoin::new();
        other.absorb_key("k", state);
        other.reduce(Record::new("k", 5));
        assert_eq!(other.snapshot(), vec![("k".into(), 15)]);
    }

    #[test]
    fn oracle_counts() {
        let items: Vec<String> = vec![
            "B:a:1".into(),
            "P:a:2".into(),
            "P:b:9".into(), // no build -> dropped
            "B:b:3".into(),
            "P:b:4".into(),
        ];
        let (result, dropped) = join_oracle(&items);
        assert_eq!(result, vec![("a".into(), 3), ("b".into(), 7)]);
        assert_eq!(dropped, 1);
    }
}
