//! Executor framework: the user-provided map and reduce functions the
//! paper's runtime applies to input elements (§2), plus builtin executors
//! (word count — the paper's running example — and friends) and the
//! XLA-backed word counter whose aggregation runs through the AOT-compiled
//! Pallas kernels ([`xla`]).

pub mod builtin;
pub mod join;
pub mod xla;

use std::fmt;

/// One routed message: a key and an integer payload. The paper's word
/// count maps a letter to `(letter, 1)`.
///
/// The key's MurmurHash3 is memoized on first use (§Perf iteration 4):
/// the mapper hashes for routing, and the reducer's ownership check —
/// plus any forwarding hops — reuse the cached value instead of
/// re-hashing. The cache is invisible to equality/debug.
#[derive(Debug)]
pub struct Record {
    pub key: String,
    pub value: i64,
    hash_cache: std::cell::Cell<Option<u32>>,
    /// Enqueue timestamp for per-record latency: µs since run start on
    /// the threads driver, virtual ticks on the sim. 0 = unstamped.
    /// Deliberately NOT refreshed on forwarding hops, so the recorded
    /// latency is end-to-end map-enqueue → final reduce. Invisible to
    /// equality/debug, like the hash cache.
    stamp: std::cell::Cell<u64>,
}

// SAFETY-free: Cell is Send (not Sync); Record moves between
// threads through queues but is never shared by reference across threads.
impl Record {
    pub fn new(key: impl Into<String>, value: i64) -> Self {
        Record {
            key: key.into(),
            value,
            hash_cache: std::cell::Cell::new(None),
            stamp: std::cell::Cell::new(0),
        }
    }

    /// The enqueue timestamp (0 if never stamped).
    #[inline]
    pub fn stamp(&self) -> u64 {
        self.stamp.get()
    }

    /// Stamp the record with its enqueue time (driver clock units).
    #[inline]
    pub fn set_stamp(&self, t: u64) {
        self.stamp.set(t);
    }

    /// MurmurHash3 of the key, computed once.
    #[inline]
    pub fn hash(&self) -> u32 {
        match self.hash_cache.get() {
            Some(h) => h,
            None => {
                let h = crate::hash::murmur3_x86_32(self.key.as_bytes());
                self.hash_cache.set(Some(h));
                h
            }
        }
    }

    /// Seed the memoized hash with an externally computed value (the
    /// compiled batch-route path hashes on device; downstream ownership
    /// checks then reuse it). Must equal `murmur3(key)` — the XLA parity
    /// suite pins the kernel to the native hash.
    #[inline]
    pub fn prime_hash(&self, h: u32) {
        debug_assert_eq!(h, crate::hash::murmur3_x86_32(self.key.as_bytes()));
        self.hash_cache.set(Some(h));
    }
}

impl Clone for Record {
    fn clone(&self) -> Self {
        Record {
            key: self.key.clone(),
            value: self.value,
            hash_cache: self.hash_cache.clone(),
            stamp: self.stamp.clone(),
        }
    }
}

impl PartialEq for Record {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.value == other.value
    }
}

impl Eq for Record {}

/// A zero-copy view of a contiguous chunk of the shared input.
///
/// The coordinator chunks one `Arc<[String]>` into tasks by range instead
/// of cloning strings, so seed sweeps and benches re-run the same input
/// without paying an O(n) copy per run. Derefs to `[String]`, so task
/// items read like a plain slice.
#[derive(Clone, Debug)]
pub struct TaskItems {
    src: std::sync::Arc<[String]>,
    start: usize,
    end: usize,
}

impl TaskItems {
    pub fn new(src: std::sync::Arc<[String]>, start: usize, end: usize) -> Self {
        assert!(start <= end && end <= src.len());
        TaskItems { src, start, end }
    }
}

impl std::ops::Deref for TaskItems {
    type Target = [String];

    fn deref(&self) -> &[String] {
        &self.src[self.start..self.end]
    }
}

impl From<Vec<String>> for TaskItems {
    fn from(v: Vec<String>) -> Self {
        let src: std::sync::Arc<[String]> = v.into();
        let end = src.len();
        TaskItems { src, start: 0, end }
    }
}

/// A unit of input handed to a mapper by the coordinator (§3: "mapper
/// actors fetch tasks or data items from the coordinator").
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    pub items: TaskItems,
}

/// How two values for the same key combine during the final state merge
/// (§2: "the state merge step would simply add those counts"; other
/// reductions admit other merge functions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeOp {
    Sum,
    Min,
    Max,
    /// Later snapshot wins; for idempotent states (e.g. distinct = 1).
    Last,
}

impl MergeOp {
    #[inline]
    pub fn apply(&self, a: i64, b: i64) -> i64 {
        match self {
            MergeOp::Sum => a + b,
            MergeOp::Min => a.min(b),
            MergeOp::Max => a.max(b),
            MergeOp::Last => b,
        }
    }

    /// May shards of ONE key, partially aggregated on several reducers,
    /// be folded with this op in any order and still equal the
    /// single-reducer result? True for the associative, commutative ops
    /// (`Sum`/`Min`/`Max`); false for `Last`, which depends on fold
    /// order. Routers with an associative merge contract (split-key) are
    /// rejected at pipeline build time when the executor's merge op is
    /// not splittable — under disjoint routing the question never arises,
    /// because each key is folded exactly once.
    #[inline]
    pub fn splittable(&self) -> bool {
        !matches!(self, MergeOp::Last)
    }
}

impl fmt::Display for MergeOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MergeOp::Sum => write!(f, "sum"),
            MergeOp::Min => write!(f, "min"),
            MergeOp::Max => write!(f, "max"),
            MergeOp::Last => write!(f, "last"),
        }
    }
}

/// The stateless map executor (§2.1: "mappers are stateless").
pub trait MapExecutor: Send + Sync {
    /// Transform one input item into zero or more routed records.
    fn map(&self, item: &str) -> Vec<Record>;
}

/// The stateful reduce executor (§2.1: "reducers can be stateful").
///
/// `snapshot` must flush any internal batching and return the complete
/// state as `(key, value)` pairs — this is what the coordinator's state
/// merge consumes. After `snapshot` the executor may keep running (the
/// balancer also snapshots live state in the state-forwarding extension).
pub trait ReduceExecutor: Send {
    /// Fold one record into local state.
    fn reduce(&mut self, rec: Record);

    /// Flush any batched-but-unapplied records into state.
    fn flush(&mut self) {}

    /// Flushed view of the full state.
    fn snapshot(&mut self) -> Vec<(String, i64)>;

    /// How the coordinator merges snapshots from different reducers.
    fn merge_op(&self) -> MergeOp;

    /// Extract and *remove* the state associated with `key`, if any —
    /// used by the §7 state-forwarding extension.
    fn extract_key(&mut self, key: &str) -> Option<i64>;

    /// Does `snapshot` consist purely of forwardable *state*? If so,
    /// state forwarding guarantees per-key single residency and the final
    /// merge asserts key-disjoint snapshots (word count: counts are the
    /// state). Executors whose snapshot includes commutative *output*
    /// accumulators that legitimately accrue on several reducers (e.g.
    /// [`join::HashJoin`]'s match sums) return `false`.
    fn snapshot_is_state(&self) -> bool {
        true
    }

    /// Absorb state for a key forwarded from another reducer.
    fn absorb_key(&mut self, key: &str, value: i64) {
        self.reduce(Record::new(key, value));
    }
}

/// Factory producing a fresh reducer-state executor per reducer actor.
/// `Arc` so pipelines can be re-run / seed-swept without re-wiring.
pub type ReduceFactory = std::sync::Arc<dyn Fn(usize) -> Box<dyn ReduceExecutor> + Send + Sync>;

/// Merge many snapshots into one sorted result using `op` (§2's final
/// state-merge step, pairwise-folded).
pub fn merge_snapshots(snaps: Vec<Vec<(String, i64)>>, op: MergeOp) -> Vec<(String, i64)> {
    let mut acc: std::collections::HashMap<String, i64> = std::collections::HashMap::new();
    for snap in snaps {
        for (k, v) in snap {
            acc.entry(k)
                .and_modify(|a| *a = op.apply(*a, v))
                .or_insert(v);
        }
    }
    let mut out: Vec<(String, i64)> = acc.into_iter().collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_ops() {
        assert_eq!(MergeOp::Sum.apply(2, 3), 5);
        assert_eq!(MergeOp::Min.apply(2, 3), 2);
        assert_eq!(MergeOp::Max.apply(2, 3), 3);
        assert_eq!(MergeOp::Last.apply(2, 3), 3);
        // order-sensitive ops cannot merge split-key shards
        assert!(MergeOp::Sum.splittable());
        assert!(MergeOp::Min.splittable());
        assert!(MergeOp::Max.splittable());
        assert!(!MergeOp::Last.splittable());
    }

    #[test]
    fn merge_snapshots_sums_shared_keys() {
        // the paper's example: "foo" counted on reducer A and reducer B
        let merged = merge_snapshots(
            vec![
                vec![("foo".into(), 3), ("bar".into(), 1)],
                vec![("foo".into(), 2)],
            ],
            MergeOp::Sum,
        );
        assert_eq!(merged, vec![("bar".into(), 1), ("foo".into(), 5)]);
    }

    #[test]
    fn merge_snapshots_empty() {
        assert!(merge_snapshots(vec![], MergeOp::Sum).is_empty());
        assert!(merge_snapshots(vec![vec![], vec![]], MergeOp::Sum).is_empty());
    }
}
