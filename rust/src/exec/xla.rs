//! XLA-backed executors: the reducer's aggregation state lives in a dense
//! `u32[V]` count vector updated by the AOT-compiled Pallas histogram
//! kernel, batched through the PJRT runtime. Python is never involved at
//! runtime — these run the artifacts produced once by `make artifacts`.
//!
//! Keys are interned into the vocab id space through a process-global
//! [`Interner`] shared by all reducers, so every reducer's dense state
//! uses the same id layout and the final state merge can run the compiled
//! `merge_state` program on raw vectors.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::{MergeOp, Record, ReduceExecutor};
use crate::runtime::programs::{CountsHandle, SharedRuntime};

/// Process-wide key → dense-id interner, capped at the vocab size the
/// artifacts were compiled for. Keys past the cap (or longer than the
/// packed-key limit) spill to a per-reducer sparse map.
pub struct Interner {
    inner: Mutex<InternerInner>,
    capacity: usize,
}

struct InternerInner {
    ids: HashMap<String, u32>,
    names: Vec<String>,
}

impl Interner {
    pub fn new(capacity: usize) -> Self {
        Interner {
            inner: Mutex::new(InternerInner { ids: HashMap::new(), names: Vec::new() }),
            capacity,
        }
    }

    /// Intern a key; `None` when the vocab is full.
    pub fn intern(&self, key: &str) -> Option<u32> {
        let mut g = self.inner.lock().unwrap();
        if let Some(&id) = g.ids.get(key) {
            return Some(id);
        }
        if g.names.len() >= self.capacity {
            return None;
        }
        let id = g.names.len() as u32;
        g.names.push(key.to_string());
        g.ids.insert(key.to_string(), id);
        Some(id)
    }

    /// Existing id for a key, if interned.
    pub fn get(&self, key: &str) -> Option<u32> {
        self.inner.lock().unwrap().ids.get(key).copied()
    }

    pub fn name(&self, id: u32) -> Option<String> {
        self.inner.lock().unwrap().names.get(id as usize).cloned()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Word-count reducer whose hot path is the compiled histogram kernel.
///
/// Records accumulate into an id batch; every `B` records (or on
/// flush/snapshot) one `reduce_count` execution folds them into the dense
/// state. Records that cannot take the dense path (vocab overflow,
/// non-unit values, oversized keys) spill to a sparse map — same
/// semantics, slower lane.
pub struct XlaWordCount {
    runtime: Arc<SharedRuntime>,
    interner: Arc<Interner>,
    /// Device-resident `u32[V]` state (§Perf: only the id batch crosses
    /// the host boundary per flush; the counts stay in PJRT memory).
    state: CountsHandle,
    batch: Vec<i32>,
    spill: HashMap<String, i64>,
    /// Records that took the dense (XLA) path vs the spill path.
    pub dense_records: u64,
    pub spill_records: u64,
}

impl XlaWordCount {
    pub fn new(runtime: Arc<SharedRuntime>, interner: Arc<Interner>) -> Self {
        let b = runtime.manifest().b;
        let state = runtime.counts_create().expect("allocating device state");
        XlaWordCount {
            runtime,
            interner,
            state,
            batch: Vec::with_capacity(b),
            spill: HashMap::new(),
            dense_records: 0,
            spill_records: 0,
        }
    }

    /// The dense state vector (flushed, read back from device) — input to
    /// the compiled `merge_state` program.
    pub fn dense_state(&mut self) -> Vec<u32> {
        self.flush_batch();
        self.runtime.counts_read(self.state).expect("reading device state")
    }

    /// Merge another reducer's dense state into this one via the compiled
    /// merge program (the §2 state-merge step on the XLA path).
    pub fn merge_dense_from(&mut self, other: &[u32]) -> crate::Result<()> {
        self.flush_batch();
        let mine = self.runtime.counts_read(self.state)?;
        let merged = self.runtime.merge_states(&mine, other)?;
        self.runtime.counts_write(self.state, &merged)?;
        Ok(())
    }

    fn flush_batch(&mut self) {
        if self.batch.is_empty() {
            return;
        }
        self.runtime
            .counts_update(self.state, &self.batch)
            .expect("reduce_count execution failed");
        self.batch.clear();
    }
}

impl Drop for XlaWordCount {
    fn drop(&mut self) {
        self.runtime.counts_free(self.state);
    }
}

impl ReduceExecutor for XlaWordCount {
    fn reduce(&mut self, rec: Record) {
        // dense lane: unit increments of interned, packable keys
        if rec.value == 1 && rec.key.len() <= self.runtime.manifest().max_key_bytes() {
            if let Some(id) = self.interner.intern(&rec.key) {
                self.batch.push(id as i32);
                self.dense_records += 1;
                if self.batch.len() >= self.runtime.manifest().b {
                    self.flush_batch();
                }
                return;
            }
        }
        self.spill_records += 1;
        *self.spill.entry(rec.key).or_insert(0) += rec.value;
    }

    fn flush(&mut self) {
        self.flush_batch();
    }

    fn snapshot(&mut self) -> Vec<(String, i64)> {
        self.flush_batch();
        let counts = self
            .runtime
            .counts_read(self.state)
            .expect("reading device state");
        let mut out: Vec<(String, i64)> = Vec::new();
        for (id, &c) in counts.iter().enumerate() {
            if c > 0 {
                let name = self
                    .interner
                    .name(id as u32)
                    .expect("count for uninterned id");
                out.push((name, c as i64));
            }
        }
        for (k, v) in &self.spill {
            // a key can have both dense and spill contributions
            match out.iter_mut().find(|(name, _)| name == k) {
                Some((_, c)) => *c += v,
                None => out.push((k.clone(), *v)),
            }
        }
        out.sort();
        out
    }

    fn merge_op(&self) -> MergeOp {
        MergeOp::Sum
    }

    fn extract_key(&mut self, key: &str) -> Option<i64> {
        self.flush_batch();
        let mut total = 0i64;
        if let Some(id) = self.interner.get(key) {
            // rare path (state forwarding): round-trip the state
            let mut counts = self
                .runtime
                .counts_read(self.state)
                .expect("reading device state");
            let c = counts[id as usize];
            if c > 0 {
                total += c as i64;
                counts[id as usize] = 0;
                self.runtime
                    .counts_write(self.state, &counts)
                    .expect("writing device state");
            }
        }
        if let Some(v) = self.spill.remove(key) {
            total += v;
        }
        (total != 0).then_some(total)
    }
}

/// Factory for [`XlaWordCount`] reducers sharing one runtime + interner.
pub fn xla_wordcount_factory(runtime: Arc<SharedRuntime>) -> super::ReduceFactory {
    let interner = Arc::new(Interner::new(runtime.manifest().v));
    Arc::new(move |_| {
        Box::new(XlaWordCount::new(runtime.clone(), interner.clone())) as Box<dyn ReduceExecutor>
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interner_assigns_stable_ids() {
        let i = Interner::new(3);
        assert_eq!(i.intern("a"), Some(0));
        assert_eq!(i.intern("b"), Some(1));
        assert_eq!(i.intern("a"), Some(0));
        assert_eq!(i.intern("c"), Some(2));
        assert_eq!(i.intern("d"), None, "capacity reached");
        assert_eq!(i.get("b"), Some(1));
        assert_eq!(i.get("d"), None);
        assert_eq!(i.name(2).as_deref(), Some("c"));
        assert_eq!(i.len(), 3);
    }

    #[test]
    fn interner_is_thread_safe() {
        let i = Arc::new(Interner::new(1000));
        let mut hs = Vec::new();
        for t in 0..4 {
            let i = i.clone();
            hs.push(std::thread::spawn(move || {
                for k in 0..250 {
                    i.intern(&format!("t{t}-k{k}"));
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(i.len(), 1000);
    }

    // XlaWordCount's end-to-end behaviour is covered by
    // rust/tests/xla_parity.rs (needs compiled artifacts).
}
