//! Keyspace partitioning: MurmurHash3 and the consistent-hash token ring.
//!
//! This is the mechanism layer of the paper's load balancer: keys are
//! placed on a 32-bit hash ring ([`ring::Ring`]) populated with per-node
//! tokens; lookups walk the ring clockwise (binary search over sorted
//! token hashes, `O(log T)`); the two repartitioning strategies from §4.2
//! (token *halving* and token *doubling*) live in [`strategy`].
//!
//! The identical MurmurHash3_x86_32 is implemented in the Pallas kernel
//! (`python/compile/kernels/murmur3.py`); `rust/tests/xla_parity.rs`
//! asserts bit-exact agreement so routing decisions match across layers.
//!
//! [`router`] lifts the routing/redistribution surface into the pluggable
//! [`Router`] trait: the token ring is one implementation
//! ([`TokenRingRouter`]) next to multi-probe hashing
//! ([`MultiProbeRouter`]), power-of-two-choices ([`TwoChoicesRouter`]),
//! d-way partial key grouping ([`SplitKeyRouter`], the one family
//! with an [`MergeContract::Associative`] merge contract) and the O(1)
//! flat partition table ([`PartitionTableRouter`], one indexed load per
//! route, zone-aware replica placement); [`strategy`]
//! holds the parsed specs that construct them. `docs/ROUTING.md` is the
//! family-by-family decision guide.

pub mod murmur3;
pub mod ptable;
pub mod ring;
pub mod router;
pub mod strategy;

pub use murmur3::murmur3_x86_32;
pub use ptable::{
    effective_zone, parse_zone_spec, PartitionTableRouter, DEFAULT_PTABLE_BITS,
    DEFAULT_PTABLE_REPLICAS, MAX_PTABLE_BITS, MAX_PTABLE_REPLICAS, ZONE_UNSET,
};
pub use ring::{Ring, SharedRing, Token};
pub use router::{
    probe_route, split_candidates_in, two_choices_candidates, two_choices_candidates_in,
    AssignTable, Loads, MergeContract, MultiProbeRouter, RingOp, RouteDelta, RouteSnapshot,
    Router, RouterBuilder, RouterCache, RouterHandle, SnapshotState, SplitKeyRouter,
    TokenRingRouter, TwoChoicesRouter, MAX_SPLIT_D, SPLIT_SENTINEL,
};
pub use strategy::{ParseStrategyError, Strategy, StrategySpec, DEFAULT_PROBES, DEFAULT_SPLIT_D};
