//! Keyspace partitioning: MurmurHash3 and the consistent-hash token ring.
//!
//! This is the mechanism layer of the paper's load balancer: keys are
//! placed on a 32-bit hash ring ([`ring::Ring`]) populated with per-node
//! tokens; lookups walk the ring clockwise (binary search over sorted
//! token hashes, `O(log T)`); the two repartitioning strategies from §4.2
//! (token *halving* and token *doubling*) live in [`strategy`].
//!
//! The identical MurmurHash3_x86_32 is implemented in the Pallas kernel
//! (`python/compile/kernels/murmur3.py`); `rust/tests/xla_parity.rs`
//! asserts bit-exact agreement so routing decisions match across layers.

pub mod murmur3;
pub mod ring;
pub mod strategy;

pub use murmur3::murmur3_x86_32;
pub use ring::{Ring, SharedRing, Token};
pub use strategy::Strategy;
