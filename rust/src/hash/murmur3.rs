//! MurmurHash3_x86_32 (Austin Appleby, public domain) — the hash the paper
//! uses for its consistent-hash ring [Appleby, 2014].
//!
//! This implementation is bit-exact with the reference `MurmurHash3_x86_32`
//! and with the Pallas kernel in `python/compile/kernels/murmur3.py`; both
//! are checked against the same published test vectors.

const C1: u32 = 0xcc9e2d51;
const C2: u32 = 0x1b873593;

/// Mix a single 4-byte block into the hash state.
#[inline(always)]
fn mix_k1(mut k1: u32) -> u32 {
    k1 = k1.wrapping_mul(C1);
    k1 = k1.rotate_left(15);
    k1.wrapping_mul(C2)
}

/// Final avalanche.
#[inline(always)]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85ebca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2ae35);
    h ^= h >> 16;
    h
}

/// MurmurHash3_x86_32 over `data` with `seed`.
pub fn murmur3_x86_32_seed(data: &[u8], seed: u32) -> u32 {
    let nblocks = data.len() / 4;
    let mut h1 = seed;

    // body: 4-byte little-endian blocks
    for i in 0..nblocks {
        let k1 = u32::from_le_bytes([
            data[4 * i],
            data[4 * i + 1],
            data[4 * i + 2],
            data[4 * i + 3],
        ]);
        h1 ^= mix_k1(k1);
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xe6546b64);
    }

    // tail
    let tail = &data[nblocks * 4..];
    let mut k1: u32 = 0;
    if !tail.is_empty() {
        if tail.len() >= 3 {
            k1 ^= (tail[2] as u32) << 16;
        }
        if tail.len() >= 2 {
            k1 ^= (tail[1] as u32) << 8;
        }
        k1 ^= tail[0] as u32;
        h1 ^= mix_k1(k1);
    }

    // finalization
    h1 ^= data.len() as u32;
    fmix32(h1)
}

/// MurmurHash3_x86_32 with the conventional zero seed (what the ring uses).
#[inline]
pub fn murmur3_x86_32(data: &[u8]) -> u32 {
    murmur3_x86_32_seed(data, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Published MurmurHash3_x86_32 test vectors (seed 0 unless noted).
    /// Cross-checked against the smhasher reference implementation and the
    /// python `mmh3` package.
    #[test]
    fn reference_vectors_seed0() {
        assert_eq!(murmur3_x86_32(b""), 0x0000_0000);
        assert_eq!(murmur3_x86_32(b"a"), 0x3c25_69b2);
        assert_eq!(murmur3_x86_32(b"abc"), 0xb3dd_93fa);
        assert_eq!(murmur3_x86_32(b"test"), 0xba6b_d213);
        assert_eq!(murmur3_x86_32(b"hello"), 0x248b_fa47);
        assert_eq!(murmur3_x86_32(b"Hello, world!"), 0xc036_3e43);
        assert_eq!(murmur3_x86_32(b"xxxxxxxx"), murmur3_x86_32(b"xxxxxxxx"));
        assert_eq!(
            murmur3_x86_32(b"The quick brown fox jumps over the lazy dog"),
            0x2e4f_f723
        );
    }

    #[test]
    fn reference_vectors_nonzero_seed() {
        // From the smhasher verification suite.
        assert_eq!(murmur3_x86_32_seed(b"", 1), 0x514e_28b7);
        assert_eq!(murmur3_x86_32_seed(b"", 0xffff_ffff), 0x81f1_6f39);
        assert_eq!(murmur3_x86_32_seed(b"aaaa", 0x9747b28c), 0x5a97_808a);
    }

    #[test]
    fn all_tail_lengths_exercise_switch() {
        // lengths 0..=8 cover every (nblocks, tail) combination twice
        let data = b"abcdefgh";
        let expected: [u32; 9] = [
            0x0000_0000, // ""
            0x3c25_69b2, // "a"
            0x9bbf_d75f, // "ab"
            0xb3dd_93fa, // "abc"
            0x43ed_676a, // "abcd"
            0xe89b_9af6, // "abcde"
            0x6181_c085, // "abcdef"
            0x883c_9b06, // "abcdefg"
            0x49ddccc4,  // "abcdefgh"
        ];
        for len in 0..=8 {
            assert_eq!(
                murmur3_x86_32(&data[..len]),
                expected[len],
                "len {len}"
            );
        }
    }

    #[test]
    fn token_format_hashes_are_spread() {
        // the ring hashes strings "token-{i}-{j}"; sanity check dispersion
        let mut hs: Vec<u32> = Vec::new();
        for i in 0..4 {
            for j in 0..8 {
                hs.push(murmur3_x86_32(format!("token-{i}-{j}").as_bytes()));
            }
        }
        hs.sort_unstable();
        hs.dedup();
        assert_eq!(hs.len(), 32, "no collisions among 32 tokens");
        // rough dispersion: max gap < 1/2 of the ring
        let mut max_gap = hs[0].wrapping_sub(*hs.last().unwrap());
        for w in hs.windows(2) {
            max_gap = max_gap.max(w[1] - w[0]);
        }
        assert!(max_gap < u32::MAX / 2);
    }
}
