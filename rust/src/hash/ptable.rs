//! O(1) partition-table routing with zone-aware replica placement —
//! the production alternative to walking a consistent-hash ring.
//!
//! A [`PartitionTableRouter`] holds a flat `2^B`-entry partition→node
//! table (Garage's ring simulator is the exemplar): routing is **one
//! indexed load**, `table[hash >> (32 - B)]`, with no ring walk, no
//! probe loop and no argmin. Rebalancing, elastic membership changes and
//! chaos surgeries all become *table rewrites* with provable movement
//! bounds:
//!
//! * the router maintains the **ownership invariant** that every live
//!   node owns at most `ceil(2^B / live)` partitions;
//! * [`Router::add_node`] moves exactly `floor(2^B / n)` partitions —
//!   all of them *to* the joiner, taken from the currently
//!   largest-owning survivors (preferring ones flagged overloaded at the
//!   last redistribute), so survivors never exchange partitions among
//!   themselves;
//! * [`Router::retire_node`] moves exactly the victim's partitions —
//!   `<= ceil(2^B / n)` by the invariant — promoting each partition's
//!   first live backup replica (cross-zone by placement) when one has
//!   quota headroom;
//! * [`Router::redistribute`] sheds up to half of the overloaded node's
//!   partitions — hottest first, per a per-partition hit sketch — onto
//!   the coldest non-overloaded receivers, swapping a cold partition
//!   back when the receiver is already at quota so the invariant
//!   survives load shedding too. Moves are gated by the signal's
//!   migration-gain guard, and the hysteresis overload flags are frozen
//!   per epoch exactly like [`MultiProbeRouter`](super::MultiProbeRouter).
//!
//! **Zones.** An optional `zone_of` map (node id → failure-domain index,
//! `balancer.zones` / `--zones`) makes the R-replica placement walk
//! *distinct zones first*, Garage's datacenter-aware walk: a partition's
//! backup replicas land in different failure domains than its primary
//! whenever the live topology allows, so checkpoint-to-peer recovery
//! (PR 9) survives a whole zone going dark. Nodes absent from the map
//! (e.g. chaos respawns beyond the configured topology) get a unique
//! singleton zone, which keeps every preference rule vacuously correct.
//!
//! Reads always route to the **primary** (`table[p]`); backups are
//! checkpoint/recovery targets, never read targets, so the compiled
//! lowering ([`SnapshotState::Table`]) ships only the primary table.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

use std::cmp::Reverse;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::Arc;

use super::murmur3::murmur3_x86_32_seed;
use super::router::{Loads, RouteDelta, RouteSnapshot, Router, SnapshotState};

/// Default partition bits `B` (`ptable` with no parameter): 1024
/// partitions — comfortably finer than any realistic reducer count, and
/// exactly the compiled artifacts' `PT` capacity.
pub const DEFAULT_PTABLE_BITS: u32 = 10;

/// Default replication factor `R`: primaries only, no backups.
pub const DEFAULT_PTABLE_REPLICAS: u32 = 1;

/// Largest supported `B` (a 2^16-entry table is 256 KiB of `u32`s;
/// anything coarser than 2^1 cannot split load at all).
pub const MAX_PTABLE_BITS: u32 = 16;

/// Largest supported replication factor `R`.
pub const MAX_PTABLE_REPLICAS: u32 = 4;

/// Sentinel for an unplaceable backup slot (fewer live nodes than `R`).
const BACKUP_NONE: u32 = u32::MAX;

/// Sentinel inside a parsed zone map for a node no zone group names.
pub const ZONE_UNSET: u32 = u32::MAX;

/// The failure domain of node `id` under a (possibly partial) zone map:
/// the configured zone index when the map names the node, otherwise a
/// unique singleton zone derived from the id. Unconfigured nodes — and
/// every node when no zones are configured at all — therefore never
/// share a domain, which makes zone-preference rules (cross-zone
/// checkpoint peers, distinct-zone replica walks) degrade *exactly* to
/// the pre-zones behavior instead of needing a special case.
#[inline]
pub fn effective_zone(zone_of: &[u32], id: usize) -> u32 {
    match zone_of.get(id) {
        Some(&z) if z != ZONE_UNSET => z,
        _ => ZONE_UNSET - 1 - (id as u32),
    }
}

/// Parse the CLI/TOML zone grammar: zone groups separated by `;`, node
/// ids inside a group separated by `,` — `"0,1;2,3"` puts nodes 0 and 1
/// in zone 0 and nodes 2 and 3 in zone 1 (the `toml_lite` subset has no
/// arrays, so `balancer.zones` is this string). Returns the
/// node-id-indexed zone map ([`ZONE_UNSET`] for ids no group names).
/// Rejects empty groups, unparsable ids and a node named by two zones.
pub fn parse_zone_spec(s: &str) -> Result<Vec<u32>, String> {
    let mut zone_of: Vec<u32> = Vec::new();
    for (zi, group) in s.split(';').enumerate() {
        let group = group.trim();
        if group.is_empty() {
            return Err(format!("zone spec '{s}': empty zone group"));
        }
        for tok in group.split(',') {
            let tok = tok.trim();
            let id: usize = tok
                .parse()
                .map_err(|_| format!("zone spec '{s}': bad node id '{tok}'"))?;
            if id >= 4096 {
                return Err(format!("zone spec '{s}': node id {id} unreasonably large"));
            }
            if zone_of.len() <= id {
                zone_of.resize(id + 1, ZONE_UNSET);
            }
            if zone_of[id] != ZONE_UNSET {
                return Err(format!("zone spec '{s}': node {id} appears in two zones"));
            }
            zone_of[id] = zi as u32;
        }
    }
    Ok(zone_of)
}

/// Garage-style fixed-table router: `2^B` partitions, each owned by one
/// primary node (and `R - 1` backup replicas placed across distinct
/// zones). See the module docs for the rewrite invariants.
#[derive(Clone)]
pub struct PartitionTableRouter {
    /// Partition bits: the table has `1 << bits` entries.
    bits: u32,
    /// Replication factor `R` (primary + `R - 1` backups).
    replicas: u32,
    /// Partition → primary node id (the routing function).
    table: Vec<u32>,
    /// Partition → backup node ids, flat with stride `replicas - 1`
    /// ([`BACKUP_NONE`] when the live set is too small). Empty for R=1.
    backups: Vec<u32>,
    /// Dense id space; retired ids stay allocated but unroutable.
    live: Vec<bool>,
    /// Node id → failure-domain index (may be shorter than the id
    /// space; [`effective_zone`] resolves the gaps).
    zones: Vec<u32>,
    /// Hysteresis overload flags frozen at the last redistribute — the
    /// membership rewrites' "prefer shedding from hot nodes" signal.
    overloaded: Vec<bool>,
    /// Per-partition record hits (Relaxed statistics, shared across
    /// clones like the split router's sketch): tells redistribute which
    /// of an overloaded node's partitions actually carry the heat.
    hits: Arc<Vec<AtomicU64>>,
    epoch: u64,
}

impl PartitionTableRouter {
    /// `nodes` live primaries over `1 << bits` partitions with `replicas`
    /// total placements per partition. The initial table deals partitions
    /// round-robin, so every node starts within the ownership quota.
    pub fn new(nodes: usize, bits: u32, replicas: u32) -> Self {
        assert!(nodes > 0, "partition-table router needs at least one node");
        assert!(
            (1..=MAX_PTABLE_BITS).contains(&bits),
            "partition bits must be in 1..={MAX_PTABLE_BITS}, got {bits}"
        );
        assert!(
            (1..=MAX_PTABLE_REPLICAS).contains(&replicas),
            "replication factor must be in 1..={MAX_PTABLE_REPLICAS}, got {replicas}"
        );
        let partitions = 1usize << bits;
        let mut r = PartitionTableRouter {
            bits,
            replicas,
            table: (0..partitions).map(|p| (p % nodes) as u32).collect(),
            backups: Vec::new(),
            live: vec![true; nodes],
            zones: Vec::new(),
            overloaded: vec![false; nodes],
            hits: Arc::new((0..partitions).map(|_| AtomicU64::new(0)).collect()),
            epoch: 1,
        };
        r.rebuild_backups();
        r
    }

    /// The partition a key hash falls in: the hash's top `B` bits.
    #[inline]
    pub fn partition_of(&self, hash: u32) -> usize {
        (hash >> (32 - self.bits)) as usize
    }

    /// Number of partitions (`1 << bits`).
    pub fn partitions(&self) -> usize {
        self.table.len()
    }

    /// Configured partition bits `B`.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Configured replication factor `R`.
    pub fn replication(&self) -> u32 {
        self.replicas
    }

    /// The ownership ceiling: `ceil(2^B / live_count)` — no live node
    /// ever owns more primaries than this, which is what bounds every
    /// membership rewrite's movement.
    pub fn quota(&self) -> usize {
        self.table.len().div_ceil(self.live_count().max(1))
    }

    /// Primary owner of partition `p`.
    pub fn owner_of(&self, p: usize) -> usize {
        self.table[p] as usize
    }

    /// Full placement of partition `p`: primary first, then the live
    /// backup replicas in walk order (fewer than `R` entries when the
    /// live set is too small to place them all).
    pub fn replicas_of(&self, p: usize) -> Vec<usize> {
        let mut out = vec![self.table[p] as usize];
        let stride = (self.replicas as usize).saturating_sub(1);
        for s in 0..stride {
            let b = self.backups[p * stride + s];
            if b != BACKUP_NONE {
                out.push(b as usize);
            }
        }
        out
    }

    /// Primaries owned per node id (retired ids own zero).
    pub fn partition_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.live.len()];
        for &n in &self.table {
            c[n as usize] += 1;
        }
        c
    }

    /// Ascending live node ids.
    fn live_ids(&self) -> Vec<u32> {
        (0..self.live.len() as u32)
            .filter(|&n| self.live[n as usize])
            .collect()
    }

    /// Recompute every partition's backup replicas from scratch. Backups
    /// are checkpoint targets, not read targets, so a wholesale rebuild
    /// after a membership change costs nothing on the hot path. The walk
    /// per partition: candidates (live nodes minus the primary) ordered
    /// by a per-`(partition, node)` hash — a deterministic pseudo-random
    /// ring walk — picked **distinct zones first** (Garage's
    /// datacenter-aware rule), then distinct nodes once zones are
    /// exhausted.
    fn rebuild_backups(&mut self) {
        let stride = (self.replicas as usize).saturating_sub(1);
        if stride == 0 {
            self.backups = Vec::new();
            return;
        }
        let live = self.live_ids();
        let mut backups = vec![BACKUP_NONE; self.table.len() * stride];
        for p in 0..self.table.len() {
            let primary = self.table[p];
            let mut cands: Vec<(u32, u32)> = live
                .iter()
                .filter(|&&n| n != primary)
                .map(|&n| {
                    (murmur3_x86_32_seed(&(p as u32).to_le_bytes(), 0x9E37_79B9 ^ n), n)
                })
                .collect();
            cands.sort_unstable();
            let mut used_zones = vec![effective_zone(&self.zones, primary as usize)];
            let mut picked: Vec<u32> = Vec::with_capacity(stride);
            for &(_, n) in &cands {
                if picked.len() == stride {
                    break;
                }
                let z = effective_zone(&self.zones, n as usize);
                if !used_zones.contains(&z) {
                    used_zones.push(z);
                    picked.push(n);
                }
            }
            for &(_, n) in &cands {
                if picked.len() == stride {
                    break;
                }
                if !picked.contains(&n) {
                    picked.push(n);
                }
            }
            for (i, n) in picked.into_iter().enumerate() {
                backups[p * stride + i] = n;
            }
        }
        self.backups = backups;
    }

    /// Halve every hit counter so stale heat decays across LB rounds
    /// (the split router's sketch discipline).
    fn decay_hits(&self) {
        for h in self.hits.iter() {
            let cur = h.load(Ordering::Relaxed);
            if cur != 0 {
                h.store(cur >> 1, Ordering::Relaxed);
            }
        }
    }
}

impl Router for PartitionTableRouter {
    fn name(&self) -> &'static str {
        "partition-table"
    }

    fn nodes(&self) -> usize {
        self.live.len()
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn route(&self, hash: u32, _loads: &Loads) -> usize {
        let p = self.partition_of(hash);
        // Relaxed statistics only — the hit sketch never affects the
        // routing decision, so routing stays a pure function of
        // (hash, epoch)
        self.hits[p].fetch_add(1, Ordering::Relaxed);
        self.table[p] as usize
    }

    fn redistribute(&mut self, target: usize, loads: &Loads) -> RouteDelta {
        if target >= self.live.len() || !self.live[target] {
            return RouteDelta::unchanged();
        }
        let mut flags = loads.flags_vec();
        flags.resize(self.live.len(), false);
        let quota = self.quota();
        let mut counts = self.partition_counts();
        // coldest-first receivers: live, not the target, not overloaded
        let mut receivers: Vec<usize> = (0..self.live.len())
            .filter(|&n| n != target && self.live[n] && !flags[n])
            .collect();
        receivers.sort_unstable_by_key(|&n| (loads.decayed(n), n));
        if receivers.is_empty() {
            self.decay_hits();
            return RouteDelta::unchanged();
        }
        // hottest partitions of the target first: the flags say which
        // NODE is hot, the sketch says which of its partitions are
        let mut owned: Vec<usize> = (0..self.table.len())
            .filter(|&p| self.table[p] as usize == target)
            .collect();
        owned.sort_unstable_by_key(|&p| (Reverse(self.hits[p].load(Ordering::Relaxed)), p));
        let shed = owned.len().div_ceil(2);
        let mut moved = 0u64;
        for (i, &p) in owned.iter().take(shed).enumerate() {
            // round-robin over the cold receivers so the shed load
            // spreads instead of dog-piling the single coldest node
            let r = receivers[i % receivers.len()];
            if !loads.migration_gain_ok(target, r) {
                continue;
            }
            if counts[r] < quota {
                self.table[p] = r as u32;
                counts[target] -= 1;
                counts[r] += 1;
                moved += 1;
            } else {
                // receiver already at quota: swap its coldest partition
                // back so the ownership invariant survives load shedding
                let back = (0..self.table.len())
                    .filter(|&q| self.table[q] as usize == r)
                    .min_by_key(|&q| (self.hits[q].load(Ordering::Relaxed), q));
                let Some(q) = back else { continue };
                self.table[p] = r as u32;
                self.table[q] = target as u32;
                moved += 2;
            }
        }
        self.decay_hits();
        if moved == 0 {
            return RouteDelta::unchanged();
        }
        self.overloaded = flags;
        self.rebuild_backups();
        self.epoch += 1;
        RouteDelta { changed: true, partitions_moved: moved, ..RouteDelta::default() }
    }

    fn add_node(&mut self, id: usize) -> RouteDelta {
        assert_eq!(id, self.live.len(), "node ids are dense and never reused");
        self.live.push(true);
        self.overloaded.push(false);
        // the joiner claims exactly floor(2^B / n) partitions — within
        // the ceil(2^B / n) movement bound — taken one at a time from
        // the currently largest-owning survivor (preferring survivors
        // flagged overloaded at the last redistribute), which provably
        // leaves every survivor at or under the new quota. No partition
        // moves between survivors.
        let need = self.table.len() / self.live_count();
        let mut counts = self.partition_counts();
        let mut moved = 0u64;
        for _ in 0..need {
            let donor = (0..self.live.len())
                .filter(|&d| d != id && self.live[d] && counts[d] > 0)
                .min_by_key(|&d| (Reverse(counts[d]), Reverse(self.overloaded[d]), d));
            let Some(d) = donor else { break };
            // hand the joiner the donor's hottest partition: the joiner
            // is the coldest node by construction
            let p = (0..self.table.len())
                .filter(|&p| self.table[p] as usize == d)
                .min_by_key(|&p| (Reverse(self.hits[p].load(Ordering::Relaxed)), p))
                .expect("donor owns at least one partition");
            self.table[p] = id as u32;
            counts[d] -= 1;
            counts[id] += 1;
            moved += 1;
        }
        self.rebuild_backups();
        self.epoch += 1;
        RouteDelta {
            changed: true,
            nodes_added: 1,
            partitions_moved: moved,
            ..RouteDelta::default()
        }
    }

    fn retire_node(&mut self, id: usize, loads: &Loads) -> RouteDelta {
        if id >= self.live.len() || !self.live[id] {
            return RouteDelta::unchanged(); // already retired
        }
        if self.live_count() <= 1 {
            return RouteDelta::unchanged(); // the last live node must stay
        }
        self.live[id] = false;
        self.overloaded[id] = false;
        // only the victim's partitions move — <= ceil(2^B / n) of them
        // by the ownership invariant. Each prefers promotion of its
        // first live backup replica (cross-zone by placement, so the
        // checkpoint that recovery replays is already there), falling
        // back to the least-loaded under-quota survivor.
        let quota = self.quota();
        let mut counts = self.partition_counts();
        let orphans: Vec<usize> = (0..self.table.len())
            .filter(|&p| self.table[p] as usize == id)
            .collect();
        let stride = (self.replicas as usize).saturating_sub(1);
        let mut moved = 0u64;
        for p in orphans {
            let mut dest: Option<usize> = None;
            for s in 0..stride {
                let b = self.backups[p * stride + s];
                if b == BACKUP_NONE {
                    continue;
                }
                let b = b as usize;
                if self.live[b] && counts[b] < quota {
                    dest = Some(b);
                    break;
                }
            }
            let dest = dest.or_else(|| {
                (0..self.live.len())
                    .filter(|&n| self.live[n] && counts[n] < quota)
                    .min_by_key(|&n| (self.overloaded[n], loads.decayed(n), n))
            });
            // an under-quota survivor always exists: live nodes all at
            // quota could absorb the whole table, contradiction while
            // orphans remain
            let Some(dst) = dest else { break };
            self.table[p] = dst as u32;
            counts[id] -= 1;
            counts[dst] += 1;
            moved += 1;
        }
        self.rebuild_backups();
        self.epoch += 1;
        RouteDelta {
            changed: true,
            nodes_retired: 1,
            partitions_moved: moved,
            ..RouteDelta::default()
        }
    }

    fn is_live(&self, id: usize) -> bool {
        id < self.live.len() && self.live[id]
    }

    fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    fn snapshot(&self, _loads: &Loads) -> RouteSnapshot {
        RouteSnapshot {
            router: self.name(),
            epoch: self.epoch,
            nodes: self.live.len(),
            state: SnapshotState::Table { table: self.table.clone(), bits: self.bits },
        }
    }

    fn set_zones(&mut self, zone_of: &[u32]) {
        self.zones = zone_of.to_vec();
        // primaries are untouched — zones shape only the backup walk —
        // but placement changed, so downstream caches must re-snapshot
        self.rebuild_backups();
        self.epoch += 1;
    }

    fn clone_router(&self) -> Box<dyn Router> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(n: usize) -> Loads {
        Loads::new(n)
    }

    #[test]
    fn routes_by_top_bits_and_stays_within_quota() {
        let r = PartitionTableRouter::new(4, 10, 1);
        let l = loads(4);
        for hash in [0u32, 0xFFFF_FFFF, 0x8000_0000, 0xDEAD_BEEF, 0x0042_4242] {
            let p = (hash >> 22) as usize;
            assert_eq!(r.route(hash, &l), r.owner_of(p), "hash {hash:#x}");
        }
        let quota = r.quota();
        assert_eq!(quota, 256);
        for (n, &c) in r.partition_counts().iter().enumerate() {
            assert!(c <= quota, "node {n} over quota: {c} > {quota}");
            assert!(c > 0, "node {n} owns nothing");
        }
    }

    #[test]
    fn add_node_moves_at_most_quota_and_only_to_the_joiner() {
        let mut r = PartitionTableRouter::new(3, 10, 1);
        let before = r.table.clone();
        let d = r.add_node(3);
        assert!(d.changed);
        assert_eq!(d.nodes_added, 1);
        let bound = 1024usize.div_ceil(4);
        assert!(
            (d.partitions_moved as usize) <= bound,
            "moved {} > ceil(2^B/n) = {bound}",
            d.partitions_moved
        );
        let mut moved = 0usize;
        for (p, (&a, &b)) in r.table.iter().zip(&before).enumerate() {
            if a != b {
                moved += 1;
                assert_eq!(a, 3, "partition {p} moved between survivors: {b} -> {a}");
            }
        }
        assert_eq!(moved as u64, d.partitions_moved);
        let quota = r.quota();
        for (n, &c) in r.partition_counts().iter().enumerate() {
            assert!(c <= quota, "node {n} over quota after join: {c} > {quota}");
        }
    }

    #[test]
    fn retire_node_moves_only_the_victims_partitions() {
        let mut r = PartitionTableRouter::new(4, 8, 1);
        let l = loads(4);
        let before = r.table.clone();
        let victim_owned = r.partition_counts()[1];
        let d = r.retire_node(1, &l);
        assert!(d.changed);
        assert_eq!(d.nodes_retired, 1);
        assert_eq!(d.partitions_moved as usize, victim_owned);
        let bound = 256usize.div_ceil(4); // n includes the leaving node
        assert!(victim_owned <= bound);
        for (p, (&a, &b)) in r.table.iter().zip(&before).enumerate() {
            if b == 1 {
                assert_ne!(a, 1, "partition {p} still on the retired node");
            } else {
                assert_eq!(a, b, "partition {p} moved between survivors");
            }
        }
        assert!(!r.is_live(1));
        assert_eq!(r.live_count(), 3);
        // double retire is a no-op
        assert!(!r.retire_node(1, &l).changed);
    }

    #[test]
    fn last_live_node_cannot_retire() {
        let mut r = PartitionTableRouter::new(1, 4, 1);
        assert!(!r.retire_node(0, &loads(1)).changed);
    }

    #[test]
    fn redistribute_sheds_hot_partitions_and_keeps_the_quota_invariant() {
        let mut r = PartitionTableRouter::new(4, 10, 1);
        let l = loads(4);
        // heat up node 0's partitions so the sketch has signal
        for p in 0..r.partitions() {
            if r.owner_of(p) == 0 {
                r.hits[p].store(50, Ordering::Relaxed);
            }
        }
        l.set(0, 100);
        let before = r.partition_counts()[0];
        let e0 = r.epoch();
        let d = r.redistribute(0, &l);
        assert!(d.changed);
        assert!(d.partitions_moved > 0);
        assert!(r.epoch() > e0);
        let counts = r.partition_counts();
        assert!(counts[0] < before, "hot node did not shed: {counts:?}");
        let quota = r.quota();
        for (n, &c) in counts.iter().enumerate() {
            assert!(c <= quota, "node {n} over quota after shed: {c} > {quota}");
        }
        // routing stays deterministic within the new epoch
        let a = r.route(0xABCD_EF01, &l);
        assert_eq!(r.route(0xABCD_EF01, &l), a);
    }

    #[test]
    fn redistribute_of_a_retired_target_is_a_no_op() {
        let mut r = PartitionTableRouter::new(3, 6, 1);
        let l = loads(3);
        r.retire_node(2, &l);
        assert!(!r.redistribute(2, &l).changed);
    }

    #[test]
    fn replica_walk_prefers_distinct_zones() {
        let mut r = PartitionTableRouter::new(4, 6, 2);
        r.set_zones(&parse_zone_spec("0,1;2,3").unwrap());
        for p in 0..r.partitions() {
            let placement = r.replicas_of(p);
            assert_eq!(placement.len(), 2, "partition {p} missing a backup");
            let zones: Vec<u32> = placement
                .iter()
                .map(|&n| effective_zone(&parse_zone_spec("0,1;2,3").unwrap(), n))
                .collect();
            assert_ne!(zones[0], zones[1], "partition {p} replicas co-located: {placement:?}");
        }
    }

    #[test]
    fn retire_promotes_a_backup_replica_when_it_has_headroom() {
        let mut r = PartitionTableRouter::new(4, 6, 2);
        r.set_zones(&parse_zone_spec("0,1;2,3").unwrap());
        let l = loads(4);
        // record each orphan's backup before the surgery
        let orphans: Vec<(usize, Vec<usize>)> = (0..r.partitions())
            .filter(|&p| r.owner_of(p) == 0)
            .map(|p| (p, r.replicas_of(p)))
            .collect();
        let d = r.retire_node(0, &l);
        assert!(d.changed);
        let quota = r.quota();
        let mut promoted = 0usize;
        for (p, placement) in orphans {
            let new_owner = r.owner_of(p);
            assert!(r.is_live(new_owner));
            if placement.len() > 1 && new_owner == placement[1] {
                promoted += 1;
            }
        }
        assert!(promoted > 0, "no orphan promoted its cross-zone backup");
        for (n, &c) in r.partition_counts().iter().enumerate() {
            assert!(c <= quota, "node {n} over quota after promotion: {c}");
        }
    }

    #[test]
    fn unconfigured_nodes_get_singleton_zones() {
        let zones = parse_zone_spec("0,1").unwrap();
        assert_eq!(effective_zone(&zones, 0), 0);
        assert_eq!(effective_zone(&zones, 1), 0);
        let a = effective_zone(&zones, 2);
        let b = effective_zone(&zones, 3);
        assert_ne!(a, b, "two unconfigured nodes share a zone");
        assert_ne!(a, 0);
        assert_eq!(effective_zone(&[], 5), effective_zone(&[], 5), "deterministic");
    }

    #[test]
    fn zone_spec_parser_rejects_garbage() {
        assert!(parse_zone_spec("0,1;;2").is_err(), "empty group");
        assert!(parse_zone_spec("0,x").is_err(), "bad id");
        assert!(parse_zone_spec("0,1;1,2").is_err(), "node in two zones");
        assert_eq!(parse_zone_spec("2").unwrap(), vec![ZONE_UNSET, ZONE_UNSET, 0]);
    }

    #[test]
    fn elastic_churn_preserves_invariants_across_a_long_schedule() {
        let mut r = PartitionTableRouter::new(2, 10, 2);
        let l = Loads::with_capacity(2, 8, &crate::balancer::signal::SignalConfig::legacy());
        let mut next_id = 2usize;
        for step in 0..6 {
            if step % 2 == 0 {
                let n_after = r.live_count() + 1;
                let d = r.add_node(next_id);
                let bound = r.partitions().div_ceil(n_after);
                assert!((d.partitions_moved as usize) <= bound, "step {step}");
                next_id += 1;
            } else {
                let victim = (0..r.nodes()).find(|&n| r.is_live(n)).unwrap();
                let n_before = r.live_count();
                let d = r.retire_node(victim, &l);
                let bound = r.partitions().div_ceil(n_before);
                assert!((d.partitions_moved as usize) <= bound, "step {step}");
            }
            let quota = r.quota();
            for (n, &c) in r.partition_counts().iter().enumerate() {
                assert!(c <= quota, "step {step}: node {n} at {c} > {quota}");
                if !r.is_live(n) {
                    assert_eq!(c, 0, "step {step}: retired node {n} owns partitions");
                }
            }
        }
    }
}
