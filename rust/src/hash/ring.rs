//! The consistent-hash token ring (§4.2 of the paper).
//!
//! Each node (reducer) `i` owns tokens `token-{i}-{j}`; a token's position
//! on the 32-bit ring is `murmur3("token-{i}-{j}")`. A key maps to the
//! node owning the first token at or clockwise after `murmur3(key)`
//! (wrapping to the smallest token). Lookup is `O(log T)` binary search
//! over tokens kept sorted by `(hash, node, idx)` — the tie order is part
//! of the cross-layer contract with the XLA `route` program, which receives
//! the same sorted arrays and must agree bit-for-bit.
//!
//! [`Ring::halve`] and [`Ring::double_others`] implement the two
//! redistribution strategies; [`Ring::add_node`] supports the paper's §7
//! elastic scale-out extension (a new reducer claims tokens at runtime).

#![forbid(unsafe_code)]

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, RwLock};

use super::murmur3::murmur3_x86_32;
use super::strategy::Strategy;

/// Maximum tokens a single node may hold. Doubling saturates here instead
/// of growing without bound (the paper never needs more than a handful of
/// redistributions; this cap also bounds the XLA route program's `T`).
pub const MAX_TOKENS_PER_NODE: u32 = 128;

/// Clockwise-successor index in a ring-ordered slice: the first element
/// whose hash (per `hash_of`) is `>= h`, wrapping to index 0 past the
/// end. The single implementation of the ring-walk shared by
/// [`Ring::lookup_hash`], the multi-probe router's position lookup and
/// the runtime's snapshot-fallback lookup — one tie/wrap semantics
/// everywhere, so the XLA parity contract cannot silently drift.
#[inline]
pub fn clockwise_successor_by<T>(items: &[T], h: u32, hash_of: impl Fn(&T) -> u32) -> usize {
    let i = items.partition_point(|t| hash_of(t) < h);
    if i == items.len() {
        0
    } else {
        i
    }
}

/// One token on the ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Token {
    /// Owning node (reducer) id.
    pub node: u32,
    /// Token index `j` within the node (names are never reused after
    /// halving; doubling extends the index range).
    pub idx: u32,
    /// `murmur3("token-{node}-{idx}")`.
    pub hash: u32,
}

impl Token {
    pub fn new(node: u32, idx: u32) -> Self {
        let name = format!("token-{node}-{idx}");
        Token {
            node,
            idx,
            hash: murmur3_x86_32(name.as_bytes()),
        }
    }
}

/// The consistent-hash ring.
#[derive(Clone, Debug)]
pub struct Ring {
    /// Tokens sorted by `(hash, node, idx)`.
    tokens: Vec<Token>,
    /// `tokens[i].hash`, kept parallel for cache-friendly binary search.
    hashes: Vec<u32>,
    /// Live token indices per node (token *names*, i.e. `idx` values).
    node_tokens: Vec<Vec<u32>>,
    /// Bumped on every mutation; lets readers cache snapshots cheaply.
    epoch: u64,
}

impl Ring {
    /// A ring with `nodes` nodes and `tokens_per_node` tokens each
    /// (indices `0..tokens_per_node`).
    pub fn new(nodes: usize, tokens_per_node: u32) -> Self {
        assert!(nodes > 0, "ring needs at least one node");
        assert!(tokens_per_node >= 1);
        assert!(tokens_per_node <= MAX_TOKENS_PER_NODE);
        let mut ring = Ring {
            tokens: Vec::new(),
            hashes: Vec::new(),
            node_tokens: vec![Vec::new(); nodes],
            epoch: 0,
        };
        for node in 0..nodes as u32 {
            for idx in 0..tokens_per_node {
                ring.node_tokens[node as usize].push(idx);
            }
        }
        ring.rebuild();
        ring
    }

    /// A ring initialized per the given strategy (§4.2 initial layouts).
    pub fn for_strategy(nodes: usize, strategy: Strategy, halving_init: u32) -> Self {
        Ring::new(nodes, strategy.initial_tokens(halving_init))
    }

    /// Rebuild the sorted token arrays from `node_tokens`.
    fn rebuild(&mut self) {
        self.tokens.clear();
        for (node, idxs) in self.node_tokens.iter().enumerate() {
            for &idx in idxs {
                self.tokens.push(Token::new(node as u32, idx));
            }
        }
        self.tokens
            .sort_by_key(|t| (t.hash, t.node, t.idx));
        self.hashes = self.tokens.iter().map(|t| t.hash).collect();
        self.epoch += 1;
    }

    /// Number of nodes (including any added at runtime).
    pub fn nodes(&self) -> usize {
        self.node_tokens.len()
    }

    /// Total live tokens `T`.
    pub fn total_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Live token count for `node`.
    pub fn tokens_of(&self, node: usize) -> u32 {
        self.node_tokens[node].len() as u32
    }

    /// Monotone mutation counter.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Sorted `(hash, owner)` view — the exact arrays fed to the XLA
    /// `route` program (padded there to its static `T`).
    pub fn sorted_tokens(&self) -> &[Token] {
        &self.tokens
    }

    /// Map a raw 32-bit hash to its owning node: first token with
    /// `token.hash >= h`, wrapping to the first token.
    #[inline]
    pub fn lookup_hash(&self, h: u32) -> usize {
        self.tokens[clockwise_successor_by(&self.hashes, h, |&th| th)].node as usize
    }

    /// Map a key (its bytes) to its owning node.
    #[inline]
    pub fn lookup(&self, key: &[u8]) -> usize {
        self.lookup_hash(murmur3_x86_32(key))
    }

    /// §4.2 strategy 1 — remove half of `node`'s tokens (the highest
    /// indices, deterministically). Returns `false` when the node has a
    /// single token left ("run out of halving") and nothing changes.
    pub fn halve(&mut self, node: usize) -> bool {
        let n = self.node_tokens[node].len();
        if n <= 1 {
            return false;
        }
        let keep = n / 2;
        // tokens are stored in insertion (idx) order; drop the later half
        self.node_tokens[node].sort_unstable();
        self.node_tokens[node].truncate(keep);
        self.rebuild();
        true
    }

    /// §4.2 strategy 2 — double the token count of every node *except*
    /// `node`. Saturates at [`MAX_TOKENS_PER_NODE`]; returns `true` if any
    /// node gained tokens.
    pub fn double_others(&mut self, node: usize) -> bool {
        let mut changed = false;
        for other in 0..self.node_tokens.len() {
            if other == node {
                continue;
            }
            let cur = self.node_tokens[other].len() as u32;
            let target = (cur * 2).min(MAX_TOKENS_PER_NODE);
            if target > cur {
                // new token names continue from the node's max index so
                // names never collide with live or halved-away tokens
                let next = self.node_tokens[other].iter().copied().max().unwrap_or(0) + 1;
                for k in 0..(target - cur) {
                    self.node_tokens[other].push(next + k);
                }
                changed = true;
            }
        }
        if changed {
            self.rebuild();
        }
        changed
    }

    /// Apply the given strategy's redistribution for an overloaded node.
    /// Returns `true` if the ring changed. Probe-based strategies do not
    /// manipulate tokens — their redistribution lives in their
    /// [`Router`](super::router::Router) implementations.
    pub fn redistribute(&mut self, node: usize, strategy: Strategy) -> bool {
        match strategy {
            Strategy::None => false,
            Strategy::Halving => self.halve(node),
            Strategy::Doubling => self.double_others(node),
            Strategy::MultiProbe { .. } | Strategy::TwoChoices => false,
        }
    }

    /// §7 extension — add a brand-new node claiming `tokens` tokens.
    /// Returns its node id.
    pub fn add_node(&mut self, tokens: u32) -> usize {
        assert!((1..=MAX_TOKENS_PER_NODE).contains(&tokens));
        let node = self.node_tokens.len();
        self.node_tokens.push((0..tokens).collect());
        self.rebuild();
        node
    }

    /// Elastic scale-down — remove **all** of `node`'s tokens, so its
    /// arcs fall to their clockwise successors (the consistent-hashing
    /// minimal-movement property: only keys the retired node owned move).
    /// The node id stays allocated (ids are never reused); the node is
    /// simply no longer routable. Returns the number of tokens removed —
    /// `0` when the node held none, or when it is the **last** node with
    /// tokens (an empty ring cannot route).
    pub fn retire_node(&mut self, node: usize) -> u32 {
        let Some(toks) = self.node_tokens.get(node) else {
            return 0; // unknown id: nothing to retire
        };
        let n = toks.len() as u32;
        if n == 0 || n as usize == self.tokens.len() {
            return 0;
        }
        self.node_tokens[node].clear();
        self.rebuild();
        n
    }

    /// Does `node` currently hold any tokens (i.e. is it routable)?
    pub fn is_live(&self, node: usize) -> bool {
        self.node_tokens.get(node).is_some_and(|t| !t.is_empty())
    }

    /// Number of nodes holding at least one token.
    pub fn live_nodes(&self) -> usize {
        self.node_tokens.iter().filter(|t| !t.is_empty()).count()
    }

    /// Fraction of the ring's hash space owned by `node` (sums to 1 across
    /// nodes). Useful for diagnostics and property tests.
    pub fn arc_fraction(&self, node: usize) -> f64 {
        if self.tokens.len() == 1 {
            return if self.tokens[0].node as usize == node { 1.0 } else { 0.0 };
        }
        let mut owned: u64 = 0;
        for (i, t) in self.tokens.iter().enumerate() {
            // the arc *ending* at token i is owned by token i's node
            let prev = if i == 0 {
                self.tokens[self.tokens.len() - 1].hash
            } else {
                self.tokens[i - 1].hash
            };
            let arc = t.hash.wrapping_sub(prev) as u64;
            if t.node as usize == node {
                owned += arc;
            }
        }
        owned as f64 / 2f64.powi(32)
    }

    /// Linear-scan lookup oracle — used by property tests to validate the
    /// binary-search path.
    pub fn lookup_hash_linear(&self, h: u32) -> usize {
        let mut best: Option<&Token> = None;
        for t in &self.tokens {
            if t.hash >= h {
                match best {
                    None => best = Some(t),
                    Some(b) if (t.hash, t.node, t.idx) < (b.hash, b.node, b.idx) => {
                        best = Some(t)
                    }
                    _ => {}
                }
            }
        }
        let t = best.unwrap_or_else(|| {
            self.tokens
                .iter()
                .min_by_key(|t| (t.hash, t.node, t.idx))
                .unwrap()
        });
        t.node as usize
    }
}

/// Shared, epoch-versioned ring handle. Mappers and reducers route through
/// this; the balancer is the only writer. The paper routes via remote calls
/// to the LB actor and argues the read-mostly access is acceptable — this
/// is the same design with the read path made explicit (RwLock + epoch).
#[derive(Clone)]
pub struct SharedRing {
    inner: Arc<RwLock<Ring>>,
    epoch: Arc<AtomicU64>,
}

impl SharedRing {
    pub fn new(ring: Ring) -> Self {
        let epoch = ring.epoch();
        SharedRing {
            inner: Arc::new(RwLock::new(ring)),
            epoch: Arc::new(AtomicU64::new(epoch)),
        }
    }

    /// Route a key to its owning node.
    pub fn lookup(&self, key: &[u8]) -> usize {
        self.inner.read().unwrap().lookup(key)
    }

    pub fn lookup_hash(&self, h: u32) -> usize {
        self.inner.read().unwrap().lookup_hash(h)
    }

    /// Current epoch without taking the lock — lets hot paths skip
    /// re-snapshotting when nothing changed.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Clone the current ring state (for snapshot-based routing and for
    /// feeding the XLA route program).
    pub fn snapshot(&self) -> Ring {
        self.inner.read().unwrap().clone()
    }

    pub fn nodes(&self) -> usize {
        self.inner.read().unwrap().nodes()
    }

    pub fn tokens_of(&self, node: usize) -> u32 {
        self.inner.read().unwrap().tokens_of(node)
    }

    pub fn total_tokens(&self) -> usize {
        self.inner.read().unwrap().total_tokens()
    }

    /// Mutate the ring under the write lock; publishes the new epoch.
    pub fn update<R>(&self, f: impl FnOnce(&mut Ring) -> R) -> R {
        let mut g = self.inner.write().unwrap();
        let r = f(&mut g);
        self.epoch.store(g.epoch(), Ordering::Release);
        r
    }
}

/// Epoch-validated local snapshot of a [`SharedRing`].
///
/// Routing hot paths (mappers route every record; reducers check every
/// dequeue) would otherwise take the `RwLock` read lock per lookup. The
/// cache re-snapshots only when the published epoch moves — between LB
/// events (rare by design) lookups are lock-free on a local `Ring`.
pub struct RingCache {
    shared: SharedRing,
    local: Ring,
    epoch: u64,
}

impl RingCache {
    pub fn new(shared: SharedRing) -> Self {
        let local = shared.snapshot();
        let epoch = local.epoch();
        RingCache { shared, local, epoch }
    }

    /// Refresh the local snapshot if the shared ring moved.
    #[inline]
    fn refresh(&mut self) {
        let e = self.shared.epoch();
        if e != self.epoch {
            self.local = self.shared.snapshot();
            self.epoch = self.local.epoch();
        }
    }

    #[inline]
    pub fn lookup(&mut self, key: &[u8]) -> usize {
        self.refresh();
        self.local.lookup(key)
    }

    #[inline]
    pub fn lookup_hash(&mut self, h: u32) -> usize {
        self.refresh();
        self.local.lookup_hash(h)
    }

    /// Current (refreshed) snapshot — for feeding the XLA route program.
    pub fn ring(&mut self) -> &Ring {
        self.refresh();
        &self.local
    }

    pub fn shared(&self) -> &SharedRing {
        &self.shared
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_cache_tracks_updates() {
        let sr = SharedRing::new(Ring::new(4, 8));
        let mut cache = RingCache::new(sr.clone());
        let key = b"hello";
        assert_eq!(cache.lookup(key), sr.lookup(key));
        let owner = sr.lookup(key);
        sr.update(|r| {
            r.halve(owner);
            r.halve(0);
            r.halve(1);
        });
        assert_eq!(cache.lookup(key), sr.lookup(key), "cache refreshed on epoch bump");
    }

    #[test]
    fn lookup_matches_linear_oracle() {
        let ring = Ring::new(4, 8);
        for i in 0..4096u32 {
            let h = i.wrapping_mul(0x9E37_79B9);
            assert_eq!(ring.lookup_hash(h), ring.lookup_hash_linear(h), "h={h:#x}");
        }
        // boundary hashes: exactly at, just below and just above each token
        for t in ring.sorted_tokens().to_vec() {
            for h in [t.hash.wrapping_sub(1), t.hash, t.hash.wrapping_add(1)] {
                assert_eq!(ring.lookup_hash(h), ring.lookup_hash_linear(h));
            }
        }
    }

    #[test]
    fn wraparound_maps_to_first_token() {
        let ring = Ring::new(3, 2);
        let max_hash = ring.sorted_tokens().last().unwrap().hash;
        if max_hash < u32::MAX {
            let first = ring.sorted_tokens().first().unwrap().node as usize;
            assert_eq!(ring.lookup_hash(max_hash + 1), first);
            assert_eq!(ring.lookup_hash(u32::MAX), first);
        }
    }

    /// Figure 2 of the paper: 3 nodes, T_i = 2, T = 6 — lookup walks
    /// clockwise to the next token.
    #[test]
    fn fig2_example() {
        let ring = Ring::new(3, 2);
        assert_eq!(ring.total_tokens(), 6);
        // for every consecutive token pair, a hash strictly between them
        // resolves to the owner of the clockwise (second) token
        let toks = ring.sorted_tokens().to_vec();
        for w in toks.windows(2) {
            if w[1].hash - w[0].hash >= 2 {
                let mid = w[0].hash + (w[1].hash - w[0].hash) / 2 + 1;
                assert_eq!(ring.lookup_hash(mid), w[1].node as usize);
            }
        }
    }

    #[test]
    fn halve_removes_half_and_only_that_node() {
        let mut ring = Ring::new(4, 8);
        let before: Vec<u32> = (0..4).map(|n| ring.tokens_of(n)).collect();
        assert!(ring.halve(2));
        assert_eq!(ring.tokens_of(2), 4);
        for n in [0usize, 1, 3] {
            assert_eq!(ring.tokens_of(n), before[n]);
        }
        assert!(ring.halve(2));
        assert!(ring.halve(2));
        assert_eq!(ring.tokens_of(2), 1);
        // run out of halving
        assert!(!ring.halve(2));
        assert_eq!(ring.tokens_of(2), 1);
    }

    #[test]
    fn halving_only_moves_keys_away_from_target() {
        // consistent hashing property: removing tokens of node x never
        // changes the owner of a key owned by another node
        let mut ring = Ring::new(4, 8);
        let keys: Vec<String> = (0..500).map(|i| format!("key-{i}")).collect();
        let before: Vec<usize> = keys.iter().map(|k| ring.lookup(k.as_bytes())).collect();
        ring.halve(1);
        for (k, &owner) in keys.iter().zip(&before) {
            if owner != 1 {
                assert_eq!(
                    ring.lookup(k.as_bytes()),
                    owner,
                    "key {k} moved although it wasn't on the halved node"
                );
            }
        }
    }

    #[test]
    fn double_others_leaves_target_alone() {
        let mut ring = Ring::new(4, 1);
        assert!(ring.double_others(0));
        assert_eq!(ring.tokens_of(0), 1);
        for n in 1..4 {
            assert_eq!(ring.tokens_of(n), 2);
        }
        assert!(ring.double_others(0));
        for n in 1..4 {
            assert_eq!(ring.tokens_of(n), 4);
        }
    }

    #[test]
    fn doubling_saturates_at_cap() {
        let mut ring = Ring::new(2, 1);
        for _ in 0..10 {
            ring.double_others(0);
        }
        assert_eq!(ring.tokens_of(1), MAX_TOKENS_PER_NODE);
        assert!(!ring.double_others(0), "saturated ring reports no change");
    }

    #[test]
    fn add_node_claims_keys() {
        let mut ring = Ring::new(4, 8);
        let keys: Vec<String> = (0..2000).map(|i| format!("key-{i}")).collect();
        let before: Vec<usize> = keys.iter().map(|k| ring.lookup(k.as_bytes())).collect();
        let new = ring.add_node(8);
        assert_eq!(new, 4);
        let mut claimed = 0;
        for (k, &owner) in keys.iter().zip(&before) {
            let now = ring.lookup(k.as_bytes());
            if now != owner {
                assert_eq!(now, new, "moved keys must move to the new node only");
                claimed += 1;
            }
        }
        assert!(claimed > 0, "the new node claimed some keys");
    }

    #[test]
    fn retire_node_moves_only_its_keys() {
        let mut ring = Ring::new(4, 8);
        let keys: Vec<String> = (0..2000).map(|i| format!("key-{i}")).collect();
        let before: Vec<usize> = keys.iter().map(|k| ring.lookup(k.as_bytes())).collect();
        assert_eq!(ring.retire_node(2), 8);
        assert!(!ring.is_live(2));
        assert_eq!(ring.live_nodes(), 3);
        assert_eq!(ring.nodes(), 4, "the id stays allocated");
        let mut moved = 0;
        for (k, &owner) in keys.iter().zip(&before) {
            let now = ring.lookup(k.as_bytes());
            assert_ne!(now, 2, "key {k} still routes to the retired node");
            if owner != 2 {
                assert_eq!(now, owner, "key {k} moved between surviving nodes");
            } else {
                moved += 1;
            }
        }
        assert!(moved > 0, "the retired node owned no keys?");
        // retiring again is a no-op
        assert_eq!(ring.retire_node(2), 0);
    }

    #[test]
    fn retire_last_live_node_refused() {
        let mut ring = Ring::new(2, 4);
        assert_eq!(ring.retire_node(0), 4);
        assert_eq!(ring.retire_node(1), 0, "an empty ring cannot route");
        assert!(ring.is_live(1));
        assert_eq!(ring.live_nodes(), 1);
    }

    #[test]
    fn arc_fractions_sum_to_one() {
        let ring = Ring::new(4, 8);
        let total: f64 = (0..4).map(|n| ring.arc_fraction(n)).sum();
        assert!((total - 1.0).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn epoch_bumps_on_mutation() {
        let mut ring = Ring::new(4, 8);
        let e0 = ring.epoch();
        ring.halve(0);
        assert!(ring.epoch() > e0);
    }

    #[test]
    fn shared_ring_update_publishes_epoch() {
        let sr = SharedRing::new(Ring::new(4, 8));
        let e0 = sr.epoch();
        sr.update(|r| {
            r.halve(0);
        });
        assert!(sr.epoch() > e0);
        assert_eq!(sr.tokens_of(0), 4);
    }

    #[test]
    fn lookup_distribution_roughly_uniform_with_many_tokens() {
        let ring = Ring::new(4, 64);
        let mut counts = [0usize; 4];
        for i in 0..40_000 {
            counts[ring.lookup(format!("k{i}").as_bytes())] += 1;
        }
        for c in counts {
            // 64 tokens/node: expect within ~3x of fair share
            assert!(c > 2_000 && c < 30_000, "count {c}");
        }
    }
}
