//! The pluggable routing/redistribution layer.
//!
//! The paper's §4.2 strategies (token halving / doubling) used to be a
//! closed enum whose semantics lived inside `Ring::halve` /
//! `Ring::double_others`; everything above — balancer, runtime, drivers,
//! pipeline — was welded to the token ring. [`Router`] turns that surface
//! into a first-class trait so redistribution families that are *not*
//! token surgery can plug in at the same seam:
//!
//! * [`TokenRingRouter`] — the paper's consistent-hash ring, bit-for-bit
//!   identical routing to the pre-trait code (same [`Ring`], same sorted
//!   `(hash, node, idx)` tie order the XLA route program relies on).
//!   `redistribute` applies halving or doubling per its [`RingOp`].
//! * [`MultiProbeRouter`] — multi-probe consistent hashing (Appleton &
//!   O'Reilly, arXiv:1505.00062; cf. farazdagi/mpchash): one position per
//!   node, `k` independent probes per key, owner chosen among the probes'
//!   owners. `redistribute` moves **zero tokens** — it re-freezes the
//!   per-node load weights the probe choice consults, so load shifts at
//!   probe (route) time only. Routing is a pure function of
//!   `(hash, epoch)`, which keeps the forwarding ownership check stable.
//! * [`TwoChoicesRouter`] — per-key power of two choices ("The Power of
//!   Both Choices", Nasir et al.): two candidate nodes per key, the
//!   less-loaded one wins at first sight. The choice is *sticky* (a
//!   shared assignment table) — the key-splitting guard that keeps a
//!   key's state on exactly one reducer so the §7 StateForward path (and
//!   the merge disjointness assertion) stay correct. `redistribute`
//!   re-homes about half of the overloaded node's keys to their alternate
//!   candidates.
//! * [`SplitKeyRouter`] — d-way partial key grouping ("When Two Choices
//!   Are not Enough", Katsipoulakis et al.): cold keys stay sticky
//!   exactly like two-choices, but a key whose estimated decayed load
//!   exceeds the split watermark is *promoted to split* — every later
//!   record of that key goes to the least-loaded of its `d` candidate
//!   nodes, so one mega-hot key finally spreads across reducers. The
//!   price is the merge contract: split shards of one key hold partial
//!   state on several reducers, so the router declares
//!   [`MergeContract::Associative`] and the §7 disjoint-merge assertion
//!   is relaxed to associative partial aggregation (see
//!   `docs/ARCHITECTURE.md`, "§7 merge contracts").
//!
//! Concurrency mirrors the old `SharedRing`/`RingCache` split:
//! [`RouterHandle`] is the shared, epoch-versioned writer handle the
//! balancer mutates; [`RouterCache`] gives mappers/reducers a lock-free
//! local clone refreshed only when the published epoch moves. Mutations
//! run on a writer copy behind a `Mutex` and are *published* arc-swap
//! style — readers swap in the finished snapshot and never wait out a
//! redistribution. The two-choices sticky table itself is a lock-free
//! concurrent map ([`AssignTable`]), so the steady-state route read path
//! (hits, probe and token routing) acquires **no** `RwLock` at all.

// All synchronization goes through the crate::sync shim so the loom model
// suite (`tests/loom_models.rs`) can exhaustively check the lock-free
// paths below; docs/ARCHITECTURE.md ("Memory-ordering contracts") lists
// each atomic's ordering and the invariant it upholds.
#![forbid(unsafe_code)]
// Every pub item in the routing layer is documented; the CI doc gate
// (`cargo doc` under -D warnings) turns an undocumented addition into a
// build failure rather than silent doc rot.
#![warn(missing_docs)]

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Mutex, RwLock};

use once_cell::sync::OnceCell;

use crate::balancer::signal::{LoadSignal, SignalConfig, FRAC_BITS};

use super::murmur3::{murmur3_x86_32, murmur3_x86_32_seed};
use super::ring::{Ring, Token};

/// The live per-node load view routers consult — since the signal
/// subsystem this *is* the [`LoadSignal`]: the balancer writes raw queue
/// lengths into it, routers read the EWMA-decayed values
/// ([`LoadSignal::decayed`]), the hysteresis overload flags
/// ([`LoadSignal::flags_vec`]) and the migration-gain guard
/// ([`LoadSignal::migration_gain_ok`]). A bare [`LoadSignal::new`]
/// carries the legacy (unsmoothed) configuration, so load values and
/// flags are bit-compatible with the raw-load era.
pub type Loads = LoadSignal;

/// What one `redistribute` / membership call changed — the routers'
/// common currency for events, metrics and the zero-churn property tests.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RouteDelta {
    /// Did the routing function change at all?
    pub changed: bool,
    /// Tokens created on the ring (doubling family, token-ring joins).
    pub tokens_added: u32,
    /// Tokens removed on the ring (halving family, token-ring retires).
    pub tokens_removed: u32,
    /// Keys explicitly re-homed (two-choices / split-key families).
    pub keys_reassigned: u64,
    /// Keys promoted from sticky to d-way split (split-key family).
    pub keys_split: u64,
    /// Nodes that joined the routable set (elastic scale-up).
    pub nodes_added: u32,
    /// Nodes that left the routable set (elastic scale-down).
    pub nodes_retired: u32,
    /// Partition-table entries rewritten (partition-table family) — the
    /// quantity the `ceil(2^B / n)` minimal-movement bound is stated
    /// over (see `rust/src/hash/ptable.rs`).
    pub partitions_moved: u64,
}

impl RouteDelta {
    /// The all-zero delta of a redistribute that changed nothing.
    pub fn unchanged() -> Self {
        RouteDelta::default()
    }

    /// No tokens were created or destroyed (the multi-probe guarantee).
    pub fn zero_token_churn(&self) -> bool {
        self.tokens_added == 0 && self.tokens_removed == 0
    }
}

/// A router's externally visible state: what the compiled XLA route
/// programs and the §7 state-forwarding key-ownership diff consume.
/// The payload is tagged per router *family* — each variant lowers to a
/// different compiled program (see
/// [`crate::runtime::programs::snapshot_tensors`]).
#[derive(Clone, Debug)]
pub struct RouteSnapshot {
    /// The producing router family's [`Router::name`].
    pub router: &'static str,
    /// The epoch this snapshot was frozen at.
    pub epoch: u64,
    /// Total id space (live ∪ retired) of the producing router.
    pub nodes: usize,
    /// The family-tagged routing state.
    pub state: SnapshotState,
}

/// Family-specific routing state inside a [`RouteSnapshot`].
#[derive(Clone, Debug)]
pub enum SnapshotState {
    /// Token-ring family: the sorted token table (the exact arrays the
    /// compiled XLA `route` program takes).
    TokenRing { tokens: Vec<Token> },
    /// Multi-probe family (`route_probe` program): node ring positions
    /// sorted by `(hash, node)` — only **live** nodes have a position, so
    /// elastic membership shrinks/grows this table — the probe count, and
    /// the per-node state frozen at the last redistribute: the hysteresis
    /// shed flags routing consults plus the EWMA-decayed load weights
    /// ([`FRAC_BITS`](crate::balancer::signal::FRAC_BITS) fixed point)
    /// they were frozen alongside (diagnostics).
    Probe {
        position_hashes: Vec<u32>,
        position_nodes: Vec<u32>,
        probes: u32,
        overloaded: Vec<bool>,
        weights: Vec<u64>,
    },
    /// Two-choices family (`route_assign` program): the sticky
    /// `(key_hash, owner)` table sorted by key hash — the basis of an
    /// ownership diff across a repartition — the ascending **live node
    /// id** list candidate hashing indexes (under elastic membership the
    /// id space has gaps; `candidate = live[h % live.len()]`), plus the
    /// per-node EWMA-decayed loads (fixed point) frozen at snapshot time,
    /// which resolve keys *not yet* in the table by the same first-sight
    /// rule the scalar router applies.
    Assignment {
        assignments: Vec<(u32, u32)>,
        live: Vec<u32>,
        loads: Vec<u64>,
    },
    /// Split-key family: the sticky `(key_hash, owner)` table sorted by
    /// key hash, where an owner equal to [`SPLIT_SENTINEL`] marks a key
    /// *promoted to split* — its records go to the least-loaded of its
    /// `d` candidates instead of a single sticky owner. Carries the
    /// ascending live node id list, the per-node EWMA-decayed loads
    /// (fixed point) frozen at snapshot time, and the split fan-out `d`.
    /// This family has **no compiled lowering**: split routing is
    /// load-adaptive per record, so
    /// [`snapshot_tensors`](crate::runtime::programs::snapshot_tensors)
    /// refuses it with a typed error and the mapper permanently falls
    /// back to the scalar lane (documented in `docs/ROUTING.md`).
    Split {
        assignments: Vec<(u32, u32)>,
        live: Vec<u32>,
        loads: Vec<u64>,
        d: u32,
    },
    /// Partition-table family (`route_table` program): the flat
    /// `2^bits`-entry partition → primary node table. Routing is one
    /// indexed load — `table[hash >> (32 - bits)]` — which lowers to a
    /// trivial XLA gather, the cheapest compiled route program of any
    /// family. Backup replicas are checkpoint targets, never read
    /// targets, so they are deliberately absent here.
    Table {
        /// Partition → primary node id, `1 << bits` entries.
        table: Vec<u32>,
        /// Partition bits `B` (the hash's top bits index the table).
        bits: u32,
    },
}

impl RouteSnapshot {
    /// Token table, if this is a token-ring snapshot.
    pub fn tokens(&self) -> Option<&[Token]> {
        match &self.state {
            SnapshotState::TokenRing { tokens } => Some(tokens),
            _ => None,
        }
    }

    /// Sticky assignment table, if this is a two-choices snapshot.
    pub fn assignments(&self) -> Option<&[(u32, u32)]> {
        match &self.state {
            SnapshotState::Assignment { assignments, .. } => Some(assignments),
            _ => None,
        }
    }

    /// Frozen load weights, if this is a multi-probe snapshot.
    pub fn weights(&self) -> Option<&[u64]> {
        match &self.state {
            SnapshotState::Probe { weights, .. } => Some(weights),
            _ => None,
        }
    }

    /// The flat partition → node table and its bit width, if this is a
    /// partition-table snapshot.
    pub fn partition_table(&self) -> Option<(&[u32], u32)> {
        match &self.state {
            SnapshotState::Table { table, bits } => Some((table, *bits)),
            _ => None,
        }
    }

    /// Route a key hash host-side, exactly as the router that produced
    /// this snapshot would at its epoch (for two-choices, as it would
    /// *record* a first sight under the frozen loads). This is the
    /// native fallback lane of the compiled route programs — one
    /// implementation per family, shared with the scalar routers, so the
    /// compiled/native/scalar paths cannot drift.
    pub fn route(&self, hash: u32) -> usize {
        match &self.state {
            SnapshotState::TokenRing { tokens } => {
                tokens[super::ring::clockwise_successor_by(tokens, hash, |t| t.hash)].node
                    as usize
            }
            SnapshotState::Probe {
                position_hashes,
                position_nodes,
                probes,
                overloaded,
                ..
            } => probe_route(position_hashes, position_nodes, overloaded, *probes, hash),
            SnapshotState::Assignment { assignments, live, loads } => {
                match assignments.binary_search_by_key(&hash, |&(k, _)| k) {
                    Ok(i) => assignments[i].1 as usize,
                    Err(_) => {
                        let (c1, c2) = two_choices_candidates_in(hash, live);
                        let l = |n: usize| loads.get(n).copied().unwrap_or(0);
                        if l(c2) < l(c1) {
                            c2
                        } else {
                            c1
                        }
                    }
                }
            }
            SnapshotState::Split { assignments, live, loads, d } => {
                match assignments.binary_search_by_key(&hash, |&(k, _)| k) {
                    Ok(i) if assignments[i].1 != SPLIT_SENTINEL => {
                        assignments[i].1 as usize
                    }
                    // split key or first sight: deterministic least
                    // frozen load among the d candidates (strict `<`, so
                    // the earliest candidate wins ties — the same rule
                    // the scalar router applies at first sight; for a
                    // *split* key the live router additionally rotates
                    // among tied candidates, which a frozen snapshot
                    // cannot reproduce and does not need to — any
                    // candidate is a legitimate shard home)
                    _ => {
                        let cands = split_candidates_in(hash, live, *d as usize);
                        let l = |n: usize| loads.get(n).copied().unwrap_or(0);
                        let mut best = cands[0];
                        for &c in &cands[1..] {
                            if l(c) < l(best) {
                                best = c;
                            }
                        }
                        best
                    }
                }
            }
            SnapshotState::Table { table, bits } => {
                table[(hash >> (32 - bits)) as usize] as usize
            }
        }
    }
}

/// The redistribution layer's trait. Implementations must route
/// deterministically for a fixed `(hash, epoch)` — reducers re-check
/// ownership on every dequeue and forward on mismatch, so an owner that
/// drifted *between* redistributions would make records ping-pong. The
/// one sanctioned exception is a key [`SplitKeyRouter`] has promoted to
/// split: its records deliberately spread over the key's `d` candidates,
/// and the ownership check goes through [`Router::is_owner`] (true for
/// *every* candidate) so the shards never ping-pong either.
pub trait Router: Send + Sync {
    /// Stable family name (`"token-ring"`, `"multi-probe"`,
    /// `"two-choices"`, `"split-key"`) — the snapshot/metrics tag.
    fn name(&self) -> &'static str;

    /// Number of routable nodes.
    fn nodes(&self) -> usize;

    /// Monotone mutation counter (1-based; bumped by `redistribute`).
    fn epoch(&self) -> u64;

    /// Map a raw 32-bit key hash to its owning node.
    fn route(&self, hash: u32, loads: &Loads) -> usize;

    /// May records of `hash` legitimately be reduced on `id` at the
    /// current epoch? For single-homed routers this is exactly
    /// `route(hash) == id`; [`SplitKeyRouter`] overrides it so *every*
    /// live candidate of a split key answers `true`. Reducers gate their
    /// forward-on-mismatch check on this — routing a split key twice
    /// would return two different candidates and make its records
    /// ping-pong forever.
    fn is_owner(&self, hash: u32, id: usize, loads: &Loads) -> bool {
        self.route(hash, loads) == id
    }

    /// What the end-of-run merge may assume about how this router
    /// distributed key state (see `docs/ARCHITECTURE.md`, "§7 merge
    /// contracts"). Single-homed families keep the paper's
    /// [`MergeContract::Disjoint`] default; [`SplitKeyRouter`] declares
    /// [`MergeContract::Associative`].
    fn merge_contract(&self) -> MergeContract {
        MergeContract::Disjoint
    }

    /// Relieve an overloaded node. Returns what changed.
    fn redistribute(&mut self, target: usize, loads: &Loads) -> RouteDelta;

    /// Elastic scale-up: grow the routable set with the brand-new node
    /// `id`, which must equal the current id space ([`Router::nodes`] —
    /// ids are dense and never reused). Minimal-movement contract: no key
    /// may move between two *surviving* nodes — only keys the new node
    /// claims change owner (token ring / multi-probe), or none at all
    /// (two-choices: sticky assignments hold; only unseen keys can
    /// first-sight onto the joiner).
    fn add_node(&mut self, id: usize) -> RouteDelta;

    /// Elastic scale-down: remove `id` from the routable set (its id
    /// stays allocated). Minimal-movement contract: only keys owned by
    /// the retired node move. Returns an unchanged delta when `id` is
    /// already retired or is the last live node (an empty routable set
    /// cannot route). `loads` resolves where the retired node's keys land
    /// for routers whose placement is load-aware (two-choices re-homes
    /// each orphaned key to the less-loaded of its re-computed
    /// candidates).
    fn retire_node(&mut self, id: usize, loads: &Loads) -> RouteDelta;

    /// Is `id` currently routable? (Retired ids stay allocated but are
    /// never returned by `route`.)
    fn is_live(&self, id: usize) -> bool {
        id < self.nodes()
    }

    /// Number of currently routable nodes (`<= nodes()`).
    fn live_count(&self) -> usize {
        self.nodes()
    }

    /// Externally visible routing state. `loads` is the live load view:
    /// routers whose *first-sight* decision consults loads (two-choices)
    /// freeze it into the snapshot so batch routing over the snapshot
    /// stays a pure function; the others ignore it.
    fn snapshot(&self, loads: &Loads) -> RouteSnapshot;

    /// Record externally computed sticky assignments (the compiled batch
    /// route path writes back its first-sight choices so later scalar
    /// routes agree). First writer wins per key; routers without a
    /// sticky table ignore this.
    fn record_assignments(&self, _assignments: &[(u32, u32)]) {}

    /// Clone into an independent (or internally shared, for sticky
    /// assignment tables) instance for per-actor route caches.
    fn clone_router(&self) -> Box<dyn Router>;

    /// Does `route` consult shared mutable state behind a lock (e.g. a
    /// sticky assignment table)? When `true`, [`RouterCache`] memoizes
    /// `(hash → owner)` per epoch — sound because routing is a pure
    /// function of `(hash, epoch)` — so the steady-state hot path stays
    /// lock-free for every router family.
    fn route_is_shared(&self) -> bool {
        false
    }

    /// Install a failure-domain map (node id → zone index; see
    /// [`effective_zone`](super::ptable::effective_zone)). Routers whose
    /// placement is zone-aware ([`PartitionTableRouter`](super::ptable::PartitionTableRouter)
    /// walks distinct zones for backup replicas) rebuild their placement;
    /// everyone else ignores it. Called by
    /// [`RouterBuilder::zones`](RouterBuilder) before the first publish.
    fn set_zones(&mut self, _zone_of: &[u32]) {}

    /// Token-ring escape hatch (elastic scale-out claims tokens directly;
    /// the XLA parity harness feeds raw rings). `None` for probe routers.
    fn as_token_ring(&self) -> Option<&Ring> {
        None
    }

    /// Mutable form of [`Router::as_token_ring`].
    fn as_token_ring_mut(&mut self) -> Option<&mut Ring> {
        None
    }
}

/// What the end-of-run merge may assume about how reducer states overlap
/// — carried by the router ([`Router::merge_contract`]), captured by the
/// execution core at build time, and enforced when the final snapshots
/// are assembled (`docs/ARCHITECTURE.md`, "§7 merge contracts").
///
/// ```
/// use dpa::hash::MergeContract;
///
/// // the paper's default: every router family is disjoint unless it
/// // explicitly relaxes the contract
/// assert_eq!(MergeContract::default(), MergeContract::Disjoint);
/// assert_ne!(MergeContract::Disjoint, MergeContract::Associative);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergeContract {
    /// The paper's §7 invariant: every key's state lives on **exactly
    /// one** reducer at end of run, so merging is pure disjoint union.
    /// Under StateForward the runtime *asserts* this — a key appearing
    /// in two final snapshots is a forwarding bug, not data.
    #[default]
    Disjoint,
    /// Partial-aggregation relaxation: shards of one key may live on
    /// several reducers and the merge folds them with the pipeline's
    /// associative, commutative [`MergeOp`](crate::exec::MergeOp)
    /// (`Sum`/`Min`/`Max`; order-sensitive ops like `Last` are rejected
    /// at pipeline build time). The disjointness assertion is disarmed —
    /// overlap is the design, not a bug.
    Associative,
}

/// Which §4.2 token operation a [`TokenRingRouter`] applies on
/// `redistribute`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingOp {
    /// Load balancing disabled (the paper's "No LB" baseline).
    NoOp,
    /// Remove half of the overloaded node's tokens.
    Halve,
    /// Double the token count of every *other* node.
    DoubleOthers,
}

/// The paper's consistent-hash token ring behind the [`Router`] trait.
/// Routing delegates to the very same [`Ring::lookup_hash`] binary search
/// as before the trait existed — bit-for-bit identical decisions.
#[derive(Clone)]
pub struct TokenRingRouter {
    ring: Ring,
    op: RingOp,
    /// Tokens a node joining at runtime claims — the founding per-node
    /// share, so a joiner takes the same expected arc fraction a seed
    /// node started with (minimal movement: exactly the claimed arcs).
    join_tokens: u32,
}

impl TokenRingRouter {
    /// Wrap `ring`, applying `op` on every redistribute.
    pub fn new(ring: Ring, op: RingOp) -> Self {
        let join_tokens = (0..ring.nodes())
            .map(|n| ring.tokens_of(n))
            .max()
            .unwrap_or(1)
            .max(1);
        TokenRingRouter { ring, op, join_tokens }
    }
}

impl Router for TokenRingRouter {
    fn name(&self) -> &'static str {
        "token-ring"
    }

    fn nodes(&self) -> usize {
        self.ring.nodes()
    }

    fn epoch(&self) -> u64 {
        self.ring.epoch()
    }

    fn route(&self, hash: u32, _loads: &Loads) -> usize {
        self.ring.lookup_hash(hash)
    }

    fn redistribute(&mut self, target: usize, _loads: &Loads) -> RouteDelta {
        match self.op {
            RingOp::NoOp => RouteDelta::unchanged(),
            RingOp::Halve => {
                let before = self.ring.tokens_of(target);
                if self.ring.halve(target) {
                    RouteDelta {
                        changed: true,
                        tokens_removed: before - self.ring.tokens_of(target),
                        ..RouteDelta::default()
                    }
                } else {
                    RouteDelta::unchanged()
                }
            }
            RingOp::DoubleOthers => {
                let before = self.ring.total_tokens();
                if self.ring.double_others(target) {
                    RouteDelta {
                        changed: true,
                        tokens_added: (self.ring.total_tokens() - before) as u32,
                        ..RouteDelta::default()
                    }
                } else {
                    RouteDelta::unchanged()
                }
            }
        }
    }

    fn add_node(&mut self, id: usize) -> RouteDelta {
        assert_eq!(id, self.ring.nodes(), "node ids are dense and never reused");
        self.ring.add_node(self.join_tokens);
        RouteDelta {
            changed: true,
            tokens_added: self.join_tokens,
            nodes_added: 1,
            ..RouteDelta::default()
        }
    }

    fn retire_node(&mut self, id: usize, _loads: &Loads) -> RouteDelta {
        let removed = self.ring.retire_node(id);
        if removed == 0 {
            return RouteDelta::unchanged();
        }
        RouteDelta {
            changed: true,
            tokens_removed: removed,
            nodes_retired: 1,
            ..RouteDelta::default()
        }
    }

    fn is_live(&self, id: usize) -> bool {
        self.ring.is_live(id)
    }

    fn live_count(&self) -> usize {
        self.ring.live_nodes()
    }

    fn snapshot(&self, _loads: &Loads) -> RouteSnapshot {
        RouteSnapshot {
            router: self.name(),
            epoch: self.ring.epoch(),
            nodes: self.ring.nodes(),
            state: SnapshotState::TokenRing { tokens: self.ring.sorted_tokens().to_vec() },
        }
    }

    fn clone_router(&self) -> Box<dyn Router> {
        Box::new(self.clone())
    }

    fn as_token_ring(&self) -> Option<&Ring> {
        Some(&self.ring)
    }

    fn as_token_ring_mut(&mut self) -> Option<&mut Ring> {
        Some(&mut self.ring)
    }
}

/// The k-probe routing decision over a frozen position/flag table —
/// lexicographic `(overloaded, clockwise distance, node)` over `probes`
/// seeded probe points. The single scalar implementation shared by
/// [`MultiProbeRouter::route`] and the runtime's snapshot fallback lane;
/// the Pallas `kprobe` kernel (`python/compile/kernels/kprobe.py`) is
/// the batched form and must agree bit-for-bit (`rust/tests/xla_parity`).
pub fn probe_route(
    position_hashes: &[u32],
    position_nodes: &[u32],
    overloaded: &[bool],
    probes: u32,
    hash: u32,
) -> usize {
    // lexicographic (overloaded?, distance, node): classic MPCH among
    // acceptable owners, falling back to pure distance when every
    // probe lands on an overloaded node
    let mut best: Option<(bool, u32, usize)> = None;
    for j in 0..probes.max(1) {
        let p = murmur3_x86_32_seed(&hash.to_le_bytes(), j);
        let i = super::ring::clockwise_successor_by(position_hashes, p, |&h| h);
        let (pos, node) = (position_hashes[i], position_nodes[i] as usize);
        let cand = (overloaded[node], pos.wrapping_sub(p), node);
        let better = match best {
            None => true,
            Some(b) => cand < b,
        };
        if better {
            best = Some(cand);
        }
    }
    best.expect("probes >= 1").2
}

/// The two candidate nodes of a key hash under the two-choices router
/// with a **contiguous** live set `0..nodes` — the fixed-membership case.
/// Equivalent to [`two_choices_candidates_in`] over the identity list.
#[inline]
pub fn two_choices_candidates(hash: u32, nodes: usize) -> (usize, usize) {
    let b = hash.to_le_bytes();
    (
        murmur3_x86_32_seed(&b, TWO_CHOICES_SEEDS[0]) as usize % nodes,
        murmur3_x86_32_seed(&b, TWO_CHOICES_SEEDS[1]) as usize % nodes,
    )
}

/// The two candidate nodes of a key hash over an explicit ascending live
/// node id list (elastic membership leaves gaps in the id space):
/// `candidate_i = live[murmur3_seed_i(hash) % live.len()]`. With
/// `live = [0, 1, .., n-1]` this is exactly [`two_choices_candidates`],
/// so fixed-membership routing is bit-identical to the pre-elastic code.
/// Shared by [`TwoChoicesRouter`], the runtime's snapshot fallback lane
/// and (in batched form) the Pallas `assign` kernel.
#[inline]
pub fn two_choices_candidates_in(hash: u32, live: &[u32]) -> (usize, usize) {
    let b = hash.to_le_bytes();
    let n = live.len();
    (
        live[murmur3_x86_32_seed(&b, TWO_CHOICES_SEEDS[0]) as usize % n] as usize,
        live[murmur3_x86_32_seed(&b, TWO_CHOICES_SEEDS[1]) as usize % n] as usize,
    )
}

/// Multi-probe consistent hashing: one ring position per node (no virtual
/// nodes), `k` seeded probes per key; the key goes to the *closest* probe
/// owner (classic MPCH), except that owners marked **overloaded** at the
/// last redistribute are avoided when any non-overloaded probe owner
/// exists. The overload flag — not the raw load — is the routing input:
/// ordering candidates by raw frozen load would herd virtually the whole
/// keyspace onto the single least-loaded node (any probe reaching it
/// would win), which is worse than no balancing at all. A binary
/// shed-from-the-hot-nodes classification keeps the classic MPCH
/// distance spread among the acceptable candidates.
///
/// `redistribute` moves **zero tokens**: it re-freezes the decayed
/// weight vector and the *hysteresis* overload flags from the live
/// [`LoadSignal`] (under the legacy signal config the flags degenerate to
/// the old strictly-above-mean classification). Freezing (rather than
/// consulting live loads per route) keeps ownership a pure function of
/// the epoch — the forwarding check and the §7 ownership diff stay
/// stable between LB events. Because the frozen flags come from the
/// banded signal, a reducer must cross distinct high/low watermarks for
/// its shed flag to flip, which is what stops the shed set (and with it
/// the keyspace) from ping-ponging on adversarial drift.
#[derive(Clone)]
pub struct MultiProbeRouter {
    /// Node positions sorted by `(hash, node)`.
    position_hashes: Vec<u32>,
    position_nodes: Vec<u32>,
    probes: u32,
    /// Per-node decayed load weights (fixed point) frozen at the last
    /// redistribute (snapshot / diagnostics; routing consults only the
    /// frozen flags).
    weights: Vec<u64>,
    /// Hysteresis overload flags frozen at the last redistribute.
    overloaded: Vec<bool>,
    epoch: u64,
}

impl MultiProbeRouter {
    /// `nodes` ring positions (one per node), `probes` probes per key.
    pub fn new(nodes: usize, probes: u32) -> Self {
        assert!(nodes > 0, "multi-probe router needs at least one node");
        assert!(probes >= 1, "need at least one probe");
        let mut positions: Vec<(u32, u32)> = (0..nodes as u32)
            .map(|n| (murmur3_x86_32(format!("node-{n}").as_bytes()), n))
            .collect();
        positions.sort_unstable();
        MultiProbeRouter {
            position_hashes: positions.iter().map(|p| p.0).collect(),
            position_nodes: positions.iter().map(|p| p.1).collect(),
            probes,
            weights: vec![0; nodes],
            overloaded: vec![false; nodes],
            epoch: 1,
        }
    }
}

impl Router for MultiProbeRouter {
    fn name(&self) -> &'static str {
        "multi-probe"
    }

    fn nodes(&self) -> usize {
        self.weights.len()
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn route(&self, hash: u32, _loads: &Loads) -> usize {
        probe_route(
            &self.position_hashes,
            &self.position_nodes,
            &self.overloaded,
            self.probes,
            hash,
        )
    }

    fn redistribute(&mut self, _target: usize, loads: &Loads) -> RouteDelta {
        let mut flags = loads.flags_vec();
        flags.resize(self.weights.len(), false);
        if flags == self.overloaded {
            // same shed set ⇒ identical routing: a no-op, not a new epoch
            return RouteDelta::unchanged();
        }
        let mut fresh = loads.decayed_vec();
        fresh.resize(self.weights.len(), 0);
        self.weights = fresh;
        self.overloaded = flags;
        self.epoch += 1;
        // zero token churn, zero explicit key moves: ownership shifts only
        // through the overload-aware probe choice
        RouteDelta { changed: true, ..RouteDelta::default() }
    }

    fn add_node(&mut self, id: usize) -> RouteDelta {
        assert_eq!(id, self.weights.len(), "node ids are dense and never reused");
        let h = murmur3_x86_32(format!("node-{id}").as_bytes());
        // keep the position table sorted by (hash, node) — the compiled
        // route_probe program receives it as-is
        let at = self
            .position_hashes
            .iter()
            .zip(&self.position_nodes)
            .position(|(&ph, &pn)| (ph, pn) > (h, id as u32))
            .unwrap_or(self.position_hashes.len());
        self.position_hashes.insert(at, h);
        self.position_nodes.insert(at, id as u32);
        self.weights.push(0);
        self.overloaded.push(false);
        self.epoch += 1;
        // minimal movement: only keys whose closest acceptable probe now
        // lands on the new position move — the MPCH consistency property
        RouteDelta { changed: true, nodes_added: 1, ..RouteDelta::default() }
    }

    fn retire_node(&mut self, id: usize, _loads: &Loads) -> RouteDelta {
        if self.position_hashes.len() <= 1 {
            return RouteDelta::unchanged(); // the last live position must stay
        }
        let Some(at) = self.position_nodes.iter().position(|&n| n as usize == id) else {
            return RouteDelta::unchanged(); // already retired
        };
        self.position_hashes.remove(at);
        self.position_nodes.remove(at);
        self.overloaded[id] = false;
        self.weights[id] = 0;
        self.epoch += 1;
        // only arcs whose successor probe was the retired position move —
        // they fall to their next-closest acceptable probe owner
        RouteDelta { changed: true, nodes_retired: 1, ..RouteDelta::default() }
    }

    fn is_live(&self, id: usize) -> bool {
        self.position_nodes.iter().any(|&n| n as usize == id)
    }

    fn live_count(&self) -> usize {
        self.position_nodes.len()
    }

    fn snapshot(&self, _loads: &Loads) -> RouteSnapshot {
        RouteSnapshot {
            router: self.name(),
            epoch: self.epoch,
            nodes: self.weights.len(),
            state: SnapshotState::Probe {
                position_hashes: self.position_hashes.clone(),
                position_nodes: self.position_nodes.clone(),
                probes: self.probes,
                overloaded: self.overloaded.clone(),
                weights: self.weights.clone(),
            },
        }
    }

    fn clone_router(&self) -> Box<dyn Router> {
        Box::new(self.clone())
    }
}

/// Seeds for the two candidate hash functions (arbitrary odd constants).
const TWO_CHOICES_SEEDS: [u32; 2] = [0x517c_c1b7, 0x9e37_79b9];

/// Pack a sticky-table entry into one atomic word: key hash in the high
/// half, `owner + 1` in the low half, so `0` unambiguously means *empty*
/// (a real entry's low half is never zero). The hash half of a slot is
/// write-once — owner rewrites keep it — which the duplicate-freedom
/// argument below leans on.
#[inline]
fn pack_slot(hash: u32, owner: u32) -> u64 {
    ((hash as u64) << 32) | (owner as u64 + 1)
}

#[inline]
fn unpack_slot(packed: u64) -> (u32, u32) {
    ((packed >> 32) as u32, (packed as u32) - 1)
}

/// Slots in the first [`AssignTable`] segment.
const FIRST_SEGMENT_SLOTS: usize = 1 << 10;
/// Per-segment growth factor for chained segments.
const SEGMENT_GROWTH: usize = 4;
/// Largest single segment (million-key tables chain a few of these).
const MAX_SEGMENT_SLOTS: usize = 1 << 22;
/// Linear-probe window inside one segment before descending to the next.
const PROBE_WINDOW: usize = 64;

/// One fixed-size open-addressing array in the [`AssignTable`] chain.
/// Segments are append-only: a full probe window overflows into `next`
/// (created on first demand), and existing slots are never moved — the
/// property that lets readers run without any synchronization beyond the
/// per-slot atomics.
struct Segment {
    slots: Box<[AtomicU64]>,
    mask: usize,
    next: OnceCell<Box<Segment>>,
}

impl Segment {
    fn new(cap: usize) -> Segment {
        debug_assert!(cap.is_power_of_two());
        let slots: Box<[AtomicU64]> = (0..cap).map(|_| AtomicU64::new(0)).collect();
        Segment { slots, mask: cap - 1, next: OnceCell::new() }
    }

    /// Fibonacci multiply-shift start slot for a key's linear probe walk.
    #[inline]
    fn start(&self, hash: u32) -> usize {
        ((hash as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize & self.mask
    }

    #[inline]
    fn window(&self) -> usize {
        PROBE_WINDOW.min(self.slots.len())
    }

    fn next_segment(&self) -> &Segment {
        self.next.get_or_init(|| {
            Box::new(Segment::new((self.slots.len() * SEGMENT_GROWTH).min(MAX_SEGMENT_SLOTS)))
        })
    }
}

/// Lock-free concurrent `key hash → owner` map — the two-choices sticky
/// table. Hand-rolled (the offline build carries no crossbeam) as a
/// chain of open-addressing segments with single-word CAS slots:
///
/// * **get** probes each segment's window linearly; finding the key's
///   hash returns its owner, finding an *empty* slot proves the key
///   absent. Fully lock-free and wait-free per segment.
/// * **insert_or_get** walks the same deterministic probe sequence and
///   claims the first empty slot with a CAS. A failed CAS re-examines
///   the slot: if the winner inserted the *same* key, its choice is
///   adopted (first writer wins); otherwise the walk continues.
/// * **rewrite** (redistribute / retire re-homes) stores a new owner
///   into the existing slot — one atomic word, so concurrent readers can
///   never observe a torn entry.
///
/// Entries are never removed, so "empty slot ⇒ absent" stays sound
/// forever, and duplicates are impossible: both inserters of a key walk
/// the same slot sequence, neither ever passes an empty slot without
/// CASing it, and a slot's key half is write-once — so the second writer
/// must either lose the CAS at the first claimable slot (and adopt) or
/// observe the first writer's entry before reaching any later slot.
///
/// The prose argument above is *checked*, not just reviewed: the type is
/// `pub` (an internal structure, not a stable API) so the bounded loom
/// models in `tests/loom_models.rs` can exhaustively verify
/// first-writer-wins, the colliding-key probe walk and the
/// no-torn-`(hash, owner)` read, and the `tests/lockfree_router.rs`
/// stress suite can sample the same invariants at scale.
pub struct AssignTable {
    head: Segment,
}

impl Default for AssignTable {
    fn default() -> Self {
        Self::new()
    }
}

impl AssignTable {
    /// An empty table (one pre-sized head segment; grows by chaining).
    pub fn new() -> Self {
        AssignTable { head: Segment::new(FIRST_SEGMENT_SLOTS) }
    }

    /// First-segment probe start for `hash` — exposed so the loom models
    /// can craft colliding key pairs deterministically.
    #[doc(hidden)]
    pub fn probe_start(&self, hash: u32) -> usize {
        self.head.start(hash)
    }

    /// Lock-free lookup (the steady-state route *hit* path).
    pub fn get(&self, hash: u32) -> Option<u32> {
        let mut seg = &self.head;
        loop {
            let start = seg.start(hash);
            for i in 0..seg.window() {
                let cur = seg.slots[(start + i) & seg.mask].load(Ordering::Acquire);
                if cur == 0 {
                    return None;
                }
                let (h, owner) = unpack_slot(cur);
                if h == hash {
                    return Some(owner);
                }
            }
            match seg.next.get() {
                Some(next) => seg = next,
                None => return None,
            }
        }
    }

    /// Insert `hash → owner` unless the key is already present; returns
    /// the winning owner either way.
    pub fn insert_or_get(&self, hash: u32, owner: u32) -> u32 {
        let packed = pack_slot(hash, owner);
        let mut seg = &self.head;
        loop {
            let start = seg.start(hash);
            'probe: for i in 0..seg.window() {
                let slot = &seg.slots[(start + i) & seg.mask];
                let mut cur = slot.load(Ordering::Acquire);
                loop {
                    if cur == 0 {
                        match slot.compare_exchange(
                            0,
                            packed,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        ) {
                            Ok(_) => return owner,
                            Err(actual) => cur = actual, // re-examine the winner
                        }
                    } else {
                        let (h, won) = unpack_slot(cur);
                        if h == hash {
                            return won; // first writer wins; adopt
                        }
                        continue 'probe; // claimed by another key
                    }
                }
            }
            seg = seg.next_segment();
        }
    }

    /// Re-point the existing entry for `hash` at `owner` (no-op if the
    /// key was never inserted). Callers serialize through the membership
    /// write lock; the single-word `Release` store keeps lock-free
    /// readers un-torn — proven by the `assign_table_rewrite_is_never_torn` loom
    /// model, which pins that a racing `get` observes the old owner or
    /// the new one and never a mixed `(hash, owner)` word. A CAS is not
    /// needed *because* of that serialization; the model is the regression
    /// guard on the claim.
    pub fn rewrite(&self, hash: u32, owner: u32) {
        let mut seg = &self.head;
        loop {
            let start = seg.start(hash);
            for i in 0..seg.window() {
                let slot = &seg.slots[(start + i) & seg.mask];
                let cur = slot.load(Ordering::Acquire);
                if cur == 0 {
                    return;
                }
                if (cur >> 32) as u32 == hash {
                    slot.store(pack_slot(hash, owner), Ordering::Release);
                    return;
                }
            }
            match seg.next.get() {
                Some(next) => seg = next,
                None => return,
            }
        }
    }

    /// All `(hash, owner)` entries, unordered — scan callers sort. Under
    /// the membership *write* lock this is an exact point-in-time view
    /// (first sights hold the read side); without it, entries landing
    /// mid-scan may or may not be included, each individually valid.
    pub fn entries(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        let mut seg = Some(&self.head);
        while let Some(s) = seg {
            for slot in s.slots.iter() {
                let cur = slot.load(Ordering::Acquire);
                if cur != 0 {
                    out.push(unpack_slot(cur));
                }
            }
            seg = s.next.get().map(|b| &**b);
        }
        out
    }
}

/// Per-key power of two choices with a sticky assignment table.
///
/// Each key hash has two candidate nodes; the first route of a key picks
/// the candidate with the lower *decayed* load and *records* it. Every
/// later route — including the reducer's ownership check and the §7
/// ownership diff — returns the recorded owner, so a key's state never
/// splits across nodes (the merge-correctness guard). `redistribute`
/// re-homes roughly every other key of the overloaded node to its
/// alternate candidate, but only keys whose move clears the signal's
/// migration-gain guard ([`LoadSignal::migration_gain_ok`]): a re-home
/// that would land on a candidate not meaningfully colder than the
/// source is skipped, which is what stops a hot key from bouncing
/// between its two candidates on adversarial drift. Under StateForward
/// the normal epoch machinery then ships the moved keys' state.
///
/// The table — and the live node id list candidate hashing indexes — is
/// shared (`Arc`) across [`Router::clone_router`] clones, so per-actor
/// route caches all see one consistent assignment and one membership.
/// The table itself is the lock-free [`AssignTable`]: steady-state hits
/// acquire no lock at all. First sights (the table-miss path) hold the
/// membership `RwLock` on the *read* side while picking and recording a
/// candidate, and membership changes hold the write side — so a first
/// sight can never record a node a concurrent retire just removed, and
/// a retire's orphan scan can never miss a racing insert.
#[derive(Clone)]
pub struct TwoChoicesRouter {
    /// Total id space (live ∪ retired); candidate hashing indexes the
    /// shared live list, so ids may have gaps after retires.
    id_space: usize,
    /// Sticky `key hash → owner` assignments (lock-free).
    table: Arc<AssignTable>,
    /// Ascending live node ids (`candidate = live[h % live.len()]`).
    membership: Arc<RwLock<Vec<u32>>>,
    epoch: Arc<AtomicU64>,
}

impl TwoChoicesRouter {
    /// `nodes` candidates in the id space, all initially live.
    pub fn new(nodes: usize) -> Self {
        assert!(nodes > 0, "two-choices router needs at least one node");
        TwoChoicesRouter {
            id_space: nodes,
            table: Arc::new(AssignTable::new()),
            membership: Arc::new(RwLock::new((0..nodes as u32).collect())),
            epoch: Arc::new(AtomicU64::new(1)),
        }
    }

    #[inline]
    fn candidates(&self, hash: u32) -> (usize, usize) {
        two_choices_candidates_in(hash, &self.membership.read().unwrap())
    }

    /// Number of keys currently pinned to `node`.
    pub fn assigned_to(&self, node: usize) -> usize {
        self.table
            .entries()
            .iter()
            .filter(|&&(_, n)| n as usize == node)
            .count()
    }
}

impl Router for TwoChoicesRouter {
    fn name(&self) -> &'static str {
        "two-choices"
    }

    fn nodes(&self) -> usize {
        self.id_space
    }

    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn route(&self, hash: u32, loads: &Loads) -> usize {
        // steady-state hit: one lock-free table probe, no RwLock at all
        if let Some(n) = self.table.get(hash) {
            return n as usize;
        }
        // first sight: candidates computed under the membership *read*
        // lock a membership change excludes, so a first sight can never
        // pick a just-retired node and a retire's orphan scan can never
        // miss this insert
        let live = self.membership.read().unwrap();
        let (c1, c2) = two_choices_candidates_in(hash, &live);
        let pick = if loads.decayed(c2) < loads.decayed(c1) {
            c2 as u32
        } else {
            c1 as u32
        };
        // a racing first-router wins the CAS; we adopt its choice
        self.table.insert_or_get(hash, pick) as usize
    }

    fn redistribute(&mut self, target: usize, loads: &Loads) -> RouteDelta {
        let live = self.membership.write().unwrap();
        let mut pinned: Vec<u32> = self
            .table
            .entries()
            .into_iter()
            .filter(|&(_, n)| n as usize == target)
            .map(|(k, _)| k)
            .collect();
        // ascending hash order, matching the old BTreeMap scan — keeps
        // the every-other-key selection below deterministic
        pinned.sort_unstable();
        let mut moved = 0u64;
        for (i, k) in pinned.iter().enumerate() {
            // re-home every other key: relieve ~half the load, like halving
            if i % 2 != 0 {
                continue;
            }
            let (c1, c2) = two_choices_candidates_in(*k, &live);
            let alt = if c1 == target { c2 } else { c1 };
            if alt == target {
                continue; // both candidates collide on the target
            }
            if !loads.migration_gain_ok(target, alt) {
                // the alternate is not meaningfully colder than the
                // source: moving would at best trade places (and at worst
                // ping-pong the key back next round)
                continue;
            }
            self.table.rewrite(*k, alt as u32);
            moved += 1;
        }
        drop(live);
        if moved == 0 {
            return RouteDelta::unchanged();
        }
        self.epoch.fetch_add(1, Ordering::AcqRel);
        RouteDelta {
            changed: true,
            keys_reassigned: moved,
            ..RouteDelta::default()
        }
    }

    fn add_node(&mut self, id: usize) -> RouteDelta {
        assert_eq!(id, self.id_space, "node ids are dense and never reused");
        let mut live = self.membership.write().unwrap();
        live.push(id as u32); // fresh max id keeps the list ascending
        self.id_space += 1;
        drop(live);
        self.epoch.fetch_add(1, Ordering::AcqRel);
        // sticky assignments hold, so NO existing key moves at all — the
        // joiner receives load only through first sights of unseen keys
        // (and any later redistribute whose candidates now include it)
        RouteDelta { changed: true, nodes_added: 1, ..RouteDelta::default() }
    }

    fn retire_node(&mut self, id: usize, loads: &Loads) -> RouteDelta {
        let mut live = self.membership.write().unwrap();
        if live.len() <= 1 {
            return RouteDelta::unchanged(); // the last live node must stay
        }
        let Ok(at) = live.binary_search(&(id as u32)) else {
            return RouteDelta::unchanged(); // already retired
        };
        live.remove(at);
        // sticky-table rewrite restricted to the retired owner: each of
        // its keys re-homes to the less-loaded of its candidates under
        // the NEW membership (the retired node is no candidate anymore);
        // every other entry is untouched
        let mut orphaned: Vec<u32> = self
            .table
            .entries()
            .into_iter()
            .filter(|&(_, n)| n as usize == id)
            .map(|(k, _)| k)
            .collect();
        orphaned.sort_unstable(); // old BTreeMap scan order
        let mut moved = 0u64;
        for k in orphaned {
            let (c1, c2) = two_choices_candidates_in(k, &live);
            let n = if loads.decayed(c2) < loads.decayed(c1) { c2 } else { c1 };
            self.table.rewrite(k, n as u32);
            moved += 1;
        }
        drop(live);
        self.epoch.fetch_add(1, Ordering::AcqRel);
        RouteDelta {
            changed: true,
            keys_reassigned: moved,
            nodes_retired: 1,
            ..RouteDelta::default()
        }
    }

    fn is_live(&self, id: usize) -> bool {
        self.membership.read().unwrap().binary_search(&(id as u32)).is_ok()
    }

    fn live_count(&self) -> usize {
        self.membership.read().unwrap().len()
    }

    fn snapshot(&self, loads: &Loads) -> RouteSnapshot {
        // freeze the *decayed* view — the very values route() consults
        // for first sights, so batch routing over the snapshot stays
        // bit-identical to the scalar router at this epoch
        let mut frozen = loads.decayed_vec();
        frozen.resize(self.id_space, 0);
        let live = self.membership.read().unwrap().clone();
        let mut assignments = self.table.entries();
        // ascending by key hash — the sort order the compiled table
        // lookup requires (the old BTreeMap iterated this way for free)
        assignments.sort_unstable_by_key(|&(k, _)| k);
        RouteSnapshot {
            router: self.name(),
            epoch: self.epoch(),
            nodes: self.id_space,
            state: SnapshotState::Assignment { assignments, live, loads: frozen },
        }
    }

    fn record_assignments(&self, assignments: &[(u32, u32)]) {
        if assignments.is_empty() {
            return;
        }
        // read side of the membership lock: a concurrent retire can't
        // slip between the live-check and the insert
        let live = self.membership.read().unwrap();
        for &(k, n) in assignments {
            // skip owners retired since the snapshot was taken — recording
            // one would pin the key to a node routing no longer returns
            if live.binary_search(&n).is_err() {
                continue;
            }
            // first writer wins: a racing scalar route (which inserts
            // under live loads) keeps its choice; ours is dropped and the
            // stale send is forwarded by the normal mechanism
            self.table.insert_or_get(k, n);
        }
    }

    fn clone_router(&self) -> Box<dyn Router> {
        Box::new(self.clone())
    }

    fn route_is_shared(&self) -> bool {
        // the sticky table is shared across clones; memoizing hot keys in
        // the cache is still cheaper than re-probing it per record
        true
    }
}

/// Seeds for the up-to-[`MAX_SPLIT_D`] candidate hash functions of the
/// split-key router. The first two are the two-choices seeds, so a
/// `d = 2` split router draws the same primary candidate pair as
/// [`TwoChoicesRouter`].
const SPLIT_SEEDS: [u32; 8] = [
    0x517c_c1b7,
    0x9e37_79b9,
    0x85eb_ca6b,
    0xc2b2_ae35,
    0x27d4_eb2f,
    0x1656_67b1,
    0xb554_6a3d,
    0x94d0_49bb,
];

/// Largest supported split fan-out `d` (the number of candidate seeds).
pub const MAX_SPLIT_D: usize = SPLIT_SEEDS.len();

/// The owner value marking a key as *split* in a [`SplitKeyRouter`]'s
/// assignment table. Not `u32::MAX`: the [`AssignTable`] packs
/// `owner + 1` into the low slot half so `0` means empty, and the
/// sentinel must survive that encoding. Real node ids are dense small
/// integers, so the sentinel can never collide with one.
pub const SPLIT_SENTINEL: u32 = u32::MAX - 1;

/// The up-to-`d` **distinct** candidate nodes of a key hash over an
/// explicit ascending live node id list — the split-key analogue of
/// [`two_choices_candidates_in`]. Candidates are drawn seed by seed
/// (first two seeds = the two-choices pair) and deduplicated in draw
/// order; if the seeds collide below `d` distinct nodes, the list is
/// completed by walking the live list clockwise from the primary
/// candidate. The result is a pure function of `(hash, live, d)` with
/// `min(d, live.len())` entries, shared by the scalar router, the
/// snapshot fallback lane and the ownership check.
///
/// ```
/// use dpa::hash::split_candidates_in;
///
/// let live = [0, 1, 2, 3];
/// let cands = split_candidates_in(0xDEAD_BEEF, &live, 4);
/// assert_eq!(cands.len(), 4, "d <= live: full fan-out");
/// let mut sorted = cands.clone();
/// sorted.sort_unstable();
/// assert_eq!(sorted, vec![0, 1, 2, 3], "distinct, all live");
/// // deterministic: same inputs, same candidates
/// assert_eq!(split_candidates_in(0xDEAD_BEEF, &live, 4), cands);
/// ```
pub fn split_candidates_in(hash: u32, live: &[u32], d: usize) -> Vec<usize> {
    let mut buf = [0usize; MAX_SPLIT_D];
    let n = split_candidates_into(hash, live, d, &mut buf);
    buf[..n].to_vec()
}

/// Allocation-free core of [`split_candidates_in`] — the route hot path
/// fills a stack buffer instead of a `Vec`.
fn split_candidates_into(
    hash: u32,
    live: &[u32],
    d: usize,
    out: &mut [usize; MAX_SPLIT_D],
) -> usize {
    let b = hash.to_le_bytes();
    let want = d.min(MAX_SPLIT_D).min(live.len()).max(1);
    let mut len = 0usize;
    for &seed in SPLIT_SEEDS.iter() {
        if len == want {
            return len;
        }
        let c = live[murmur3_x86_32_seed(&b, seed) as usize % live.len()] as usize;
        if !out[..len].contains(&c) {
            out[len] = c;
            len += 1;
        }
    }
    // the seeds collided below `want` distinct nodes: complete the set
    // deterministically by walking the live list clockwise from the
    // primary candidate's position
    let start = murmur3_x86_32_seed(&b, SPLIT_SEEDS[0]) as usize % live.len();
    let mut i = 0usize;
    while len < want {
        let c = live[(start + i) % live.len()] as usize;
        i += 1;
        if !out[..len].contains(&c) {
            out[len] = c;
            len += 1;
        }
    }
    len
}

/// Slots in the split router's per-key hit sketch.
const SKETCH_SLOTS: usize = 1 << 12;

/// One-row count-min sketch of per-key record hits — the split router's
/// per-*key* load estimator (the [`LoadSignal`] is per-*node*). Hash
/// collisions only ever **over**-estimate a key's hit share, which for
/// the promotion decision errs toward splitting a key that shares a slot
/// with a genuinely hot one — safe, because split routing still load
/// balances correctly for cold keys, it just costs them stickiness.
/// Counters are `Relaxed`: they are statistics consulted under the
/// membership write lock at redistribute time, ordering nothing.
struct HitSketch {
    counts: Box<[AtomicU64]>,
}

impl HitSketch {
    fn new() -> Self {
        HitSketch { counts: (0..SKETCH_SLOTS).map(|_| AtomicU64::new(0)).collect() }
    }

    #[inline]
    fn slot(hash: u32) -> usize {
        hash as usize & (SKETCH_SLOTS - 1)
    }

    #[inline]
    fn bump(&self, hash: u32) {
        self.counts[Self::slot(hash)].fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    fn estimate(&self, hash: u32) -> u64 {
        self.counts[Self::slot(hash)].load(Ordering::Relaxed)
    }

    /// Halve every counter — called once per redistribute so a key that
    /// *was* hot long ago decays back below the promotion threshold
    /// estimate instead of looking hot forever.
    fn decay(&self) {
        for c in self.counts.iter() {
            let cur = c.load(Ordering::Relaxed);
            if cur != 0 {
                c.store(cur >> 1, Ordering::Relaxed);
            }
        }
    }
}

/// d-way partial key grouping with a split watermark ("When Two Choices
/// Are not Enough", Katsipoulakis et al.; "The Power of Both Choices",
/// Nasir et al.).
///
/// Cold keys behave exactly like [`TwoChoicesRouter`]: the first route
/// picks the least decayed-loaded of the key's candidates and *records*
/// it in the shared lock-free [`AssignTable`]; every later route returns
/// the sticky owner with one lock-free probe. What's new is the hot
/// tier: `redistribute` estimates each sticky key's share of the
/// overloaded node's decayed load (via a hit sketch) and **promotes**
/// any key whose estimated load alone crosses the split watermark —
/// its table entry is rewritten to [`SPLIT_SENTINEL`], and from then on
/// every record of that key is routed to the least-loaded of its `d`
/// candidate nodes ([`split_candidates_in`]), ties broken round-robin
/// so a uniform load spreads a mega-hot key evenly.
///
/// Split routing is deliberately **not** a pure function of
/// `(hash, epoch)` — that is the point — so this family:
///
/// * answers `false` from [`Router::route_is_shared`] (memoizing a
///   split key would pin all its records to one shard again),
/// * overrides [`Router::is_owner`] so every live candidate of a split
///   key is a legitimate home (no forward ping-pong),
/// * declares [`MergeContract::Associative`]: shards of a split key
///   hold partial aggregates on several reducers and the end-of-run
///   merge folds them with the pipeline's associative merge op instead
///   of asserting §7 disjointness,
/// * has no compiled kernel lowering — the snapshot is tagged
///   [`SnapshotState::Split`] and the mapper permanently falls back to
///   the scalar route lane (see `docs/ROUTING.md`).
///
/// ```
/// use dpa::hash::{Loads, MergeContract, Router, SplitKeyRouter};
///
/// let mut r = SplitKeyRouter::new(4, 2);
/// assert_eq!(r.merge_contract(), MergeContract::Associative);
/// let loads = Loads::new(4);
/// let h = 0x5EED_CAFE;
/// let owner = r.route(h, &loads);
/// // cold keys are sticky, exactly like two-choices
/// assert_eq!(r.route(h, &loads), owner);
/// assert!(r.is_owner(h, owner, &loads));
/// // force-promote the key: every live candidate now owns it
/// assert!(r.promote(h));
/// assert!(r.is_split(h));
/// assert!(r.is_owner(h, r.route(h, &loads), &loads));
/// ```
#[derive(Clone)]
pub struct SplitKeyRouter {
    /// Total id space (live ∪ retired), as in [`TwoChoicesRouter`].
    id_space: usize,
    /// Split fan-out: a promoted key spreads over `min(d, live)` nodes.
    d: usize,
    /// Fixed-point ([`FRAC_BITS`] fractional bits) decayed-load threshold
    /// a key's estimated load must cross to be promoted.
    watermark_fp: u64,
    /// Sticky `key hash → owner` assignments; owner [`SPLIT_SENTINEL`]
    /// marks a split key. Shared (lock-free) across clones.
    table: Arc<AssignTable>,
    /// Ascending live node ids (shared across clones).
    membership: Arc<RwLock<Vec<u32>>>,
    epoch: Arc<AtomicU64>,
    /// Per-key hit estimator feeding the promotion decision.
    hits: Arc<HitSketch>,
    /// Round-robin tie-breaker for split picks under equal loads.
    rotation: Arc<AtomicU64>,
}

impl SplitKeyRouter {
    /// Default split watermark (in decayed-load units — queue-length
    /// scale): a key estimated to carry this much load alone is split.
    pub const DEFAULT_WATERMARK: f64 = 4.0;

    /// `nodes` candidates, fan-out `d`, the default watermark.
    pub fn new(nodes: usize, d: usize) -> Self {
        Self::with_watermark(nodes, d, Self::DEFAULT_WATERMARK)
    }

    /// `nodes` candidates, fan-out `d` (clamped to
    /// `2..=`[`MAX_SPLIT_D`]), splitting keys whose estimated decayed
    /// load exceeds `watermark` (must be positive).
    pub fn with_watermark(nodes: usize, d: usize, watermark: f64) -> Self {
        assert!(nodes > 0, "split-key router needs at least one node");
        assert!(
            (2..=MAX_SPLIT_D).contains(&d),
            "split fan-out d must be in 2..={MAX_SPLIT_D}, got {d}"
        );
        assert!(watermark > 0.0, "split watermark must be positive");
        let watermark_fp = (watermark * (1u64 << FRAC_BITS) as f64) as u64;
        SplitKeyRouter {
            id_space: nodes,
            d,
            watermark_fp: watermark_fp.max(1),
            table: Arc::new(AssignTable::new()),
            membership: Arc::new(RwLock::new((0..nodes as u32).collect())),
            epoch: Arc::new(AtomicU64::new(1)),
            hits: Arc::new(HitSketch::new()),
            rotation: Arc::new(AtomicU64::new(0)),
        }
    }

    /// The configured split fan-out `d`.
    pub fn fanout(&self) -> usize {
        self.d
    }

    /// Number of keys currently sticky-pinned to `node`.
    pub fn assigned_to(&self, node: usize) -> usize {
        self.table
            .entries()
            .iter()
            .filter(|&&(_, n)| n as usize == node)
            .count()
    }

    /// Number of keys currently promoted to split.
    pub fn split_count(&self) -> usize {
        self.table
            .entries()
            .iter()
            .filter(|&&(_, n)| n == SPLIT_SENTINEL)
            .count()
    }

    /// Is `hash` currently promoted to split?
    pub fn is_split(&self, hash: u32) -> bool {
        self.table.get(hash) == Some(SPLIT_SENTINEL)
    }

    /// Force-promote a *seen* key to split (tests, diagnostics; the
    /// production path promotes inside `redistribute` when the key's
    /// estimated load crosses the watermark). Returns `false` for a key
    /// not in the table — promotion rewrites an existing entry; an
    /// unseen key has no entry to rewrite. Bumps the epoch on success so
    /// shared-table clones drop their memo; when driven through a
    /// [`RouterHandle`], prefer promoting before the handle is built or
    /// via `redistribute`, which also republishes.
    pub fn promote(&self, hash: u32) -> bool {
        let _live = self.membership.write().unwrap();
        if self.table.get(hash).is_none() {
            return false;
        }
        self.table.rewrite(hash, SPLIT_SENTINEL);
        self.epoch.fetch_add(1, Ordering::AcqRel);
        true
    }

    /// Deterministic first-sight pick: the least decayed-loaded
    /// candidate, earliest in candidate order on ties (the rule the
    /// snapshot fallback lane replays bit-for-bit).
    fn least_decayed(cands: &[usize], loads: &Loads) -> usize {
        let mut best = cands[0];
        for &c in &cands[1..] {
            if loads.decayed(c) < loads.decayed(best) {
                best = c;
            }
        }
        best
    }

    /// Per-record pick for a split key: least decayed-loaded candidate,
    /// rotating round-robin among ties so equal loads spread evenly.
    fn split_pick(&self, hash: u32, live: &[u32], loads: &Loads) -> usize {
        let mut buf = [0usize; MAX_SPLIT_D];
        let n = split_candidates_into(hash, live, self.d, &mut buf);
        let cands = &buf[..n];
        let min = cands.iter().map(|&c| loads.decayed(c)).min().unwrap_or(0);
        let mut tied = [0usize; MAX_SPLIT_D];
        let mut t = 0usize;
        for &c in cands {
            if loads.decayed(c) == min {
                tied[t] = c;
                t += 1;
            }
        }
        if t <= 1 {
            tied[0]
        } else {
            let r = self.rotation.fetch_add(1, Ordering::Relaxed) as usize;
            tied[r % t]
        }
    }
}

impl Router for SplitKeyRouter {
    fn name(&self) -> &'static str {
        "split-key"
    }

    fn nodes(&self) -> usize {
        self.id_space
    }

    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn route(&self, hash: u32, loads: &Loads) -> usize {
        self.hits.bump(hash);
        // steady-state: one lock-free table probe, no RwLock at all
        match self.table.get(hash) {
            Some(SPLIT_SENTINEL) => {
                // split key: least-loaded-of-d per record
                let live = self.membership.read().unwrap();
                self.split_pick(hash, &live, loads)
            }
            Some(n) => n as usize,
            None => {
                // first sight under the membership read lock, exactly
                // like two-choices: pick, then first-writer-wins record
                let live = self.membership.read().unwrap();
                let mut buf = [0usize; MAX_SPLIT_D];
                let n = split_candidates_into(hash, &live, self.d, &mut buf);
                let pick = Self::least_decayed(&buf[..n], loads);
                self.table.insert_or_get(hash, pick as u32) as usize
            }
        }
    }

    fn is_owner(&self, hash: u32, id: usize, loads: &Loads) -> bool {
        match self.table.get(hash) {
            Some(SPLIT_SENTINEL) => {
                // every live candidate of a split key is a legitimate
                // shard home — forwarding between them would ping-pong
                let live = self.membership.read().unwrap();
                let mut buf = [0usize; MAX_SPLIT_D];
                let n = split_candidates_into(hash, &live, self.d, &mut buf);
                buf[..n].contains(&id)
            }
            Some(n) => n as usize == id,
            None => {
                // unseen key: replay the deterministic first-sight pick
                // WITHOUT recording — an ownership probe must not grow
                // the table
                let live = self.membership.read().unwrap();
                let mut buf = [0usize; MAX_SPLIT_D];
                let n = split_candidates_into(hash, &live, self.d, &mut buf);
                Self::least_decayed(&buf[..n], loads) == id
            }
        }
    }

    fn merge_contract(&self) -> MergeContract {
        MergeContract::Associative
    }

    fn redistribute(&mut self, target: usize, loads: &Loads) -> RouteDelta {
        let live = self.membership.write().unwrap();
        let mut sticky: Vec<u32> = self
            .table
            .entries()
            .into_iter()
            .filter(|&(_, n)| n as usize == target)
            .map(|(k, _)| k)
            .collect();
        sticky.sort_unstable(); // deterministic every-other selection
        let mut split = 0u64;
        let node_fp = loads.decayed(target);
        if node_fp >= self.watermark_fp {
            // promotion pass: apportion the node's decayed load over its
            // sticky keys by sketch hit share; a key estimated to carry
            // the watermark's worth of load *alone* goes d-way
            let hits: Vec<u64> = sticky.iter().map(|&k| self.hits.estimate(k)).collect();
            let total: u128 = hits.iter().map(|&h| h as u128).sum::<u128>().max(1);
            let mut keep = Vec::with_capacity(sticky.len());
            for (&k, &h) in sticky.iter().zip(&hits) {
                let est = (node_fp as u128).saturating_mul(h as u128) / total;
                if est >= self.watermark_fp as u128 {
                    self.table.rewrite(k, SPLIT_SENTINEL);
                    split += 1;
                } else {
                    keep.push(k);
                }
            }
            sticky = keep;
        }
        // two-choices-style relief for the keys that stayed sticky:
        // re-home every other one to its least-loaded other candidate,
        // gated by the signal's migration-gain guard
        let mut moved = 0u64;
        for (i, &k) in sticky.iter().enumerate() {
            if i % 2 != 0 {
                continue;
            }
            let mut buf = [0usize; MAX_SPLIT_D];
            let n = split_candidates_into(k, &live, self.d, &mut buf);
            let alt = buf[..n]
                .iter()
                .copied()
                .filter(|&c| c != target)
                .min_by_key(|&c| loads.decayed(c));
            let Some(alt) = alt else {
                continue; // every candidate collides on the target
            };
            if !loads.migration_gain_ok(target, alt) {
                continue;
            }
            self.table.rewrite(k, alt as u32);
            moved += 1;
        }
        // halve the sketch so stale hot history decays across LB rounds
        self.hits.decay();
        drop(live);
        if split == 0 && moved == 0 {
            return RouteDelta::unchanged();
        }
        self.epoch.fetch_add(1, Ordering::AcqRel);
        RouteDelta {
            changed: true,
            keys_reassigned: moved,
            keys_split: split,
            ..RouteDelta::default()
        }
    }

    fn add_node(&mut self, id: usize) -> RouteDelta {
        assert_eq!(id, self.id_space, "node ids are dense and never reused");
        let mut live = self.membership.write().unwrap();
        live.push(id as u32); // fresh max id keeps the list ascending
        self.id_space += 1;
        drop(live);
        self.epoch.fetch_add(1, Ordering::AcqRel);
        // sticky assignments hold; split keys pick the joiner up
        // automatically once it enters their candidate set
        RouteDelta { changed: true, nodes_added: 1, ..RouteDelta::default() }
    }

    fn retire_node(&mut self, id: usize, loads: &Loads) -> RouteDelta {
        let mut live = self.membership.write().unwrap();
        if live.len() <= 1 {
            return RouteDelta::unchanged(); // the last live node must stay
        }
        let Ok(at) = live.binary_search(&(id as u32)) else {
            return RouteDelta::unchanged(); // already retired
        };
        live.remove(at);
        // sticky orphans re-home to the least-loaded candidate under the
        // NEW membership; split entries are untouched — their candidate
        // sets recompute over the shrunken live list on the next route
        let mut orphaned: Vec<u32> = self
            .table
            .entries()
            .into_iter()
            .filter(|&(_, n)| n as usize == id)
            .map(|(k, _)| k)
            .collect();
        orphaned.sort_unstable();
        let mut moved = 0u64;
        for k in orphaned {
            let mut buf = [0usize; MAX_SPLIT_D];
            let n = split_candidates_into(k, &live, self.d, &mut buf);
            let pick = Self::least_decayed(&buf[..n], loads);
            self.table.rewrite(k, pick as u32);
            moved += 1;
        }
        drop(live);
        self.epoch.fetch_add(1, Ordering::AcqRel);
        RouteDelta {
            changed: true,
            keys_reassigned: moved,
            nodes_retired: 1,
            ..RouteDelta::default()
        }
    }

    fn is_live(&self, id: usize) -> bool {
        self.membership.read().unwrap().binary_search(&(id as u32)).is_ok()
    }

    fn live_count(&self) -> usize {
        self.membership.read().unwrap().len()
    }

    fn snapshot(&self, loads: &Loads) -> RouteSnapshot {
        // freeze the decayed view the scalar router consults, as
        // two-choices does; split (sentinel) entries are carried so the
        // host fallback lane can tell split keys from first sights
        let mut frozen = loads.decayed_vec();
        frozen.resize(self.id_space, 0);
        let live = self.membership.read().unwrap().clone();
        let mut assignments = self.table.entries();
        assignments.sort_unstable_by_key(|&(k, _)| k);
        RouteSnapshot {
            router: self.name(),
            epoch: self.epoch(),
            nodes: self.id_space,
            state: SnapshotState::Split {
                assignments,
                live,
                loads: frozen,
                d: self.d as u32,
            },
        }
    }

    fn clone_router(&self) -> Box<dyn Router> {
        Box::new(self.clone())
    }

    fn route_is_shared(&self) -> bool {
        // the table IS shared across clones, but split routing is not a
        // pure function of (hash, epoch) — memoizing a split key's pick
        // would pin every record of the hottest key to one shard again,
        // defeating the family. The cache must re-route per record.
        false
    }
}

/// Shared, epoch-versioned router handle — the trait-layer successor of
/// `SharedRing`. The balancer is the only redistribute caller; mappers
/// and reducers read through [`RouterCache`] clones.
///
/// Reads and mutations are decoupled arc-swap style: mutators serialize
/// on a `Mutex`-guarded writer copy, do all their work there, and then
/// *publish* — an O(1) swap of the `published` snapshot followed by the
/// epoch store (in that order, so any reader that observes the new epoch
/// finds the new snapshot already in place). Readers grab the published
/// `Arc` under a momentary `RwLock` read — never contended by in-flight
/// redistribution work, only by the final pointer swap — so the read
/// path never waits out a redistribution.
#[derive(Clone)]
pub struct RouterHandle {
    /// Mutation side: redistribute/add/retire run here, then publish.
    writer: Arc<Mutex<Box<dyn Router>>>,
    /// Read side: the last published router snapshot.
    published: Arc<RwLock<Arc<dyn Router>>>,
    epoch: Arc<AtomicU64>,
    loads: Loads,
    /// Failure-domain map (node id → zone index; empty = no zones
    /// configured). Resolved through
    /// [`effective_zone`](super::ptable::effective_zone), so ids beyond
    /// the map get unique singleton zones.
    zones: Arc<Vec<u32>>,
}

/// Builder for [`RouterHandle`] — the single construction path that
/// replaced the `new` / `with_signal` / `with_signal_capacity`
/// constructor sprawl. Every knob is optional:
///
/// * [`signal`](Self::signal) — the [`SignalConfig`] the load view
///   carries (default: the legacy unsmoothed signal, bit-compatible
///   with the raw-load era);
/// * [`capacity`](Self::capacity) — pre-allocated load-signal slots,
///   the elastic id ceiling ([`RouterHandle::add_node`] refuses to grow
///   past it; default: the router's current node count);
/// * [`zones`](Self::zones) — the failure-domain map, pushed into the
///   router ([`Router::set_zones`]) before the first publish and kept
///   on the handle for the runtime's cross-zone checkpoint preference.
///
/// ```
/// use dpa::hash::{Ring, RingOp, RouterHandle, TokenRingRouter};
///
/// let handle = RouterHandle::builder(Box::new(TokenRingRouter::new(
///     Ring::new(4, 8),
///     RingOp::Halve,
/// )))
/// .capacity(8)
/// .build();
/// assert_eq!(handle.nodes(), 4);
/// assert_eq!(handle.capacity(), 8);
/// ```
pub struct RouterBuilder {
    router: Box<dyn Router>,
    signal: SignalConfig,
    capacity: usize,
    zones: Vec<u32>,
}

impl RouterBuilder {
    /// Use `signal` (EWMA decay, hysteresis band, migration-gain guard)
    /// for the handle's load view instead of the legacy default.
    pub fn signal(mut self, cfg: &SignalConfig) -> Self {
        self.signal = cfg.clone();
        self
    }

    /// Pre-allocate load-signal slots for up to `n` nodes — the elastic
    /// ceiling (`balancer.max_reducers` plus chaos respawn headroom).
    /// Clamped up to the router's current node count.
    pub fn capacity(mut self, n: usize) -> Self {
        self.capacity = n;
        self
    }

    /// Install a failure-domain map (node id → zone index, e.g. from
    /// [`parse_zone_spec`](super::ptable::parse_zone_spec)). Zone-aware
    /// routers rebuild their replica placement; the runtime's
    /// checkpoint-to-peer path prefers a cross-zone peer.
    pub fn zones(mut self, zone_of: Vec<u32>) -> Self {
        self.zones = zone_of;
        self
    }

    /// Construct the handle: zones reach the router before the first
    /// publish, so no reader ever observes a zone-less placement.
    pub fn build(self) -> RouterHandle {
        let RouterBuilder { mut router, signal, capacity, zones } = self;
        if !zones.is_empty() {
            router.set_zones(&zones);
        }
        let epoch = router.epoch();
        let nodes = router.nodes();
        let loads = Loads::with_capacity(nodes, capacity.max(nodes), &signal);
        let published: Arc<dyn Router> = Arc::from(router.clone_router());
        RouterHandle {
            writer: Arc::new(Mutex::new(router)),
            published: Arc::new(RwLock::new(published)),
            epoch: Arc::new(AtomicU64::new(epoch)),
            loads,
            zones: Arc::new(zones),
        }
    }
}

impl RouterHandle {
    /// Start building a handle over `router` — see [`RouterBuilder`].
    pub fn builder(router: Box<dyn Router>) -> RouterBuilder {
        RouterBuilder {
            router,
            signal: SignalConfig::legacy(),
            capacity: 0,
            zones: Vec::new(),
        }
    }

    /// Thin alias for `RouterHandle::builder(router).build()`, kept for
    /// the many call sites that want the all-defaults handle.
    ///
    /// **Deprecated in spirit:** new code should use
    /// [`RouterHandle::builder`], which is the only path offering the
    /// signal/capacity/zones knobs. (Not `#[deprecated]` — the bare
    /// form is still the idiomatic spelling in tests.)
    pub fn new(router: Box<dyn Router>) -> Self {
        Self::builder(router).build()
    }

    /// The last published router snapshot (shared, immutable-by-readers).
    /// Hot paths clone this `Arc` once per epoch via [`RouterCache`].
    pub fn published_router(&self) -> Arc<dyn Router> {
        self.published.read().unwrap().clone()
    }

    /// Swap in a fresh snapshot of the writer copy, then bump the
    /// published epoch. Snapshot first, epoch second: a reader that sees
    /// the new epoch is guaranteed to find the new snapshot.
    fn publish(&self, w: &dyn Router) {
        let fresh: Arc<dyn Router> = Arc::from(w.clone_router());
        *self.published.write().unwrap() = fresh;
        self.epoch.store(w.epoch(), Ordering::Release);
    }

    /// Convenience: a token-ring router over `ring` applying `op`.
    pub fn token_ring(ring: Ring, op: RingOp) -> Self {
        Self::new(Box::new(TokenRingRouter::new(ring, op)))
    }

    /// The published router's family name.
    pub fn name(&self) -> &'static str {
        self.published_router().name()
    }

    /// Total id space of the published router.
    pub fn nodes(&self) -> usize {
        self.published_router().nodes()
    }

    /// The published router's merge contract — captured by the execution
    /// core at build time to decide whether the §7 disjoint-merge
    /// assertion is armed for the run.
    pub fn merge_contract(&self) -> MergeContract {
        self.published_router().merge_contract()
    }

    /// Published epoch without taking the lock.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// The live load view routers consult (the balancer writes it).
    pub fn loads(&self) -> &Loads {
        &self.loads
    }

    /// Route a raw key hash through the published snapshot (hot paths
    /// amortize the snapshot grab via [`RouterCache`]).
    pub fn route_hash(&self, h: u32) -> usize {
        self.published_router().route(h, &self.loads)
    }

    /// Route a key's bytes.
    pub fn route_key(&self, key: &[u8]) -> usize {
        self.route_hash(murmur3_x86_32(key))
    }

    /// Family-tagged routing state of the published router.
    pub fn snapshot(&self) -> RouteSnapshot {
        self.published_router().snapshot(&self.loads)
    }

    /// Write back first-sight assignments computed by the compiled batch
    /// route path (no-op for routers without a sticky table). Goes
    /// through the published snapshot — sticky tables are shared across
    /// clones, so the writer copy sees the same entries.
    pub fn record_assignments(&self, assignments: &[(u32, u32)]) {
        self.published_router().record_assignments(assignments);
    }

    /// Apply the router's redistribution for an overloaded node and
    /// publish the new epoch. All rewrite work happens on the writer
    /// copy; readers only ever see the O(1) publish at the end.
    pub fn redistribute(&self, target: usize) -> RouteDelta {
        let mut g = self.writer.lock().unwrap();
        let delta = g.redistribute(target, &self.loads);
        self.publish(&**g);
        delta
    }

    /// Elastic scale-up: grow the routable set by one brand-new node and
    /// publish the new epoch. Returns the node's id and the membership
    /// delta, or `None` when the pre-allocated slot capacity (see
    /// [`RouterBuilder::capacity`]) is exhausted. The new node joins
    /// the load signal with a clean history.
    pub fn add_node(&self) -> Option<(usize, RouteDelta)> {
        let mut g = self.writer.lock().unwrap();
        let id = g.nodes();
        if id >= self.loads.nodes() {
            return None; // out of pre-allocated slots; nothing published
        }
        let delta = g.add_node(id);
        self.loads.activate(id);
        self.publish(&**g);
        Some((id, delta))
    }

    /// Elastic scale-down: remove `id` from the routable set and publish
    /// the new epoch (every [`RouterCache`] drops its memo on the bump, so
    /// a cached `hash → retired id` entry is never served again). The
    /// node also leaves the load signal's mean/flag computation. No-op
    /// delta when `id` is already retired or is the last live node.
    pub fn retire_node(&self, id: usize) -> RouteDelta {
        let mut g = self.writer.lock().unwrap();
        let delta = g.retire_node(id, &self.loads);
        if delta.changed {
            self.loads.retire(id);
        }
        self.publish(&**g);
        delta
    }

    /// Is `id` currently routable?
    pub fn is_live(&self, id: usize) -> bool {
        self.published_router().is_live(id)
    }

    /// Number of currently routable nodes (`<= nodes()`).
    pub fn live_count(&self) -> usize {
        self.published_router().live_count()
    }

    /// Ascending ids of the currently routable nodes.
    pub fn live_nodes(&self) -> Vec<usize> {
        let g = self.published_router();
        (0..g.nodes()).filter(|&n| g.is_live(n)).collect()
    }

    /// Pre-allocated id-space ceiling (the load signal's slot count).
    pub fn capacity(&self) -> usize {
        self.loads.nodes()
    }

    /// The failure-domain map installed via [`RouterBuilder::zones`]
    /// (empty when no zones were configured).
    pub fn zones(&self) -> &[u32] {
        &self.zones
    }

    /// Failure domain of node `id`, resolved through
    /// [`effective_zone`](super::ptable::effective_zone): nodes outside
    /// the configured map get unique singleton zones, so "different
    /// zone" checks degrade to "different node" without a special case.
    pub fn zone_of(&self, id: usize) -> u32 {
        super::ptable::effective_zone(&self.zones, id)
    }

    /// Mutate the underlying token ring directly (elastic scale-out, test
    /// surgery). `None` when the router is not ring-based.
    pub fn update_ring<R>(&self, f: impl FnOnce(&mut Ring) -> R) -> Option<R> {
        let mut g = self.writer.lock().unwrap();
        let out = g.as_token_ring_mut().map(f);
        self.publish(&**g);
        out
    }

    /// Read the underlying token ring. `None` when not ring-based.
    pub fn with_ring<R>(&self, f: impl FnOnce(&Ring) -> R) -> Option<R> {
        let g = self.published_router();
        g.as_token_ring().map(f)
    }

    /// Clone the current router state for a local cache.
    pub fn clone_router(&self) -> Box<dyn Router> {
        self.published_router().clone_router()
    }

    /// A per-actor epoch-validated cache over this handle.
    pub fn cache(&self) -> RouterCache {
        RouterCache::new(self.clone())
    }
}

/// Epoch-validated local router snapshot — the trait-layer successor of
/// `RingCache`. Routing hot paths (mappers route every record; reducers
/// check ownership on every dequeue) re-grab the published `Arc` only
/// when the epoch atomic moves; between LB events lookups run on the
/// local snapshot, and the staleness check itself is a single relaxed
/// atomic load (amortized to once per batch on the batched path). For
/// routers whose table is shared across clones (sticky assignment
/// tables), the cache additionally memoizes `(hash → owner)` for the
/// current epoch — routing is a pure function of `(hash, epoch)`, so
/// repeat lookups of hot keys skip even the lock-free table probe.
pub struct RouterCache {
    handle: RouterHandle,
    local: Arc<dyn Router>,
    epoch: u64,
    memo: std::collections::HashMap<u32, usize>,
    memoize: bool,
}

impl RouterCache {
    /// A cache over `handle`, initialized at its current epoch.
    pub fn new(handle: RouterHandle) -> Self {
        let local = handle.published_router();
        let epoch = handle.epoch();
        let memoize = local.route_is_shared();
        RouterCache {
            handle,
            local,
            epoch,
            memo: std::collections::HashMap::new(),
            memoize,
        }
    }

    #[inline]
    fn refresh(&mut self) {
        let e = self.handle.epoch();
        if e != self.epoch {
            self.local = self.handle.published_router();
            self.memoize = self.local.route_is_shared();
            self.memo.clear();
            self.epoch = e;
        }
    }

    /// Route against the already-refreshed local snapshot.
    #[inline]
    fn route_local(&mut self, h: u32) -> usize {
        if self.memoize {
            if let Some(&n) = self.memo.get(&h) {
                return n;
            }
            let n = self.local.route(h, self.handle.loads());
            self.memo.insert(h, n);
            n
        } else {
            self.local.route(h, self.handle.loads())
        }
    }

    /// Route a raw key hash through the epoch-validated local snapshot.
    #[inline]
    pub fn route_hash(&mut self, h: u32) -> usize {
        self.refresh();
        self.route_local(h)
    }

    /// May records of `h` legitimately be reduced on `id` at the current
    /// epoch? The reducers' dequeue-time ownership check: single-homed
    /// families answer `route(h) == id`; a split key answers `true` for
    /// every live candidate, so shards are reduced where they land
    /// instead of ping-ponging between candidates. Deliberately NOT
    /// memoized — the memo stores one owner per hash, which is exactly
    /// the single-homing assumption split keys break.
    #[inline]
    pub fn may_own_hash(&mut self, h: u32, id: usize) -> bool {
        self.refresh();
        self.local.is_owner(h, id, self.handle.loads())
    }

    /// Route a whole slice of hashes with ONE epoch staleness check —
    /// the batched mapper path. Destinations are appended to `dests`
    /// (cleared first) in input order.
    pub fn route_batch(&mut self, hashes: &[u32], dests: &mut Vec<usize>) {
        self.refresh();
        dests.clear();
        dests.reserve(hashes.len());
        for &h in hashes {
            let n = self.route_local(h);
            dests.push(n);
        }
    }

    /// Route a key's bytes (hashes, then [`Self::route_hash`]).
    #[inline]
    pub fn route_key(&mut self, key: &[u8]) -> usize {
        self.route_hash(murmur3_x86_32(key))
    }

    /// Refreshed snapshot (e.g. to feed the XLA route programs).
    pub fn snapshot(&mut self) -> RouteSnapshot {
        self.refresh();
        self.local.snapshot(self.handle.loads())
    }

    /// The shared handle this cache reads through.
    pub fn handle(&self) -> &RouterHandle {
        &self.handle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("key-{i}")).collect()
    }

    #[test]
    fn assign_table_insert_get_rewrite() {
        let t = AssignTable::new();
        assert_eq!(t.get(42), None);
        assert_eq!(t.insert_or_get(42, 3), 3);
        assert_eq!(t.get(42), Some(3));
        // first writer wins: a second insert for the same hash is a no-op
        assert_eq!(t.insert_or_get(42, 7), 3);
        assert_eq!(t.get(42), Some(3));
        // hash 0 is a valid key (emptiness is encoded in the owner half)
        assert_eq!(t.insert_or_get(0, 1), 1);
        assert_eq!(t.get(0), Some(1));
        t.rewrite(42, 9);
        assert_eq!(t.get(42), Some(9));
        t.rewrite(999, 5); // absent key: rewrite is a no-op, not an insert
        assert_eq!(t.get(999), None);
        let mut es = t.entries();
        es.sort_unstable();
        assert_eq!(es, vec![(0, 1), (42, 9)]);
    }

    #[test]
    fn assign_table_chains_segments_past_first_capacity() {
        // far more distinct hashes than FIRST_SEGMENT_SLOTS: forces probe
        // windows to fill and the table to descend into chained segments
        let t = AssignTable::new();
        let n = 100_000u32;
        for h in 0..n {
            assert_eq!(t.insert_or_get(h, h % 7), h % 7);
        }
        for h in 0..n {
            assert_eq!(t.get(h), Some(h % 7), "hash {h}");
        }
        assert_eq!(t.entries().len(), n as usize);
    }

    #[test]
    fn router_cache_route_batch_matches_scalar() {
        let handle = RouterHandle::new(Box::new(TwoChoicesRouter::new(4)));
        let hashes: Vec<u32> =
            keys(300).iter().map(|k| murmur3_x86_32(k.as_bytes())).collect();
        let mut scalar = handle.cache();
        let expect: Vec<usize> = hashes.iter().map(|&h| scalar.route_hash(h)).collect();
        let mut batched = handle.cache();
        let mut dests = Vec::new();
        batched.route_batch(&hashes, &mut dests);
        assert_eq!(dests, expect);
        // batch across an epoch bump still matches the scalar path
        handle.redistribute(expect[0]);
        let expect2: Vec<usize> = hashes.iter().map(|&h| scalar.route_hash(h)).collect();
        batched.route_batch(&hashes, &mut dests);
        assert_eq!(dests, expect2);
    }

    #[test]
    fn token_ring_router_routes_identically_to_raw_ring() {
        let ring = Ring::new(4, 8);
        let router = TokenRingRouter::new(ring.clone(), RingOp::Halve);
        let loads = Loads::new(4);
        for k in keys(500) {
            let h = murmur3_x86_32(k.as_bytes());
            assert_eq!(router.route(h, &loads), ring.lookup_hash(h), "key {k}");
        }
    }

    #[test]
    fn token_ring_redistribute_matches_ring_ops() {
        let loads = Loads::new(4);
        let mut halver = TokenRingRouter::new(Ring::new(4, 8), RingOp::Halve);
        let d = halver.redistribute(1, &loads);
        assert!(d.changed);
        assert_eq!(d.tokens_removed, 4);
        assert_eq!(d.tokens_added, 0);
        assert_eq!(halver.as_token_ring().unwrap().tokens_of(1), 4);

        let mut doubler = TokenRingRouter::new(Ring::new(4, 1), RingOp::DoubleOthers);
        let d = doubler.redistribute(0, &loads);
        assert!(d.changed);
        assert_eq!(d.tokens_added, 3);
        assert!(d.tokens_removed == 0);

        let mut noop = TokenRingRouter::new(Ring::new(4, 8), RingOp::NoOp);
        assert!(!noop.redistribute(0, &loads).changed);
    }

    #[test]
    fn token_ring_halving_exhaustion_reports_unchanged() {
        let loads = Loads::new(2);
        let mut r = TokenRingRouter::new(Ring::new(2, 1), RingOp::Halve);
        assert!(!r.redistribute(0, &loads).changed);
    }

    #[test]
    fn multi_probe_routes_every_key_to_live_node_and_spreads() {
        let router = MultiProbeRouter::new(4, 5);
        let loads = Loads::new(4);
        let mut counts = [0usize; 4];
        for k in keys(4000) {
            let n = router.route(murmur3_x86_32(k.as_bytes()), &loads);
            assert!(n < 4);
            counts[n] += 1;
        }
        for c in counts {
            assert!(c > 400, "multi-probe badly skewed: {counts:?}");
        }
    }

    #[test]
    fn multi_probe_redistribute_is_zero_token_churn_and_shifts_load() {
        let mut router = MultiProbeRouter::new(4, 5);
        let loads = Loads::new(4);
        let ks = keys(2000);
        let before: Vec<usize> = ks
            .iter()
            .map(|k| router.route(murmur3_x86_32(k.as_bytes()), &loads))
            .collect();
        // find the busiest node under uniform weights and overload it
        let mut counts = [0usize; 4];
        for &n in &before {
            counts[n] += 1;
        }
        let hot = (0..4).max_by_key(|&n| counts[n]).unwrap();
        for n in 0..4 {
            loads.set(n, if n == hot { 100 } else { 1 });
        }
        let e0 = router.epoch();
        let d = router.redistribute(hot, &loads);
        assert!(d.changed);
        assert!(d.zero_token_churn());
        assert_eq!(d.keys_reassigned, 0);
        assert!(router.epoch() > e0);
        // load shifted away from the hot node at probe time
        let mut lost = 0usize;
        let mut gained_elsewhere = 0usize;
        for (k, &b) in ks.iter().zip(&before) {
            let now = router.route(murmur3_x86_32(k.as_bytes()), &loads);
            if b == hot && now != hot {
                lost += 1;
            }
            if b != hot && now == hot {
                gained_elsewhere += 1;
            }
        }
        assert!(lost > 0, "no key left the overloaded node");
        assert_eq!(gained_elsewhere, 0, "keys moved ONTO the overloaded node");
    }

    #[test]
    fn multi_probe_distinct_loads_do_not_herd_onto_coldest() {
        // regression: ordering candidates by raw frozen load would send
        // every key with a probe reaching the least-loaded node there,
        // starving the mid-loaded nodes; the overload-flag design must
        // shed only the above-mean node and keep the distance spread
        let mut router = MultiProbeRouter::new(4, 5);
        let loads = Loads::new(4);
        let ks = keys(4000);
        let mut before = [0usize; 4];
        for k in &ks {
            before[router.route(murmur3_x86_32(k.as_bytes()), &loads)] += 1;
        }
        for (n, l) in [(0, 40u64), (1, 7), (2, 6), (3, 5)] {
            loads.set(n, l);
        }
        let d = router.redistribute(0, &loads);
        assert!(d.changed);
        assert!(d.zero_token_churn());
        let mut after = [0usize; 4];
        for k in &ks {
            after[router.route(murmur3_x86_32(k.as_bytes()), &loads)] += 1;
        }
        assert!(
            after[0] < before[0] / 2,
            "overloaded node did not shed: {before:?} -> {after:?}"
        );
        for n in 1..4 {
            assert!(
                after[n] >= before[n],
                "non-overloaded node {n} lost keys: {before:?} -> {after:?}"
            );
            assert!(
                after[n] > 300,
                "node {n} starved — keyspace herded by load ordering: {after:?}"
            );
        }
    }

    #[test]
    fn multi_probe_routing_is_stable_within_an_epoch() {
        let router = MultiProbeRouter::new(6, 3);
        let loads = Loads::new(6);
        for k in keys(200) {
            let h = murmur3_x86_32(k.as_bytes());
            let a = router.route(h, &loads);
            // live loads changing must NOT change routing between epochs
            loads.set(a, 999);
            assert_eq!(router.route(h, &loads), a);
            loads.set(a, 0);
        }
    }

    #[test]
    fn two_choices_is_sticky_and_balances() {
        let router = TwoChoicesRouter::new(4);
        let loads = Loads::new(4);
        for k in keys(1000) {
            let h = murmur3_x86_32(k.as_bytes());
            let first = router.route(h, &loads);
            // loads swing wildly; the recorded choice must hold
            loads.set(first, 10_000);
            assert_eq!(router.route(h, &loads), first, "assignment not sticky");
            loads.set(first, 0);
        }
        let total: usize = (0..4).map(|n| router.assigned_to(n)).sum();
        assert!(total <= 1000, "at most one assignment per distinct hash");
        for n in 0..4 {
            assert!(router.assigned_to(n) > 0, "node {n} starved");
        }
    }

    #[test]
    fn two_choices_prefers_less_loaded_candidate() {
        let router = TwoChoicesRouter::new(2);
        let loads = Loads::new(2);
        loads.set(0, 50);
        loads.set(1, 0);
        // any key whose candidates differ must land on node 1
        let mut differing = 0;
        for k in keys(200) {
            let h = murmur3_x86_32(k.as_bytes());
            let (c1, c2) = router.candidates(h);
            if c1 != c2 {
                differing += 1;
                assert_eq!(router.route(h, &loads), 1);
            }
        }
        assert!(differing > 50, "hash functions collapsed");
    }

    #[test]
    fn two_choices_redistribute_rehomes_about_half() {
        let router_master = TwoChoicesRouter::new(4);
        let loads = Loads::new(4);
        let ks = keys(800);
        for k in &ks {
            router_master.route(murmur3_x86_32(k.as_bytes()), &loads);
        }
        let target = (0..4).max_by_key(|&n| router_master.assigned_to(n)).unwrap();
        let before = router_master.assigned_to(target);
        let mut router = router_master.clone();
        let d = router.redistribute(target, &loads);
        assert!(d.changed);
        assert!(d.zero_token_churn());
        assert!(d.keys_reassigned > 0);
        let after = router_master.assigned_to(target); // shared table
        assert_eq!(before - after, d.keys_reassigned as usize);
        assert!(after < before, "target not relieved");
        assert!(
            after >= before / 2 - before / 8,
            "moved far more than ~half: {before} -> {after}"
        );
    }

    #[test]
    fn two_choices_clones_share_assignments() {
        let a = TwoChoicesRouter::new(4);
        let b = a.clone_router();
        let loads = Loads::new(4);
        let h = murmur3_x86_32(b"shared-key");
        let owner = a.route(h, &loads);
        loads.set(owner, 10_000);
        assert_eq!(b.route(h, &loads), owner, "clone ignored the shared table");
    }

    #[test]
    fn two_choices_cache_memo_tracks_epochs() {
        let handle = RouterHandle::new(Box::new(TwoChoicesRouter::new(4)));
        let mut cache = handle.cache();
        let ks = keys(100);
        let before: Vec<usize> = ks.iter().map(|k| cache.route_key(k.as_bytes())).collect();
        // memo hit path returns the recorded owners
        for (k, &b) in ks.iter().zip(&before) {
            assert_eq!(cache.route_key(k.as_bytes()), b);
        }
        let target = before[0];
        let d = handle.redistribute(target);
        assert!(d.changed);
        // epoch bump clears the memo: the cache agrees with the shared table
        for k in &ks {
            assert_eq!(
                cache.route_key(k.as_bytes()),
                handle.route_key(k.as_bytes()),
                "stale memo after redistribute"
            );
        }
    }

    #[test]
    fn handle_publishes_epoch_and_caches_refresh() {
        let handle = RouterHandle::token_ring(Ring::new(4, 8), RingOp::Halve);
        let mut cache = handle.cache();
        let key = b"hello";
        assert_eq!(cache.route_key(key), handle.route_key(key));
        let owner = handle.route_key(key);
        let e0 = handle.epoch();
        let d = handle.redistribute(owner);
        assert!(d.changed);
        assert!(handle.epoch() > e0);
        assert_eq!(cache.route_key(key), handle.route_key(key), "cache refreshed");
    }

    #[test]
    fn handle_ring_escape_hatch() {
        let handle = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        assert_eq!(handle.with_ring(|r| r.total_tokens()), Some(32));
        let e0 = handle.epoch();
        let new = handle.update_ring(|r| r.add_node(8)).unwrap();
        assert_eq!(new, 4);
        assert_eq!(handle.nodes(), 5);
        assert!(handle.epoch() > e0, "ring surgery published a new epoch");

        let probing = RouterHandle::new(Box::new(MultiProbeRouter::new(4, 3)));
        assert!(probing.with_ring(|r| r.total_tokens()).is_none());
        assert!(probing.update_ring(|r| r.add_node(1)).is_none());
    }

    #[test]
    fn snapshots_expose_family_specific_state() {
        let ring = RouterHandle::token_ring(Ring::new(3, 2), RingOp::NoOp);
        let snap = ring.snapshot();
        assert_eq!(snap.router, "token-ring");
        assert_eq!(snap.tokens().map(<[Token]>::len), Some(6));
        assert!(snap.assignments().is_none());

        let mp = RouterHandle::new(Box::new(MultiProbeRouter::new(3, 7)));
        let snap = mp.snapshot();
        assert_eq!(snap.router, "multi-probe");
        assert!(snap.tokens().is_none());
        assert_eq!(snap.weights().map(<[u64]>::len), Some(3));
        match &snap.state {
            SnapshotState::Probe { position_hashes, position_nodes, probes, overloaded, .. } => {
                assert_eq!(position_hashes.len(), 3);
                assert_eq!(position_nodes.len(), 3);
                assert!(position_hashes.windows(2).all(|w| w[0] <= w[1]), "sorted");
                assert_eq!(*probes, 7);
                assert_eq!(overloaded.len(), 3);
            }
            other => panic!("expected Probe state, got {other:?}"),
        }

        let tc = RouterHandle::new(Box::new(TwoChoicesRouter::new(3)));
        tc.route_key(b"k");
        tc.loads().set(1, 42);
        let snap = tc.snapshot();
        assert_eq!(snap.router, "two-choices");
        assert_eq!(snap.assignments().map(<[(u32, u32)]>::len), Some(1));
        match &snap.state {
            SnapshotState::Assignment { loads, .. } => {
                // frozen values are the decayed signal in fixed point
                // (legacy config: exactly raw << FRAC_BITS)
                let fp = 1u64 << crate::balancer::signal::FRAC_BITS;
                assert_eq!(loads, &vec![0, 42 * fp, 0], "decayed loads frozen");
            }
            other => panic!("expected Assignment state, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_route_matches_scalar_router_every_family() {
        // the host-side fallback lane of the compiled route programs must
        // agree with Router::route at the snapshot's epoch
        let loads = Loads::new(5);
        let mut routers: Vec<Box<dyn Router>> = vec![
            Box::new(TokenRingRouter::new(Ring::new(5, 4), RingOp::Halve)),
            Box::new(MultiProbeRouter::new(5, 3)),
            Box::new(TwoChoicesRouter::new(5)),
            Box::new(SplitKeyRouter::new(5, 3)),
        ];
        for r in routers.iter_mut() {
            // include a post-redistribute epoch
            for n in 0..5 {
                loads.set(n, if n == 2 { 90 } else { 3 });
            }
            r.redistribute(2, &loads);
            // warm the sticky table for some keys, leave others cold
            for k in keys(40) {
                r.route(murmur3_x86_32(k.as_bytes()), &loads);
            }
            let snap = r.snapshot(&loads);
            for k in keys(300) {
                let h = murmur3_x86_32(k.as_bytes());
                assert_eq!(snap.route(h), r.route(h, &loads), "{} key {k}", r.name());
            }
        }
    }

    #[test]
    fn two_choices_record_assignments_first_writer_wins() {
        let router = TwoChoicesRouter::new(4);
        let loads = Loads::new(4);
        let h_new = murmur3_x86_32(b"cold-key");
        let h_seen = murmur3_x86_32(b"warm-key");
        let seen_owner = router.route(h_seen, &loads) as u32;
        let (c1, _) = router.candidates(h_new);
        router.record_assignments(&[(h_new, c1 as u32), (h_seen, seen_owner + 1)]);
        // the cold key's write-back sticks; the warm key keeps its owner
        assert_eq!(router.route(h_new, &loads), c1);
        assert_eq!(router.route(h_seen, &loads) as u32, seen_owner);
    }

    #[test]
    fn multi_probe_redistribute_freezes_hysteresis_flags() {
        // same observation sequence against both signal configs: one hot
        // node flags and freezes, then mild drift around the mean — the
        // banded signal keeps the shed set (no-op, no epoch burn) while
        // the legacy above-mean signal churns it
        let drive = |loads: &Loads, r: &mut MultiProbeRouter| {
            for n in 0..4 {
                loads.set(n, 10); // warm-up: uniform, all-clear flags
            }
            loads.set(0, 28); // node 0 goes hot → flagged either way
            assert!(r.redistribute(0, loads).changed, "hot flag freezes");
            let epoch = r.epoch();
            // mild drift around the mean, hot node still clearly hot
            loads.set(0, 12);
            loads.set(2, 14);
            (epoch, r.redistribute(0, loads).changed)
        };

        let banded = SignalConfig { decay_alpha: 1.0, hysteresis: 0.5, min_gain: 0.0 };
        let loads = Loads::with_config(4, &banded);
        let mut r = MultiProbeRouter::new(4, 3);
        let (epoch, changed) = drive(&loads, &mut r);
        assert!(!changed, "drift inside the band must not re-freeze");
        assert_eq!(r.epoch(), epoch, "no-op keeps the epoch");

        let raw = Loads::new(4);
        let mut legacy = MultiProbeRouter::new(4, 3);
        let (epoch, changed) = drive(&raw, &mut legacy);
        assert!(changed, "legacy above-mean flags churn on the same drift");
        assert!(legacy.epoch() > epoch);
    }

    #[test]
    fn two_choices_min_gain_guard_blocks_lateral_rehomes() {
        let cfg = SignalConfig { decay_alpha: 1.0, hysteresis: 0.0, min_gain: 0.5 };
        let loads = Loads::with_config(4, &cfg);
        let router = TwoChoicesRouter::new(4);
        for k in keys(400) {
            router.route(murmur3_x86_32(k.as_bytes()), &loads);
        }
        let target = (0..4).max_by_key(|&n| router.assigned_to(n)).unwrap();
        // the target is hot but every alternate is nearly as hot: moving
        // a key would merely trade places, so the guard rejects it all
        for n in 0..4 {
            loads.set(n, if n == target { 100 } else { 80 });
        }
        let mut r = router.clone();
        assert!(
            !r.redistribute(target, &loads).changed,
            "gain guard must reject lateral moves"
        );
        // a genuinely cold alternate clears the guard
        for n in 0..4 {
            loads.set(n, if n == target { 100 } else { 10 });
        }
        let d = r.redistribute(target, &loads);
        assert!(d.changed);
        assert!(d.keys_reassigned > 0);
    }

    #[test]
    fn token_ring_membership_minimal_movement() {
        let loads = Loads::new(4);
        let mut r = TokenRingRouter::new(Ring::new(4, 8), RingOp::Halve);
        let ks = keys(1500);
        let before: Vec<usize> =
            ks.iter().map(|k| r.route(murmur3_x86_32(k.as_bytes()), &loads)).collect();
        let d = r.add_node(4);
        assert!(d.changed);
        assert_eq!((d.nodes_added, d.tokens_added), (1, 8));
        assert!(r.is_live(4));
        assert_eq!(r.live_count(), 5);
        for (k, &b) in ks.iter().zip(&before) {
            let now = r.route(murmur3_x86_32(k.as_bytes()), &loads);
            if now != b {
                assert_eq!(now, 4, "key {k} moved between surviving nodes on join");
            }
        }
        let mid: Vec<usize> =
            ks.iter().map(|k| r.route(murmur3_x86_32(k.as_bytes()), &loads)).collect();
        let d = r.retire_node(4, &loads);
        assert!(d.changed);
        assert_eq!((d.nodes_retired, d.tokens_removed), (1, 8));
        assert!(!r.is_live(4));
        assert_eq!(r.nodes(), 5, "the id stays allocated");
        for (k, &b) in ks.iter().zip(&mid) {
            let now = r.route(murmur3_x86_32(k.as_bytes()), &loads);
            assert_ne!(now, 4, "key {k} still routed to the retired node");
            if b != 4 {
                assert_eq!(now, b, "key {k} moved between survivors on retire");
            }
        }
        assert!(!r.retire_node(4, &loads).changed, "double retire is a no-op");
    }

    #[test]
    fn multi_probe_membership_minimal_movement() {
        let loads = Loads::new(4);
        let mut r = MultiProbeRouter::new(4, 3);
        let ks = keys(1500);
        let before: Vec<usize> =
            ks.iter().map(|k| r.route(murmur3_x86_32(k.as_bytes()), &loads)).collect();
        let d = r.add_node(4);
        assert!(d.changed && d.zero_token_churn());
        assert_eq!(d.nodes_added, 1);
        assert!(r.is_live(4));
        assert_eq!(r.live_count(), 5);
        let mut claimed = 0;
        for (k, &b) in ks.iter().zip(&before) {
            let now = r.route(murmur3_x86_32(k.as_bytes()), &loads);
            if now != b {
                assert_eq!(now, 4, "key {k} moved between surviving nodes on join");
                claimed += 1;
            }
        }
        assert!(claimed > 0, "the joiner claimed nothing");
        let mid: Vec<usize> =
            ks.iter().map(|k| r.route(murmur3_x86_32(k.as_bytes()), &loads)).collect();
        let d = r.retire_node(1, &loads);
        assert!(d.changed);
        assert_eq!(d.nodes_retired, 1);
        assert!(!r.is_live(1));
        for (k, &b) in ks.iter().zip(&mid) {
            let now = r.route(murmur3_x86_32(k.as_bytes()), &loads);
            assert_ne!(now, 1, "key {k} still routed to the retired node");
            if b != 1 {
                assert_eq!(now, b, "key {k} moved between survivors on retire");
            }
        }
    }

    #[test]
    fn two_choices_membership_sticky_and_orphan_rewrite() {
        let loads = Loads::new(4);
        let mut r = TwoChoicesRouter::new(4);
        let ks = keys(600);
        let before: Vec<usize> =
            ks.iter().map(|k| r.route(murmur3_x86_32(k.as_bytes()), &loads)).collect();
        // join: sticky assignments hold — NO seen key moves at all
        let d = r.add_node(4);
        assert!(d.changed && d.zero_token_churn());
        assert_eq!(d.keys_reassigned, 0);
        for (k, &b) in ks.iter().zip(&before) {
            assert_eq!(
                r.route(murmur3_x86_32(k.as_bytes()), &loads),
                b,
                "sticky key {k} moved on join"
            );
        }
        // unseen keys can first-sight onto the joiner
        let fresh: Vec<String> = (0..800).map(|i| format!("fresh-{i}")).collect();
        let landed = fresh
            .iter()
            .filter(|k| r.route(murmur3_x86_32(k.as_bytes()), &loads) == 4)
            .count();
        assert!(landed > 0, "the joiner never appears among fresh candidates");
        // retire: only the retired owner's keys are rewritten
        let victim = 2usize;
        let owned = r.assigned_to(victim);
        assert!(owned > 0);
        let mid: Vec<(String, usize)> = ks
            .iter()
            .chain(fresh.iter())
            .map(|k| (k.clone(), r.route(murmur3_x86_32(k.as_bytes()), &loads)))
            .collect();
        let d = r.retire_node(victim, &loads);
        assert!(d.changed);
        assert_eq!(d.nodes_retired, 1);
        assert_eq!(d.keys_reassigned as usize, owned, "rewrite restricted to the victim");
        assert_eq!(r.assigned_to(victim), 0);
        for (k, b) in &mid {
            let now = r.route(murmur3_x86_32(k.as_bytes()), &loads);
            assert_ne!(now, victim, "key {k} still pinned to the retired node");
            if *b != victim {
                assert_eq!(now, *b, "key {k} moved although its owner survived");
            }
        }
    }

    #[test]
    fn router_cache_never_serves_a_retired_owner() {
        // regression (elastic membership): the cache memoizes
        // (hash → owner) per epoch for shared-table routers; a membership
        // change MUST invalidate it — a memoized entry for a retired node
        // being served would strand records on a dead reducer
        let handle = RouterHandle::new(Box::new(TwoChoicesRouter::new(4)));
        let mut cache = handle.cache();
        let ks = keys(300);
        // warm the memo through the cache
        let before: Vec<usize> = ks.iter().map(|k| cache.route_key(k.as_bytes())).collect();
        let victim = before[0];
        let d = handle.retire_node(victim);
        assert!(d.changed);
        for (k, &b) in ks.iter().zip(&before) {
            let now = cache.route_key(k.as_bytes());
            assert_ne!(now, victim, "cache served the retired owner for {k}");
            assert_eq!(now, handle.route_key(k.as_bytes()), "cache != shared table");
            if b != victim {
                assert_eq!(now, b, "{k} moved although its owner survived");
            }
        }
        // and the same through a token-ring cache (epoch comes from the ring)
        let handle = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let mut cache = handle.cache();
        let owner = cache.route_key(b"some-key");
        assert!(handle.retire_node(owner).changed);
        assert_ne!(cache.route_key(b"some-key"), owner, "stale ring snapshot served");
    }

    #[test]
    fn handle_add_node_respects_capacity_and_signal() {
        let cfg = SignalConfig::legacy();
        let handle = RouterHandle::builder(Box::new(MultiProbeRouter::new(2, 3)))
            .signal(&cfg)
            .capacity(3)
            .build();
        assert_eq!(handle.capacity(), 3);
        let e0 = handle.epoch();
        let (id, d) = handle.add_node().expect("one slot free");
        assert_eq!(id, 2);
        assert!(d.changed);
        assert!(handle.epoch() > e0);
        assert_eq!(handle.live_nodes(), vec![0, 1, 2]);
        assert!(handle.add_node().is_none(), "capacity exhausted");
        // the joiner participates in the load signal
        handle.loads().set(2, 9);
        assert_eq!(handle.loads().get(2), 9);
        // retire publishes and removes it from the signal's live set
        let d = handle.retire_node(2);
        assert!(d.changed);
        assert_eq!(handle.live_nodes(), vec![0, 1]);
        assert!(!handle.loads().is_live(2));
    }

    #[test]
    fn two_choices_record_assignments_skips_retired_owners() {
        let router = TwoChoicesRouter::new(4);
        let loads = Loads::new(4);
        let mut r = router.clone();
        r.retire_node(3, &loads);
        let h = murmur3_x86_32(b"late-write-back");
        router.record_assignments(&[(h, 3)]);
        // the stale write-back was dropped; routing resolves live
        let owner = router.route(h, &loads);
        assert_ne!(owner, 3, "recorded a retired owner");
    }

    #[test]
    fn two_choices_first_sight_uses_decayed_signal() {
        let cfg = SignalConfig { decay_alpha: 0.25, hysteresis: 0.0, min_gain: 0.0 };
        let loads = Loads::with_config(2, &cfg);
        let router = TwoChoicesRouter::new(2);
        // node 0 has a long hot history; node 1 one taller spike
        for _ in 0..8 {
            loads.set(0, 60);
        }
        loads.set(1, 70);
        assert!(loads.decayed(0) > loads.decayed(1), "EWMA remembers history");
        assert!(loads.get(0) < loads.get(1), "raw view says the opposite");
        let mut differing = 0;
        for k in keys(200) {
            let h = murmur3_x86_32(k.as_bytes());
            let (c1, c2) = two_choices_candidates(h, 2);
            if c1 != c2 {
                differing += 1;
                // the decayed-cold candidate wins first sight
                assert_eq!(router.route(h, &loads), 1);
            }
        }
        assert!(differing > 50, "hash functions collapsed");
    }

    #[test]
    fn split_candidates_are_distinct_live_and_share_the_primary_seed() {
        let live: Vec<u32> = (0..10).collect();
        for k in keys(500) {
            let h = murmur3_x86_32(k.as_bytes());
            for d in 2..=MAX_SPLIT_D {
                let cands = split_candidates_in(h, &live, d);
                assert_eq!(cands.len(), d, "short candidate set for d={d}");
                let mut sorted = cands.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), d, "duplicate candidates for d={d}");
                assert!(cands.iter().all(|&c| c < 10), "dead candidate");
            }
            // the primary candidate is the two-choices first draw, so a
            // d=2 split router shares two-choices' primary placement
            let (c1, _) = two_choices_candidates_in(h, &live);
            assert_eq!(split_candidates_in(h, &live, 2)[0], c1);
        }
        // d capped by the live set: all live nodes, no repeats
        let small: Vec<u32> = vec![3, 7];
        for k in keys(50) {
            let h = murmur3_x86_32(k.as_bytes());
            let mut cands = split_candidates_in(h, &small, 5);
            cands.sort_unstable();
            assert_eq!(cands, vec![3, 7]);
        }
    }

    #[test]
    fn split_key_promotes_hot_key_and_keeps_cold_keys_sticky() {
        // the AssignTable interaction pinned by ISSUE 8: the mega-hot key
        // is promoted to SPLIT_SENTINEL while cold keys keep their
        // first-writer-wins sticky entries
        let loads = Loads::new(4);
        let mut r = SplitKeyRouter::with_watermark(4, 4, 2.0);
        let ks = keys(200);
        let cold: Vec<(u32, usize)> = ks
            .iter()
            .map(|k| {
                let h = murmur3_x86_32(k.as_bytes());
                (h, r.route(h, &loads))
            })
            .collect();
        let hot = murmur3_x86_32(b"mega-hot-key");
        let hot_home = r.route(hot, &loads);
        for _ in 0..2000 {
            assert_eq!(r.route(hot, &loads), hot_home, "pre-split key not sticky");
        }
        loads.set(hot_home, 100);
        let d = r.redistribute(hot_home, &loads);
        assert!(d.changed);
        assert!(d.keys_split >= 1, "the mega-hot key was not promoted");
        assert!(r.is_split(hot));
        // cold keys: at most sketch-collision casualties get split
        let split_cold = cold.iter().filter(|&&(h, _)| r.is_split(h)).count();
        assert!(split_cold <= 5, "{split_cold} cold keys were promoted");
        // surviving sticky keys stay sticky under wild load swings
        loads.set(hot_home, 0);
        for &(h, _) in cold.iter().filter(|&&(h, _)| !r.is_split(h)) {
            let now = r.route(h, &loads);
            loads.set(now, 10_000);
            assert_eq!(r.route(h, &loads), now, "cold key not sticky");
            loads.set(now, 0);
        }
    }

    #[test]
    fn split_key_spreads_a_mega_hot_key_across_all_candidates() {
        let loads = Loads::new(4);
        let r = SplitKeyRouter::with_watermark(4, 4, 1.0);
        let hot = murmur3_x86_32(b"the-one-key");
        let home = r.route(hot, &loads);
        loads.set(home, 50);
        let mut writer = r.clone(); // shares the table, like clone_router
        let delta = writer.redistribute(home, &loads);
        assert_eq!(delta.keys_split, 1);
        assert!(r.is_split(hot), "clones share the split promotion");
        // equal loads: the rotating tie-break spreads records evenly
        // over all d=4 candidates (the fill rule covers every node)
        loads.set(home, 0);
        let mut counts = [0usize; 4];
        for _ in 0..100 {
            counts[r.route(hot, &loads)] += 1;
        }
        for (n, c) in counts.iter().enumerate() {
            assert!(*c >= 20, "shard {n} starved: {counts:?}");
        }
    }

    #[test]
    fn split_key_is_owner_accepts_exactly_the_candidates() {
        let loads = Loads::new(4);
        let r = SplitKeyRouter::new(4, 2);
        let hot = murmur3_x86_32(b"owned-by-two");
        let home = r.route(hot, &loads);
        // sticky: exactly the recorded owner
        for n in 0..4 {
            assert_eq!(r.is_owner(hot, n, &loads), n == home);
        }
        assert!(r.promote(hot));
        let live: Vec<u32> = (0..4).collect();
        let cands = split_candidates_in(hot, &live, 2);
        for n in 0..4 {
            assert_eq!(
                r.is_owner(hot, n, &loads),
                cands.contains(&n),
                "node {n} vs candidates {cands:?}"
            );
        }
    }

    #[test]
    fn split_key_ownership_probe_does_not_record() {
        let loads = Loads::new(4);
        let r = SplitKeyRouter::new(4, 2);
        let h = murmur3_x86_32(b"probe-only-key");
        let _ = r.is_owner(h, 0, &loads);
        let sticky: usize = (0..4).map(|n| r.assigned_to(n)).sum();
        assert_eq!(sticky + r.split_count(), 0, "ownership probe grew the table");
        // and promote() of an unseen key refuses rather than inserting
        assert!(!r.promote(h));
    }

    #[test]
    fn split_key_membership_rehomes_sticky_and_keeps_split_live() {
        let loads = Loads::new(4);
        let mut r = SplitKeyRouter::new(4, 2);
        let ks = keys(400);
        for k in &ks {
            r.route(murmur3_x86_32(k.as_bytes()), &loads);
        }
        let hot = murmur3_x86_32(b"split-me");
        r.route(hot, &loads);
        assert!(r.promote(hot));
        // join: sticky holds, split keys may pick the joiner up
        let d = r.add_node(4);
        assert!(d.changed && d.zero_token_churn());
        assert_eq!(d.keys_reassigned, 0);
        // retire: only the victim's sticky keys move; the split key
        // keeps routing, never to the retired node
        let victim = 2usize;
        let owned = r.assigned_to(victim);
        let d = r.retire_node(victim, &loads);
        assert!(d.changed);
        assert_eq!(d.keys_reassigned as usize, owned);
        assert_eq!(r.assigned_to(victim), 0);
        assert!(r.is_split(hot), "retire must not demote a split key");
        for _ in 0..50 {
            assert_ne!(r.route(hot, &loads), victim, "shard on a retired node");
        }
    }
}
