//! Keyspace-redistribution strategy *specs*.
//!
//! [`StrategySpec`] is what config/CLI parsing produces: a plain value
//! naming a redistribution family plus its parameters. It is **not** the
//! mechanism — [`StrategySpec::build_router`] constructs the boxed
//! [`Router`](super::router::Router) that actually routes and
//! redistributes, and everything above the trait depends only on that.
//!
//! * [`StrategySpec::None`] — load balancing disabled (the paper's
//!   "No LB" baseline column in Table 1); token-ring routing.
//! * [`StrategySpec::Halving`] — §4.2: every node starts with `N = 2^k`
//!   tokens; a redistribution removes half of the overloaded node's
//!   tokens. Gentle, but you can "run out of halving" at one token.
//! * [`StrategySpec::Doubling`] — §4.2: every node starts with one token;
//!   a redistribution doubles every *other* node's token count.
//! * [`StrategySpec::MultiProbe`] — multi-probe consistent hashing:
//!   `probes` independent probes per key, closest probe owner wins,
//!   avoiding owners frozen as overloaded; redistribution is
//!   zero-token-churn.
//! * [`StrategySpec::TwoChoices`] — per-key power of two choices with a
//!   sticky assignment table (the key-splitting guard).
//! * [`StrategySpec::SplitKey`] — d-way partial key grouping: cold keys
//!   sticky like two-choices, mega-hot keys promoted to split across `d`
//!   candidates once their estimated decayed load crosses the split
//!   watermark (`balancer.split_watermark`). The one family with an
//!   [`MergeContract::Associative`] merge contract.
//!
//! `Strategy` remains as an alias — the spec is the same value that used
//! to be the closed strategy enum, so TOML/CLI round-trips and existing
//! call sites keep working.

use std::fmt;
use std::str::FromStr;

use super::ring::Ring;
use super::router::{
    MergeContract, MultiProbeRouter, RingOp, Router, SplitKeyRouter, TokenRingRouter,
    TwoChoicesRouter, MAX_SPLIT_D,
};

/// Default probe count for [`StrategySpec::MultiProbe`]. The MPCH paper
/// suggests ~21 probes for a 1.05 peak-to-average ratio on large
/// clusters; for the paper's 4-reducer topology a handful suffices.
pub const DEFAULT_PROBES: u32 = 5;

/// Default split fan-out for [`StrategySpec::SplitKey`] — the classic
/// partial-key-grouping d=2 ("The Power of Both Choices"); WL3-style
/// single-mega-key workloads on small topologies profit from `splitkey:4`
/// (fan out across every reducer).
pub const DEFAULT_SPLIT_D: u32 = 2;

/// Parsed redistribution-strategy specification.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StrategySpec {
    None,
    Halving,
    Doubling,
    MultiProbe { probes: u32 },
    TwoChoices,
    SplitKey { d: u32 },
}

/// Historical name: the spec used to be the closed strategy enum.
pub type Strategy = StrategySpec;

impl StrategySpec {
    /// Initial tokens per node for the ring-based layouts. `halving_init`
    /// must be a power of two (§4.2: "N initial tokens where N is a power
    /// of 2"). Probe-based strategies have one position per node.
    pub fn initial_tokens(&self, halving_init: u32) -> u32 {
        match self {
            // The no-LB baseline in the paper is the same runtime with the
            // trigger disabled; its initial partition matches whichever
            // method it is compared against, so the caller picks. We default
            // to the halving layout for standalone use.
            StrategySpec::None => halving_init,
            StrategySpec::Halving => {
                assert!(
                    halving_init.is_power_of_two(),
                    "halving initial token count must be a power of two, got {halving_init}"
                );
                halving_init
            }
            StrategySpec::Doubling => 1,
            StrategySpec::MultiProbe { .. }
            | StrategySpec::TwoChoices
            | StrategySpec::SplitKey { .. } => 1,
        }
    }

    /// What the end-of-run merge may assume under this spec's router —
    /// the pipeline consults this at build time to reject order-sensitive
    /// merge ops before any record flows (see `docs/ARCHITECTURE.md`,
    /// "§7 merge contracts").
    pub fn merge_contract(&self) -> MergeContract {
        match self {
            StrategySpec::SplitKey { .. } => MergeContract::Associative,
            _ => MergeContract::Disjoint,
        }
    }

    /// Is this a token-ring-family spec (where `initial_tokens` /
    /// `initial_tokens` overrides are meaningful)?
    pub fn is_token_ring(&self) -> bool {
        matches!(
            self,
            StrategySpec::None | StrategySpec::Halving | StrategySpec::Doubling
        )
    }

    /// Construct the router this spec describes. `initial_tokens`
    /// overrides the ring layout (used to run the no-LB baseline on a
    /// specific method's initial layout); probe routers ignore it.
    /// Split-key routers get their default watermark — the pipeline goes
    /// through [`Self::build_router_tuned`] to thread the configured one.
    pub fn build_router(
        &self,
        nodes: usize,
        halving_init: u32,
        initial_tokens: Option<u32>,
    ) -> Box<dyn Router> {
        self.build_router_tuned(
            nodes,
            halving_init,
            initial_tokens,
            SplitKeyRouter::DEFAULT_WATERMARK,
        )
    }

    /// [`Self::build_router`] with the split watermark threaded through
    /// (`balancer.split_watermark`); only the split-key family reads it.
    pub fn build_router_tuned(
        &self,
        nodes: usize,
        halving_init: u32,
        initial_tokens: Option<u32>,
        split_watermark: f64,
    ) -> Box<dyn Router> {
        match self {
            StrategySpec::None | StrategySpec::Halving | StrategySpec::Doubling => {
                let tokens = initial_tokens.unwrap_or_else(|| self.initial_tokens(halving_init));
                let op = match self {
                    StrategySpec::None => RingOp::NoOp,
                    StrategySpec::Halving => RingOp::Halve,
                    _ => RingOp::DoubleOthers,
                };
                Box::new(TokenRingRouter::new(Ring::new(nodes, tokens), op))
            }
            StrategySpec::MultiProbe { probes } => {
                Box::new(MultiProbeRouter::new(nodes, *probes))
            }
            StrategySpec::TwoChoices => Box::new(TwoChoicesRouter::new(nodes)),
            StrategySpec::SplitKey { d } => {
                Box::new(SplitKeyRouter::with_watermark(nodes, *d as usize, split_watermark))
            }
        }
    }

    /// Every spec (one representative per family parameterization).
    pub fn all() -> [StrategySpec; 6] {
        [
            StrategySpec::None,
            StrategySpec::Halving,
            StrategySpec::Doubling,
            StrategySpec::MultiProbe { probes: DEFAULT_PROBES },
            StrategySpec::TwoChoices,
            StrategySpec::SplitKey { d: DEFAULT_SPLIT_D },
        ]
    }

    /// The two active methods compared in the paper's evaluation.
    pub fn methods() -> [StrategySpec; 2] {
        [StrategySpec::Halving, StrategySpec::Doubling]
    }

    /// Parse a comma-separated strategy list (the `--strategies` filter).
    pub fn parse_list(s: &str) -> Result<Vec<StrategySpec>, String> {
        s.split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(str::parse)
            .collect()
    }
}

impl fmt::Display for StrategySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StrategySpec::None => write!(f, "none"),
            StrategySpec::Halving => write!(f, "halving"),
            StrategySpec::Doubling => write!(f, "doubling"),
            StrategySpec::MultiProbe { probes } if *probes == DEFAULT_PROBES => {
                write!(f, "multiprobe")
            }
            StrategySpec::MultiProbe { probes } => write!(f, "multiprobe:{probes}"),
            StrategySpec::TwoChoices => write!(f, "twochoices"),
            StrategySpec::SplitKey { d } if *d == DEFAULT_SPLIT_D => write!(f, "splitkey"),
            StrategySpec::SplitKey { d } => write!(f, "splitkey:{d}"),
        }
    }
}

impl FromStr for StrategySpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        if let Some((name, arg)) = lower.split_once(':') {
            return match name {
                "multiprobe" | "multi-probe" | "mpch" => {
                    let probes: u32 = arg
                        .parse()
                        .map_err(|e| format!("invalid probe count '{arg}': {e}"))?;
                    if probes == 0 {
                        return Err("probe count must be at least 1".into());
                    }
                    Ok(StrategySpec::MultiProbe { probes })
                }
                "splitkey" | "split-key" | "pkg" => {
                    let d: u32 = arg
                        .parse()
                        .map_err(|e| format!("invalid split fan-out '{arg}': {e}"))?;
                    if !(2..=MAX_SPLIT_D as u32).contains(&d) {
                        return Err(format!(
                            "split fan-out must be in 2..={MAX_SPLIT_D}, got {d}"
                        ));
                    }
                    Ok(StrategySpec::SplitKey { d })
                }
                other => Err(format!("strategy '{other}' takes no ':' parameter")),
            };
        }
        match lower.as_str() {
            "none" | "nolb" | "no-lb" | "off" => Ok(StrategySpec::None),
            "halving" | "halve" => Ok(StrategySpec::Halving),
            "doubling" | "double" => Ok(StrategySpec::Doubling),
            "multiprobe" | "multi-probe" | "mpch" => {
                Ok(StrategySpec::MultiProbe { probes: DEFAULT_PROBES })
            }
            "twochoices" | "two-choices" | "2choices" => Ok(StrategySpec::TwoChoices),
            "splitkey" | "split-key" | "pkg" => {
                Ok(StrategySpec::SplitKey { d: DEFAULT_SPLIT_D })
            }
            other => Err(format!(
                "unknown strategy '{other}' \
                 (expected none|halving|doubling|multiprobe[:K]|twochoices|splitkey[:D])"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for s in StrategySpec::all() {
            assert_eq!(s.to_string().parse::<StrategySpec>().unwrap(), s);
        }
        assert_eq!("no-lb".parse::<StrategySpec>().unwrap(), StrategySpec::None);
        assert_eq!(
            "multiprobe:9".parse::<StrategySpec>().unwrap(),
            StrategySpec::MultiProbe { probes: 9 }
        );
        assert_eq!(
            StrategySpec::MultiProbe { probes: 9 }.to_string(),
            "multiprobe:9"
        );
        assert!("bogus".parse::<StrategySpec>().is_err());
        assert!("multiprobe:0".parse::<StrategySpec>().is_err());
        assert!("halving:2".parse::<StrategySpec>().is_err());
        assert_eq!(
            "splitkey".parse::<StrategySpec>().unwrap(),
            StrategySpec::SplitKey { d: DEFAULT_SPLIT_D }
        );
        assert_eq!(
            "split-key:4".parse::<StrategySpec>().unwrap(),
            StrategySpec::SplitKey { d: 4 }
        );
        assert_eq!(StrategySpec::SplitKey { d: 4 }.to_string(), "splitkey:4");
        assert!("splitkey:1".parse::<StrategySpec>().is_err(), "d < 2");
        assert!("splitkey:9".parse::<StrategySpec>().is_err(), "d > seeds");
    }

    #[test]
    fn merge_contract_per_family() {
        for s in StrategySpec::all() {
            let expect = matches!(s, StrategySpec::SplitKey { .. });
            assert_eq!(
                s.merge_contract() == MergeContract::Associative,
                expect,
                "{s}"
            );
            // the spec-level contract agrees with the built router's
            let r = s.build_router(4, 8, None);
            assert_eq!(r.merge_contract(), s.merge_contract(), "{s}");
        }
    }

    #[test]
    fn parse_strategy_lists() {
        assert_eq!(
            StrategySpec::parse_list("halving, doubling,multiprobe").unwrap(),
            vec![
                StrategySpec::Halving,
                StrategySpec::Doubling,
                StrategySpec::MultiProbe { probes: DEFAULT_PROBES },
            ]
        );
        assert!(StrategySpec::parse_list("halving,bogus").is_err());
    }

    #[test]
    fn initial_tokens_per_method() {
        assert_eq!(StrategySpec::Halving.initial_tokens(8), 8);
        assert_eq!(StrategySpec::Doubling.initial_tokens(8), 1);
        assert_eq!(StrategySpec::None.initial_tokens(8), 8);
        assert_eq!(StrategySpec::TwoChoices.initial_tokens(8), 1);
        assert_eq!(StrategySpec::MultiProbe { probes: 3 }.initial_tokens(8), 1);
    }

    #[test]
    #[should_panic]
    fn halving_requires_power_of_two() {
        StrategySpec::Halving.initial_tokens(6);
    }

    #[test]
    fn build_router_families() {
        for spec in StrategySpec::all() {
            let r = spec.build_router(4, 8, None);
            assert_eq!(r.nodes(), 4, "{spec}");
            let is_ring = r.as_token_ring().is_some();
            assert_eq!(is_ring, spec.is_token_ring(), "{spec}");
        }
        // the no-LB baseline can borrow a method's initial layout
        let r = StrategySpec::None.build_router(4, 8, Some(1));
        assert_eq!(r.as_token_ring().unwrap().tokens_of(0), 1);
    }
}
