//! Keyspace-redistribution strategy *specs*.
//!
//! [`StrategySpec`] is what config/CLI parsing produces: a plain value
//! naming a redistribution family plus its parameters. It is **not** the
//! mechanism — [`StrategySpec::build_router`] constructs the boxed
//! [`Router`](super::router::Router) that actually routes and
//! redistributes, and everything above the trait depends only on that.
//!
//! * [`StrategySpec::None`] — load balancing disabled (the paper's
//!   "No LB" baseline column in Table 1); token-ring routing.
//! * [`StrategySpec::Halving`] — §4.2: every node starts with `N = 2^k`
//!   tokens; a redistribution removes half of the overloaded node's
//!   tokens. Gentle, but you can "run out of halving" at one token.
//! * [`StrategySpec::Doubling`] — §4.2: every node starts with one token;
//!   a redistribution doubles every *other* node's token count.
//! * [`StrategySpec::MultiProbe`] — multi-probe consistent hashing:
//!   `probes` independent probes per key, closest probe owner wins,
//!   avoiding owners frozen as overloaded; redistribution is
//!   zero-token-churn.
//! * [`StrategySpec::TwoChoices`] — per-key power of two choices with a
//!   sticky assignment table (the key-splitting guard).
//! * [`StrategySpec::SplitKey`] — d-way partial key grouping: cold keys
//!   sticky like two-choices, mega-hot keys promoted to split across `d`
//!   candidates once their estimated decayed load crosses the split
//!   watermark (`balancer.split_watermark`). The one family with an
//!   [`MergeContract::Associative`] merge contract.
//! * [`StrategySpec::Ptable`] — O(1) flat partition table
//!   (`ptable[:B][:R]`): `2^B` partitions dealt over the nodes, routing
//!   is one indexed load, membership changes are minimal-movement table
//!   rewrites, and `R`-replica placement walks distinct failure domains
//!   when zones are configured (`balancer.zones`).
//!
//! Parsing and `Display` are driven by one [`FamilyDef`] registry row per
//! family (canonical name, aliases, `:`-parameter grammar), so the
//! accepted spellings, the error message's expected-syntax list and the
//! round-trip property in `tests/props.rs` (`parse ∘ display == id`) all
//! read from the same table. Unknown names and bad parameters surface as
//! the typed [`ParseStrategyError`] — `dpa table1 --strategies` propagates
//! it instead of skipping silently.
//!
//! `Strategy` remains as an alias — the spec is the same value that used
//! to be the closed strategy enum, so TOML/CLI round-trips and existing
//! call sites keep working.

use std::error::Error;
use std::fmt;
use std::str::FromStr;

use super::ptable::{
    PartitionTableRouter, DEFAULT_PTABLE_BITS, DEFAULT_PTABLE_REPLICAS, MAX_PTABLE_BITS,
    MAX_PTABLE_REPLICAS,
};
use super::ring::Ring;
use super::router::{
    MergeContract, MultiProbeRouter, RingOp, Router, SplitKeyRouter, TokenRingRouter,
    TwoChoicesRouter, MAX_SPLIT_D,
};

/// Default probe count for [`StrategySpec::MultiProbe`]. The MPCH paper
/// suggests ~21 probes for a 1.05 peak-to-average ratio on large
/// clusters; for the paper's 4-reducer topology a handful suffices.
pub const DEFAULT_PROBES: u32 = 5;

/// Default split fan-out for [`StrategySpec::SplitKey`] — the classic
/// partial-key-grouping d=2 ("The Power of Both Choices"); WL3-style
/// single-mega-key workloads on small topologies profit from `splitkey:4`
/// (fan out across every reducer).
pub const DEFAULT_SPLIT_D: u32 = 2;

/// Parsed redistribution-strategy specification.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StrategySpec {
    None,
    Halving,
    Doubling,
    MultiProbe { probes: u32 },
    TwoChoices,
    SplitKey { d: u32 },
    Ptable { bits: u32, replicas: u32 },
}

/// Historical name: the spec used to be the closed strategy enum.
pub type Strategy = StrategySpec;

/// Why a strategy string failed to parse. Carries enough structure for
/// callers to distinguish "no such family" (the `--strategies` filter
/// rejects these outright) from "family exists, parameter out of range",
/// while `Display` keeps the old human-readable phrasing — CLI call
/// sites still just `.map_err(anyhow::Error::msg)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParseStrategyError {
    /// The family name matched no registry row (canonical or alias).
    UnknownFamily {
        /// The unrecognized (lowercased) family name.
        name: String,
    },
    /// The family exists but a `:`-parameter was malformed or out of
    /// range for its grammar.
    BadParameter {
        /// Canonical name of the family whose parameter was rejected.
        family: &'static str,
        /// Human-readable description of the rejection.
        detail: String,
    },
}

impl fmt::Display for ParseStrategyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseStrategyError::UnknownFamily { name } => {
                write!(f, "unknown strategy '{name}' (expected {})", syntax_summary())
            }
            ParseStrategyError::BadParameter { family, detail } => {
                write!(f, "strategy '{family}': {detail}")
            }
        }
    }
}

impl Error for ParseStrategyError {}

/// One registry row: everything the parser, `Display`, and error
/// messages need to know about a strategy family. [`REGISTRY`] holds one
/// per [`StrategySpec`] variant, in declaration order.
struct FamilyDef {
    /// Canonical name — what `Display` prints and errors cite.
    name: &'static str,
    /// Accepted alternative spellings (lowercase).
    aliases: &'static [&'static str],
    /// Grammar shown in the unknown-strategy error, e.g. `ptable[:B][:R]`.
    syntax: &'static str,
    /// Maximum number of `:`-separated parameters.
    max_args: usize,
    /// Construct the spec from the (possibly empty) parameter list.
    build: fn(&[&str]) -> Result<StrategySpec, ParseStrategyError>,
}

fn parse_param(
    family: &'static str,
    what: &str,
    raw: &str,
) -> Result<u32, ParseStrategyError> {
    raw.parse().map_err(|e| ParseStrategyError::BadParameter {
        family,
        detail: format!("invalid {what} '{raw}': {e}"),
    })
}

fn build_none(_: &[&str]) -> Result<StrategySpec, ParseStrategyError> {
    Ok(StrategySpec::None)
}

fn build_halving(_: &[&str]) -> Result<StrategySpec, ParseStrategyError> {
    Ok(StrategySpec::Halving)
}

fn build_doubling(_: &[&str]) -> Result<StrategySpec, ParseStrategyError> {
    Ok(StrategySpec::Doubling)
}

fn build_multiprobe(args: &[&str]) -> Result<StrategySpec, ParseStrategyError> {
    let probes = match args {
        [] => DEFAULT_PROBES,
        [k, ..] => parse_param("multiprobe", "probe count", k)?,
    };
    if probes == 0 {
        return Err(ParseStrategyError::BadParameter {
            family: "multiprobe",
            detail: "probe count must be at least 1".into(),
        });
    }
    Ok(StrategySpec::MultiProbe { probes })
}

fn build_twochoices(_: &[&str]) -> Result<StrategySpec, ParseStrategyError> {
    Ok(StrategySpec::TwoChoices)
}

fn build_splitkey(args: &[&str]) -> Result<StrategySpec, ParseStrategyError> {
    let d = match args {
        [] => DEFAULT_SPLIT_D,
        [d, ..] => parse_param("splitkey", "split fan-out", d)?,
    };
    if !(2..=MAX_SPLIT_D as u32).contains(&d) {
        return Err(ParseStrategyError::BadParameter {
            family: "splitkey",
            detail: format!("split fan-out must be in 2..={MAX_SPLIT_D}, got {d}"),
        });
    }
    Ok(StrategySpec::SplitKey { d })
}

fn build_ptable(args: &[&str]) -> Result<StrategySpec, ParseStrategyError> {
    let bits = match args.first() {
        None => DEFAULT_PTABLE_BITS,
        Some(b) => parse_param("ptable", "partition bits", b)?,
    };
    if !(1..=MAX_PTABLE_BITS).contains(&bits) {
        return Err(ParseStrategyError::BadParameter {
            family: "ptable",
            detail: format!("partition bits must be in 1..={MAX_PTABLE_BITS}, got {bits}"),
        });
    }
    let replicas = match args.get(1) {
        None => DEFAULT_PTABLE_REPLICAS,
        Some(r) => parse_param("ptable", "replica count", r)?,
    };
    if !(1..=MAX_PTABLE_REPLICAS).contains(&replicas) {
        return Err(ParseStrategyError::BadParameter {
            family: "ptable",
            detail: format!(
                "replica count must be in 1..={MAX_PTABLE_REPLICAS}, got {replicas}"
            ),
        });
    }
    Ok(StrategySpec::Ptable { bits, replicas })
}

/// The family registry, one row per [`StrategySpec`] variant in
/// declaration order ([`StrategySpec::family_def`] indexes it).
static REGISTRY: &[FamilyDef] = &[
    FamilyDef {
        name: "none",
        aliases: &["nolb", "no-lb", "off"],
        syntax: "none",
        max_args: 0,
        build: build_none,
    },
    FamilyDef {
        name: "halving",
        aliases: &["halve"],
        syntax: "halving",
        max_args: 0,
        build: build_halving,
    },
    FamilyDef {
        name: "doubling",
        aliases: &["double"],
        syntax: "doubling",
        max_args: 0,
        build: build_doubling,
    },
    FamilyDef {
        name: "multiprobe",
        aliases: &["multi-probe", "mpch"],
        syntax: "multiprobe[:K]",
        max_args: 1,
        build: build_multiprobe,
    },
    FamilyDef {
        name: "twochoices",
        aliases: &["two-choices", "2choices"],
        syntax: "twochoices",
        max_args: 0,
        build: build_twochoices,
    },
    FamilyDef {
        name: "splitkey",
        aliases: &["split-key", "pkg"],
        syntax: "splitkey[:D]",
        max_args: 1,
        build: build_splitkey,
    },
    FamilyDef {
        name: "ptable",
        aliases: &["partition-table", "table"],
        syntax: "ptable[:B][:R]",
        max_args: 2,
        build: build_ptable,
    },
];

/// `none|halving|…|ptable[:B][:R]` — the expected-syntax list in the
/// unknown-strategy error, generated from the registry.
fn syntax_summary() -> String {
    let names: Vec<&str> = REGISTRY.iter().map(|d| d.syntax).collect();
    names.join("|")
}

impl StrategySpec {
    fn family_def(&self) -> &'static FamilyDef {
        let idx = match self {
            StrategySpec::None => 0,
            StrategySpec::Halving => 1,
            StrategySpec::Doubling => 2,
            StrategySpec::MultiProbe { .. } => 3,
            StrategySpec::TwoChoices => 4,
            StrategySpec::SplitKey { .. } => 5,
            StrategySpec::Ptable { .. } => 6,
        };
        &REGISTRY[idx]
    }

    /// Canonical family name from the registry (`Display` appends any
    /// non-default parameters to it).
    pub fn family_name(&self) -> &'static str {
        self.family_def().name
    }

    /// Initial tokens per node for the ring-based layouts. `halving_init`
    /// must be a power of two (§4.2: "N initial tokens where N is a power
    /// of 2"). Probe- and table-based strategies have one position per
    /// node.
    pub fn initial_tokens(&self, halving_init: u32) -> u32 {
        match self {
            // The no-LB baseline in the paper is the same runtime with the
            // trigger disabled; its initial partition matches whichever
            // method it is compared against, so the caller picks. We default
            // to the halving layout for standalone use.
            StrategySpec::None => halving_init,
            StrategySpec::Halving => {
                assert!(
                    halving_init.is_power_of_two(),
                    "halving initial token count must be a power of two, got {halving_init}"
                );
                halving_init
            }
            StrategySpec::Doubling => 1,
            StrategySpec::MultiProbe { .. }
            | StrategySpec::TwoChoices
            | StrategySpec::SplitKey { .. }
            | StrategySpec::Ptable { .. } => 1,
        }
    }

    /// What the end-of-run merge may assume under this spec's router —
    /// the pipeline consults this at build time to reject order-sensitive
    /// merge ops before any record flows (see `docs/ARCHITECTURE.md`,
    /// "§7 merge contracts").
    pub fn merge_contract(&self) -> MergeContract {
        match self {
            StrategySpec::SplitKey { .. } => MergeContract::Associative,
            _ => MergeContract::Disjoint,
        }
    }

    /// Is this a token-ring-family spec (where `initial_tokens` /
    /// `initial_tokens` overrides are meaningful)?
    pub fn is_token_ring(&self) -> bool {
        matches!(
            self,
            StrategySpec::None | StrategySpec::Halving | StrategySpec::Doubling
        )
    }

    /// Construct the router this spec describes. `initial_tokens`
    /// overrides the ring layout (used to run the no-LB baseline on a
    /// specific method's initial layout); probe routers ignore it.
    /// Split-key routers get their default watermark — the pipeline goes
    /// through [`Self::build_router_tuned`] to thread the configured one.
    pub fn build_router(
        &self,
        nodes: usize,
        halving_init: u32,
        initial_tokens: Option<u32>,
    ) -> Box<dyn Router> {
        self.build_router_tuned(
            nodes,
            halving_init,
            initial_tokens,
            SplitKeyRouter::DEFAULT_WATERMARK,
        )
    }

    /// [`Self::build_router`] with the split watermark threaded through
    /// (`balancer.split_watermark`); only the split-key family reads it.
    pub fn build_router_tuned(
        &self,
        nodes: usize,
        halving_init: u32,
        initial_tokens: Option<u32>,
        split_watermark: f64,
    ) -> Box<dyn Router> {
        match self {
            StrategySpec::None | StrategySpec::Halving | StrategySpec::Doubling => {
                let tokens = initial_tokens.unwrap_or_else(|| self.initial_tokens(halving_init));
                let op = match self {
                    StrategySpec::None => RingOp::NoOp,
                    StrategySpec::Halving => RingOp::Halve,
                    _ => RingOp::DoubleOthers,
                };
                Box::new(TokenRingRouter::new(Ring::new(nodes, tokens), op))
            }
            StrategySpec::MultiProbe { probes } => {
                Box::new(MultiProbeRouter::new(nodes, *probes))
            }
            StrategySpec::TwoChoices => Box::new(TwoChoicesRouter::new(nodes)),
            StrategySpec::SplitKey { d } => {
                Box::new(SplitKeyRouter::with_watermark(nodes, *d as usize, split_watermark))
            }
            StrategySpec::Ptable { bits, replicas } => {
                Box::new(PartitionTableRouter::new(nodes, *bits, *replicas))
            }
        }
    }

    /// Every spec (one representative per family parameterization).
    pub fn all() -> [StrategySpec; 7] {
        [
            StrategySpec::None,
            StrategySpec::Halving,
            StrategySpec::Doubling,
            StrategySpec::MultiProbe { probes: DEFAULT_PROBES },
            StrategySpec::TwoChoices,
            StrategySpec::SplitKey { d: DEFAULT_SPLIT_D },
            StrategySpec::Ptable {
                bits: DEFAULT_PTABLE_BITS,
                replicas: DEFAULT_PTABLE_REPLICAS,
            },
        ]
    }

    /// The two active methods compared in the paper's evaluation.
    pub fn methods() -> [StrategySpec; 2] {
        [StrategySpec::Halving, StrategySpec::Doubling]
    }

    /// Parse a comma-separated strategy list (the `--strategies` filter).
    /// Any unknown name or bad parameter fails the whole list — nothing
    /// is silently skipped.
    pub fn parse_list(s: &str) -> Result<Vec<StrategySpec>, ParseStrategyError> {
        s.split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(str::parse)
            .collect()
    }
}

impl fmt::Display for StrategySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.family_name())?;
        match self {
            StrategySpec::MultiProbe { probes } if *probes != DEFAULT_PROBES => {
                write!(f, ":{probes}")
            }
            StrategySpec::SplitKey { d } if *d != DEFAULT_SPLIT_D => write!(f, ":{d}"),
            // Only trailing defaults elide: `ptable:12`, `ptable:10:2`.
            StrategySpec::Ptable { bits, replicas } if *replicas != DEFAULT_PTABLE_REPLICAS => {
                write!(f, ":{bits}:{replicas}")
            }
            StrategySpec::Ptable { bits, replicas: _ } if *bits != DEFAULT_PTABLE_BITS => {
                write!(f, ":{bits}")
            }
            _ => Ok(()),
        }
    }
}

impl FromStr for StrategySpec {
    type Err = ParseStrategyError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let lower = s.trim().to_ascii_lowercase();
        let mut parts = lower.split(':');
        let name = parts.next().unwrap_or_default();
        let args: Vec<&str> = parts.collect();
        let def = REGISTRY
            .iter()
            .find(|d| d.name == name || d.aliases.contains(&name))
            .ok_or_else(|| ParseStrategyError::UnknownFamily { name: name.to_string() })?;
        if args.len() > def.max_args {
            return Err(ParseStrategyError::BadParameter {
                family: def.name,
                detail: if def.max_args == 0 {
                    "takes no ':' parameter".into()
                } else {
                    format!(
                        "takes at most {} ':' parameter(s), syntax {}",
                        def.max_args, def.syntax
                    )
                },
            });
        }
        (def.build)(&args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for s in StrategySpec::all() {
            assert_eq!(s.to_string().parse::<StrategySpec>().unwrap(), s);
        }
        assert_eq!("no-lb".parse::<StrategySpec>().unwrap(), StrategySpec::None);
        assert_eq!(
            "multiprobe:9".parse::<StrategySpec>().unwrap(),
            StrategySpec::MultiProbe { probes: 9 }
        );
        assert_eq!(
            StrategySpec::MultiProbe { probes: 9 }.to_string(),
            "multiprobe:9"
        );
        assert!("bogus".parse::<StrategySpec>().is_err());
        assert!("multiprobe:0".parse::<StrategySpec>().is_err());
        assert!("halving:2".parse::<StrategySpec>().is_err());
        assert_eq!(
            "splitkey".parse::<StrategySpec>().unwrap(),
            StrategySpec::SplitKey { d: DEFAULT_SPLIT_D }
        );
        assert_eq!(
            "split-key:4".parse::<StrategySpec>().unwrap(),
            StrategySpec::SplitKey { d: 4 }
        );
        assert_eq!(StrategySpec::SplitKey { d: 4 }.to_string(), "splitkey:4");
        assert!("splitkey:1".parse::<StrategySpec>().is_err(), "d < 2");
        assert!("splitkey:9".parse::<StrategySpec>().is_err(), "d > seeds");
    }

    #[test]
    fn ptable_parse_and_display() {
        // every alias and parameterization lands on the same family
        assert_eq!(
            "ptable".parse::<StrategySpec>().unwrap(),
            StrategySpec::Ptable {
                bits: DEFAULT_PTABLE_BITS,
                replicas: DEFAULT_PTABLE_REPLICAS
            }
        );
        assert_eq!(
            "partition-table:8".parse::<StrategySpec>().unwrap(),
            StrategySpec::Ptable { bits: 8, replicas: DEFAULT_PTABLE_REPLICAS }
        );
        assert_eq!(
            "table:10:2".parse::<StrategySpec>().unwrap(),
            StrategySpec::Ptable { bits: 10, replicas: 2 }
        );
        // Display elides only trailing defaults: a non-default replica
        // count forces the bits out too, so the string re-parses exactly.
        assert_eq!(
            StrategySpec::Ptable { bits: DEFAULT_PTABLE_BITS, replicas: 2 }.to_string(),
            "ptable:10:2"
        );
        assert_eq!(
            StrategySpec::Ptable { bits: 12, replicas: 1 }.to_string(),
            "ptable:12"
        );
        assert!("ptable:0".parse::<StrategySpec>().is_err(), "bits < 1");
        assert!("ptable:17".parse::<StrategySpec>().is_err(), "bits > max");
        assert!("ptable:10:0".parse::<StrategySpec>().is_err(), "r < 1");
        assert!("ptable:10:5".parse::<StrategySpec>().is_err(), "r > max");
        assert!("ptable:10:2:3".parse::<StrategySpec>().is_err(), "arity");
    }

    #[test]
    fn typed_errors_distinguish_unknown_from_bad_parameter() {
        match "bogus".parse::<StrategySpec>() {
            Err(ParseStrategyError::UnknownFamily { name }) => assert_eq!(name, "bogus"),
            other => panic!("expected UnknownFamily, got {other:?}"),
        }
        match "ptable:99".parse::<StrategySpec>() {
            Err(ParseStrategyError::BadParameter { family, .. }) => {
                assert_eq!(family, "ptable");
            }
            other => panic!("expected BadParameter, got {other:?}"),
        }
        // the unknown-family message lists every registry syntax
        let msg = "bogus".parse::<StrategySpec>().unwrap_err().to_string();
        for def in ["none", "halving", "doubling", "multiprobe[:K]", "ptable[:B][:R]"] {
            assert!(msg.contains(def), "missing '{def}' in: {msg}");
        }
    }

    #[test]
    fn merge_contract_per_family() {
        for s in StrategySpec::all() {
            let expect = matches!(s, StrategySpec::SplitKey { .. });
            assert_eq!(
                s.merge_contract() == MergeContract::Associative,
                expect,
                "{s}"
            );
            // the spec-level contract agrees with the built router's
            let r = s.build_router(4, 8, None);
            assert_eq!(r.merge_contract(), s.merge_contract(), "{s}");
        }
    }

    #[test]
    fn parse_strategy_lists() {
        assert_eq!(
            StrategySpec::parse_list("halving, doubling,multiprobe").unwrap(),
            vec![
                StrategySpec::Halving,
                StrategySpec::Doubling,
                StrategySpec::MultiProbe { probes: DEFAULT_PROBES },
            ]
        );
        assert!(StrategySpec::parse_list("halving,bogus").is_err());
    }

    #[test]
    fn initial_tokens_per_method() {
        assert_eq!(StrategySpec::Halving.initial_tokens(8), 8);
        assert_eq!(StrategySpec::Doubling.initial_tokens(8), 1);
        assert_eq!(StrategySpec::None.initial_tokens(8), 8);
        assert_eq!(StrategySpec::TwoChoices.initial_tokens(8), 1);
        assert_eq!(StrategySpec::MultiProbe { probes: 3 }.initial_tokens(8), 1);
    }

    #[test]
    #[should_panic]
    fn halving_requires_power_of_two() {
        StrategySpec::Halving.initial_tokens(6);
    }

    #[test]
    fn build_router_families() {
        for spec in StrategySpec::all() {
            let r = spec.build_router(4, 8, None);
            assert_eq!(r.nodes(), 4, "{spec}");
            let is_ring = r.as_token_ring().is_some();
            assert_eq!(is_ring, spec.is_token_ring(), "{spec}");
        }
        // the no-LB baseline can borrow a method's initial layout
        let r = StrategySpec::None.build_router(4, 8, Some(1));
        assert_eq!(r.as_token_ring().unwrap().tokens_of(0), 1);
    }
}
