//! Keyspace-redistribution strategies from §4.2 of the paper.

use std::fmt;
use std::str::FromStr;

/// Which token-manipulation strategy `redistribute(node_id)` applies.
///
/// * [`Strategy::None`] — load balancing disabled (the paper's "No LB"
///   baseline column in Table 1).
/// * [`Strategy::Halving`] — every node starts with `N = 2^k` tokens; a
///   redistribution removes half of the overloaded node's tokens. Gentle,
///   only the target node's keys move, but you can "run out of halving"
///   once a node is down to one token.
/// * [`Strategy::Doubling`] — every node starts with one token; a
///   redistribution doubles the token count of every *other* node.
///   Aggressive: non-problematic nodes' keys reshuffle too.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    None,
    Halving,
    Doubling,
}

impl Strategy {
    /// Initial tokens per node for this strategy. `halving_init` must be a
    /// power of two (§4.2: "N initial tokens where N is a power of 2").
    pub fn initial_tokens(&self, halving_init: u32) -> u32 {
        match self {
            // The no-LB baseline in the paper is the same runtime with the
            // trigger disabled; its initial partition matches whichever
            // method it is compared against, so the caller picks. We default
            // to the halving layout for standalone use.
            Strategy::None => halving_init,
            Strategy::Halving => {
                assert!(
                    halving_init.is_power_of_two(),
                    "halving initial token count must be a power of two, got {halving_init}"
                );
                halving_init
            }
            Strategy::Doubling => 1,
        }
    }

    pub fn all() -> [Strategy; 3] {
        [Strategy::None, Strategy::Halving, Strategy::Doubling]
    }

    /// The two active methods compared in the paper's evaluation.
    pub fn methods() -> [Strategy; 2] {
        [Strategy::Halving, Strategy::Doubling]
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Strategy::None => write!(f, "none"),
            Strategy::Halving => write!(f, "halving"),
            Strategy::Doubling => write!(f, "doubling"),
        }
    }
}

impl FromStr for Strategy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "nolb" | "no-lb" | "off" => Ok(Strategy::None),
            "halving" | "halve" => Ok(Strategy::Halving),
            "doubling" | "double" => Ok(Strategy::Doubling),
            other => Err(format!(
                "unknown strategy '{other}' (expected none|halving|doubling)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for s in Strategy::all() {
            assert_eq!(s.to_string().parse::<Strategy>().unwrap(), s);
        }
        assert_eq!("no-lb".parse::<Strategy>().unwrap(), Strategy::None);
        assert!("bogus".parse::<Strategy>().is_err());
    }

    #[test]
    fn initial_tokens_per_method() {
        assert_eq!(Strategy::Halving.initial_tokens(8), 8);
        assert_eq!(Strategy::Doubling.initial_tokens(8), 1);
        assert_eq!(Strategy::None.initial_tokens(8), 8);
    }

    #[test]
    #[should_panic]
    fn halving_requires_power_of_two() {
        Strategy::Halving.initial_tokens(6);
    }
}
