//! # dpa — DPA Load Balancer
//!
//! A reproduction of *"DPA Load Balancer: Load balancing for Data Parallel
//! Actor-based systems"* (Wang, Ziai, Aguer — CS.DC 2023) as a
//! production-shaped rust + JAX + Pallas stack.
//!
//! The library implements a streaming map-reduce runtime built from
//! stateful actors in which input skew across hash-partitioned reducers is
//! corrected **at runtime** — no coordinated global rollback. Keyspace
//! routing/redistribution is a pluggable trait layer ([`hash::router`]):
//! the paper's MurmurHash3 consistent-hash token ring ([`hash::ring`])
//! with *token halving* / *token doubling* is one implementation, next to
//! multi-probe consistent hashing (zero token churn) and per-key
//! power-of-two-choices. A load-balancer actor ([`balancer`]) watches
//! per-reducer queue lengths and calls the router's redistribution when
//! the paper's Eq. 1 predicate `Q_max > Q_s * (1 + tau)` fires; the
//! probe routers consume an adaptive load signal ([`balancer::signal`]:
//! EWMA decay, hysteresis overload flags, migration-gain guard) instead
//! of raw instantaneous loads, so repeated redistributions converge
//! rather than ping-pong on adversarial drift. Records enqueued under an
//! old partition scheme are *forwarded* by the dequeuing reducer, and
//! reducer states are *merged* at the end of the run.
//!
//! ## Layers
//!
//! - **L3 (this crate)** — coordinator, mappers, reducers, queues, load
//!   balancer, metrics, CLI. One shared execution core
//!   ([`runtime::exec`]) owns the pipeline semantics; two thin
//!   schedulers drive it: a deterministic discrete-event simulator
//!   ([`sim`]) and real OS threads ([`driver`]). See
//!   `docs/ARCHITECTURE.md` for the layer diagram.
//! - **L2/L1 (python, build-time only)** — the batched data-plane (murmur3
//!   hashing, ring lookup, count aggregation, state merge) authored in
//!   JAX + Pallas and AOT-lowered to HLO text under `artifacts/`.
//! - **runtime** — loads those artifacts through the PJRT CPU client
//!   (`xla` crate) so the rust hot path executes the XLA programs with no
//!   python anywhere near the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dpa::pipeline::{Pipeline, PipelineConfig};
//! use dpa::hash::strategy::Strategy;
//!
//! let mut cfg = PipelineConfig::default();
//! cfg.strategy = Strategy::Doubling;
//! cfg.tau = 0.2;
//! let input: Vec<String> = ["a", "b", "a", "c"].iter().map(|s| s.to_string()).collect();
//! let report = Pipeline::wordcount(cfg).run(input).unwrap();
//! println!("skew S = {:.2}", report.skew());
//! ```

// The `let mut cfg = PipelineConfig::default(); cfg.field = …` pattern is
// the crate's idiom for building experiment configs (mirroring how the
// paper's sweeps override one knob at a time); rewriting every site into
// struct-update syntax would obscure which knob each experiment varies.
#![allow(clippy::field_reassign_with_default)]
// Concurrency-correctness gate: unsafe code is banned crate-wide except
// where explicitly allowed with a SAFETY contract (the sole escape hatch
// is `runtime::programs::SharedRuntime`'s Send/Sync impls), and any
// allowed unsafe must carry a `// SAFETY:` comment or clippy rejects it.
#![deny(unsafe_code)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod sync;

pub mod util;
pub mod hash;
pub mod config;
pub mod cli;
pub mod metrics;
pub mod workload;
pub mod exec;
pub mod actor;
pub mod queue;
pub mod balancer;
pub mod mapper;
pub mod reducer;
pub mod coordinator;
pub mod sim;
pub mod driver;
pub mod pipeline;
pub mod runtime;
pub mod benchkit;
pub mod testkit;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
