//! `dpa` — the DPA Load Balancer CLI.
//!
//! Run `dpa help` for usage. The interesting commands:
//! - `dpa run --workload wl4 --strategy doubling` — one pipeline run
//! - `dpa table1` — reproduce the paper's Table 1 (Experiment 1)
//! - `dpa fig3` — reproduce the paper's Figure 3 (Experiment 2)

fn main() {
    dpa::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dpa::cli::parse(&argv).and_then(dpa::cli::execute) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}
