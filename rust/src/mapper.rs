//! Mapper core logic (§2.1): stateless actors that fetch tasks from the
//! coordinator, apply the map executor to each input element and push the
//! resulting records to the owning reducer's queue — owner resolved
//! through the (shared) consistent-hashing object.
//!
//! Both drivers run this same core; only the surrounding loop differs.

use std::sync::Arc;

use crate::exec::{MapExecutor, Record, Task};
use crate::hash::ring::RingCache;
use crate::hash::SharedRing;

/// Per-mapper state + the map-and-route step.
pub struct MapperCore {
    pub id: usize,
    exec: Arc<dyn MapExecutor>,
    ring: RingCache,
    /// Records emitted (the run report's `mapped[i]`).
    pub emitted: u64,
    /// Input items consumed.
    pub items_in: u64,
    /// Tasks fetched.
    pub tasks_in: u64,
}

impl MapperCore {
    pub fn new(id: usize, exec: Arc<dyn MapExecutor>, ring: SharedRing) -> Self {
        MapperCore {
            id,
            exec,
            ring: RingCache::new(ring),
            emitted: 0,
            items_in: 0,
            tasks_in: 0,
        }
    }

    /// Map one input item and route each output record: returns
    /// `(destination reducer, record)` pairs in emission order.
    pub fn process_item(&mut self, item: &str) -> Vec<(usize, Record)> {
        self.items_in += 1;
        let recs = self.exec.map(item);
        self.emitted += recs.len() as u64;
        recs.into_iter()
            .map(|r| {
                // memoized hash: the reducer's ownership check reuses it
                let dest = self.ring.lookup_hash(r.hash());
                (dest, r)
            })
            .collect()
    }

    /// Process a whole task (convenience for drivers that work per-task).
    pub fn process_task(&mut self, task: &Task) -> Vec<(usize, Record)> {
        self.tasks_in += 1;
        let mut out = Vec::with_capacity(task.items.len());
        for item in task.items.iter() {
            out.extend(self.process_item(item));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::IdentityMap;
    use crate::hash::Ring;

    fn mk() -> MapperCore {
        MapperCore::new(0, Arc::new(IdentityMap), SharedRing::new(Ring::new(4, 8)))
    }

    #[test]
    fn routes_consistently_with_ring() {
        let ring = SharedRing::new(Ring::new(4, 8));
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), ring.clone());
        for key in ["a", "hello", "zz"] {
            let routed = m.process_item(key);
            assert_eq!(routed.len(), 1);
            assert_eq!(routed[0].0, ring.lookup(key.as_bytes()));
            assert_eq!(routed[0].1.key, key);
        }
        assert_eq!(m.emitted, 3);
        assert_eq!(m.items_in, 3);
    }

    #[test]
    fn observes_ring_updates() {
        let ring = SharedRing::new(Ring::new(4, 1));
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), ring.clone());
        // find a key owned by node 0, then double others until it moves
        let pool = crate::workload::generators::key_pool();
        let key = pool
            .iter()
            .find(|k| ring.lookup(k.as_bytes()) == 0)
            .unwrap()
            .clone();
        assert_eq!(m.process_item(&key)[0].0, 0);
        let mut moved = false;
        for _ in 0..7 {
            ring.update(|r| r.double_others(0));
            if m.process_item(&key)[0].0 != 0 {
                moved = true;
                break;
            }
        }
        assert!(moved, "key never left the overloaded node after 7 doublings");
    }

    #[test]
    fn task_processing_counts() {
        let mut m = mk();
        let task = Task { id: 0, items: vec!["a".to_string(), "b".to_string()].into() };
        let routed = m.process_task(&task);
        assert_eq!(routed.len(), 2);
        assert_eq!(m.tasks_in, 1);
    }
}
