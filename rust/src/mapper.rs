//! Mapper core logic (§2.1): stateless actors that fetch tasks from the
//! coordinator, apply the map executor to each input element and push the
//! resulting records to the owning reducer's queue — owner resolved
//! through the shared routing layer ([`RouterHandle`] /
//! [`RouterCache`]).
//!
//! Both drivers run this same core; only the surrounding loop differs.

use std::sync::Arc;

use crate::exec::{MapExecutor, Record, Task};
use crate::hash::{RouterCache, RouterHandle};

/// Per-mapper state + the map-and-route step.
pub struct MapperCore {
    pub id: usize,
    exec: Arc<dyn MapExecutor>,
    router: RouterCache,
    /// Records emitted (the run report's `mapped[i]`).
    pub emitted: u64,
    /// Input items consumed.
    pub items_in: u64,
    /// Tasks fetched.
    pub tasks_in: u64,
}

impl MapperCore {
    pub fn new(id: usize, exec: Arc<dyn MapExecutor>, router: RouterHandle) -> Self {
        MapperCore {
            id,
            exec,
            router: router.cache(),
            emitted: 0,
            items_in: 0,
            tasks_in: 0,
        }
    }

    /// Map one input item and route each output record: returns
    /// `(destination reducer, record)` pairs in emission order.
    pub fn process_item(&mut self, item: &str) -> Vec<(usize, Record)> {
        self.items_in += 1;
        let recs = self.exec.map(item);
        self.emitted += recs.len() as u64;
        recs.into_iter()
            .map(|r| {
                // memoized hash: the reducer's ownership check reuses it
                let dest = self.router.route_hash(r.hash());
                (dest, r)
            })
            .collect()
    }

    /// Process a whole task (convenience for drivers that work per-task).
    pub fn process_task(&mut self, task: &Task) -> Vec<(usize, Record)> {
        self.tasks_in += 1;
        let mut out = Vec::with_capacity(task.items.len());
        for item in task.items.iter() {
            out.extend(self.process_item(item));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::IdentityMap;
    use crate::hash::{Ring, RingOp};

    fn mk() -> MapperCore {
        MapperCore::new(
            0,
            Arc::new(IdentityMap),
            RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp),
        )
    }

    #[test]
    fn routes_consistently_with_router() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), router.clone());
        for key in ["a", "hello", "zz"] {
            let routed = m.process_item(key);
            assert_eq!(routed.len(), 1);
            assert_eq!(routed[0].0, router.route_key(key.as_bytes()));
            assert_eq!(routed[0].1.key, key);
        }
        assert_eq!(m.emitted, 3);
        assert_eq!(m.items_in, 3);
    }

    #[test]
    fn observes_router_updates() {
        let router = RouterHandle::token_ring(Ring::new(4, 1), RingOp::NoOp);
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), router.clone());
        // find a key owned by node 0, then double others until it moves
        let pool = crate::workload::generators::key_pool();
        let key = pool
            .iter()
            .find(|k| router.route_key(k.as_bytes()) == 0)
            .unwrap()
            .clone();
        assert_eq!(m.process_item(&key)[0].0, 0);
        let mut moved = false;
        for _ in 0..7 {
            router.update_ring(|r| r.double_others(0)).unwrap();
            if m.process_item(&key)[0].0 != 0 {
                moved = true;
                break;
            }
        }
        assert!(moved, "key never left the overloaded node after 7 doublings");
    }

    #[test]
    fn task_processing_counts() {
        let mut m = mk();
        let task = Task { id: 0, items: vec!["a".to_string(), "b".to_string()].into() };
        let routed = m.process_task(&task);
        assert_eq!(routed.len(), 2);
        assert_eq!(m.tasks_in, 1);
    }

    #[test]
    fn routes_through_probe_routers_too() {
        let router =
            RouterHandle::new(crate::hash::StrategySpec::TwoChoices.build_router(4, 8, None));
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), router.clone());
        let dest = m.process_item("some-key")[0].0;
        assert!(dest < 4);
        // sticky: re-mapping the same key lands on the same reducer
        assert_eq!(m.process_item("some-key")[0].0, dest);
        assert_eq!(router.route_key(b"some-key"), dest);
    }
}
