//! Mapper core logic (§2.1): stateless actors that fetch tasks from the
//! coordinator, apply the map executor to each input element and push the
//! resulting records to the owning reducer's queue — owner resolved
//! through the shared routing layer ([`RouterHandle`] /
//! [`RouterCache`]).
//!
//! Both drivers run this same core; only the surrounding loop differs.
//! With a [`SharedRuntime`] attached ([`MapperCore::with_route_runtime`])
//! the per-task path routes through the compiled XLA route program of the
//! router's family ([`SharedRuntime::route_batch_snapshot`]) — hash +
//! owner for a whole task in one batched call — falling back to the
//! scalar [`RouterCache`] when the snapshot has no compiled lowering.

use std::sync::Arc;

use crate::exec::{MapExecutor, Record, Task};
use crate::hash::{RouterCache, RouterHandle};
use crate::runtime::programs::SharedRuntime;

/// Per-mapper state + the map-and-route step.
pub struct MapperCore {
    pub id: usize,
    exec: Arc<dyn MapExecutor>,
    router: RouterCache,
    /// Compiled data plane for batched routing (`None` = scalar routing).
    route_runtime: Option<Arc<SharedRuntime>>,
    /// Last snapshot taken for the batched path, tagged with its epoch.
    /// Reused across tasks for routers whose snapshot is a pure function
    /// of the epoch (token-ring, multi-probe) — no per-task state clone
    /// or shared-lock traffic; sticky-table snapshots are refreshed every
    /// task (the table grows within an epoch).
    snapshot_cache: Option<(u64, crate::hash::RouteSnapshot)>,
    /// Records emitted (the run report's `mapped[i]`).
    pub emitted: u64,
    /// Input items consumed.
    pub items_in: u64,
    /// Tasks fetched.
    pub tasks_in: u64,
    /// Records routed through the compiled batch path.
    pub compiled_routed: u64,
}

impl MapperCore {
    pub fn new(id: usize, exec: Arc<dyn MapExecutor>, router: RouterHandle) -> Self {
        MapperCore {
            id,
            exec,
            router: router.cache(),
            route_runtime: None,
            snapshot_cache: None,
            emitted: 0,
            items_in: 0,
            tasks_in: 0,
            compiled_routed: 0,
        }
    }

    /// Route whole tasks through the compiled XLA route program of the
    /// router's snapshot family.
    pub fn with_route_runtime(mut self, rt: Arc<SharedRuntime>) -> Self {
        self.route_runtime = Some(rt);
        self
    }

    /// Map one input item and route each output record: returns
    /// `(destination reducer, record)` pairs in emission order.
    pub fn process_item(&mut self, item: &str) -> Vec<(usize, Record)> {
        self.items_in += 1;
        let recs = self.exec.map(item);
        self.emitted += recs.len() as u64;
        recs.into_iter()
            .map(|r| {
                // memoized hash: the reducer's ownership check reuses it
                let dest = self.router.route_hash(r.hash());
                (dest, r)
            })
            .collect()
    }

    /// Process a whole task. With a route runtime attached, the task's
    /// records are hashed *and* routed in one batched XLA call per `B`
    /// records; otherwise the scalar router runs over the whole task as
    /// one [`RouterCache::route_batch`] slice — a single epoch staleness
    /// check per task instead of one per record.
    pub fn process_task(&mut self, task: &Task) -> Vec<(usize, Record)> {
        if self.route_runtime.is_none() {
            self.tasks_in += 1;
            self.items_in += task.items.len() as u64;
            let mut recs = Vec::with_capacity(task.items.len());
            for item in task.items.iter() {
                recs.extend(self.exec.map(item));
            }
            self.emitted += recs.len() as u64;
            let hashes: Vec<u32> = recs.iter().map(|r| r.hash()).collect();
            let mut dests = Vec::new();
            self.router.route_batch(&hashes, &mut dests);
            return dests.into_iter().zip(recs).map(|(d, r)| (d, r)).collect();
        }
        self.tasks_in += 1;
        let mut recs = Vec::with_capacity(task.items.len());
        for item in task.items.iter() {
            self.items_in += 1;
            recs.extend(self.exec.map(item));
        }
        self.emitted += recs.len() as u64;
        self.route_records(recs)
    }

    /// Batched routing over the current snapshot, with the scalar path as
    /// fallback for snapshots the loaded artifacts cannot serve.
    fn route_records(&mut self, recs: Vec<Record>) -> Vec<(usize, Record)> {
        let rt = self.route_runtime.clone().expect("checked by caller");
        let epoch = self.router.handle().epoch();
        let refresh = match &self.snapshot_cache {
            Some((e, snap)) => {
                *e != epoch
                    || matches!(
                        snap.state,
                        crate::hash::SnapshotState::Assignment { .. }
                            | crate::hash::SnapshotState::Split { .. }
                    )
            }
            None => true,
        };
        if refresh {
            self.snapshot_cache = Some((epoch, self.router.snapshot()));
        }
        let snap = &self.snapshot_cache.as_ref().expect("just filled").1;
        let keys: Vec<&[u8]> = recs.iter().map(|r| r.key.as_bytes()).collect();
        match rt.route_batch_snapshot(&keys, snap) {
            Ok(routed) => {
                // sticky-table routers: record first-sight choices so the
                // shared table (which reducers' ownership checks consult)
                // agrees with the owners we just computed. First writer
                // wins; a lost race is a stale send the forwarding
                // mechanism absorbs, never a split key.
                if let Some(table) = snap.assignments() {
                    let fresh: Vec<(u32, u32)> = routed
                        .iter()
                        .filter(|(h, _)| table.binary_search_by_key(h, |&(k, _)| k).is_err())
                        .map(|&(h, o)| (h, o as u32))
                        .collect();
                    self.router.handle().record_assignments(&fresh);
                }
                self.compiled_routed += routed.len() as u64;
                recs.into_iter()
                    .zip(routed)
                    .map(|(r, (h, dest))| {
                        r.prime_hash(h);
                        (dest, r)
                    })
                    .collect()
            }
            Err(e) => {
                if e.downcast_ref::<crate::runtime::Error>().is_some() {
                    // structural (artifacts lack this family's program, or
                    // the live state outgrew the compiled capacity): it
                    // would fail identically every task — go scalar for
                    // the rest of the run
                    log::debug!(
                        "mapper {}: compiled route path disabled, routing scalar: {e:#}",
                        self.id
                    );
                    self.route_runtime = None;
                    self.snapshot_cache = None;
                } else {
                    // a real execution fault deserves a loud signal; the
                    // scalar fallback keeps the run correct
                    log::warn!(
                        "mapper {}: compiled route failed, routed this task scalar: {e:#}",
                        self.id
                    );
                }
                recs.into_iter()
                    .map(|r| {
                        let dest = self.router.route_hash(r.hash());
                        (dest, r)
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::IdentityMap;
    use crate::hash::{Ring, RingOp};

    fn mk() -> MapperCore {
        MapperCore::new(
            0,
            Arc::new(IdentityMap),
            RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp),
        )
    }

    #[test]
    fn routes_consistently_with_router() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), router.clone());
        for key in ["a", "hello", "zz"] {
            let routed = m.process_item(key);
            assert_eq!(routed.len(), 1);
            assert_eq!(routed[0].0, router.route_key(key.as_bytes()));
            assert_eq!(routed[0].1.key, key);
        }
        assert_eq!(m.emitted, 3);
        assert_eq!(m.items_in, 3);
    }

    #[test]
    fn observes_router_updates() {
        let router = RouterHandle::token_ring(Ring::new(4, 1), RingOp::NoOp);
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), router.clone());
        // find a key owned by node 0, then double others until it moves
        let pool = crate::workload::generators::key_pool();
        let key = pool
            .iter()
            .find(|k| router.route_key(k.as_bytes()) == 0)
            .unwrap()
            .clone();
        assert_eq!(m.process_item(&key)[0].0, 0);
        let mut moved = false;
        for _ in 0..7 {
            router.update_ring(|r| r.double_others(0)).unwrap();
            if m.process_item(&key)[0].0 != 0 {
                moved = true;
                break;
            }
        }
        assert!(moved, "key never left the overloaded node after 7 doublings");
    }

    #[test]
    fn task_processing_counts() {
        let mut m = mk();
        let task = Task { id: 0, items: vec!["a".to_string(), "b".to_string()].into() };
        let routed = m.process_task(&task);
        assert_eq!(routed.len(), 2);
        assert_eq!(m.tasks_in, 1);
    }

    #[test]
    fn routes_through_probe_routers_too() {
        let router =
            RouterHandle::new(crate::hash::StrategySpec::TwoChoices.build_router(4, 8, None));
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), router.clone());
        let dest = m.process_item("some-key")[0].0;
        assert!(dest < 4);
        // sticky: re-mapping the same key lands on the same reducer
        assert_eq!(m.process_item("some-key")[0].0, dest);
        assert_eq!(router.route_key(b"some-key"), dest);
    }

    #[test]
    fn routes_through_split_key_router_scalar() {
        let router = RouterHandle::new(
            crate::hash::StrategySpec::SplitKey { d: 2 }.build_router(4, 8, None),
        );
        let mut m = MapperCore::new(0, Arc::new(IdentityMap), router.clone());
        let dest = m.process_item("cold-key")[0].0;
        assert!(dest < 4);
        // cold keys stay sticky until the watermark promotes them
        assert_eq!(m.process_item("cold-key")[0].0, dest);
    }
}
