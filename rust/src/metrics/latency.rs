//! Per-record latency histogram — HDR-style bucketed, allocation-free on
//! the record path. The hot path does ONE relaxed `fetch_add` per record
//! (no locks, no per-record allocation), so the measurement substrate the
//! throughput bench relies on cannot itself perturb the hot path it
//! measures.
//!
//! Bucketing: values below 32 get exact unit buckets; above that, each
//! power-of-two group is split into 32 log-linear subbuckets (5 bits of
//! precision, ≤ ~3% relative error) — the classic HdrHistogram layout,
//! sized here for `u64` values (µs on the threads driver, virtual ticks
//! on the sim).

#![forbid(unsafe_code)]

use crate::sync::atomic::{AtomicU64, Ordering};

/// 5 bits of subbucket precision per power-of-two group.
const SUB_BITS: u32 = 5;
const SUB_COUNT: usize = 1 << SUB_BITS; // 32
/// Unit buckets 0..32, then groups for msb 5..=63 → 60 groups of 32.
const BUCKETS: usize = SUB_COUNT * 60;

#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB_COUNT as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS
    let group = (msb - SUB_BITS + 1) as usize; // 1..=59
    // top 6 bits of v are in [32, 64); subtracting 32 yields the subbucket
    let sub = (v >> (msb - SUB_BITS)) as usize - SUB_COUNT;
    group * SUB_COUNT + sub
}

/// Lower edge of a bucket — the value `percentile` reports for it.
#[inline]
fn bucket_value(index: usize) -> u64 {
    if index < SUB_COUNT {
        return index as u64;
    }
    let group = index / SUB_COUNT; // >= 1
    let sub = index % SUB_COUNT;
    ((SUB_COUNT + sub) as u64) << (group - 1)
}

/// Concurrent latency histogram. `record` is safe to call from any number
/// of reducer threads simultaneously; readers (`percentile`, `stats`)
/// take an unsynchronized snapshot, which is exact once the run is over.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let buckets: Box<[AtomicU64]> =
            (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram { buckets, count: AtomicU64::new(0) }
    }

    /// Record one latency sample — one relaxed `fetch_add`, no allocation.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Value at percentile `p` in [0, 100] (lower bucket edge, ≤ ~3%
    /// relative error). 0 for an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_value(i);
            }
        }
        bucket_value(BUCKETS - 1)
    }

    /// Unsynchronized per-bucket snapshot (exact once recording has
    /// quiesced) — lets the concurrency property tests compare a
    /// multi-thread run against a sequential merge bucket by bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// The count/p50/p99 summary reports carry.
    pub fn stats(&self) -> LatencyStats {
        LatencyStats {
            count: self.count(),
            p50: self.percentile(50.0),
            p99: self.percentile(99.0),
        }
    }
}

/// Frozen latency summary attached to a
/// [`RunReport`](crate::metrics::RunReport). Units follow the driver
/// clock: µs on threads, virtual ticks on the sim.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencyStats {
    pub count: u64,
    pub p50: u64,
    pub p99: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 32);
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(100.0), 31);
        // rank 16 of 32 → value 15 exactly (unit buckets)
        assert_eq!(h.percentile(50.0), 15);
    }

    #[test]
    fn large_values_within_relative_error() {
        let h = Histogram::new();
        for &v in &[1_000u64, 50_000, 1_000_000, u64::MAX / 2] {
            h.record(v);
            let got = bucket_value(bucket_index(v));
            assert!(got <= v, "edge {got} above sample {v}");
            assert!(
                (v - got) as f64 / v as f64 <= 1.0 / SUB_COUNT as f64,
                "error too big for {v}: edge {got}"
            );
        }
    }

    #[test]
    fn bucket_index_is_monotone_on_edges() {
        for i in 0..BUCKETS {
            assert_eq!(bucket_index(bucket_value(i)), i, "bucket {i}");
        }
    }

    #[test]
    fn percentiles_split_a_bimodal_set() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record(10);
        }
        h.record(1_000_000);
        let s = h.stats();
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, 10);
        assert!(s.p99 == 10, "p99 rank 99 still lands on the mode");
        assert!(h.percentile(100.0) >= 970_000);
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.stats(), LatencyStats::default());
    }

    #[test]
    fn concurrent_recording_conserves_count() {
        // miri interprets ~300x slower; shrink the sample count, the
        // interleaving coverage comes from running under its scheduler
        let n: u64 = if cfg!(miri) { 200 } else { 10_000 };
        let h = std::sync::Arc::new(Histogram::new());
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..n {
                    h.record(t * 1000 + i);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4 * n);
    }
}
