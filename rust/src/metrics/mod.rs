//! Metrics: the paper's skew metric `S` (§6.1.1), per-reducer counters,
//! the per-record latency histogram and the run report produced by every
//! pipeline execution.

pub mod latency;
pub mod skew;
pub mod report;

pub use latency::{Histogram, LatencyStats};
pub use report::{FaultRecord, LbEvent, MembershipChange, RecoveryCounts, RunReport};
pub use skew::skew;
