//! Run reports: everything a pipeline execution measured, in one struct,
//! with pretty-printing for the CLI / examples / benches.

use std::time::Duration;

use crate::hash::{RouteDelta, Strategy};
use crate::util::table::{f2, Table};

use super::latency::LatencyStats;
use super::skew::skew;

/// An elastic reducer-membership change carried by an [`LbEvent`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipChange {
    /// A brand-new reducer joined the routable set (scale-up). The
    /// drivers spawn its actor when they see this event.
    Added { id: u32 },
    /// A reducer left the routable set (scale-down). Its actor drains —
    /// the ownership check forwards everything it still holds — and its
    /// remaining state merges exactly once at the end (§7 extraction
    /// ships it immediately under state forwarding).
    Retired { id: u32 },
}

/// One injected fault, as logged by the chaos controller
/// (`testkit::chaos`) when it fired.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultRecord {
    /// Driver clock when the fault fired (virtual ticks on the sim,
    /// elapsed µs on threads).
    pub at: u64,
    /// Victim reducer id.
    pub reducer: usize,
    /// Fault kind name: `kill`, `slow`, `stall` or `drop`.
    pub kind: String,
}

/// Crash-recovery accounting for a chaos run (all zeros on fault-free
/// runs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryCounts {
    /// Reducers fail-stopped by the plan.
    pub kills: u64,
    /// Retire-and-respawn sequences completed.
    pub respawns: u64,
    /// Checkpoints cut to a peer over the priority lane.
    pub checkpoints: u64,
    /// (key, partial) pairs rebuilt and re-homed during recoveries.
    pub state_restored: u64,
    /// Write-ahead-log entries replayed on top of checkpoints.
    pub wal_replayed: u64,
    /// Envelopes re-routed out of dead reducers' queues.
    pub requeued: u64,
}

/// One load-balancing event — a `redistribute(node)` call that changed
/// the routing, or an elastic membership change — recorded by the
/// balancer.
#[derive(Clone, Debug)]
pub struct LbEvent {
    /// Virtual time (sim driver) or elapsed µs (thread driver).
    pub at: u64,
    /// The overloaded reducer the event targeted (for membership events:
    /// the joining / retiring reducer).
    pub target: u32,
    /// Queue lengths observed when the predicate fired.
    pub qlens: Vec<usize>,
    /// Router epoch after the update.
    pub epoch: u64,
    /// Strategy spec applied.
    pub strategy: Strategy,
    /// What the router's redistribution changed (token churn / key
    /// re-homes; empty-churn for multi-probe).
    pub delta: RouteDelta,
    /// Elastic membership change (`None` for a plain redistribution).
    pub membership: Option<MembershipChange>,
}

/// Full accounting of a pipeline run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Messages *reduced* per reducer (the paper's `M_i`).
    pub processed: Vec<u64>,
    /// Messages each reducer forwarded onward after a repartition.
    pub forwarded: Vec<u64>,
    /// Records each mapper emitted.
    pub mapped: Vec<u64>,
    /// Load-balancing events in order.
    pub lb_events: Vec<LbEvent>,
    /// Final merged result (key, aggregate).
    pub result: Vec<(String, i64)>,
    /// Wall-clock duration of the run (threads driver; sim reports virtual
    /// end time separately).
    pub wall: Duration,
    /// Virtual end time (sim driver), 0 for threads.
    pub virtual_end: u64,
    /// Peak queue length observed per reducer.
    pub peak_qlen: Vec<usize>,
    /// Total items of input consumed.
    pub input_items: u64,
    /// Per-record map-enqueue → reduce latency summary (µs on the threads
    /// driver, virtual ticks on the sim); `None` when no record carried a
    /// stamp.
    pub latency: Option<LatencyStats>,
    /// Injected faults in firing order (chaos runs only).
    pub fault_events: Vec<FaultRecord>,
    /// Crash-recovery counters (zeros for fault-free runs).
    pub recovery: RecoveryCounts,
    /// Kill → respawn-complete latency summary (same units as `latency`);
    /// `None` when the run had no kills.
    pub recovery_latency: Option<LatencyStats>,
}

impl RunReport {
    /// The paper's skew metric `S` over reduced-message counts.
    pub fn skew(&self) -> f64 {
        skew(&self.processed)
    }

    pub fn total_processed(&self) -> u64 {
        self.processed.iter().sum()
    }

    pub fn total_forwarded(&self) -> u64 {
        self.forwarded.iter().sum()
    }

    pub fn lb_rounds(&self) -> usize {
        self.lb_events.len()
    }

    /// Redistributions that actually changed the routing (every recorded
    /// event did — no-op redistributes are not events). This is the
    /// migration count `dpa table1` and the bench gate track: on
    /// adversarial drift (WL3) a raw load signal makes it balloon
    /// (ping-pong) while the decayed+hysteresis signal keeps it small.
    pub fn migrations(&self) -> u64 {
        self.lb_events.len() as u64
    }

    /// Keys explicitly re-homed across all events (two-choices family;
    /// token churn families move keys implicitly instead).
    pub fn keys_reassigned(&self) -> u64 {
        self.lb_events.iter().map(|e| e.delta.keys_reassigned).sum()
    }

    /// Elastic membership events, in order (empty for fixed-membership
    /// runs).
    pub fn membership_events(&self) -> Vec<&LbEvent> {
        self.lb_events.iter().filter(|e| e.membership.is_some()).collect()
    }

    /// Reducers added / retired by elastic scaling over the run.
    pub fn scale_counts(&self) -> (u64, u64) {
        let mut added = 0;
        let mut retired = 0;
        for e in &self.lb_events {
            match e.membership {
                Some(MembershipChange::Added { .. }) => added += 1,
                Some(MembershipChange::Retired { .. }) => retired += 1,
                None => {}
            }
        }
        (added, retired)
    }

    /// Throughput in reduced messages per wall second (threads driver).
    pub fn throughput(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return f64::NAN;
        }
        self.total_processed() as f64 / secs
    }

    /// Validate internal consistency; returns an error string on mismatch.
    /// Every mapped record must be reduced exactly once (forwards do not
    /// duplicate or drop messages) — the core correctness invariant of the
    /// forwarding design.
    pub fn check_conservation(&self) -> Result<(), String> {
        let mapped: u64 = self.mapped.iter().sum();
        let processed = self.total_processed();
        if mapped != processed {
            return Err(format!(
                "conservation violated: {mapped} mapped records vs {processed} reduced"
            ));
        }
        Ok(())
    }

    /// Render a human-readable summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "S = {:.4}   processed = {:?}   forwarded = {:?}   lb_events = {}\n",
            self.skew(),
            self.processed,
            self.forwarded,
            self.lb_events.len()
        ));
        if self.virtual_end > 0 {
            out.push_str(&format!("virtual time = {}\n", self.virtual_end));
        }
        if !self.wall.is_zero() {
            out.push_str(&format!(
                "wall = {:?}  throughput = {:.0} msg/s\n",
                self.wall,
                self.throughput()
            ));
        }
        if let Some(lat) = self.latency {
            let unit = if self.virtual_end > 0 { "ticks" } else { "µs" };
            out.push_str(&format!(
                "latency p50 = {} {unit}  p99 = {} {unit}  ({} records)\n",
                lat.p50, lat.p99, lat.count
            ));
        }
        if !self.fault_events.is_empty() {
            out.push_str(&format!(
                "faults = {}  kills = {}  respawns = {}  checkpoints = {}  \
                 wal replayed = {}  state restored = {}  requeued = {}\n",
                self.fault_events.len(),
                self.recovery.kills,
                self.recovery.respawns,
                self.recovery.checkpoints,
                self.recovery.wal_replayed,
                self.recovery.state_restored,
                self.recovery.requeued,
            ));
            if let Some(lat) = self.recovery_latency {
                let unit = if self.virtual_end > 0 { "ticks" } else { "µs" };
                out.push_str(&format!(
                    "recovery p50 = {} {unit}  p99 = {} {unit}  ({} kills)\n",
                    lat.p50, lat.p99, lat.count
                ));
            }
        }
        let mut t = Table::new(["reducer", "processed", "forwarded", "peak qlen"]);
        for i in 0..self.processed.len() {
            t.row([
                i.to_string(),
                self.processed[i].to_string(),
                self.forwarded.get(i).copied().unwrap_or(0).to_string(),
                self.peak_qlen.get(i).copied().unwrap_or(0).to_string(),
            ]);
        }
        out.push_str(&t.render());
        for f in &self.fault_events {
            out.push_str(&format!("CHAOS@{} {} reducer {}\n", f.at, f.kind, f.reducer));
        }
        for e in &self.lb_events {
            match e.membership {
                Some(MembershipChange::Added { id }) => out.push_str(&format!(
                    "LB@{} SCALE-UP reducer {id} joined (epoch {}, +{} tokens) qlens={:?}\n",
                    e.at, e.epoch, e.delta.tokens_added, e.qlens
                )),
                Some(MembershipChange::Retired { id }) => out.push_str(&format!(
                    "LB@{} SCALE-DOWN reducer {id} retired (epoch {}, -{} tokens, \
                     {} keys re-homed) qlens={:?}\n",
                    e.at, e.epoch, e.delta.tokens_removed, e.delta.keys_reassigned, e.qlens
                )),
                None => out.push_str(&format!(
                    "LB@{} target={} strategy={} qlens={:?} \
                     (+{} / -{} tokens, {} keys re-homed)\n",
                    e.at,
                    e.target,
                    e.strategy,
                    e.qlens,
                    e.delta.tokens_added,
                    e.delta.tokens_removed,
                    e.delta.keys_reassigned
                )),
            }
        }
        out
    }

    /// Short one-line summary (for sweeps).
    pub fn one_line(&self) -> String {
        format!(
            "S={} events={} processed={:?}",
            f2(self.skew()),
            self.lb_events.len(),
            self.processed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunReport {
        RunReport {
            processed: vec![85, 5, 5, 5],
            forwarded: vec![0, 0, 0, 0],
            mapped: vec![25, 25, 25, 25],
            peak_qlen: vec![40, 5, 5, 5],
            input_items: 100,
            ..Default::default()
        }
    }

    #[test]
    fn skew_delegates_to_metric() {
        assert!((sample().skew() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn conservation_check() {
        let r = sample();
        assert!(r.check_conservation().is_ok());
        let mut bad = sample();
        bad.processed[0] -= 1;
        assert!(bad.check_conservation().is_err());
    }

    #[test]
    fn render_contains_table() {
        let r = sample();
        let s = r.render();
        assert!(s.contains("S = 0.8000"));
        assert!(s.contains("| reducer"));
    }

    #[test]
    fn throughput_nan_without_wall() {
        assert!(sample().throughput().is_nan());
    }

    #[test]
    fn migration_counters() {
        let mut r = sample();
        assert_eq!(r.migrations(), 0);
        assert_eq!(r.keys_reassigned(), 0);
        for moved in [2u64, 3] {
            r.lb_events.push(LbEvent {
                at: 0,
                target: 0,
                qlens: vec![],
                epoch: 2,
                strategy: Strategy::TwoChoices,
                delta: RouteDelta {
                    changed: true,
                    keys_reassigned: moved,
                    ..RouteDelta::default()
                },
                membership: None,
            });
        }
        assert_eq!(r.migrations(), 2);
        assert_eq!(r.keys_reassigned(), 5);
    }
}
