//! The paper's skew metric (§6.1.1):
//!
//! Let `M_i` be the messages processed by reducer `i`, `M = Σ M_i`,
//! `U = ceil(M / R)` the ideal per-reducer load and `W = max_i M_i`.
//!
//! ```text
//! S = (W - U) / (M - U)
//! ```
//!
//! `S = 0` means no skew, `S = 1` means all messages were processed by a
//! single reducer. "Processed" counts messages actually *reduced*: a
//! message forwarded by reducer A and reduced by reducer B counts once,
//! at B.

use crate::util::ceil_div;

/// Compute `S` over per-reducer processed-message counts.
///
/// Degenerate cases: fewer than 2 reducers, zero messages, or `M == U`
/// (e.g. M < R so one message per reducer is already "ideal") return 0.
pub fn skew(processed: &[u64]) -> f64 {
    let r = processed.len() as u64;
    if r <= 1 {
        return 0.0;
    }
    let m: u64 = processed.iter().sum();
    if m == 0 {
        return 0.0;
    }
    let u = ceil_div(m, r);
    let w = *processed.iter().max().unwrap();
    if m <= u {
        return 0.0;
    }
    // W >= ceil(M/R) is guaranteed only when loads are integral and R | M;
    // with U = ceil(M/R), W can be U-1... clamp into [0, 1].
    let s = (w as f64 - u as f64) / (m as f64 - u as f64);
    s.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_zero() {
        assert_eq!(skew(&[25, 25, 25, 25]), 0.0);
    }

    #[test]
    fn single_reducer_takes_all_is_one() {
        assert_eq!(skew(&[100, 0, 0, 0]), 1.0);
    }

    #[test]
    fn paper_wl4_halving_value() {
        // W = 85, M = 100, R = 4 -> U = 25, S = 60/75 = 0.8
        let s = skew(&[85, 5, 5, 5]);
        assert!((s - 0.8).abs() < 1e-12, "s = {s}");
    }

    #[test]
    fn paper_wl5_halving_value() {
        // W = 40 -> S = 15/75 = 0.2
        let s = skew(&[40, 20, 20, 20]);
        assert!((s - 0.2).abs() < 1e-12, "s = {s}");
    }

    #[test]
    fn rounding_of_u_uses_ceiling() {
        // M = 101, R = 4 -> U = 26
        let s = skew(&[26, 25, 25, 25]);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn degenerate_cases_are_zero() {
        assert_eq!(skew(&[]), 0.0);
        assert_eq!(skew(&[7]), 0.0);
        assert_eq!(skew(&[0, 0, 0, 0]), 0.0);
        assert_eq!(skew(&[1, 0, 0, 0]), 0.0); // M == U == 1
    }

    #[test]
    fn range_is_clamped() {
        for loads in [
            vec![3u64, 3, 3, 1],
            vec![10, 0, 0, 1],
            vec![1, 1, 1, 1],
            vec![99, 1, 0, 0],
        ] {
            let s = skew(&loads);
            assert!((0.0..=1.0).contains(&s), "{loads:?} -> {s}");
        }
    }
}
