//! The high-level pipeline API: configure once, run a workload, get a
//! [`RunReport`]. This is the library's main entry point and what the CLI,
//! examples and benches drive.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context};

use crate::balancer::elastic::{ElasticConfig, ElasticController};
use crate::balancer::signal::SignalConfig;
use crate::balancer::state_forward::ConsistencyMode;
use crate::balancer::BalancerCore;
use crate::config::Document;
use crate::driver::{ThreadDriver, ThreadParams};
use crate::exec::builtin::{Distinct, IdentityMap, KeyValueMap, TokenizeMap, TopK, WordCount};
use crate::exec::{MapExecutor, ReduceFactory};
use crate::hash::{MergeContract, Ring, RouterHandle, Strategy};
use crate::metrics::RunReport;
use crate::sim::{SimCosts, SimDriver, SimParams};
use crate::testkit::chaos::{ChaosConfig, ChaosPlan};

/// Which execution driver runs the actors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriverKind {
    /// Deterministic discrete-event simulation (virtual time, seeded).
    Sim,
    /// Real OS threads (wall time, nondeterministic).
    Threads,
}

impl std::str::FromStr for DriverKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sim" | "des" => Ok(DriverKind::Sim),
            "threads" | "thread" => Ok(DriverKind::Threads),
            other => Err(format!("unknown driver '{other}' (expected sim|threads)")),
        }
    }
}

/// Builtin executor selection (CLI-facing; library users can pass custom
/// executors via [`Pipeline::new`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Count per-key occurrences of pre-split items (paper's workload).
    WordCount,
    /// Tokenize lines, then count words (e2e corpus pipeline).
    TokenizedWordCount,
    /// Parse `key:value` items and sum values per key.
    KeyedSum,
    /// Distinct keys.
    Distinct,
    /// Word count + top-k post-selection.
    TopK(usize),
}

/// Everything a pipeline run needs. Defaults mirror the paper's
/// evaluation setup: 4 mappers, 4 reducers, τ = 0.2, one LB round.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub mappers: usize,
    pub reducers: usize,
    /// Redistribution strategy spec
    /// ([`StrategySpec::None`](crate::hash::StrategySpec::None) = the paper's
    /// "No LB" baseline; `multiprobe[:K]` and `twochoices` select the
    /// probe-based routers).
    pub strategy: Strategy,
    /// Eq. 1 sensitivity threshold τ.
    pub tau: f64,
    /// Initial tokens/node for the halving layout (power of two, §4.2).
    pub halving_init_tokens: u32,
    /// Override the initial tokens/node regardless of strategy — used to
    /// run the no-LB baseline on a specific method's initial layout.
    pub initial_tokens: Option<u32>,
    /// Max LB rounds per reducer (Experiment 2 sweeps this).
    pub max_rounds: u32,
    /// Absolute floor on `Q_max` before Eq. 1 may fire.
    pub min_trigger_qlen: usize,
    /// Min driver-time between LB events (sim: ticks; threads: µs).
    pub cooldown: u64,
    /// Split-key only (`splitkey[:D]`): decayed-load threshold (queue
    /// length scale) a single key's estimated load must cross before the
    /// router promotes it from sticky to d-way split. Other strategies
    /// ignore it. TOML: `balancer.split_watermark`.
    pub split_watermark: f64,
    /// The adaptive load-signal knobs (EWMA decay, hysteresis band,
    /// migration-gain guard) the routers consume. The Eq. 1 *trigger*
    /// keeps evaluating raw queue lengths — the paper's policy semantics
    /// are untouched; the signal shapes what the probe routers freeze and
    /// which key migrations two-choices admits.
    pub signal: SignalConfig,
    /// Failure-domain map: zone groups separated by `;`, node ids by `,`
    /// (`"0,1;2,3"` = two zones of two reducers). `None` = no zones —
    /// every node is its own singleton domain. Zone-aware routers
    /// (`ptable[:B][:R]`) place replicas across distinct zones and the
    /// chaos checkpoint path prefers a cross-zone peer. TOML:
    /// `balancer.zones`; CLI: `--zones`.
    pub zones: Option<String>,
    /// Elastic reducer membership: `None` = the reducer set is fixed for
    /// the whole run (the paper's setup); `Some` attaches the
    /// decayed-signal scaling policy — the run starts at `reducers` live
    /// reducers and may grow to `max_reducers` / shrink to
    /// `min_reducers`, with every membership change flowing through the
    /// §7 synchronization machinery. Enabled by any of the
    /// `balancer.{scale_up,scale_down,min_reducers,max_reducers}` TOML
    /// keys or their CLI flags; the scale cooldown rides
    /// `balancer.cooldown`.
    pub elastic: Option<ElasticConfig>,
    /// Load report every N handled messages.
    pub report_interval: u64,
    /// Items per coordinator task.
    pub chunk_size: usize,
    /// Per-reducer queue capacity (threads driver backpressure).
    pub queue_capacity: usize,
    pub driver: DriverKind,
    /// Sim RNG seed (schedule jitter).
    pub seed: u64,
    pub sim_costs: SimCosts,
    /// Threads driver: busy-work per mapped item / reduced record (µs).
    pub map_delay_us: u64,
    pub reduce_delay_us: u64,
    /// Threads driver: reducer queue-poll timeout (ms). Bounds how long an
    /// idle reducer waits before re-checking shutdown / §7 extraction
    /// duties.
    pub pop_timeout_ms: u64,
    /// Threads driver: max envelopes a reducer drains per queue lock
    /// acquisition (1 = the old one-pop-per-lock hot path).
    pub batch_max: usize,
    /// Post-repartition consistency: merge-at-end (paper) or §7 state
    /// forwarding (either driver).
    pub mode: ConsistencyMode,
    /// Fault-injection schedule (chaos testkit spec, e.g.
    /// `"kill@1:40,slow:4@0:20"`). `None` = no fault hooks installed —
    /// the hot path stays untouched. TOML: `chaos.plan`.
    pub chaos: Option<String>,
    /// Under chaos, cut a checkpoint of each reducer's state to a live
    /// peer every N folded records (smaller = tighter replication lag =
    /// shorter WAL replays on recovery). TOML: `chaos.checkpoint_interval`.
    pub checkpoint_interval: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            mappers: 4,
            reducers: 4,
            strategy: Strategy::None,
            tau: 0.2,
            halving_init_tokens: 8,
            initial_tokens: None,
            max_rounds: 1,
            min_trigger_qlen: 8,
            cooldown: 50,
            split_watermark: crate::hash::SplitKeyRouter::DEFAULT_WATERMARK,
            signal: SignalConfig::default(),
            zones: None,
            elastic: None,
            report_interval: 2,
            chunk_size: 10,
            queue_capacity: 1 << 16,
            driver: DriverKind::Sim,
            seed: 0,
            sim_costs: SimCosts::default(),
            map_delay_us: 0,
            reduce_delay_us: 200,
            pop_timeout_ms: 2,
            batch_max: 32,
            mode: ConsistencyMode::MergeAtEnd,
            chaos: None,
            checkpoint_interval: 16,
        }
    }
}

impl PipelineConfig {
    /// Load overrides from a TOML-subset document (see
    /// [`crate::config::toml_lite`]). Unknown keys are rejected so typos
    /// fail loudly.
    pub fn apply_document(&mut self, doc: &Document) -> crate::Result<()> {
        for key in doc.keys() {
            match key {
                "pipeline.mappers" => self.mappers = doc.get_int(key).context("mappers")? as usize,
                "pipeline.reducers" => {
                    self.reducers = doc.get_int(key).context("reducers")? as usize
                }
                "pipeline.chunk_size" => {
                    self.chunk_size = doc.get_int(key).context("chunk_size")? as usize
                }
                "pipeline.queue_capacity" => {
                    self.queue_capacity = doc.get_int(key).context("queue_capacity")? as usize
                }
                "pipeline.driver" => {
                    self.driver = doc
                        .get_str(key)
                        .context("driver")?
                        .parse()
                        .map_err(anyhow::Error::msg)?
                }
                "pipeline.seed" => self.seed = doc.get_int(key).context("seed")? as u64,
                "balancer.strategy" => {
                    self.strategy = doc
                        .get_str(key)
                        .context("strategy")?
                        .parse()
                        .map_err(anyhow::Error::msg)?
                }
                "balancer.tau" => self.tau = doc.get_float(key).context("tau")?,
                "balancer.max_rounds" => {
                    self.max_rounds = doc.get_int(key).context("max_rounds")? as u32
                }
                "balancer.min_trigger_qlen" => {
                    self.min_trigger_qlen = doc.get_int(key).context("min_trigger_qlen")? as usize
                }
                "balancer.cooldown" => self.cooldown = doc.get_int(key).context("cooldown")? as u64,
                "balancer.split_watermark" => {
                    self.split_watermark = doc.get_float(key).context("split_watermark")?
                }
                "balancer.decay_alpha" => {
                    self.signal.decay_alpha = doc.get_float(key).context("decay_alpha")?
                }
                "balancer.hysteresis" => {
                    self.signal.hysteresis = doc.get_float(key).context("hysteresis")?
                }
                "balancer.min_gain" => {
                    self.signal.min_gain = doc.get_float(key).context("min_gain")?
                }
                "balancer.zones" => {
                    self.zones = Some(doc.get_str(key).context("zones")?.to_string())
                }
                "balancer.scale_up" => {
                    self.elastic_mut().scale_up = doc.get_float(key).context("scale_up")?
                }
                "balancer.scale_down" => {
                    self.elastic_mut().scale_down = doc.get_float(key).context("scale_down")?
                }
                "balancer.min_reducers" => {
                    self.elastic_mut().min_reducers =
                        doc.get_int(key).context("min_reducers")? as usize
                }
                "balancer.max_reducers" => {
                    self.elastic_mut().max_reducers =
                        doc.get_int(key).context("max_reducers")? as usize
                }
                "balancer.report_interval" => {
                    self.report_interval = doc.get_int(key).context("report_interval")? as u64
                }
                "balancer.halving_init_tokens" => {
                    self.halving_init_tokens =
                        doc.get_int(key).context("halving_init_tokens")? as u32
                }
                "sim.map_cost" => {
                    self.sim_costs.map_cost = doc.get_int(key).context("map_cost")? as u64
                }
                "sim.reduce_cost" => {
                    self.sim_costs.reduce_cost = doc.get_int(key).context("reduce_cost")? as u64
                }
                "sim.fetch_cost" => {
                    self.sim_costs.fetch_cost = doc.get_int(key).context("fetch_cost")? as u64
                }
                "sim.forward_cost" => {
                    self.sim_costs.forward_cost = doc.get_int(key).context("forward_cost")? as u64
                }
                "sim.poll_interval" => {
                    self.sim_costs.poll_interval = doc.get_int(key).context("poll_interval")? as u64
                }
                "sim.cost_jitter" => {
                    self.sim_costs.cost_jitter = doc.get_float(key).context("cost_jitter")?
                }
                "threads.map_delay_us" => {
                    self.map_delay_us = doc.get_int(key).context("map_delay_us")? as u64
                }
                "threads.reduce_delay_us" => {
                    self.reduce_delay_us = doc.get_int(key).context("reduce_delay_us")? as u64
                }
                "threads.pop_timeout_ms" => {
                    self.pop_timeout_ms = doc.get_int(key).context("pop_timeout_ms")? as u64
                }
                "threads.batch_max" => {
                    self.batch_max = doc.get_int(key).context("batch_max")? as usize
                }
                "chaos.plan" => {
                    self.chaos = Some(doc.get_str(key).context("chaos plan")?.to_string())
                }
                "chaos.checkpoint_interval" => {
                    self.checkpoint_interval =
                        doc.get_int(key).context("checkpoint_interval")? as u64
                }
                other => bail!("unknown config key '{other}'"),
            }
        }
        self.validate()
    }

    pub fn from_toml_file(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = crate::config::parse(&text).map_err(anyhow::Error::msg)?;
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc)?;
        Ok(cfg)
    }

    /// Elastic knobs, created with defaults on first touch (any
    /// `balancer.scale_*` / `*_reducers` key or CLI flag enables the
    /// subsystem).
    pub fn elastic_mut(&mut self) -> &mut ElasticConfig {
        self.elastic.get_or_insert_with(ElasticConfig::default)
    }

    pub fn validate(&self) -> crate::Result<()> {
        if self.mappers == 0 || self.reducers == 0 {
            bail!("need at least one mapper and one reducer");
        }
        if let Some(e) = &self.elastic {
            e.validate().map_err(anyhow::Error::msg)?;
            if self.reducers < e.min_reducers || self.reducers > e.max_reducers {
                bail!(
                    "pipeline.reducers ({}) must start within \
                     [balancer.min_reducers, balancer.max_reducers] = [{}, {}]",
                    self.reducers,
                    e.min_reducers,
                    e.max_reducers
                );
            }
        }
        if self.tau < 0.0 {
            bail!("τ must be non-negative (§4.1)");
        }
        if !self.halving_init_tokens.is_power_of_two() {
            bail!("halving_init_tokens must be a power of two (§4.2)");
        }
        if self.split_watermark <= 0.0 {
            bail!("balancer.split_watermark must be positive");
        }
        if self.pop_timeout_ms == 0 {
            bail!("threads.pop_timeout_ms must be at least 1 (idle reducers would busy-spin)");
        }
        if self.batch_max == 0 {
            bail!("threads.batch_max must be at least 1 (reducers must pop something)");
        }
        self.signal.validate().map_err(anyhow::Error::msg)?;
        if let Some(spec) = &self.zones {
            // ids beyond the starting reducer set are allowed — they name
            // zones for elastic joiners / chaos respawns.
            crate::hash::parse_zone_spec(spec).map_err(anyhow::Error::msg)?;
        }
        if self.checkpoint_interval == 0 {
            bail!("chaos.checkpoint_interval must be at least 1");
        }
        if let Some(spec) = &self.chaos {
            let plan = ChaosPlan::parse(spec).map_err(anyhow::Error::msg)?;
            if let Some(v) = plan.max_victim() {
                if v >= self.reducers {
                    bail!(
                        "chaos plan targets reducer {v} but the run starts \
                         with {} reducers",
                        self.reducers
                    );
                }
            }
            if plan.kill_count() > 0 {
                if self.mode != ConsistencyMode::StateForward {
                    bail!(
                        "chaos kill events need mode = state-forward — crash \
                         recovery re-homes the victim's keys through the §7 \
                         transfer lane"
                    );
                }
                if self.reducers < 2 {
                    bail!(
                        "chaos kill events need at least 2 reducers (a live \
                         peer holds the checkpoint replica)"
                    );
                }
            }
        }
        Ok(())
    }

    /// The parsed chaos configuration, if fault injection is enabled.
    /// Callers run [`validate`](Self::validate) first (the drivers do),
    /// so the spec is known to parse.
    pub fn chaos_config(&self) -> Option<ChaosConfig> {
        self.chaos.as_deref().map(|spec| {
            let plan = ChaosPlan::parse(spec).expect("chaos plan validated");
            ChaosConfig { plan, checkpoint_interval: self.checkpoint_interval }
        })
    }

    /// The ring this configuration starts from (token-ring strategies;
    /// probe routers have no token layout).
    pub fn initial_ring(&self) -> Ring {
        match self.initial_tokens {
            Some(n) => Ring::new(self.reducers, n),
            None => Ring::for_strategy(self.reducers, self.strategy, self.halving_init_tokens),
        }
    }

    /// The parsed node-id-indexed zone map (empty when `balancer.zones`
    /// is unset). Callers run [`validate`](Self::validate) first (the
    /// drivers do), so the spec is known to parse.
    pub fn zone_map(&self) -> Vec<u32> {
        self.zones
            .as_deref()
            .map(|s| crate::hash::parse_zone_spec(s).expect("zone spec validated"))
            .unwrap_or_default()
    }

    /// Construct the routing layer this configuration describes, with
    /// its load view carrying the configured [`SignalConfig`], the
    /// failure-domain map installed, and — under elastic membership —
    /// slots pre-allocated up to `max_reducers`.
    pub fn build_router(&self) -> RouterHandle {
        let router = self.strategy.build_router_tuned(
            self.reducers,
            self.halving_init_tokens,
            self.initial_tokens,
            self.split_watermark,
        );
        RouterHandle::builder(router)
            .signal(&self.signal)
            .capacity(self.reducer_capacity())
            .zones(self.zone_map())
            .build()
    }

    /// Reducer-id ceiling the drivers pre-allocate for (0 = fixed
    /// membership; the drivers then size everything off `reducers`).
    /// Every scheduled kill reserves one extra slot so the respawned
    /// replacement gets a fresh dense id.
    pub fn reducer_capacity(&self) -> usize {
        let kills = self
            .chaos
            .as_deref()
            .and_then(|s| ChaosPlan::parse(s).ok())
            .map_or(0, |p| p.kill_count());
        match &self.elastic {
            Some(e) => e.max_reducers + kills,
            None if kills > 0 => self.reducers + kills,
            None => 0,
        }
    }
}

/// A configured pipeline, ready to run workloads.
pub struct Pipeline {
    cfg: PipelineConfig,
    map_exec: Arc<dyn MapExecutor>,
    reduce_factory: ReduceFactory,
    route_runtime: Option<Arc<crate::runtime::programs::SharedRuntime>>,
}

impl Pipeline {
    pub fn new(
        cfg: PipelineConfig,
        map_exec: Arc<dyn MapExecutor>,
        reduce_factory: ReduceFactory,
    ) -> Self {
        Pipeline { cfg, map_exec, reduce_factory, route_runtime: None }
    }

    /// Route whole tasks through the compiled XLA route program of the
    /// configured router's family (threads driver; the sim models
    /// per-item costs and keeps the scalar path). Token-ring, multi-probe
    /// and two-choices snapshots all lower to tensors; split-key has no
    /// compiled lowering and routes through the documented scalar
    /// fallback (see `docs/ROUTING.md`).
    pub fn with_route_runtime(
        mut self,
        rt: Arc<crate::runtime::programs::SharedRuntime>,
    ) -> Self {
        self.route_runtime = Some(rt);
        self
    }

    /// The paper's word-count pipeline over pre-split items.
    pub fn wordcount(cfg: PipelineConfig) -> Self {
        Self::new(cfg, Arc::new(IdentityMap), Arc::new(|_| Box::new(WordCount::new()) as _))
    }

    /// Pick a builtin executor pair.
    pub fn builtin(cfg: PipelineConfig, kind: ExecutorKind) -> Self {
        match kind {
            ExecutorKind::WordCount => Self::wordcount(cfg),
            ExecutorKind::TokenizedWordCount => Self::new(
                cfg,
                Arc::new(TokenizeMap),
                Arc::new(|_| Box::new(WordCount::new()) as _),
            ),
            ExecutorKind::KeyedSum => Self::new(
                cfg,
                Arc::new(KeyValueMap),
                Arc::new(|_| Box::new(WordCount::new()) as _),
            ),
            ExecutorKind::Distinct => Self::new(
                cfg,
                Arc::new(IdentityMap),
                Arc::new(|_| Box::new(Distinct::new()) as _),
            ),
            ExecutorKind::TopK(k) => Self::new(
                cfg,
                Arc::new(IdentityMap),
                Arc::new(move |_| Box::new(TopK::new(k)) as _),
            ),
        }
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    fn build_balancer(&self) -> BalancerCore {
        let router = self.cfg.build_router();
        // `cooldown` is in driver time units: sim ticks for the DES, and
        // milliseconds for the threads driver (whose balancer clock runs
        // in µs) — 50 sim-ticks ≈ 10 reduce steps ≈ 50ms of real queue
        // drainage, keeping the two drivers' trigger hygiene comparable.
        let cooldown = match self.cfg.driver {
            DriverKind::Sim => self.cfg.cooldown,
            DriverKind::Threads => self.cfg.cooldown.saturating_mul(1000),
        };
        let mut balancer = BalancerCore::new(
            router,
            self.cfg.strategy,
            self.cfg.tau,
            self.cfg.min_trigger_qlen,
            self.cfg.max_rounds,
            cooldown,
        );
        if let Some(e) = &self.cfg.elastic {
            // the scale cooldown rides the same driver-time conversion
            balancer = balancer.with_elastic(ElasticController::from_watermarks(*e, cooldown));
        }
        balancer
    }

    /// Execute the pipeline over `items`. Accepts anything convertible to
    /// a shared `Arc<[String]>` (a `Vec<String>` converts in place); pass
    /// an `Arc` clone to re-run the same input with zero copying.
    pub fn run(&self, items: impl Into<Arc<[String]>>) -> crate::Result<RunReport> {
        self.run_shared(items.into())
    }

    fn run_shared(&self, items: Arc<[String]>) -> crate::Result<RunReport> {
        self.cfg.validate()?;
        // merge-contract enforcement, before any record flows: an
        // associative-contract router (split-key) leaves shards of a hot
        // key on several reducers, which only merges correctly under an
        // associative, commutative op (docs/ARCHITECTURE.md, "§7 merge
        // contracts")
        if self.cfg.strategy.merge_contract() == MergeContract::Associative {
            let op = (self.reduce_factory)(0).merge_op();
            if !op.splittable() {
                bail!(
                    "strategy '{}' splits hot keys across reducers, but the \
                     executor's merge op '{op}' is order-sensitive — pick a \
                     disjoint-contract strategy or a splittable (sum/min/max) \
                     reduction",
                    self.cfg.strategy
                );
            }
        }
        let balancer = self.build_balancer();
        let report = match self.cfg.driver {
            DriverKind::Sim => {
                let driver = SimDriver::new(SimParams {
                    costs: self.cfg.sim_costs.clone(),
                    seed: self.cfg.seed,
                    report_interval: self.cfg.report_interval,
                    chunk_size: self.cfg.chunk_size,
                    mode: self.cfg.mode,
                    max_reducers: self.cfg.reducer_capacity(),
                    chaos: self.cfg.chaos_config(),
                });
                driver.run(
                    self.map_exec.clone(),
                    &self.reduce_factory,
                    self.cfg.mappers,
                    balancer,
                    items,
                )
            }
            DriverKind::Threads => {
                let driver = ThreadDriver::new(ThreadParams {
                    report_interval: self.cfg.report_interval,
                    chunk_size: self.cfg.chunk_size,
                    queue_capacity: self.cfg.queue_capacity,
                    map_delay_us: self.cfg.map_delay_us,
                    reduce_delay_us: self.cfg.reduce_delay_us,
                    pop_timeout: std::time::Duration::from_millis(self.cfg.pop_timeout_ms),
                    batch_max: self.cfg.batch_max,
                    mode: self.cfg.mode,
                    route_runtime: self.route_runtime.clone(),
                    max_reducers: self.cfg.reducer_capacity(),
                    chaos: self.cfg.chaos_config(),
                });
                driver.run(
                    self.map_exec.clone(),
                    &self.reduce_factory,
                    self.cfg.mappers,
                    balancer,
                    items,
                )
            }
        };
        report
            .check_conservation()
            .map_err(anyhow::Error::msg)
            .context("message conservation check failed")?;
        Ok(report)
    }

    /// Run the same workload over several seeds (sim driver) and return
    /// all reports — the "3 runs, small variance" protocol of §6.1. The
    /// input is shared across runs (one copy total, not one per seed).
    pub fn run_seeds(&self, items: &[String], seeds: &[u64]) -> crate::Result<Vec<RunReport>> {
        let shared: Arc<[String]> = items.into();
        let mut out = Vec::with_capacity(seeds.len());
        for &seed in seeds {
            let mut cfg = self.cfg.clone();
            cfg.seed = seed;
            let p = Pipeline {
                cfg,
                map_exec: self.map_exec.clone(),
                reduce_factory: self.reduce_factory.clone(),
                route_runtime: self.route_runtime.clone(),
            };
            out.push(p.run_shared(shared.clone())?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let cfg = PipelineConfig::default();
        assert_eq!(cfg.mappers, 4);
        assert_eq!(cfg.reducers, 4);
        assert!((cfg.tau - 0.2).abs() < 1e-12);
        assert_eq!(cfg.max_rounds, 1);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn config_document_roundtrip() {
        let doc = crate::config::parse(
            r#"
[pipeline]
mappers = 2
reducers = 8
driver = "sim"
[balancer]
strategy = "doubling"
tau = 0.5
max_rounds = 3
"#,
        )
        .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.mappers, 2);
        assert_eq!(cfg.reducers, 8);
        assert_eq!(cfg.strategy, Strategy::Doubling);
        assert_eq!(cfg.max_rounds, 3);
        assert!((cfg.tau - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = crate::config::parse("[pipeline]\nbogus = 1\n").unwrap();
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply_document(&doc).is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = PipelineConfig::default();
        cfg.mappers = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = PipelineConfig::default();
        cfg.tau = -0.1;
        assert!(cfg.validate().is_err());

        let mut cfg = PipelineConfig::default();
        cfg.halving_init_tokens = 6;
        assert!(cfg.validate().is_err());

        let mut cfg = PipelineConfig::default();
        cfg.pop_timeout_ms = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn state_forwarding_valid_on_both_drivers() {
        // the unified runtime lifted the old threads-driver ban
        let mut cfg = PipelineConfig::default();
        cfg.mode = ConsistencyMode::StateForward;
        cfg.driver = DriverKind::Threads;
        assert!(cfg.validate().is_ok());
        cfg.driver = DriverKind::Sim;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn pop_timeout_config_key_applies() {
        let doc = crate::config::parse("[threads]\npop_timeout_ms = 7\n").unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.pop_timeout_ms, 7);
    }

    #[test]
    fn batch_max_config_key_applies_and_validates() {
        let doc = crate::config::parse("[threads]\nbatch_max = 8\n").unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.batch_max, 8);
        assert_eq!(PipelineConfig::default().batch_max, 32);

        let mut bad = PipelineConfig::default();
        bad.batch_max = 0;
        assert!(bad.validate().is_err(), "batch_max = 0 would pop nothing");
    }

    #[test]
    fn signal_config_keys_round_trip() {
        let doc = crate::config::parse(
            "[balancer]\ndecay_alpha = 0.3\nhysteresis = 0.4\nmin_gain = 0.2\n",
        )
        .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert!((cfg.signal.decay_alpha - 0.3).abs() < 1e-12);
        assert!((cfg.signal.hysteresis - 0.4).abs() < 1e-12);
        assert!((cfg.signal.min_gain - 0.2).abs() < 1e-12);
    }

    #[test]
    fn invalid_signal_configs_rejected() {
        let mut cfg = PipelineConfig::default();
        cfg.signal.decay_alpha = 0.0;
        assert!(cfg.validate().is_err(), "α = 0 would freeze the signal");

        let mut cfg = PipelineConfig::default();
        cfg.signal.min_gain = 1.0;
        assert!(cfg.validate().is_err(), "min_gain = 1 blocks every move");

        // and through the document path, so typos fail loudly too
        let doc = crate::config::parse("[balancer]\ndecay_alpha = 2.0\n").unwrap();
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply_document(&doc).is_err());
    }

    #[test]
    fn build_router_threads_the_signal() {
        let mut cfg = PipelineConfig::default();
        cfg.strategy = Strategy::TwoChoices;
        cfg.signal = crate::balancer::signal::SignalConfig {
            decay_alpha: 0.5,
            hysteresis: 0.0,
            min_gain: 0.0,
        };
        let router = cfg.build_router();
        router.loads().set(0, 100);
        // half-weight EWMA instead of the raw mirror ⇒ the configured
        // signal reached the router's load view
        let fp = 1u64 << crate::balancer::signal::FRAC_BITS;
        assert_eq!(router.loads().decayed(0), 50 * fp);
        assert_eq!(router.loads().get(0), 100);
    }

    #[test]
    fn elastic_config_keys_round_trip_and_validate() {
        let doc = crate::config::parse(
            "[balancer]\nscale_up = 6.0\nscale_down = 0.5\nmin_reducers = 2\nmax_reducers = 8\n",
        )
        .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        let e = cfg.elastic.expect("any scale key enables elastic");
        assert!((e.scale_up - 6.0).abs() < 1e-12);
        assert!((e.scale_down - 0.5).abs() < 1e-12);
        assert_eq!((e.min_reducers, e.max_reducers), (2, 8));
        assert_eq!(cfg.reducer_capacity(), 8);
        // the router pre-allocates signal slots up to the ceiling
        let router = cfg.build_router();
        assert_eq!(router.capacity(), 8);
        assert_eq!(router.nodes(), 4);

        // inverted watermarks rejected
        let mut bad = PipelineConfig::default();
        bad.elastic_mut().scale_up = 1.0;
        bad.elastic_mut().scale_down = 2.0;
        assert!(bad.validate().is_err());
        // starting outside [min, max] rejected
        let mut bad = PipelineConfig::default();
        bad.elastic_mut().min_reducers = 6;
        bad.elastic_mut().max_reducers = 8;
        assert!(bad.validate().is_err(), "reducers=4 below min_reducers=6");
        // fixed-membership default stays off
        assert!(PipelineConfig::default().elastic.is_none());
        assert_eq!(PipelineConfig::default().reducer_capacity(), 0);
    }

    #[test]
    fn elastic_sim_run_scales_and_stays_exact() {
        // aggressive watermarks on a skewed workload: the run must stay
        // exact (conservation + oracle) whatever membership does, and
        // under this configuration the hot phase reliably trips scale-up
        let w = crate::workload::paperwl::wl1();
        let mut cfg = PipelineConfig::default();
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(1);
        cfg.mode = ConsistencyMode::StateForward;
        cfg.cooldown = 30;
        *cfg.elastic_mut() = crate::balancer::elastic::ElasticConfig {
            scale_up: 2.0,
            scale_down: 0.25,
            min_reducers: 2,
            max_reducers: 8,
        };
        let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
        r.check_conservation().unwrap();
        let mut oracle = std::collections::HashMap::new();
        for i in &w.items {
            *oracle.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut expect: Vec<(String, i64)> = oracle.into_iter().collect();
        expect.sort();
        assert_eq!(r.result, expect);
        let (added, _retired) = r.scale_counts();
        assert!(added > 0, "WL1 hot phase never tripped the scale-up watermark");
        assert!(r.processed.len() > 4, "no reducer actually spawned");
    }

    #[test]
    fn initial_tokens_override() {
        let mut cfg = PipelineConfig::default();
        cfg.strategy = Strategy::None;
        cfg.initial_tokens = Some(1);
        assert_eq!(cfg.initial_ring().tokens_of(0), 1, "doubling-layout baseline");
        cfg.initial_tokens = None;
        assert_eq!(cfg.initial_ring().tokens_of(0), 8, "halving layout default");
    }

    #[test]
    fn sim_wordcount_end_to_end() {
        let cfg = PipelineConfig::default();
        let items: Vec<String> = (0..60).map(|i| format!("w{}", i % 6)).collect();
        let r = Pipeline::wordcount(cfg).run(items).unwrap();
        assert_eq!(r.total_processed(), 60);
        assert_eq!(r.result.len(), 6);
        for (_, c) in &r.result {
            assert_eq!(*c, 10);
        }
    }

    #[test]
    fn split_watermark_key_applies_and_validates() {
        let doc = crate::config::parse(
            "[balancer]\nstrategy = \"splitkey:4\"\nsplit_watermark = 2.5\n",
        )
        .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.strategy, Strategy::SplitKey { d: 4 });
        assert!((cfg.split_watermark - 2.5).abs() < 1e-12);
        assert_eq!(cfg.build_router().name(), "split-key");
        assert_eq!(
            PipelineConfig::default().split_watermark,
            crate::hash::SplitKeyRouter::DEFAULT_WATERMARK
        );

        let mut bad = PipelineConfig::default();
        bad.split_watermark = 0.0;
        assert!(bad.validate().is_err(), "watermark must be positive");
    }

    #[test]
    fn split_key_rejects_order_sensitive_merge_ops_at_build() {
        use crate::exec::{MergeOp, Record, ReduceExecutor};

        // a word count that (wrongly for splitting) merges last-wins
        struct LastWins(crate::exec::builtin::WordCount);
        impl ReduceExecutor for LastWins {
            fn reduce(&mut self, rec: Record) {
                self.0.reduce(rec)
            }
            fn snapshot(&mut self) -> Vec<(String, i64)> {
                self.0.snapshot()
            }
            fn merge_op(&self) -> MergeOp {
                MergeOp::Last
            }
            fn extract_key(&mut self, key: &str) -> Option<i64> {
                self.0.extract_key(key)
            }
        }

        let mut cfg = PipelineConfig::default();
        cfg.strategy = Strategy::SplitKey { d: 2 };
        let items: Vec<String> = (0..10).map(|i| format!("k{i}")).collect();
        let p = Pipeline::new(
            cfg.clone(),
            Arc::new(IdentityMap),
            Arc::new(|_| Box::new(LastWins(WordCount::new())) as _),
        );
        let err = p.run(items.clone()).unwrap_err();
        assert!(err.to_string().contains("order-sensitive"), "{err}");

        // the same strategy with a splittable op (sum) runs fine
        let r = Pipeline::wordcount(cfg).run(items).unwrap();
        assert_eq!(r.result.len(), 10);
    }

    #[test]
    fn chaos_config_keys_round_trip() {
        let doc = crate::config::parse(
            "[chaos]\nplan = \"slow:3@0:10,stall:40@1:5\"\ncheckpoint_interval = 4\n",
        )
        .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.chaos.as_deref(), Some("slow:3@0:10,stall:40@1:5"));
        assert_eq!(cfg.checkpoint_interval, 4);
        let cc = cfg.chaos_config().expect("plan set");
        assert_eq!(cc.plan.events.len(), 2);
        assert_eq!(cc.checkpoint_interval, 4);
        // defaults: no fault hooks, paper cadence
        assert!(PipelineConfig::default().chaos.is_none());
        assert!(PipelineConfig::default().chaos_config().is_none());
        assert_eq!(PipelineConfig::default().checkpoint_interval, 16);
    }

    #[test]
    fn chaos_validation_guards() {
        // unparseable plan fails loudly
        let mut cfg = PipelineConfig::default();
        cfg.chaos = Some("explode@1:2".into());
        assert!(cfg.validate().is_err());

        // kills need the §7 state-forwarding lane for recovery
        let mut cfg = PipelineConfig::default();
        cfg.chaos = Some("kill@1:10".into());
        assert!(cfg.validate().is_err(), "kill under merge-at-end must be rejected");
        cfg.mode = ConsistencyMode::StateForward;
        assert!(cfg.validate().is_ok());

        // victim beyond the starting membership
        cfg.chaos = Some("kill@9:10".into());
        assert!(cfg.validate().is_err());

        // a kill needs a live peer to hold the replica
        let mut cfg = PipelineConfig::default();
        cfg.mode = ConsistencyMode::StateForward;
        cfg.reducers = 1;
        cfg.chaos = Some("kill@0:10".into());
        assert!(cfg.validate().is_err());

        // zero checkpoint cadence would never cut a checkpoint
        let mut cfg = PipelineConfig::default();
        cfg.checkpoint_interval = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn kill_plans_reserve_respawn_headroom() {
        let mut cfg = PipelineConfig::default();
        cfg.mode = ConsistencyMode::StateForward;
        cfg.chaos = Some("kill@1:10,kill@2:20".into());
        assert_eq!(cfg.reducer_capacity(), 6, "4 starters + 2 respawn slots");
        let router = cfg.build_router();
        assert_eq!(router.capacity(), 6);
        assert_eq!(router.nodes(), 4);
        // elastic ceilings stack with the kill headroom
        cfg.elastic_mut().max_reducers = 8;
        assert_eq!(cfg.reducer_capacity(), 10);
    }

    #[test]
    fn chaos_plan_threads_through_the_pipeline() {
        // a slow+drop plan must leave the answer untouched and surface
        // its fired faults in the report
        let items: Vec<String> = (0..290).map(|i| format!("w{}", i % 29)).collect();
        let mut cfg = PipelineConfig::default();
        cfg.chaos = Some("slow:4@0:5,drop:2@1:3".into());
        let r = Pipeline::wordcount(cfg).run(items).unwrap();
        assert_eq!(r.total_processed(), 290);
        assert_eq!(r.result.len(), 29);
        for (_, c) in &r.result {
            assert_eq!(*c, 10);
        }
        assert_eq!(r.fault_events.len(), 2, "both scheduled faults fired");
        assert_eq!(r.recovery.kills, 0);
    }

    #[test]
    fn probe_strategies_config_round_trip_and_run() {
        let doc = crate::config::parse(
            "[balancer]\nstrategy = \"multiprobe:3\"\n",
        )
        .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.strategy, Strategy::MultiProbe { probes: 3 });
        assert_eq!(cfg.build_router().name(), "multi-probe");

        cfg.strategy = Strategy::TwoChoices;
        assert_eq!(cfg.build_router().name(), "two-choices");
        let items: Vec<String> = (0..60).map(|i| format!("w{}", i % 6)).collect();
        let r = Pipeline::wordcount(cfg).run(items).unwrap();
        assert_eq!(r.total_processed(), 60);
        assert_eq!(r.result.len(), 6);
    }

    #[test]
    fn zones_config_round_trip_and_reach_the_router() {
        let doc = crate::config::parse(
            "[balancer]\nstrategy = \"ptable:6:2\"\nzones = \"0,1;2,3\"\n",
        )
        .unwrap();
        let mut cfg = PipelineConfig::default();
        cfg.apply_document(&doc).unwrap();
        assert_eq!(cfg.strategy, Strategy::Ptable { bits: 6, replicas: 2 });
        assert_eq!(cfg.zones.as_deref(), Some("0,1;2,3"));
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.zone_map(), vec![0, 0, 1, 1]);

        let router = cfg.build_router();
        assert_eq!(router.name(), "partition-table");
        assert_eq!(router.zones(), &[0, 0, 1, 1]);
        assert_eq!(router.zone_of(0), router.zone_of(1));
        assert_ne!(router.zone_of(0), router.zone_of(2));

        // and the pipeline still runs oracle-exact under zones
        let items: Vec<String> = (0..60).map(|i| format!("w{}", i % 6)).collect();
        let r = Pipeline::wordcount(cfg).run(items).unwrap();
        assert_eq!(r.total_processed(), 60);
        assert_eq!(r.result.len(), 6);
    }

    #[test]
    fn invalid_zone_specs_rejected() {
        for bad in ["0,1;;2", "0,x", "0,1;1", ""] {
            let mut cfg = PipelineConfig::default();
            cfg.zones = Some(bad.to_string());
            assert!(cfg.validate().is_err(), "zone spec {bad:?} must be rejected");
        }
        // unset zones stay a no-op
        let cfg = PipelineConfig::default();
        assert!(cfg.zone_map().is_empty());
    }
}
