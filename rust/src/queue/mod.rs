//! Per-reducer queues (§2.2).
//!
//! Each reducer consumes from its own queue; mappers (and forwarding
//! reducers) are producers. Per-reducer queues eliminate the contention a
//! single shared queue would create — the paper's stated motivation.
//!
//! [`DataQueue`] is the threads-driver implementation: a bounded
//! `Mutex<VecDeque>` + condvars, with the current length mirrored in an
//! `AtomicUsize` so the load balancer (and metrics) can read queue sizes
//! without touching the lock — the "load state is just the queue size"
//! signal of §3 made contention-free.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::exec::Record;

/// A bounded MPMC queue of records with lock-free length reads.
pub struct DataQueue {
    inner: Mutex<VecDeque<Record>>,
    not_empty: Condvar,
    not_full: Condvar,
    len: AtomicUsize,
    peak: AtomicUsize,
    capacity: usize,
}

impl DataQueue {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        DataQueue {
            inner: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            len: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            capacity,
        }
    }

    /// Current length — lock-free; the balancer's load signal.
    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Highest length ever observed (reported in [`RunReport::peak_qlen`]
    /// (crate::metrics::RunReport::peak_qlen)).
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    fn bump_len(&self, new_len: usize) {
        self.len.store(new_len, Ordering::Relaxed);
        self.peak.fetch_max(new_len, Ordering::Relaxed);
    }

    /// Blocking push — applies backpressure when the queue is full.
    pub fn push(&self, rec: Record) {
        let mut q = self.inner.lock().unwrap();
        while q.len() >= self.capacity {
            q = self.not_full.wait(q).unwrap();
        }
        q.push_back(rec);
        self.bump_len(q.len());
        drop(q);
        self.not_empty.notify_one();
    }

    /// Blocking batch push: one lock acquisition for the whole batch
    /// (§Perf iteration 3 — mappers enqueue a task's records per
    /// destination in one go). Waits while the queue cannot take the
    /// *entire* batch; batches larger than the capacity are pushed in
    /// capacity-sized waves.
    pub fn push_batch(&self, recs: Vec<Record>) {
        let mut it = recs.into_iter().peekable();
        while it.peek().is_some() {
            let mut q = self.inner.lock().unwrap();
            while q.len() >= self.capacity {
                q = self.not_full.wait(q).unwrap();
            }
            let room = self.capacity - q.len();
            for rec in it.by_ref().take(room) {
                q.push_back(rec);
            }
            self.bump_len(q.len());
            drop(q);
            self.not_empty.notify_all();
        }
    }

    /// Non-blocking push; returns the record back on a full queue.
    pub fn try_push(&self, rec: Record) -> Result<(), Record> {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.capacity {
            return Err(rec);
        }
        q.push_back(rec);
        self.bump_len(q.len());
        drop(q);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop with timeout — reducers poll so they can also check shutdown
    /// conditions while idle (§2.3: a reducer can never stop on its own).
    pub fn pop_timeout(&self, timeout: Duration) -> Option<Record> {
        let mut q = self.inner.lock().unwrap();
        if q.is_empty() {
            let (guard, res) = self.not_empty.wait_timeout(q, timeout).unwrap();
            q = guard;
            if res.timed_out() && q.is_empty() {
                return None;
            }
            if q.is_empty() {
                return None;
            }
        }
        let rec = q.pop_front();
        self.len.store(q.len(), Ordering::Relaxed);
        drop(q);
        self.not_full.notify_one();
        rec
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<Record> {
        let mut q = self.inner.lock().unwrap();
        let rec = q.pop_front()?;
        self.len.store(q.len(), Ordering::Relaxed);
        drop(q);
        self.not_full.notify_one();
        Some(rec)
    }

    /// Drain everything (used by tests and the elastic example when
    /// retiring a reducer).
    pub fn drain(&self) -> Vec<Record> {
        let mut q = self.inner.lock().unwrap();
        let out: Vec<Record> = q.drain(..).collect();
        self.len.store(0, Ordering::Relaxed);
        drop(q);
        self.not_full.notify_all();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = DataQueue::new(16);
        for i in 0..5 {
            q.push(Record::new(format!("k{i}"), i));
        }
        for i in 0..5 {
            assert_eq!(q.try_pop().unwrap().value, i);
        }
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn len_and_peak_track() {
        let q = DataQueue::new(16);
        assert_eq!(q.len(), 0);
        q.push(Record::new("a", 1));
        q.push(Record::new("b", 1));
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak(), 2);
        q.try_pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.peak(), 2, "peak is sticky");
    }

    #[test]
    fn try_push_full_returns_record() {
        let q = DataQueue::new(1);
        q.push(Record::new("a", 1));
        let rejected = q.try_push(Record::new("b", 2));
        assert_eq!(rejected.unwrap_err().key, "b");
    }

    #[test]
    fn pop_timeout_expires() {
        let q = DataQueue::new(4);
        let t0 = std::time::Instant::now();
        assert!(q.pop_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn backpressure_unblocks_producer() {
        let q = Arc::new(DataQueue::new(1));
        q.push(Record::new("first", 1));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            q2.push(Record::new("second", 2)); // blocks until consumer pops
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.try_pop().unwrap().key, "first");
        producer.join().unwrap();
        assert_eq!(q.try_pop().unwrap().key, "second");
    }

    #[test]
    fn concurrent_producers_consumers_conserve_records() {
        let q = Arc::new(DataQueue::new(64));
        let n_per = 500;
        let mut producers = Vec::new();
        for p in 0..4 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..n_per {
                    q.push(Record::new(format!("p{p}-{i}"), 1));
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = 0u64;
                while got < (4 * n_per / 2) as u64 {
                    if q.pop_timeout(Duration::from_millis(50)).is_some() {
                        got += 1;
                    }
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        let total: u64 = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 4 * n_per as u64);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_empties() {
        let q = DataQueue::new(8);
        q.push(Record::new("a", 1));
        q.push(Record::new("b", 2));
        let drained = q.drain();
        assert_eq!(drained.len(), 2);
        assert!(q.is_empty());
    }
}
