//! Per-reducer queues (§2.2).
//!
//! Each reducer consumes from its own queue; mappers (and forwarding
//! reducers) are producers. Per-reducer queues eliminate the contention a
//! single shared queue would create — the paper's stated motivation.
//!
//! [`DataQueue<T>`] is the shared-runtime implementation: a bounded
//! two-lane `Mutex<VecDeque>` + condvars, with the current length mirrored
//! in an `AtomicUsize` so the load balancer (and metrics) can read queue
//! sizes without touching the lock — the "load state is just the queue
//! size" signal of §3 made contention-free.
//!
//! The **priority lane** carries §7 state-forwarding transfers: it is
//! consumed before the data lane (state must be applied before any data
//! processing at the new owner) and is exempt from the capacity bound so a
//! repartition can never deadlock against data backpressure. This mirrors
//! the sim driver's historical `push_front` semantics on one queue type
//! that both drivers now share.

#![forbid(unsafe_code)]

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{Condvar, Mutex};

struct Lanes<T> {
    /// State-transfer lane: popped first, never bounded.
    priority: VecDeque<T>,
    /// Data lane: FIFO, bounded by `capacity`.
    data: VecDeque<T>,
}

impl<T> Lanes<T> {
    fn len(&self) -> usize {
        self.priority.len() + self.data.len()
    }

    fn pop(&mut self) -> Option<T> {
        self.priority.pop_front().or_else(|| self.data.pop_front())
    }
}

/// A bounded MPMC queue with lock-free length reads and a priority lane.
pub struct DataQueue<T> {
    inner: Mutex<Lanes<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    len: AtomicUsize,
    peak: AtomicUsize,
    capacity: usize,
}

impl<T> DataQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        DataQueue {
            inner: Mutex::new(Lanes { priority: VecDeque::new(), data: VecDeque::new() }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            len: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            capacity,
        }
    }

    /// Current length (both lanes) — lock-free; the balancer's load signal.
    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Highest length ever observed (reported in [`RunReport::peak_qlen`]
    /// (crate::metrics::RunReport::peak_qlen)).
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    fn bump_len(&self, new_len: usize) {
        self.len.store(new_len, Ordering::Relaxed);
        // a CAS loop instead of `fetch_max` so the peak update is a loom
        // primitive; callers hold the queue mutex, so it never contends
        let mut cur = self.peak.load(Ordering::Relaxed);
        while new_len > cur {
            match self.peak.compare_exchange_weak(
                cur,
                new_len,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Blocking push — applies backpressure when the data lane is full.
    pub fn push(&self, item: T) {
        let mut q = self.inner.lock().unwrap();
        while q.data.len() >= self.capacity {
            q = self.not_full.wait(q).unwrap();
        }
        q.data.push_back(item);
        self.bump_len(q.len());
        drop(q);
        self.not_empty.notify_one();
    }

    /// Blocking batch push: one lock acquisition for the whole batch
    /// (§Perf iteration 3 — mappers enqueue a task's records per
    /// destination in one go). Waits while the data lane cannot take the
    /// *entire* batch; batches larger than the capacity are pushed in
    /// capacity-sized waves.
    pub fn push_batch(&self, items: Vec<T>) {
        let mut it = items.into_iter().peekable();
        while it.peek().is_some() {
            let mut q = self.inner.lock().unwrap();
            while q.data.len() >= self.capacity {
                q = self.not_full.wait(q).unwrap();
            }
            let room = self.capacity - q.data.len();
            for item in it.by_ref().take(room) {
                q.data.push_back(item);
            }
            self.bump_len(q.len());
            drop(q);
            self.not_empty.notify_all();
        }
    }

    /// Non-blocking push; returns the item back on a full data lane.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut q = self.inner.lock().unwrap();
        if q.data.len() >= self.capacity {
            return Err(item);
        }
        q.data.push_back(item);
        self.bump_len(q.len());
        drop(q);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Push to the priority lane: consumed before any data, exempt from
    /// the capacity bound (a state transfer must never block behind the
    /// very data backpressure it is trying to resolve).
    pub fn push_priority(&self, item: T) {
        let mut q = self.inner.lock().unwrap();
        q.priority.push_back(item);
        self.bump_len(q.len());
        drop(q);
        self.not_empty.notify_one();
    }

    /// Put an item back at the *front* of the data lane without waiting on
    /// capacity — used by reducers deferring data during a §7 substage-1
    /// synchronization window. Never blocks: the caller just popped, and a
    /// blocking re-queue against a producer that raced into the freed slot
    /// would deadlock the queue's own consumer.
    pub fn requeue_front(&self, item: T) {
        let mut q = self.inner.lock().unwrap();
        q.data.push_front(item);
        self.bump_len(q.len());
        drop(q);
        self.not_empty.notify_one();
    }

    /// Batch [`Self::requeue_front`]: put drained-but-unprocessed items
    /// back at the front of the data lane in one lock acquisition,
    /// preserving the given order (`items[0]` pops first). Ignores the
    /// capacity bound for the same no-deadlock reason as `requeue_front`.
    pub fn requeue_front_batch(&self, items: Vec<T>) {
        if items.is_empty() {
            return;
        }
        let mut q = self.inner.lock().unwrap();
        for item in items.into_iter().rev() {
            q.data.push_front(item);
        }
        self.bump_len(q.len());
        drop(q);
        self.not_empty.notify_all();
    }

    /// Pop with timeout — reducers poll so they can also check shutdown
    /// conditions while idle (§2.3: a reducer can never stop on its own).
    ///
    /// Single wait loop shared with [`Self::pop_batch`]: each wakeup
    /// (signal, spurious, or timeout) checks *both* lanes under the one
    /// mutex acquisition the condvar hands back, so a push landing right
    /// at the timeout boundary is returned instead of dropped, and an
    /// empty priority lane costs no extra re-lock.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        self.pop_batch(1, timeout).pop()
    }

    /// Pop up to `max` items in ONE lock acquisition — the batched
    /// reducer drain. The priority lane empties first (state transfers
    /// must be applied before any data at the new owner), then the data
    /// lane up to `max`. Blocks until the deadline for the *first* item;
    /// returns an empty vec on timeout, and never waits for a full batch
    /// — whatever is queued when the first item lands comes along.
    pub fn pop_batch(&self, max: usize, timeout: Duration) -> Vec<T> {
        if max == 0 {
            return Vec::new();
        }
        let deadline = Instant::now() + timeout;
        let mut q = self.inner.lock().unwrap();
        loop {
            if q.len() > 0 {
                let mut out = Vec::with_capacity(max.min(q.len()));
                let mut from_data = 0usize;
                while out.len() < max {
                    // priority items free no capacity; only data-lane
                    // removals count toward producer wakeups
                    let from_priority = !q.priority.is_empty();
                    if let Some(item) = q.pop() {
                        if !from_priority {
                            from_data += 1;
                        }
                        out.push(item);
                    } else {
                        break;
                    }
                }
                self.len.store(q.len(), Ordering::Relaxed);
                drop(q);
                match from_data {
                    0 => {}
                    1 => self.not_full.notify_one(),
                    _ => self.not_full.notify_all(),
                }
                return out;
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            let (guard, _res) = self.not_empty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        let item = q.pop()?;
        self.len.store(q.len(), Ordering::Relaxed);
        drop(q);
        self.not_full.notify_one();
        Some(item)
    }

    /// Drain everything, priority lane first (used by tests and the
    /// elastic example when retiring a reducer).
    pub fn drain(&self) -> Vec<T> {
        let mut q = self.inner.lock().unwrap();
        let mut out: Vec<T> = q.priority.drain(..).collect();
        out.extend(q.data.drain(..));
        self.len.store(0, Ordering::Relaxed);
        drop(q);
        self.not_full.notify_all();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Record;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = DataQueue::new(16);
        for i in 0..5 {
            q.push(Record::new(format!("k{i}"), i));
        }
        for i in 0..5 {
            assert_eq!(q.try_pop().unwrap().value, i);
        }
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn priority_lane_pops_first() {
        let q = DataQueue::new(16);
        q.push(Record::new("data1", 1));
        q.push(Record::new("data2", 2));
        q.push_priority(Record::new("state", 99));
        assert_eq!(q.len(), 3);
        assert_eq!(q.try_pop().unwrap().key, "state");
        assert_eq!(q.try_pop().unwrap().key, "data1");
        assert_eq!(q.try_pop().unwrap().key, "data2");
    }

    #[test]
    fn priority_lane_ignores_capacity() {
        let q = DataQueue::new(1);
        q.push(Record::new("data", 1));
        // data lane full; state must still get through without blocking
        q.push_priority(Record::new("state", 2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop().unwrap().key, "state");
    }

    #[test]
    fn requeue_front_goes_before_queued_data() {
        let q = DataQueue::new(2);
        q.push(Record::new("a", 1));
        q.push(Record::new("b", 2));
        let a = q.try_pop().unwrap();
        // deferred: put it back without waiting even if the lane refilled
        q.push(Record::new("c", 3));
        q.requeue_front(a);
        assert_eq!(q.try_pop().unwrap().key, "a");
        assert_eq!(q.try_pop().unwrap().key, "b");
        assert_eq!(q.try_pop().unwrap().key, "c");
    }

    #[test]
    fn len_and_peak_track() {
        let q = DataQueue::new(16);
        assert_eq!(q.len(), 0);
        q.push(Record::new("a", 1));
        q.push(Record::new("b", 1));
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak(), 2);
        q.try_pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.peak(), 2, "peak is sticky");
    }

    #[test]
    fn try_push_full_returns_record() {
        let q = DataQueue::new(1);
        q.push(Record::new("a", 1));
        let rejected = q.try_push(Record::new("b", 2));
        assert_eq!(rejected.unwrap_err().key, "b");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock sleeps; interpreter time is unrelated
    fn pop_timeout_expires() {
        let q: DataQueue<Record> = DataQueue::new(4);
        let t0 = std::time::Instant::now();
        assert!(q.pop_timeout(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock sleeps; interpreter time is unrelated
    fn pop_timeout_catches_late_push() {
        // regression: a push racing the tail end of a pop wait must be
        // delivered, not lost to an early empty-queue return
        let q = Arc::new(DataQueue::new(4));
        let q2 = q.clone();
        let popper =
            std::thread::spawn(move || q2.pop_timeout(Duration::from_millis(500)));
        std::thread::sleep(Duration::from_millis(40));
        q.push(Record::new("late", 7));
        let got = popper.join().unwrap();
        assert_eq!(got.expect("late push must be seen").key, "late");
        assert!(q.is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock sleeps; interpreter time is unrelated
    fn backpressure_unblocks_producer() {
        let q = Arc::new(DataQueue::new(1));
        q.push(Record::new("first", 1));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            q2.push(Record::new("second", 2)); // blocks until consumer pops
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.try_pop().unwrap().key, "first");
        producer.join().unwrap();
        assert_eq!(q.try_pop().unwrap().key, "second");
    }

    #[test]
    fn concurrent_producers_consumers_conserve_records() {
        let q = Arc::new(DataQueue::new(64));
        let n_per = if cfg!(miri) { 20 } else { 500 };
        let mut producers = Vec::new();
        for p in 0..4 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..n_per {
                    q.push(Record::new(format!("p{p}-{i}"), 1));
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = 0u64;
                while got < (4 * n_per / 2) as u64 {
                    if q.pop_timeout(Duration::from_millis(50)).is_some() {
                        got += 1;
                    }
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        let total: u64 = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 4 * n_per as u64);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_batch_drains_priority_first_up_to_max() {
        let q = DataQueue::new(16);
        q.push(Record::new("d1", 1));
        q.push(Record::new("d2", 2));
        q.push_priority(Record::new("s1", 3));
        let got = q.pop_batch(2, Duration::from_millis(10));
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].key, "s1");
        assert_eq!(got[1].key, "d1");
        let rest = q.pop_batch(8, Duration::from_millis(10));
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].key, "d2");
        assert!(q.pop_batch(8, Duration::from_millis(5)).is_empty());
        assert!(q.is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock sleeps; interpreter time is unrelated
    fn pop_batch_frees_backpressured_producer() {
        let q = Arc::new(DataQueue::new(2));
        q.push(Record::new("a", 1));
        q.push(Record::new("b", 2));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            q2.push(Record::new("c", 3)); // blocks on the full lane
        });
        std::thread::sleep(Duration::from_millis(30));
        let got = q.pop_batch(2, Duration::from_millis(100));
        assert_eq!(got.len(), 2);
        producer.join().unwrap();
        assert_eq!(q.try_pop().unwrap().key, "c");
    }

    #[test]
    fn requeue_front_batch_preserves_order() {
        let q = DataQueue::new(2);
        q.push(Record::new("x", 1));
        let batch =
            vec![Record::new("a", 1), Record::new("b", 2), Record::new("c", 3)];
        // over capacity on purpose: requeue must not block
        q.requeue_front_batch(batch);
        for want in ["a", "b", "c", "x"] {
            assert_eq!(q.try_pop().unwrap().key, want);
        }
    }

    #[test]
    fn drain_empties_priority_first() {
        let q = DataQueue::new(8);
        q.push(Record::new("a", 1));
        q.push(Record::new("b", 2));
        q.push_priority(Record::new("s", 3));
        let drained = q.drain();
        assert_eq!(drained.len(), 3);
        assert_eq!(drained[0].key, "s");
        assert!(q.is_empty());
    }
}
