//! Reducer core logic (§2.1, §3): stateful actors that poll their queue,
//! verify ownership against the current partitioning before processing
//! (forwarding records they no longer own), periodically report their load
//! to the balancer, and surrender their state for the final merge.
//!
//! Ownership questions go through the pluggable routing layer
//! ([`RouterCache`]); both drivers run this same core — only the
//! surrounding loop differs.

use crate::exec::{Record, ReduceExecutor};
use crate::hash::{RouterCache, RouterHandle};

/// Outcome of handling one dequeued record.
#[derive(Debug)]
pub enum Handled {
    /// Folded into local state.
    Reduced,
    /// Reducer no longer owns the key: forward to this destination (§3:
    /// "if it's not then the key is forwarded to the appropriate
    /// reducer").
    Forward(usize, Record),
}

/// Per-reducer state + the check-then-reduce step.
pub struct ReducerCore {
    pub id: usize,
    pub exec: Box<dyn ReduceExecutor>,
    router: RouterCache,
    /// Messages reduced (the paper's `M_i`).
    pub processed: u64,
    /// Messages forwarded onward after a repartition.
    pub forwarded: u64,
    /// §7 state-forwarding: transfers absorbed / extracted.
    pub state_absorbed: u64,
    pub state_extracted: u64,
    handled_since_report: u64,
}

impl ReducerCore {
    pub fn new(id: usize, exec: Box<dyn ReduceExecutor>, router: RouterHandle) -> Self {
        ReducerCore {
            id,
            exec,
            router: router.cache(),
            processed: 0,
            forwarded: 0,
            state_absorbed: 0,
            state_extracted: 0,
            handled_since_report: 0,
        }
    }

    /// Handle one data record: check the current partitioning first (§3:
    /// "before it processes a piece of data, it checks the load balancer
    /// to see if it is indeed assigned to this key").
    ///
    /// The check is *may-own*, not owner-equality: a split key has up to
    /// `d` legitimate homes, and a shard that landed on any of them must
    /// be reduced in place — re-routing it would ping-pong records
    /// between candidates. Single-owner families answer may-own exactly
    /// as `route == id` did.
    pub fn handle(&mut self, rec: Record) -> Handled {
        self.handled_since_report += 1;
        // hash memoized at map time — the check costs one route lookup
        let h = rec.hash();
        if self.router.may_own_hash(h, self.id) {
            self.exec.reduce(rec);
            self.processed += 1;
            Handled::Reduced
        } else {
            let owner = self.router.route_hash(h);
            self.forwarded += 1;
            Handled::Forward(owner, rec)
        }
    }

    /// Current owner of a key under the live partitioning.
    pub fn owner_of(&mut self, key: &str) -> usize {
        self.router.route_key(key.as_bytes())
    }

    /// Should this reducer send a load report now? Counts handled
    /// messages; fires every `interval` (§3: reducers "periodically"
    /// update their load state).
    pub fn due_report(&mut self, interval: u64) -> bool {
        if self.handled_since_report >= interval.max(1) {
            self.handled_since_report = 0;
            true
        } else {
            false
        }
    }

    /// §7 state forwarding — apply an incoming state transfer.
    pub fn absorb_state(&mut self, rec: Record) {
        self.state_absorbed += 1;
        self.exec.absorb_key(&rec.key, rec.value);
    }

    /// §7 state forwarding, substage 1 — extract state for every key this
    /// reducer no longer owns (the snapshot-vs-router ownership diff);
    /// returns `(new_owner, state_record)` pairs.
    ///
    /// Ownership is the same *may-own* question [`Self::handle`] asks, so
    /// a split key's shard partial stays resident on each of its `d`
    /// candidate homes — shipping shards to one "owner" would silently
    /// restore the single-homed hot spot the split exists to break.
    pub fn extract_disowned(&mut self) -> Vec<(usize, Record)> {
        self.exec.flush();
        let snapshot = self.exec.snapshot();
        let mut out = Vec::new();
        for (key, _) in snapshot {
            let h = crate::hash::murmur3_x86_32(key.as_bytes());
            if !self.router.may_own_hash(h, self.id) {
                if let Some(v) = self.exec.extract_key(&key) {
                    self.state_extracted += 1;
                    out.push((self.router.route_hash(h), Record::new(key, v)));
                }
            }
        }
        out
    }

    /// Flush + snapshot state for the final merge.
    pub fn final_snapshot(&mut self) -> Vec<(String, i64)> {
        self.exec.flush();
        self.exec.snapshot()
    }

    /// Flush + *non-destructive* snapshot for a replication checkpoint
    /// (testkit::chaos). Unlike §7 extraction nothing leaves the
    /// executor: the copy ships to a peer while this reducer keeps
    /// reducing, so a later kill can restore from it.
    pub fn checkpoint_snapshot(&mut self) -> Vec<(String, i64)> {
        self.exec.flush();
        self.exec.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::WordCount;
    use crate::hash::{Ring, RingOp};

    fn owned_key(router: &RouterHandle, node: usize) -> String {
        crate::workload::generators::key_pool()
            .into_iter()
            .find(|k| router.route_key(k.as_bytes()) == node)
            .expect("pool has a key for every node")
    }

    #[test]
    fn reduces_owned_keys() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let key = owned_key(&router, 1);
        let mut r = ReducerCore::new(1, Box::new(WordCount::new()), router);
        match r.handle(Record::new(key.clone(), 1)) {
            Handled::Reduced => {}
            h => panic!("expected Reduced, got {h:?}"),
        }
        assert_eq!(r.processed, 1);
        assert_eq!(r.final_snapshot(), vec![(key, 1)]);
    }

    #[test]
    fn forwards_disowned_keys() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let key = owned_key(&router, 2);
        // reducer 0 receives a key owned by reducer 2 (stale routing)
        let mut r = ReducerCore::new(0, Box::new(WordCount::new()), router);
        match r.handle(Record::new(key.clone(), 1)) {
            Handled::Forward(dest, rec) => {
                assert_eq!(dest, 2);
                assert_eq!(rec.key, key);
            }
            h => panic!("expected Forward, got {h:?}"),
        }
        assert_eq!(r.forwarded, 1);
        assert_eq!(r.processed, 0);
        assert!(r.final_snapshot().is_empty());
    }

    #[test]
    fn due_report_fires_on_interval() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let key = owned_key(&router, 0);
        let mut r = ReducerCore::new(0, Box::new(WordCount::new()), router);
        let mut fired = 0;
        for _ in 0..10 {
            r.handle(Record::new(key.clone(), 1));
            if r.due_report(5) {
                fired += 1;
            }
        }
        assert_eq!(fired, 2);
    }

    #[test]
    fn extract_disowned_moves_state_after_repartition() {
        let router = RouterHandle::token_ring(Ring::new(4, 1), RingOp::NoOp);
        let key = owned_key(&router, 0);
        let mut r = ReducerCore::new(0, Box::new(WordCount::new()), router.clone());
        r.handle(Record::new(key.clone(), 1));
        r.handle(Record::new(key.clone(), 1));
        assert_eq!(r.processed, 2);
        // repartition until the key leaves node 0
        let mut moved = false;
        for _ in 0..7 {
            router.update_ring(|rr| rr.double_others(0)).unwrap();
            if router.route_key(key.as_bytes()) != 0 {
                moved = true;
                break;
            }
        }
        assert!(moved);
        let transfers = r.extract_disowned();
        assert_eq!(transfers.len(), 1);
        let (dest, rec) = &transfers[0];
        assert_eq!(*dest, router.route_key(key.as_bytes()));
        assert_eq!(rec.value, 2, "full count extracted");
        assert!(r.final_snapshot().is_empty(), "state left the reducer");
        assert_eq!(r.state_extracted, 1);
    }

    #[test]
    fn absorb_state_merges() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let key = owned_key(&router, 3);
        let mut r = ReducerCore::new(3, Box::new(WordCount::new()), router);
        r.handle(Record::new(key.clone(), 1));
        r.absorb_state(Record::new(key.clone(), 5));
        assert_eq!(r.final_snapshot(), vec![(key, 6)]);
        assert_eq!(r.state_absorbed, 1);
    }

    #[test]
    fn split_shards_reduce_in_place_and_survive_extraction() {
        // a promoted key's shards have d legitimate homes: candidates
        // reduce in place, non-candidates forward to a candidate, and §7
        // extraction never ships a shard partial anywhere
        let sk = crate::hash::SplitKeyRouter::new(4, 2);
        let router = RouterHandle::new(Box::new(sk.clone()));
        let hot = "mega-hot-key";
        let hot_h = crate::hash::murmur3_x86_32(hot.as_bytes());
        let shard = router.route_key(hot.as_bytes()); // records the sticky home
        assert!(sk.promote(hot_h), "seen key promotes");
        let cands = crate::hash::split_candidates_in(hot_h, &[0, 1, 2, 3], 2);
        assert!(cands.contains(&shard));

        let mut r = ReducerCore::new(shard, Box::new(WordCount::new()), router.clone());
        match r.handle(Record::new(hot, 1)) {
            Handled::Reduced => {}
            h => panic!("split shard must reduce in place, got {h:?}"),
        }
        assert!(r.extract_disowned().is_empty(), "shard partial stays resident");
        assert_eq!(r.final_snapshot(), vec![(hot.to_string(), 1)]);

        let outsider = (0..4).find(|i| !cands.contains(i)).unwrap();
        let mut o = ReducerCore::new(outsider, Box::new(WordCount::new()), router);
        match o.handle(Record::new(hot, 1)) {
            Handled::Forward(dest, _) => assert!(cands.contains(&dest)),
            h => panic!("non-candidate must forward, got {h:?}"),
        }
    }

    #[test]
    fn extract_disowned_after_two_choices_rehoming() {
        // the §7 ownership diff works for probe routers too: redistribute
        // re-homes keys, extraction ships exactly the moved keys' state
        let router =
            RouterHandle::new(crate::hash::StrategySpec::TwoChoices.build_router(4, 8, None));
        let keys: Vec<String> = (0..40).map(|i| format!("tck-{i}")).collect();
        let owner0 = router.route_key(keys[0].as_bytes());
        let mut r = ReducerCore::new(owner0, Box::new(WordCount::new()), router.clone());
        let mine: Vec<&String> = keys
            .iter()
            .filter(|k| router.route_key(k.as_bytes()) == owner0)
            .collect();
        for k in &mine {
            r.handle(Record::new((*k).clone(), 1));
        }
        assert_eq!(r.processed as usize, mine.len());
        let delta = router.redistribute(owner0);
        assert!(delta.keys_reassigned > 0);
        let transfers = r.extract_disowned();
        assert_eq!(transfers.len() as u64, delta.keys_reassigned);
        for (dest, rec) in &transfers {
            assert_eq!(*dest, router.route_key(rec.key.as_bytes()));
            assert_ne!(*dest, owner0);
        }
    }
}
