//! Artifact discovery and the build manifest.
//!
//! `make artifacts` writes `artifacts/manifest.json` with the static
//! shapes the XLA programs were lowered for; rust pads every batch to
//! these. The manifest is flat JSON (`{"B": 256, ...}`) parsed with a
//! tiny scanner (offline build: no serde).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

/// Static shapes of the compiled programs (see DESIGN.md §Artifact
/// contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Batch size of `route` / `hash_only` / `reduce_count`.
    pub b: usize,
    /// Key words (u32) per key: max key bytes = 4*W.
    pub w: usize,
    /// Ring capacity (max tokens) of `route`.
    pub t: usize,
    /// Vocab slots of `reduce_count` / `merge_state`.
    pub v: usize,
    /// Node/position capacity of `route_probe` tables and the
    /// `route_assign` loads vector.
    pub p: usize,
    /// Probe capacity `route_probe` was unrolled for.
    pub k: usize,
    /// Sticky-assignment table capacity of `route_assign`.
    pub a: usize,
    /// `route_assign` ABI version: 2 added the live-node-id tensors
    /// (elastic membership). Version-1 artifacts still load, but their
    /// `route_assign` is reported unsupported (typed error) instead of
    /// being fed tensors whose shapes it predates.
    pub av: usize,
    /// Partition-table capacity of `route_table` (max `2^B` entries a
    /// `ptable` snapshot may carry).
    pub pt: usize,
}

impl Manifest {
    /// Max key length in bytes the XLA hash path supports.
    pub fn max_key_bytes(&self) -> usize {
        self.w * 4
    }

    /// Parse flat JSON like `{"B": 256, "W": 8, "T": 512, "V": 4096}`.
    pub fn parse(text: &str) -> crate::Result<Self> {
        let map = parse_flat_json(text)?;
        let get = |k: &str| -> crate::Result<usize> {
            map.get(k)
                .copied()
                .with_context(|| format!("manifest missing key '{k}'"))
                .map(|v| v as usize)
        };
        // P/K/A arrived with the router-aware route programs; default to
        // their aot.py values so pre-existing manifests still parse (the
        // corresponding .hlo.txt files are simply absent then and the
        // runtime reports the snapshot as unsupported on use)
        let get_or = |k: &str, d: usize| map.get(k).map_or(d, |&v| v as usize);
        let m = Manifest {
            b: get("B")?,
            w: get("W")?,
            t: get("T")?,
            v: get("V")?,
            p: get_or("P", 64),
            k: get_or("K", 8),
            a: get_or("A", 4096),
            av: get_or("AV", 1),
            // PT arrived with the partition-table route program; absent =
            // old artifacts, whose missing route_table.hlo.txt makes the
            // ptable snapshot a typed unsupported error at use
            pt: get_or("PT", 1024),
        };
        if m.b == 0 || m.w == 0 || m.t == 0 || m.v == 0 || m.p == 0 || m.k == 0 || m.a == 0 {
            bail!("manifest has zero-sized dimension: {m:?}");
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> crate::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }
}

/// Parse a flat `{"key": int, ...}` JSON object.
fn parse_flat_json(text: &str) -> crate::Result<HashMap<String, i64>> {
    let text = text.trim();
    let inner = text
        .strip_prefix('{')
        .and_then(|t| t.strip_suffix('}'))
        .context("manifest is not a JSON object")?;
    let mut map = HashMap::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once(':').context("expected \"key\": value")?;
        let k = k.trim().trim_matches('"').to_string();
        let v: i64 = v.trim().parse().context("manifest values must be integers")?;
        map.insert(k, v);
    }
    Ok(map)
}

/// Locate the artifacts directory: `$DPA_ARTIFACTS`, else `artifacts/`
/// relative to the current dir, the crate root, or their parents.
pub fn default_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("DPA_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut candidates: Vec<PathBuf> = vec![PathBuf::from("artifacts")];
    // crate root (tests/benches run from target subdirs)
    candidates.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if let Ok(cwd) = std::env::current_dir() {
        let mut d = cwd.as_path();
        while let Some(parent) = d.parent() {
            candidates.push(d.join("artifacts"));
            d = parent;
        }
    }
    candidates.into_iter().find(|p| p.join("manifest.json").exists())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(
            r#"{"B": 256, "W": 8, "T": 512, "V": 4096, "P": 64, "K": 8, "A": 4096, "AV": 2,
                "PT": 1024}"#,
        )
        .unwrap();
        assert_eq!(
            m,
            Manifest { b: 256, w: 8, t: 512, v: 4096, p: 64, k: 8, a: 4096, av: 2, pt: 1024 }
        );
        assert_eq!(m.max_key_bytes(), 32);
    }

    #[test]
    fn parse_manifest_defaults_probe_dims() {
        // manifests written before the router-aware route programs
        let m = Manifest::parse(r#"{"B": 256, "W": 8, "T": 512, "V": 4096}"#).unwrap();
        assert_eq!((m.p, m.k, m.a), (64, 8, 4096));
        assert_eq!(m.av, 1, "pre-elastic manifests default to assign ABI v1");
        assert_eq!(m.pt, 1024, "pre-ptable manifests default the table capacity");
        let m = Manifest::parse(
            r#"{"B": 256, "W": 8, "T": 512, "V": 4096, "P": 16, "K": 4, "A": 128}"#,
        )
        .unwrap();
        assert_eq!((m.p, m.k, m.a), (16, 4, 128));
    }

    #[test]
    fn parse_tolerates_whitespace_and_order() {
        let m = Manifest::parse("{ \"V\":16,\n \"T\":4, \"W\": 2, \"B\": 8 }").unwrap();
        assert_eq!(m.b, 8);
        assert_eq!(m.v, 16);
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse(r#"{"B": 256}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"B": 0, "W": 8, "T": 512, "V": 4096}"#).is_err());
    }
}
