//! Thin wrapper around the `xla` crate's PJRT CPU client: HLO-text →
//! compiled executable, with error context. One client per process;
//! executables are compiled once and reused for every batch.

use std::path::Path;

use anyhow::Context;

/// A PJRT client plus compile helpers.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// CPU PJRT client (the only backend in this environment; TPU/GPU
    /// plugins would slot in here).
    pub fn cpu() -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(RuntimeClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The underlying PJRT client (buffer creation etc.).
    pub fn pjrt(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_text(&self, path: &Path) -> crate::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Execute with literal inputs; unwraps the 1-tuple convention
    /// (`aot.py` lowers with `return_tuple=True`).
    pub fn execute_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> crate::Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .context("executing compiled program")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("device-to-host transfer")?;
        lit.to_tuple().context("decomposing result tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let c = RuntimeClient::cpu().expect("PJRT CPU client");
        assert!(c.device_count() >= 1);
        assert!(!c.platform().is_empty());
    }

    #[test]
    fn compile_missing_file_errors() {
        let c = RuntimeClient::cpu().unwrap();
        assert!(c
            .compile_hlo_text(Path::new("/nonexistent/x.hlo.txt"))
            .is_err());
    }
}
