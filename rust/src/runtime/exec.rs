//! The shared execution runtime both drivers schedule over.
//!
//! Historically [`crate::sim`] and [`crate::driver`] were two independent
//! ~300-line reimplementations of the same pipeline: topology construction
//! (task pool, per-reducer queues, actor cores), the reducer step
//! state-machine (ownership check → reduce / forward / state-extract /
//! state-absorb, staged by [`StageTracker`]), the drain/termination
//! condition, and the final snapshot → [`merge_states`] → [`RunReport`]
//! assembly — and only the sim's queues carried the §7 `Envelope` protocol,
//! so `ConsistencyMode::StateForward` was banned on real threads.
//!
//! [`ExecCore`] now owns all of that once. A driver contributes only its
//! *scheduler*: the DES supplies virtual time and a deterministic event
//! heap and calls [`ExecCore::reducer_step`] with a non-blocking pop; the
//! threads driver supplies OS threads and calls the same step with a
//! timeout pop. Load reports flow through [`LoadReport`] values — applied
//! inline by the sim, shipped over a lock-free channel to a dedicated
//! balancer thread by the threads driver, so the reducer hot path never
//! takes a global balancer lock.

#![forbid(unsafe_code)]

use std::time::Duration;

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Arc;

use crate::actor::{Envelope, ShutdownMonitor};
use crate::balancer::state_forward::{ConsistencyMode, Stage, StageTracker};
use crate::balancer::BalancerCore;
use crate::coordinator::{merge_states, TaskPool};
use crate::exec::{Record, ReduceFactory};
use crate::hash::{MergeContract, RouterHandle};
use crate::mapper::MapperCore;
use crate::metrics::{Histogram, LbEvent, MembershipChange, RecoveryCounts, RunReport};
use crate::queue::DataQueue;
use crate::reducer::{Handled, ReducerCore};
use crate::testkit::chaos::ChaosController;

/// Driver-agnostic knobs for one pipeline execution.
#[derive(Clone, Debug)]
pub struct ExecParams {
    /// Items per coordinator task.
    pub chunk_size: usize,
    /// Per-reducer data-lane capacity (`usize::MAX` for the sim: a
    /// single-threaded scheduler must never block on backpressure).
    pub queue_capacity: usize,
    /// Load report every N handled messages (§3 "periodically").
    pub report_interval: u64,
    /// Merge-at-end (§2) or state forwarding (§7).
    pub mode: ConsistencyMode,
    /// `true` = reducers stop only when [`ExecCore::request_stop`] is
    /// called (threads driver: the balancer thread confirms global drain,
    /// closing the race between a late rebalance and an exiting reducer).
    /// `false` = reducers stop themselves on drained + synchronized (sim:
    /// the single-threaded schedule makes the condition stable).
    pub coordinated_stop: bool,
    /// Reducer-id ceiling for elastic scale-up (`balancer.max_reducers`).
    /// Queues and tracker slots are pre-allocated up to it so membership
    /// changes never reallocate shared structures; equal to the initial
    /// reducer count for fixed-membership runs.
    pub max_reducers: usize,
}

/// One load report flowing from a reducer to the balancer's owner.
#[derive(Clone, Copy, Debug)]
pub struct LoadReport {
    pub reducer: usize,
    pub qlen: usize,
    /// Driver timestamp: virtual ticks (sim) or elapsed µs (threads).
    pub at: u64,
    /// `true` = periodic report (evaluate the LB policy), `false` = idle
    /// observation (record load only).
    pub evaluate: bool,
}

/// What one reducer step did — the scheduler charges costs / delays off
/// this, never re-implementing the decision logic itself.
#[derive(Debug)]
pub enum ReducerStep {
    /// §7 substage 1: extracted disowned state, `sent` transfers shipped.
    StateExtracted { sent: usize },
    /// Applied an incoming state transfer.
    StateAbsorbed,
    /// Folded one data record into local state.
    Reduced,
    /// Forwarded a stale-routed record to its current owner.
    Forwarded,
    /// Data deferred (re-queued locally) during a synchronization window.
    Deferred,
    /// Queue empty; `stop` = the termination condition held.
    Idle { stop: bool },
}

/// Everything the two drivers used to duplicate, built once per run.
pub struct ExecCore {
    pub pool: TaskPool,
    pub queues: Vec<DataQueue<Envelope>>,
    pub monitor: ShutdownMonitor,
    pub tracker: StageTracker,
    pub mode: ConsistencyMode,
    pub report_interval: u64,
    /// Per-record map-enqueue → reduce latency (all reducers share it:
    /// recording is one relaxed fetch_add, and a single histogram keeps
    /// the report assembly trivial).
    pub latency: Histogram,
    input_items: u64,
    coordinated_stop: bool,
    /// The router family's merge contract, captured at build time. Under
    /// [`MergeContract::Disjoint`] the §7 final merge asserts that no key
    /// has state on more than one reducer; [`MergeContract::Associative`]
    /// (split-key) relaxes that to an order-independent fold of per-shard
    /// partials, so the disjointness assertion is skipped.
    merge_contract: MergeContract,
    /// Fault-injection controller (testkit::chaos). `None` — the default —
    /// keeps every hook on the hot path a single branch on an unset
    /// `Option`; a chaos run threads WAL logging, checkpoint cadence and
    /// the kill/recovery protocol through the same step state-machine.
    chaos: Option<Arc<ChaosController>>,
    stop: AtomicBool,
}

impl ExecCore {
    /// Build the run topology: chunk the shared input into the task pool,
    /// one envelope queue per reducer, shutdown accounting for `n_mappers`
    /// and a stage tracker pinned to the router's current epoch.
    pub fn build(
        router: &RouterHandle,
        n_mappers: usize,
        items: impl Into<Arc<[String]>>,
        params: ExecParams,
    ) -> Self {
        let items: Arc<[String]> = items.into();
        let n_reducers = router.nodes();
        // elastic runs pre-allocate queue + tracker slots to the ceiling,
        // so a scale-up only *activates* a slot — shared structures never
        // grow while other actors hold references to them
        let capacity = params.max_reducers.max(n_reducers);
        let input_items = items.len() as u64;
        ExecCore {
            pool: TaskPool::from_items(items, params.chunk_size),
            queues: (0..capacity)
                .map(|_| DataQueue::new(params.queue_capacity))
                .collect(),
            monitor: ShutdownMonitor::new(n_mappers),
            tracker: {
                let mut t = StageTracker::with_capacity(n_reducers, capacity, router.epoch());
                // checkpoint-to-peer then prefers a cross-zone replica
                t.set_zones(router.zones());
                t
            },
            mode: params.mode,
            report_interval: params.report_interval,
            latency: Histogram::new(),
            input_items,
            coordinated_stop: params.coordinated_stop,
            merge_contract: router.merge_contract(),
            chaos: None,
            stop: AtomicBool::new(false),
        }
    }

    /// Attach a fault-injection controller (testkit::chaos). The
    /// controller must have been built with at least this core's queue
    /// capacity so every pre-allocated slot has a WAL.
    pub fn with_chaos(mut self, chaos: Arc<ChaosController>) -> Self {
        self.chaos = Some(chaos);
        self
    }

    /// The attached fault-injection controller, if any.
    pub fn chaos(&self) -> Option<&Arc<ChaosController>> {
        self.chaos.as_ref()
    }

    /// Is the §7 protocol (if active) in substage 2? Always `true` under
    /// merge-at-end.
    pub fn synced(&self) -> bool {
        self.mode != ConsistencyMode::StateForward || self.tracker.stage() == Stage::Synchronized
    }

    /// Route one mapped record: in-flight accounting strictly before the
    /// push so the drain condition never undercounts.
    pub fn push_mapped(&self, dest: usize, rec: Record) {
        self.monitor.produced(1);
        self.queues[dest].push(Envelope::Data(rec));
    }

    /// Batch variant (threads driver: one queue lock per task per
    /// destination instead of one per record).
    pub fn push_mapped_batch(&self, dest: usize, recs: Vec<Record>) {
        if recs.is_empty() {
            return;
        }
        self.monitor.produced(recs.len() as u64);
        self.queues[dest]
            .push_batch(recs.into_iter().map(Envelope::Data).collect());
    }

    /// The reducer step state-machine (§3 + §7) both drivers share.
    ///
    /// `pop` is the only driver-specific ingredient: the sim passes a
    /// non-blocking [`DataQueue::try_pop`], the threads driver a batched
    /// [`DataQueue::pop_batch`] drain. `now` is the driver clock (virtual
    /// ticks / elapsed µs) — a reduced record's stamp subtracted from it
    /// is the per-record latency sample.
    pub fn reducer_step<F>(&self, rc: &mut ReducerCore, i: usize, now: u64, pop: F) -> ReducerStep
    where
        F: FnOnce(&DataQueue<Envelope>) -> Option<Envelope>,
    {
        // §7 substage 1: extract before touching any data
        if self.mode == ConsistencyMode::StateForward && self.tracker.needs_extraction(i) {
            let transfers = rc.extract_disowned();
            let sent = transfers.len();
            for (dest, rec) in transfers {
                if let Some(ch) = &self.chaos {
                    // WAL the departure: a later crash replay must not
                    // resurrect state that legally moved away
                    ch.on_extracted(i, &rec.key);
                }
                // state rides the priority lane: destinations apply it
                // before any queued data
                self.queues[dest].push_priority(Envelope::State(rec));
            }
            self.tracker.extraction_done(i, sent as u64);
            return ReducerStep::StateExtracted { sent };
        }

        match pop(&self.queues[i]) {
            Some(Envelope::State(rec)) => {
                if let Some(ch) = &self.chaos {
                    ch.on_absorbed(i, &rec.key, rec.value);
                }
                rc.absorb_state(rec);
                self.tracker.transfer_landed();
                ReducerStep::StateAbsorbed
            }
            Some(Envelope::Checkpoint { origin, seq, state }) => {
                // replicated-state snapshot from a peer: install into the
                // run's controller, never into this reducer's executor
                if let Some(ch) = &self.chaos {
                    ch.install_checkpoint(origin, seq, state);
                }
                ReducerStep::StateAbsorbed
            }
            Some(Envelope::Data(rec)) => {
                if self.mode == ConsistencyMode::StateForward
                    && self.tracker.stage() == Stage::Synchronizing
                {
                    // substage 1: no data processing — put it back (paper:
                    // "any data that need to be forwarded gets put back
                    // into the queue")
                    self.queues[i].requeue_front(Envelope::Data(rec));
                    return ReducerStep::Deferred;
                }
                // stamp before handle() consumes the record; unstamped
                // (0) records — direct core tests — record no sample
                let stamp = rec.stamp();
                let logged = self.chaos.as_ref().map(|_| (rec.key.clone(), rec.value));
                match rc.handle(rec) {
                    Handled::Reduced => {
                        if stamp > 0 {
                            self.latency.record(now.saturating_sub(stamp));
                        }
                        self.monitor.consumed();
                        if let (Some(ch), Some((key, value))) = (&self.chaos, logged) {
                            if ch.on_reduced(i, &key, value) {
                                self.cut_checkpoint(ch, rc, i);
                            }
                        }
                        ReducerStep::Reduced
                    }
                    Handled::Forward(dest, rec) => {
                        self.queues[dest].push(Envelope::Data(rec));
                        ReducerStep::Forwarded
                    }
                }
            }
            None => ReducerStep::Idle { stop: self.reducer_can_stop(i) },
        }
    }

    /// §2.3: a reducer can never stop on its own — only when the global
    /// drain condition holds (and, under §7, no synchronization is in
    /// flight that could still route state or deferred data to it, and no
    /// kill is due or mid-recovery that could still re-home state to it).
    fn reducer_can_stop(&self, i: usize) -> bool {
        if self.coordinated_stop {
            self.stop.load(Ordering::Acquire) && self.queues[i].is_empty()
        } else {
            self.monitor.drained()
                && self.synced()
                && self.chaos.as_ref().map_or(true, |c| c.quiescent())
                && self.queues[i].is_empty()
        }
    }

    /// Cut a replication checkpoint for reducer `i` and ship it to the
    /// nearest live peer over the §7 priority lane. With no live peer
    /// left the snapshot installs locally — degenerate but still exact,
    /// since a controller outlives every reducer.
    fn cut_checkpoint(&self, ch: &ChaosController, rc: &mut ReducerCore, i: usize) {
        let seq = ch.begin_checkpoint(i);
        let state = rc.checkpoint_snapshot();
        match self.tracker.next_live_peer(i) {
            Some(peer) => {
                self.queues[peer].push_priority(Envelope::Checkpoint { origin: i, seq, state });
            }
            None => ch.install_checkpoint(i, seq, state),
        }
    }

    /// Threads driver: the balancer thread confirms global drain and
    /// releases the reducers.
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    pub fn all_queues_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Fail-stop bookkeeping at the instant a [`FaultAction::Kill`]
    /// (testkit::chaos) fires: the victim leaves the §7 extraction quorum
    /// — a pending epoch must not wait on a corpse — and its queue's
    /// protocol traffic is absorbed on its behalf.
    ///
    /// [`FaultAction::Kill`]: crate::testkit::chaos::FaultAction::Kill
    pub fn chaos_fail_stop(&self, i: usize) {
        self.tracker.retire_faulted(i);
        self.chaos_drain_dead(i);
    }

    /// Absorb the §7 protocol traffic sitting in a dead reducer's queue.
    ///
    /// Peers that were already extracting when the kill fired may have
    /// shipped `State` at the victim; nobody will ever pop it, so the
    /// epoch would wedge on `outstanding` forever. Settling it here —
    /// into the victim's WAL, so recovery re-homes it — unwedges the
    /// epoch without losing a single key. Data records are put back:
    /// they re-route only after the membership surgery. Call this at kill
    /// time and again on every wait iteration while recovery is queued.
    pub fn chaos_drain_dead(&self, i: usize) {
        let Some(ch) = &self.chaos else { return };
        let drained = self.queues[i].drain();
        if drained.is_empty() {
            return;
        }
        let mut data = Vec::new();
        for env in drained {
            match env {
                Envelope::State(rec) => {
                    ch.on_absorbed(i, &rec.key, rec.value);
                    self.tracker.transfer_landed();
                }
                Envelope::Checkpoint { origin, seq, state } => {
                    ch.install_checkpoint(origin, seq, state);
                }
                env @ Envelope::Data(_) => data.push(env),
            }
        }
        if !data.is_empty() {
            self.queues[i].push_batch(data);
        }
    }

    /// After the membership surgery: re-route the dead reducer's queued
    /// data to its post-recovery owners. The records are already in
    /// flight — they only change queues, so the shutdown monitor is not
    /// touched. Returns how many records moved. Safe to call repeatedly:
    /// a mapper holding a stale route cache may land data on the corpse
    /// after the first sweep.
    pub fn chaos_requeue_dead(&self, i: usize, router: &RouterHandle) -> u64 {
        let Some(ch) = &self.chaos else { return 0 };
        let mut n = 0;
        for env in self.queues[i].drain() {
            match env {
                Envelope::State(rec) => {
                    ch.on_absorbed(i, &rec.key, rec.value);
                    self.tracker.transfer_landed();
                }
                Envelope::Checkpoint { origin, seq, state } => {
                    ch.install_checkpoint(origin, seq, state);
                }
                Envelope::Data(rec) => {
                    let dest = router.route_key(rec.key.as_bytes());
                    self.queues[dest].push(Envelope::Data(rec));
                    n += 1;
                }
            }
        }
        if n > 0 {
            ch.note_requeued(n);
        }
        n
    }

    /// Re-home the victim's recovered state (checkpoint + WAL-tail
    /// replay) onto its post-surgery owners, via the same priority lane
    /// §7 transfers ride. Books the transfers with the tracker so no new
    /// epoch opens until every one has landed (see [`Self::apply_report`]).
    pub fn chaos_rehome(&self, victim: usize, router: &RouterHandle, factory: &ReduceFactory) {
        let Some(ch) = &self.chaos else { return };
        let state = ch.recovered_state(victim, factory);
        if state.is_empty() {
            return;
        }
        self.tracker.transfers_booked(state.len() as u64);
        for (key, value) in state {
            let dest = router.route_key(key.as_bytes());
            self.queues[dest].push_priority(Envelope::State(Record::new(key, value)));
        }
    }

    /// Apply one load report to the balancer, honouring the §7 gating: no
    /// repartition may start while a previous one is still synchronizing
    /// ("updates must be atomic and infrequent"), and a repartition that
    /// does fire immediately opens the new epoch's synchronization window.
    ///
    /// Elastic membership events flow through the very same gate: a
    /// scale-up first activates the joiner's pre-allocated tracker slot
    /// (so it participates in the extraction quorum from this epoch on),
    /// then the epoch opens like any repartition. The driver watches the
    /// returned event's [`MembershipChange::Added`] to actually spawn the
    /// reducer actor; its queue already exists and may legally receive
    /// records before the actor starts stepping.
    /// Chaos runs add two more gates. A `DropReports` fault swallows the
    /// report entirely (not even an observation — the wire ate it). And
    /// no new epoch may open while a kill is unrecovered or a recovery's
    /// re-homed state is still in flight: extraction diffs ownership
    /// against the *current* router, so state still travelling under the
    /// old assignment would strand at a non-owner.
    pub fn apply_report(&self, balancer: &mut BalancerCore, r: LoadReport) -> Option<LbEvent> {
        if let Some(ch) = &self.chaos {
            if r.evaluate && ch.should_drop_report(r.reducer) {
                return None;
            }
        }
        let quiet = self.chaos.as_ref().map_or(true, |c| c.quiescent());
        let settled =
            self.mode != ConsistencyMode::StateForward || self.tracker.transfers_settled();
        if !r.evaluate || !self.synced() || !quiet || !settled {
            balancer.observe(r.reducer, r.qlen);
            return None;
        }
        let event = balancer.report(r.reducer, r.qlen, r.at);
        if let Some(e) = &event {
            if let Some(MembershipChange::Added { id }) = e.membership {
                self.tracker.activate(id as usize);
            }
            if self.mode == ConsistencyMode::StateForward {
                self.tracker.begin_epoch(e.epoch);
            }
        }
        event
    }

    /// Final-snapshot → state-merge → report assembly (§2), identical for
    /// every driver. Under §7 with a pure-state executor *and* a disjoint
    /// merge contract the snapshots must be key-disjoint and
    /// [`merge_states`] asserts it; an associative contract (split-key
    /// routing) folds per-shard partials instead.
    pub fn finish(
        &self,
        mappers: &[MapperCore],
        reducers: &mut [ReducerCore],
        balancer: &mut BalancerCore,
        reduce_factory: &ReduceFactory,
        wall: Duration,
        virtual_end: u64,
    ) -> RunReport {
        let snaps: Vec<Vec<(String, i64)>> =
            reducers.iter_mut().map(|r| r.final_snapshot()).collect();
        let probe = reduce_factory(0);
        let op = probe.merge_op();
        // §7 disjointness is only an invariant under a disjoint merge
        // contract: split-key routers deliberately leave shards of one
        // mega-hot key on several reducers, to be folded associatively.
        let expect_disjoint = self.mode == ConsistencyMode::StateForward
            && probe.snapshot_is_state()
            && self.merge_contract == MergeContract::Disjoint;
        let result = merge_states(snaps, op, expect_disjoint);

        let mut report = RunReport {
            processed: reducers.iter().map(|r| r.processed).collect(),
            forwarded: reducers.iter().map(|r| r.forwarded).collect(),
            mapped: mappers.iter().map(|m| m.emitted).collect(),
            lb_events: balancer.take_events(),
            result,
            wall,
            virtual_end,
            // only the spawned reducers' queues (elastic runs pre-allocate
            // more slots than ever activate)
            peak_qlen: self.queues.iter().take(reducers.len()).map(|q| q.peak()).collect(),
            input_items: self.input_items,
            latency: (!self.latency.is_empty()).then(|| self.latency.stats()),
            fault_events: Vec::new(),
            recovery: RecoveryCounts::default(),
            recovery_latency: None,
        };
        if let Some(ch) = &self.chaos {
            let (fault_events, recovery, recovery_latency) = ch.summary();
            report.fault_events = fault_events;
            report.recovery = recovery;
            report.recovery_latency = recovery_latency;
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::WordCount;
    use crate::hash::{Ring, RingOp, Strategy};

    fn core(mode: ConsistencyMode, router: &RouterHandle, items: Vec<String>) -> ExecCore {
        ExecCore::build(
            router,
            1,
            items,
            ExecParams {
                chunk_size: 10,
                queue_capacity: usize::MAX,
                report_interval: 2,
                mode,
                coordinated_stop: false,
                max_reducers: 0,
            },
        )
    }

    fn owned_key(router: &RouterHandle, node: usize) -> String {
        crate::workload::generators::key_pool()
            .into_iter()
            .find(|k| router.route_key(k.as_bytes()) == node)
            .expect("pool has a key for every node")
    }

    #[test]
    fn merge_contract_captured_at_build() {
        let ring = RouterHandle::token_ring(Ring::new(2, 8), RingOp::NoOp);
        let c = core(ConsistencyMode::StateForward, &ring, vec![]);
        assert_eq!(c.merge_contract, MergeContract::Disjoint);

        let split = RouterHandle::new(Strategy::SplitKey { d: 2 }.build_router(2, 8, None));
        let c = core(ConsistencyMode::StateForward, &split, vec![]);
        assert_eq!(
            c.merge_contract,
            MergeContract::Associative,
            "split-key runs must skip the §7 disjointness assertion"
        );
    }

    #[test]
    fn topology_matches_router() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let c = core(ConsistencyMode::MergeAtEnd, &router, vec!["a".into(); 25]);
        assert_eq!(c.queues.len(), 4);
        assert_eq!(c.pool.total(), 3);
        assert!(c.synced());
    }

    #[test]
    fn step_reduces_owned_and_forwards_disowned() {
        let router = RouterHandle::token_ring(Ring::new(4, 8), RingOp::NoOp);
        let c = core(ConsistencyMode::MergeAtEnd, &router, vec![]);
        let key = owned_key(&router, 1);
        let other = owned_key(&router, 2);
        let mut rc = ReducerCore::new(1, Box::new(WordCount::new()), router.clone());

        c.push_mapped(1, Record::new(key, 1));
        c.push_mapped(1, Record::new(other, 1)); // stale-routed
        assert!(matches!(
            c.reducer_step(&mut rc, 1, 0, |q| q.try_pop()),
            ReducerStep::Reduced
        ));
        assert!(matches!(
            c.reducer_step(&mut rc, 1, 0, |q| q.try_pop()),
            ReducerStep::Forwarded
        ));
        assert_eq!(c.queues[2].len(), 1, "forward landed at the owner");
        // one record reduced, one still in flight (forwarded)
        assert_eq!(c.monitor.in_flight(), 1);
    }

    #[test]
    fn idle_stop_requires_drain_and_sync() {
        let router = RouterHandle::token_ring(Ring::new(2, 8), RingOp::NoOp);
        let c = core(ConsistencyMode::MergeAtEnd, &router, vec![]);
        let mut rc = ReducerCore::new(0, Box::new(WordCount::new()), router.clone());
        // mapper still running → no stop
        match c.reducer_step(&mut rc, 0, 0, |q| q.try_pop()) {
            ReducerStep::Idle { stop } => assert!(!stop),
            s => panic!("expected Idle, got {s:?}"),
        }
        c.monitor.mapper_done();
        match c.reducer_step(&mut rc, 0, 0, |q| q.try_pop()) {
            ReducerStep::Idle { stop } => assert!(stop),
            s => panic!("expected Idle, got {s:?}"),
        }
    }

    #[test]
    fn coordinated_stop_waits_for_request() {
        let router = RouterHandle::token_ring(Ring::new(2, 8), RingOp::NoOp);
        let mut c = core(ConsistencyMode::MergeAtEnd, &router, vec![]);
        c.coordinated_stop = true;
        c.monitor.mapper_done();
        let mut rc = ReducerCore::new(0, Box::new(WordCount::new()), router.clone());
        match c.reducer_step(&mut rc, 0, 0, |q| q.try_pop()) {
            ReducerStep::Idle { stop } => assert!(!stop, "no stop before request"),
            s => panic!("expected Idle, got {s:?}"),
        }
        c.request_stop();
        match c.reducer_step(&mut rc, 0, 0, |q| q.try_pop()) {
            ReducerStep::Idle { stop } => assert!(stop),
            s => panic!("expected Idle, got {s:?}"),
        }
    }

    #[test]
    fn state_forward_round_trip_through_core() {
        // repartition → extraction ships state on the priority lane →
        // destination absorbs → synchronized again
        let router = RouterHandle::token_ring(Ring::new(4, 1), RingOp::NoOp);
        let c = core(ConsistencyMode::StateForward, &router, vec![]);
        let key = owned_key(&router, 0);
        let mut r0 = ReducerCore::new(0, Box::new(WordCount::new()), router.clone());
        let mut others: Vec<ReducerCore> = (1..4)
            .map(|i| ReducerCore::new(i, Box::new(WordCount::new()), router.clone()))
            .collect();

        c.push_mapped(0, Record::new(key.clone(), 1));
        c.push_mapped(0, Record::new(key.clone(), 1));
        assert!(matches!(c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()), ReducerStep::Reduced));
        assert!(matches!(c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()), ReducerStep::Reduced));

        // move the key off node 0, then open the epoch like apply_report
        let mut moved = false;
        for _ in 0..7 {
            router.update_ring(|rr| rr.double_others(0)).unwrap();
            if router.route_key(key.as_bytes()) != 0 {
                moved = true;
                break;
            }
        }
        assert!(moved);
        c.tracker.begin_epoch(router.epoch());

        // every reducer runs substage 1; node 0 ships its count
        match c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()) {
            ReducerStep::StateExtracted { sent } => assert_eq!(sent, 1),
            s => panic!("expected extraction, got {s:?}"),
        }
        for rc in others.iter_mut() {
            let id = rc.id;
            match c.reducer_step(rc, id, 0, |q| q.try_pop()) {
                ReducerStep::StateExtracted { sent } => assert_eq!(sent, 0),
                s => panic!("expected extraction, got {s:?}"),
            }
        }
        assert!(!c.synced(), "transfer still in flight");

        // new owner absorbs the state from its priority lane
        let owner = router.route_key(key.as_bytes());
        let rc = others.iter_mut().find(|r| r.id == owner).unwrap();
        assert!(matches!(
            c.reducer_step(rc, owner, 0, |q| q.try_pop()),
            ReducerStep::StateAbsorbed
        ));
        assert!(c.synced());
        assert_eq!(rc.final_snapshot(), vec![(key, 2)], "state arrived whole");
        assert!(r0.final_snapshot().is_empty(), "state left the old owner");
    }

    #[test]
    fn synchronizing_defers_data() {
        let router = RouterHandle::token_ring(Ring::new(2, 1), RingOp::NoOp);
        let c = core(ConsistencyMode::StateForward, &router, vec![]);
        let key = owned_key(&router, 0);
        let mut r0 = ReducerCore::new(0, Box::new(WordCount::new()), router.clone());
        c.push_mapped(0, Record::new(key, 1));
        router.update_ring(|rr| rr.double_others(1)).unwrap();
        c.tracker.begin_epoch(router.epoch());
        // extraction first (empty state), then the queued data defers
        // until the OTHER reducer also extracts
        assert!(matches!(
            c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()),
            ReducerStep::StateExtracted { sent: 0 }
        ));
        assert!(matches!(c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()), ReducerStep::Deferred));
        assert_eq!(c.queues[0].len(), 1, "deferred data stays local");
    }

    #[test]
    fn apply_report_feeds_the_load_signal() {
        use crate::balancer::signal::{FRAC_BITS, SignalConfig};
        let cfg = SignalConfig { decay_alpha: 0.5, hysteresis: 0.0, min_gain: 0.0 };
        let router = RouterHandle::builder(Strategy::TwoChoices.build_router(4, 8, None))
            .signal(&cfg)
            .build();
        let c = core(ConsistencyMode::MergeAtEnd, &router, vec![]);
        let mut balancer =
            BalancerCore::new(router.clone(), Strategy::TwoChoices, 0.2, 4, 1, 0)
                .without_warmup();
        // non-evaluating (idle) reports still feed the decayed signal the
        // routers consume — both report kinds flow through observe()
        for _ in 0..2 {
            let e = c.apply_report(
                &mut balancer,
                LoadReport { reducer: 1, qlen: 100, at: 0, evaluate: false },
            );
            assert!(e.is_none(), "idle observations never trigger");
        }
        assert_eq!(router.loads().get(1), 100);
        assert_eq!(router.loads().decayed(1), 75 << FRAC_BITS);
    }

    #[test]
    fn apply_report_scale_up_activates_tracker_and_opens_epoch() {
        use crate::balancer::elastic::{ElasticConfig, ElasticController};
        use crate::balancer::signal::SignalConfig;
        let cfg =
            ElasticConfig { scale_up: 2.0, scale_down: 0.5, min_reducers: 2, max_reducers: 4 };
        let router = RouterHandle::builder(Strategy::Doubling.build_router(2, 8, None))
            .signal(&SignalConfig::legacy())
            .capacity(cfg.max_reducers)
            .build();
        let mut balancer = BalancerCore::new(router.clone(), Strategy::Doubling, 0.2, 4, 1, 0)
            .with_elastic(ElasticController::from_watermarks(cfg, 0))
            .without_warmup();
        let c = ExecCore::build(
            &router,
            1,
            Vec::<String>::new(),
            ExecParams {
                chunk_size: 10,
                queue_capacity: usize::MAX,
                report_interval: 2,
                mode: ConsistencyMode::StateForward,
                coordinated_stop: false,
                max_reducers: cfg.max_reducers,
            },
        );
        assert_eq!(c.queues.len(), 4, "queues pre-allocated to the ceiling");
        assert_eq!(c.tracker.active_count(), 2);
        let e = c
            .apply_report(&mut balancer, LoadReport { reducer: 0, qlen: 30, at: 0, evaluate: true })
            .expect("scale-up fires");
        assert!(matches!(
            e.membership,
            Some(crate::metrics::MembershipChange::Added { id: 2 })
        ));
        assert_eq!(c.tracker.active_count(), 3, "joiner in the extraction quorum");
        assert_eq!(c.tracker.stage(), Stage::Synchronizing, "membership opened the epoch");
        assert_eq!(router.nodes(), 3);
    }

    fn chaos_core(
        router: &RouterHandle,
        plan: &str,
        interval: u64,
    ) -> (ExecCore, Arc<ChaosController>) {
        use crate::testkit::chaos::{ChaosConfig, ChaosPlan};
        let mut cfg = ChaosConfig::new(ChaosPlan::parse(plan).expect("test plan parses"));
        cfg.checkpoint_interval = interval;
        let ch = Arc::new(ChaosController::new(&cfg, router.nodes()));
        let c = core(ConsistencyMode::MergeAtEnd, router, vec![]).with_chaos(Arc::clone(&ch));
        (c, ch)
    }

    fn wordcount_factory() -> ReduceFactory {
        Arc::new(|_| Box::new(WordCount::new()) as Box<dyn crate::exec::ReduceExecutor>)
    }

    #[test]
    fn chaos_checkpoint_rides_the_priority_lane_to_a_peer() {
        let router = RouterHandle::token_ring(Ring::new(2, 8), RingOp::NoOp);
        let (c, ch) = chaos_core(&router, "", 2);
        let key = owned_key(&router, 0);
        let mut r0 = ReducerCore::new(0, Box::new(WordCount::new()), router.clone());
        c.push_mapped(0, Record::new(key.clone(), 1));
        c.push_mapped(0, Record::new(key.clone(), 1));
        assert!(matches!(c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()), ReducerStep::Reduced));
        assert!(matches!(c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()), ReducerStep::Reduced));
        // the second reduce crossed the cadence: a checkpoint sits on the
        // peer's priority lane, and installing it makes the origin's full
        // state recoverable
        assert_eq!(c.queues[1].len(), 1);
        let mut r1 = ReducerCore::new(1, Box::new(WordCount::new()), router.clone());
        assert!(matches!(
            c.reducer_step(&mut r1, 1, 0, |q| q.try_pop()),
            ReducerStep::StateAbsorbed
        ));
        assert!(r1.final_snapshot().is_empty(), "checkpoints never fold into a peer");
        assert_eq!(ch.recovered_state(0, &wordcount_factory()), vec![(key, 2)]);
    }

    #[test]
    fn chaos_kill_drain_and_rehome_preserves_state() {
        use crate::testkit::chaos::FaultAction;
        let router = RouterHandle::token_ring(Ring::new(2, 8), RingOp::NoOp);
        // kill reducer 0 after one step; interval 100 = WAL-only recovery
        let (c, ch) = chaos_core(&router, "kill@0:1", 100);
        let key = owned_key(&router, 0);
        let mut r0 = ReducerCore::new(0, Box::new(WordCount::new()), router.clone());
        c.push_mapped(0, Record::new(key.clone(), 1));
        c.push_mapped(0, Record::new(key.clone(), 1)); // still queued at the kill
        assert!(matches!(c.reducer_step(&mut r0, 0, 0, |q| q.try_pop()), ReducerStep::Reduced));
        assert!(matches!(ch.poll_fault(0, 5), Some(FaultAction::Kill)));
        assert!(!ch.quiescent(), "an unrecovered kill holds the run open");
        c.chaos_fail_stop(0);
        assert!(c.tracker.is_faulted(0));

        // membership surgery (no respawn capacity here: survivors absorb),
        // then the dead queue re-routes and the WAL re-homes
        assert!(router.retire_node(0).changed);
        assert_eq!(c.chaos_requeue_dead(0, &router), 1, "queued data re-routed");
        c.chaos_rehome(0, &router, &wordcount_factory());
        let rec = ch.take_recovery().expect("kill queued a recovery");
        assert_eq!(rec.victim, 0);
        ch.recovery_done(rec.at, 9);
        assert!(ch.quiescent());

        // survivor sees: re-homed state (priority lane) then the record
        let mut r1 = ReducerCore::new(1, Box::new(WordCount::new()), router.clone());
        assert!(matches!(
            c.reducer_step(&mut r1, 1, 9, |q| q.try_pop()),
            ReducerStep::StateAbsorbed
        ));
        assert!(matches!(c.reducer_step(&mut r1, 1, 9, |q| q.try_pop()), ReducerStep::Reduced));
        assert_eq!(r1.final_snapshot(), vec![(key, 2)], "nothing lost to the kill");
        let (_, counts, _) = ch.summary();
        assert_eq!(counts.kills, 1);
        assert_eq!(counts.state_restored, 1);
        assert_eq!(counts.requeued, 1);
    }

    #[test]
    fn report_gating_follows_stage() {
        let router = RouterHandle::new(Strategy::Doubling.build_router(4, 8, None));
        let c = core(ConsistencyMode::StateForward, &router, vec![]);
        let mut balancer =
            BalancerCore::new(router.clone(), Strategy::Doubling, 0.2, 4, 2, 0).without_warmup();
        // skewed report fires and opens a synchronization window
        let e = c
            .apply_report(
                &mut balancer,
                LoadReport { reducer: 0, qlen: 50, at: 0, evaluate: true },
            )
            .expect("policy fires");
        assert_eq!(c.tracker.stage(), Stage::Synchronizing);
        // while synchronizing, an even more skewed report must NOT fire
        assert!(c
            .apply_report(
                &mut balancer,
                LoadReport { reducer: 0, qlen: 500, at: 100, evaluate: true },
            )
            .is_none());
        assert!(e.epoch > 1);
    }
}
