//! Runtime layer: the shared execution core every driver schedules over
//! ([`exec`]), plus the PJRT runtime that loads the AOT-compiled XLA
//! programs (HLO text emitted by `python/compile/aot.py`) and executes
//! them from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path interface to the compiled data plane:
//!
//! - `hash_only`  — batched MurmurHash3 (the L1 Pallas kernel),
//! - `route`      — hash + consistent-ring lookup (ring state passed as
//!   runtime tensors, so one executable serves every repartition),
//! - `route_probe` — hash + k-probe lookup (the multi-probe router's
//!   position/flag tables as runtime tensors; L1 `kprobe` kernel),
//! - `route_assign` — hash + sticky-assignment lookup (the two-choices
//!   table + frozen loads as runtime tensors; L1 `assign` kernel),
//! - `reduce_count` — histogram update of a reducer's dense count state
//!   (the L1 Pallas histogram kernel),
//! - `merge_state`  — the §2 state-merge step over dense states.
//!
//! Interchange is HLO **text**: the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod client;
pub mod exec;
pub mod programs;

pub use artifacts::{default_artifacts_dir, Manifest};
pub use client::RuntimeClient;
pub use programs::{pack_key, ring_tensors, snapshot_tensors, Error, Runtime, SnapshotTensors};
