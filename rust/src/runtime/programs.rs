//! Typed wrappers over the compiled programs, plus the host-side
//! packing that must agree bit-for-bit with `python/compile/model.py`.
//!
//! Routing is compiled per router *family*: a [`RouteSnapshot`] lowers
//! through [`snapshot_tensors`] into a tagged [`SnapshotTensors`] —
//! token table (`route`), probe table (`route_probe`), assignment
//! table (`route_assign`) or flat partition table (`route_table`, a
//! single gather) — and [`Runtime::route_batch_snapshot`]
//! dispatches on the tag, so every router the `hash::router` layer can
//! build routes in one batched XLA call. The one exception is the
//! split-key family: its per-record least-loaded-of-d decision has no
//! compiled lowering, so [`snapshot_tensors`] returns a typed
//! [`Error::UnsupportedSnapshot`] and the mapper drops to the documented
//! scalar fallback (see `docs/ROUTING.md`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::hash::{Ring, RouteSnapshot, SnapshotState, Token};

use super::artifacts::Manifest;
use super::client::RuntimeClient;

/// Typed failures of the compiled route-program lane. Wrapped in the
/// crate's `anyhow` result; callers that need to react (rather than
/// propagate) downcast to this.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum Error {
    /// The snapshot has no compiled lowering in the loaded artifacts —
    /// e.g. artifacts predating the `route_probe`/`route_assign`
    /// programs, or a future router family without a kernel.
    #[error(
        "router '{router}' snapshot is not supported by the compiled route \
         programs: {reason}"
    )]
    UnsupportedSnapshot { router: String, reason: String },
    /// The snapshot's live state exceeds the static capacity (a
    /// manifest dimension) the program was compiled for.
    #[error("{what} has {have} live entries but {program} was compiled for {cap}")]
    CapacityExceeded {
        program: &'static str,
        what: &'static str,
        have: usize,
        cap: usize,
    },
}

/// Pack a key's bytes into little-endian u32 words (zero padded) plus its
/// byte length — the exact layout the murmur3 Pallas kernel consumes.
/// Returns `None` for keys longer than `4*w` bytes (callers fall back to
/// the native rust hash; see DESIGN.md).
pub fn pack_key(key: &[u8], w: usize) -> Option<(Vec<u32>, i32)> {
    if key.len() > w * 4 {
        return None;
    }
    let mut words = vec![0u32; w];
    for (i, chunk) in key.chunks(4).enumerate() {
        let mut b = [0u8; 4];
        b[..chunk.len()].copy_from_slice(chunk);
        words[i] = u32::from_le_bytes(b);
    }
    Some((words, key.len() as i32))
}

/// Token table as the padded tensors the `route` program takes: sorted
/// token hashes (padded with `u32::MAX`), owners (padded with 0) and the
/// live token count.
fn token_tensors(tokens: &[Token], t: usize) -> crate::Result<(Vec<u32>, Vec<i32>, i32)> {
    if tokens.len() > t {
        return Err(Error::CapacityExceeded {
            program: "route",
            what: "token table",
            have: tokens.len(),
            cap: t,
        }
        .into());
    }
    let mut hashes = vec![u32::MAX; t];
    let mut owners = vec![0i32; t];
    for (i, tok) in tokens.iter().enumerate() {
        hashes[i] = tok.hash;
        owners[i] = tok.node as i32;
    }
    Ok((hashes, owners, tokens.len() as i32))
}

/// Ring state as the padded `route`-program tensors.
pub fn ring_tensors(ring: &Ring, t: usize) -> crate::Result<(Vec<u32>, Vec<i32>, i32)> {
    token_tensors(ring.sorted_tokens(), t)
}

/// A [`RouteSnapshot`] lowered to the padded tensors of its family's
/// compiled route program. Tagged exactly like [`SnapshotState`]; the
/// tensor layouts are the kernel contracts documented in
/// `python/compile/kernels/{kprobe,assign}.py` and `model.py`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SnapshotTensors {
    /// `route`: sorted token hashes (padded `u32::MAX`), owners, live
    /// count.
    Tokens { hashes: Vec<u32>, owners: Vec<i32>, len: i32 },
    /// `route_probe`: sorted node positions (padded `u32::MAX`/0), live
    /// count, per-node shed flags (padded 0), live probe count.
    Probe {
        pos_hashes: Vec<u32>,
        pos_nodes: Vec<i32>,
        len: i32,
        overloaded: Vec<i32>,
        probes: i32,
    },
    /// `route_assign`: sorted assignment keys (padded `u32::MAX`),
    /// owners, live count, frozen per-node decayed loads (fixed point,
    /// padded 0, indexed by node id), the ascending live node id list
    /// (padded 0) and its length — elastic membership leaves gaps in the
    /// id space, and the kernel's candidate rule hashes into this list.
    /// The signal already saturates decayed values at `u32::MAX`, so the
    /// u32 clamp here is a no-op and the kernel's u32 comparisons match
    /// the scalar router's u64 ones in every regime, including at the
    /// ceiling.
    Assignment {
        keys: Vec<u32>,
        owners: Vec<i32>,
        len: i32,
        loads: Vec<u32>,
        live: Vec<i32>,
        n_live: i32,
    },
    /// `route_table`: the flat `2^bits`-entry partition→node table
    /// (padded with 0 — the kernel only gathers the first `2^bits`
    /// entries) and the partition bit count. Routing is
    /// `table[hash >> (32 - bits)]`, one gather per key.
    Table { table: Vec<i32>, bits: i32 },
}

/// Lower a router snapshot of **any** family to its compiled-program
/// tensors, validating against the manifest's static capacities.
pub fn snapshot_tensors(snap: &RouteSnapshot, m: &Manifest) -> crate::Result<SnapshotTensors> {
    let cap = |program: &'static str, what: &'static str, have: usize, cap: usize| {
        if have > cap {
            Err(Error::CapacityExceeded { program, what, have, cap })
        } else {
            Ok(())
        }
    };
    match &snap.state {
        SnapshotState::TokenRing { tokens } => {
            let (hashes, owners, len) = token_tensors(tokens, m.t)?;
            Ok(SnapshotTensors::Tokens { hashes, owners, len })
        }
        SnapshotState::Probe {
            position_hashes,
            position_nodes,
            probes,
            overloaded,
            ..
        } => {
            let n = position_hashes.len();
            cap("route_probe", "position table", n, m.p)?;
            cap("route_probe", "overload flags", overloaded.len(), m.p)?;
            cap("route_probe", "probe count", *probes as usize, m.k)?;
            let mut pos_hashes = vec![u32::MAX; m.p];
            let mut pos_nodes = vec![0i32; m.p];
            pos_hashes[..n].copy_from_slice(position_hashes);
            for (o, &node) in pos_nodes.iter_mut().zip(position_nodes) {
                *o = node as i32;
            }
            let mut flags = vec![0i32; m.p];
            for (f, &b) in flags.iter_mut().zip(overloaded) {
                *f = b as i32;
            }
            Ok(SnapshotTensors::Probe {
                pos_hashes,
                pos_nodes,
                len: n as i32,
                overloaded: flags,
                probes: *probes as i32,
            })
        }
        SnapshotState::Assignment { assignments, live, loads } => {
            cap("route_assign", "assignment table", assignments.len(), m.a)?;
            cap("route_assign", "node loads", snap.nodes, m.p)?;
            cap("route_assign", "live node list", live.len(), m.p)?;
            let mut keys = vec![u32::MAX; m.a];
            let mut owners = vec![0i32; m.a];
            for (i, &(k, o)) in assignments.iter().enumerate() {
                keys[i] = k;
                owners[i] = o as i32;
            }
            let mut frozen = vec![0u32; m.p];
            for (f, &l) in frozen.iter_mut().zip(loads) {
                *f = l.min(u32::MAX as u64) as u32;
            }
            let mut live_ids = vec![0i32; m.p];
            for (o, &n) in live_ids.iter_mut().zip(live) {
                *o = n as i32;
            }
            Ok(SnapshotTensors::Assignment {
                keys,
                owners,
                len: assignments.len() as i32,
                loads: frozen,
                live: live_ids,
                n_live: live.len() as i32,
            })
        }
        SnapshotState::Table { table, bits } => {
            cap("route_table", "partition table", table.len(), m.pt)?;
            let mut padded = vec![0i32; m.pt];
            for (o, &n) in padded.iter_mut().zip(table) {
                *o = n as i32;
            }
            Ok(SnapshotTensors::Table { table: padded, bits: *bits as i32 })
        }
        // No compiled lowering: the split decision is least-loaded-of-d
        // with a rotation tie-break, i.e. per-record mutable state the
        // pure batched kernel cannot express. The mapper downcasts this
        // and permanently disables the compiled lane for the run
        // (documented scalar fallback; see docs/ROUTING.md).
        SnapshotState::Split { .. } => Err(Error::UnsupportedSnapshot {
            router: snap.router.to_string(),
            reason: "the split-key family has no compiled route program; \
                     records route through the scalar fallback"
                .to_string(),
        }
        .into()),
    }
}

/// The `route` program's routing-state literals — the one place the
/// token-table argument layout is spelled out, shared by the raw-ring
/// and snapshot entry points so they cannot diverge.
fn token_state_literals(hashes: &[u32], owners: &[i32], len: i32) -> Vec<xla::Literal> {
    vec![
        xla::Literal::vec1(hashes),
        xla::Literal::vec1(owners),
        xla::Literal::scalar(len),
    ]
}

/// Opaque handle to a device-resident reducer state (`u32[V]` counts
/// buffer living in PJRT device memory). Created/updated/read through the
/// runtime; the §Perf device-resident path keeps the state on device
/// across batches so only the `B`-sized id batch crosses the host
/// boundary per flush.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CountsHandle(u64);

/// The loaded + compiled data plane.
pub struct Runtime {
    client: RuntimeClient,
    pub manifest: Manifest,
    pub dir: PathBuf,
    hash_only: xla::PjRtLoadedExecutable,
    route: xla::PjRtLoadedExecutable,
    /// Probe-family route program (`None` when the loaded artifacts
    /// predate it; probe snapshots then error typed, not panic).
    route_probe: Option<xla::PjRtLoadedExecutable>,
    /// Assignment-family route program (`None` as above).
    route_assign: Option<xla::PjRtLoadedExecutable>,
    /// Partition-table route program (`None` as above).
    route_table: Option<xla::PjRtLoadedExecutable>,
    reduce_count: xla::PjRtLoadedExecutable,
    /// Untupled variant whose output buffer feeds back as the next
    /// call's input (device-resident state path).
    reduce_count_raw: xla::PjRtLoadedExecutable,
    merge_state: xla::PjRtLoadedExecutable,
    /// Live device-resident count states.
    device_counts: std::collections::HashMap<u64, xla::PjRtBuffer>,
    next_handle: u64,
}

impl Runtime {
    /// Load all artifacts from `dir` and compile them on the CPU PJRT
    /// client. Expensive (one-time); share the result via `Arc`.
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = RuntimeClient::cpu()?;
        let compile = |name: &str| client.compile_hlo_text(&dir.join(name));
        // the router-family programs are optional: absent in artifacts
        // built before them, and their absence is a typed error at use,
        // not a load failure
        let compile_opt = |name: &str| -> crate::Result<Option<xla::PjRtLoadedExecutable>> {
            if dir.join(name).exists() {
                Ok(Some(compile(name)?))
            } else {
                Ok(None)
            }
        };
        Ok(Runtime {
            hash_only: compile("hash_only.hlo.txt")?,
            route: compile("route.hlo.txt")?,
            route_probe: compile_opt("route_probe.hlo.txt")?,
            route_assign: compile_opt("route_assign.hlo.txt")?,
            route_table: compile_opt("route_table.hlo.txt")?,
            reduce_count: compile("reduce_count.hlo.txt")?,
            reduce_count_raw: compile("reduce_count_raw.hlo.txt")?,
            merge_state: compile("merge_state.hlo.txt")?,
            client,
            manifest,
            dir: dir.to_path_buf(),
            device_counts: std::collections::HashMap::new(),
            next_handle: 0,
        })
    }

    /// Allocate a zeroed device-resident counts state.
    pub fn counts_create(&mut self) -> crate::Result<CountsHandle> {
        let zeros = vec![0u32; self.manifest.v];
        self.counts_create_from(&zeros)
    }

    /// Allocate a device-resident counts state from host values.
    pub fn counts_create_from(&mut self, values: &[u32]) -> crate::Result<CountsHandle> {
        if values.len() != self.manifest.v {
            bail!("counts length {} != V {}", values.len(), self.manifest.v);
        }
        let buf = self
            .client
            .pjrt()
            .buffer_from_host_buffer(values, &[self.manifest.v], None)
            .context("uploading counts state")?;
        let h = CountsHandle(self.next_handle);
        self.next_handle += 1;
        self.device_counts.insert(h.0, buf);
        Ok(h)
    }

    /// Fold a batch of ids into a device-resident state. Only the ids
    /// cross the host boundary; the counts stay on device — the output
    /// buffer of the untupled program becomes the new state.
    pub fn counts_update(&mut self, h: CountsHandle, ids: &[i32]) -> crate::Result<()> {
        let b = self.manifest.b;
        if ids.len() > b {
            bail!("batch of {} ids exceeds B {}", ids.len(), b);
        }
        let mut padded = vec![-1i32; b];
        padded[..ids.len()].copy_from_slice(ids);
        let ids_buf = self
            .client
            .pjrt()
            .buffer_from_host_buffer(&padded, &[b], None)
            .context("uploading id batch")?;
        let counts_buf = self
            .device_counts
            .get(&h.0)
            .context("counts handle already freed")?;
        let outs = {
            let args: [&xla::PjRtBuffer; 2] = [counts_buf, &ids_buf];
            self.reduce_count_raw
                .execute_b(&args)
                .context("executing reduce_count_raw")?
        };
        let new_buf = outs
            .into_iter()
            .next()
            .and_then(|mut replica| {
                if replica.is_empty() {
                    None
                } else {
                    Some(replica.remove(0))
                }
            })
            .context("reduce_count_raw returned no output")?;
        self.device_counts.insert(h.0, new_buf);
        Ok(())
    }

    /// Read a device-resident state back to the host.
    pub fn counts_read(&self, h: CountsHandle) -> crate::Result<Vec<u32>> {
        let buf = self
            .device_counts
            .get(&h.0)
            .context("counts handle already freed")?;
        let lit = buf.to_literal_sync().context("device-to-host transfer")?;
        Ok(lit.to_vec()?)
    }

    /// Overwrite a device-resident state with host values.
    pub fn counts_write(&mut self, h: CountsHandle, values: &[u32]) -> crate::Result<()> {
        if values.len() != self.manifest.v {
            bail!("counts length {} != V {}", values.len(), self.manifest.v);
        }
        let buf = self
            .client
            .pjrt()
            .buffer_from_host_buffer(values, &[self.manifest.v], None)
            .context("uploading counts state")?;
        self.device_counts.insert(h.0, buf);
        Ok(())
    }

    /// Release a device-resident state.
    pub fn counts_free(&mut self, h: CountsHandle) {
        self.device_counts.remove(&h.0);
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> crate::Result<Self> {
        let dir = super::artifacts::default_artifacts_dir()
            .context("artifacts directory not found — run `make artifacts`")?;
        Self::load(&dir)
    }

    /// MurmurHash3 of each key via the Pallas kernel, batched to `B`.
    /// Keys longer than `4*W` bytes are hashed with the bit-identical
    /// native implementation.
    pub fn hash_batch(&self, keys: &[&[u8]]) -> crate::Result<Vec<u32>> {
        let (b, w) = (self.manifest.b, self.manifest.w);
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(b) {
            let mut words = vec![0u32; b * w];
            let mut lens = vec![0i32; b];
            let mut native = vec![None; chunk.len()];
            for (i, key) in chunk.iter().enumerate() {
                match pack_key(key, w) {
                    Some((kw, len)) => {
                        words[i * w..(i + 1) * w].copy_from_slice(&kw);
                        lens[i] = len;
                    }
                    None => native[i] = Some(crate::hash::murmur3_x86_32(key)),
                }
            }
            let words_lit = xla::Literal::vec1(&words).reshape(&[b as i64, w as i64])?;
            let lens_lit = xla::Literal::vec1(&lens);
            let outs = self
                .client
                .execute_tuple(&self.hash_only, &[words_lit, lens_lit])?;
            let hashes: Vec<u32> = outs[0].to_vec()?;
            for (i, h) in hashes.iter().take(chunk.len()).enumerate() {
                out.push(native[i].unwrap_or(*h));
            }
        }
        Ok(out)
    }

    /// Hash + ring lookup via the compiled route program. Returns
    /// `(hash, owner)` per key.
    pub fn route_batch(&self, keys: &[&[u8]], ring: &Ring) -> crate::Result<Vec<(u32, usize)>> {
        let (hashes, owners, len) = ring_tensors(ring, self.manifest.t)?;
        let state = token_state_literals(&hashes, &owners, len);
        self.route_batch_with(keys, &self.route, state, &|h| ring.lookup_hash(h))
    }

    /// Hash + lookup via the compiled route program of the snapshot's
    /// router family — the trait-layer entry point
    /// ([`crate::hash::RouterCache::snapshot`] feeds it). Dispatches on
    /// the [`SnapshotTensors`] tag: token table → `route`, probe table →
    /// `route_probe`, assignment table → `route_assign`, partition
    /// table → `route_table`. Returns a typed
    /// [`Error::UnsupportedSnapshot`] when the loaded artifacts lack the
    /// family's program.
    pub fn route_batch_snapshot(
        &self,
        keys: &[&[u8]],
        snap: &RouteSnapshot,
    ) -> crate::Result<Vec<(u32, usize)>> {
        let unsupported = |reason: &str| Error::UnsupportedSnapshot {
            router: snap.router.to_string(),
            reason: reason.to_string(),
        };
        let (exe, state) = match snapshot_tensors(snap, &self.manifest)? {
            SnapshotTensors::Tokens { hashes, owners, len } => {
                (&self.route, token_state_literals(&hashes, &owners, len))
            }
            SnapshotTensors::Probe { pos_hashes, pos_nodes, len, overloaded, probes } => (
                self.route_probe.as_ref().ok_or_else(|| {
                    unsupported("artifacts lack route_probe.hlo.txt — run `make artifacts`")
                })?,
                vec![
                    xla::Literal::vec1(&pos_hashes),
                    xla::Literal::vec1(&pos_nodes),
                    xla::Literal::scalar(len),
                    xla::Literal::vec1(&overloaded),
                    xla::Literal::scalar(probes),
                ],
            ),
            SnapshotTensors::Assignment { keys: akeys, owners, len, loads, live, n_live } => (
                self.route_assign
                    .as_ref()
                    .filter(|_| self.manifest.av >= 2)
                    .ok_or_else(|| {
                        if self.manifest.av < 2 {
                            unsupported(
                                "artifacts predate the elastic route_assign ABI \
                                 (manifest AV < 2) — run `make artifacts`",
                            )
                        } else {
                            unsupported(
                                "artifacts lack route_assign.hlo.txt — run `make artifacts`",
                            )
                        }
                    })?,
                vec![
                    xla::Literal::vec1(&akeys),
                    xla::Literal::vec1(&owners),
                    xla::Literal::scalar(len),
                    xla::Literal::vec1(&loads),
                    xla::Literal::vec1(&live),
                    xla::Literal::scalar(n_live),
                ],
            ),
            SnapshotTensors::Table { table, bits } => (
                self.route_table.as_ref().ok_or_else(|| {
                    unsupported("artifacts lack route_table.hlo.txt — run `make artifacts`")
                })?,
                vec![xla::Literal::vec1(&table), xla::Literal::scalar(bits)],
            ),
        };
        // native fallback: the snapshot's own host-side route — the same
        // per-family decision the scalar routers share
        self.route_batch_with(keys, exe, state, &|h| snap.route(h))
    }

    /// Shared body of the `route_batch*` entry points: `state` holds the
    /// routing-table literals appended after the packed key batch (in
    /// the program's argument order); `native_lookup` resolves keys too
    /// long for the kernel (host-side fallback, bit-identical semantics).
    fn route_batch_with(
        &self,
        keys: &[&[u8]],
        exe: &xla::PjRtLoadedExecutable,
        state: Vec<xla::Literal>,
        native_lookup: &dyn Fn(u32) -> usize,
    ) -> crate::Result<Vec<(u32, usize)>> {
        let (b, w) = (self.manifest.b, self.manifest.w);
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(b) {
            let mut words = vec![0u32; b * w];
            let mut lens = vec![0i32; b];
            let mut native = vec![None; chunk.len()];
            for (i, key) in chunk.iter().enumerate() {
                match pack_key(key, w) {
                    Some((kw, l)) => {
                        words[i * w..(i + 1) * w].copy_from_slice(&kw);
                        lens[i] = l;
                    }
                    None => {
                        let h = crate::hash::murmur3_x86_32(key);
                        native[i] = Some((h, native_lookup(h)));
                    }
                }
            }
            let words_lit = xla::Literal::vec1(&words).reshape(&[b as i64, w as i64])?;
            let lens_lit = xla::Literal::vec1(&lens);
            let mut args = Vec::with_capacity(2 + state.len());
            args.push(words_lit);
            args.push(lens_lit);
            args.extend(state.iter().cloned());
            let outs = self.client.execute_tuple(exe, &args)?;
            let hs: Vec<u32> = outs[0].to_vec()?;
            let os: Vec<i32> = outs[1].to_vec()?;
            for i in 0..chunk.len() {
                out.push(native[i].unwrap_or((hs[i], os[i] as usize)));
            }
        }
        Ok(out)
    }

    /// Histogram-update `counts` with a batch of vocab ids (`-1` = skip).
    /// `ids.len()` must be ≤ B; shorter batches are padded.
    pub fn reduce_counts(&self, counts: &[u32], ids: &[i32]) -> crate::Result<Vec<u32>> {
        let (b, v) = (self.manifest.b, self.manifest.v);
        if counts.len() != v {
            bail!("counts length {} != V {}", counts.len(), v);
        }
        if ids.len() > b {
            bail!("batch of {} ids exceeds B {}", ids.len(), b);
        }
        let mut padded = vec![-1i32; b];
        padded[..ids.len()].copy_from_slice(ids);
        let counts_lit = xla::Literal::vec1(counts);
        let ids_lit = xla::Literal::vec1(&padded);
        let outs = self
            .client
            .execute_tuple(&self.reduce_count, &[counts_lit, ids_lit])?;
        Ok(outs[0].to_vec()?)
    }

    /// The §2 state-merge step over two dense states.
    pub fn merge_states(&self, a: &[u32], b: &[u32]) -> crate::Result<Vec<u32>> {
        let v = self.manifest.v;
        if a.len() != v || b.len() != v {
            bail!("merge inputs must be length V={v}");
        }
        let outs = self.client.execute_tuple(
            &self.merge_state,
            &[xla::Literal::vec1(a), xla::Literal::vec1(b)],
        )?;
        Ok(outs[0].to_vec()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform()
    }
}

/// Thread-shareable runtime handle.
///
/// The `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` wrappers hold
/// non-atomic `Rc` bookkeeping, so they are `!Send + !Sync` even though
/// the underlying PJRT CPU client is thread-safe. `SharedRuntime` restores
/// shareability by serializing *every* access behind one mutex: no two
/// threads ever touch the wrappers (or their `Rc` counts) concurrently.
/// Contention is acceptable because callers batch (one lock per `B=256`
/// records, not per record).
pub struct SharedRuntime {
    inner: std::sync::Mutex<Runtime>,
    manifest: Manifest,
}

// The crate denies `unsafe_code`; these two impls are the ONLY escape
// hatch, narrowly allowed here. Everything else in the crate is
// `#![forbid(unsafe_code)]` at module level.
//
// SAFETY: `Runtime`'s fields are `!Send` only because the `xla` wrappers
// hold non-atomic `Rc` bookkeeping. The mutex serializes every access —
// construction happens on one thread, and afterwards no `Rc` clone or
// drop can race because no reference ever escapes the guard. The raw
// PJRT objects behind the wrappers are documented thread-safe in the
// PJRT C API.
#[allow(unsafe_code)]
unsafe impl Send for SharedRuntime {}
// SAFETY: `&SharedRuntime` only exposes `lock`-guarded methods plus the
// `Copy` manifest; shared references can therefore never reach the inner
// `Rc` counts concurrently (same serialization argument as `Send`).
#[allow(unsafe_code)]
unsafe impl Sync for SharedRuntime {}

impl std::fmt::Debug for SharedRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedRuntime").field("manifest", &self.manifest).finish_non_exhaustive()
    }
}

impl SharedRuntime {
    pub fn load(dir: &Path) -> crate::Result<std::sync::Arc<Self>> {
        let rt = Runtime::load(dir)?;
        Ok(std::sync::Arc::new(SharedRuntime {
            manifest: rt.manifest,
            inner: std::sync::Mutex::new(rt),
        }))
    }

    pub fn load_default() -> crate::Result<std::sync::Arc<Self>> {
        let dir = super::artifacts::default_artifacts_dir()
            .context("artifacts directory not found — run `make artifacts`")?;
        Self::load(&dir)
    }

    pub fn manifest(&self) -> Manifest {
        self.manifest
    }

    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().platform()
    }

    pub fn hash_batch(&self, keys: &[&[u8]]) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().hash_batch(keys)
    }

    pub fn route_batch(&self, keys: &[&[u8]], ring: &Ring) -> crate::Result<Vec<(u32, usize)>> {
        self.inner.lock().unwrap().route_batch(keys, ring)
    }

    pub fn route_batch_snapshot(
        &self,
        keys: &[&[u8]],
        snap: &RouteSnapshot,
    ) -> crate::Result<Vec<(u32, usize)>> {
        self.inner.lock().unwrap().route_batch_snapshot(keys, snap)
    }

    pub fn reduce_counts(&self, counts: &[u32], ids: &[i32]) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().reduce_counts(counts, ids)
    }

    pub fn merge_states(&self, a: &[u32], b: &[u32]) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().merge_states(a, b)
    }

    // -- device-resident counts states (§Perf iteration 2) ----------------

    pub fn counts_create(&self) -> crate::Result<CountsHandle> {
        self.inner.lock().unwrap().counts_create()
    }

    pub fn counts_create_from(&self, values: &[u32]) -> crate::Result<CountsHandle> {
        self.inner.lock().unwrap().counts_create_from(values)
    }

    pub fn counts_update(&self, h: CountsHandle, ids: &[i32]) -> crate::Result<()> {
        self.inner.lock().unwrap().counts_update(h, ids)
    }

    pub fn counts_read(&self, h: CountsHandle) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().counts_read(h)
    }

    pub fn counts_write(&self, h: CountsHandle, values: &[u32]) -> crate::Result<()> {
        self.inner.lock().unwrap().counts_write(h, values)
    }

    pub fn counts_free(&self, h: CountsHandle) {
        self.inner.lock().unwrap().counts_free(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_key_layout() {
        let (words, len) = pack_key(b"abcdef", 8).unwrap();
        assert_eq!(len, 6);
        assert_eq!(words[0], u32::from_le_bytes(*b"abcd"));
        assert_eq!(words[1], u32::from_le_bytes([b'e', b'f', 0, 0]));
        assert!(words[2..].iter().all(|&w| w == 0));
    }

    #[test]
    fn pack_key_empty_and_exact() {
        let (words, len) = pack_key(b"", 2).unwrap();
        assert_eq!(len, 0);
        assert!(words.iter().all(|&w| w == 0));
        let (_, len) = pack_key(b"12345678", 2).unwrap();
        assert_eq!(len, 8);
        assert!(pack_key(b"123456789", 2).is_none(), "too long");
    }

    #[test]
    fn ring_tensor_layout() {
        let ring = Ring::new(3, 2);
        let (hashes, owners, len) = ring_tensors(&ring, 16).unwrap();
        assert_eq!(len, 6);
        // live prefix is sorted, padding is MAX
        for i in 0..5 {
            assert!(hashes[i] <= hashes[i + 1]);
        }
        assert!(hashes[6..].iter().all(|&h| h == u32::MAX));
        assert!(owners[..6].iter().all(|&o| (0..3).contains(&o)));
    }

    #[test]
    fn ring_too_big_errors() {
        let ring = Ring::new(4, 8);
        assert!(ring_tensors(&ring, 8).is_err());
    }

    fn mini_manifest() -> Manifest {
        Manifest { b: 64, w: 8, t: 16, v: 512, p: 8, k: 4, a: 16, av: 2, pt: 64 }
    }

    #[test]
    fn snapshot_route_matches_ring_lookup() {
        use crate::hash::{RingOp, RouterHandle};
        let mut ring = Ring::new(4, 8);
        ring.halve(2);
        let handle = RouterHandle::token_ring(ring.clone(), RingOp::NoOp);
        let snap = handle.snapshot();
        for i in 0..4096u32 {
            let h = i.wrapping_mul(0x9E37_79B9);
            assert_eq!(snap.route(h), ring.lookup_hash(h), "h={h:#x}");
        }
        for t in ring.sorted_tokens().to_vec() {
            for h in [t.hash.wrapping_sub(1), t.hash, t.hash.wrapping_add(1)] {
                assert_eq!(snap.route(h), ring.lookup_hash(h));
            }
        }
    }

    #[test]
    fn snapshot_tensors_token_family_packs_like_ring_tensors() {
        use crate::hash::{RingOp, RouterHandle};
        let handle = RouterHandle::token_ring(Ring::new(3, 2), RingOp::NoOp);
        let got = snapshot_tensors(&handle.snapshot(), &mini_manifest()).unwrap();
        let (rh, ro, rl) = handle.with_ring(|r| ring_tensors(r, 16)).unwrap().unwrap();
        assert_eq!(
            got,
            SnapshotTensors::Tokens { hashes: rh, owners: ro, len: rl },
            "same packing as ring_tensors"
        );
    }

    #[test]
    fn snapshot_tensors_probe_family() {
        use crate::hash::{RouterHandle, StrategySpec};
        let probing =
            RouterHandle::new(StrategySpec::MultiProbe { probes: 3 }.build_router(3, 8, None));
        match snapshot_tensors(&probing.snapshot(), &mini_manifest()).unwrap() {
            SnapshotTensors::Probe { pos_hashes, pos_nodes, len, overloaded, probes } => {
                assert_eq!(len, 3);
                assert_eq!(probes, 3);
                assert!(pos_hashes[..3].windows(2).all(|w| w[0] <= w[1]), "sorted");
                assert!(pos_hashes[3..].iter().all(|&h| h == u32::MAX), "padding");
                assert!(pos_nodes[..3].iter().all(|&n| (0..3).contains(&n)));
                assert_eq!(overloaded, vec![0; 8], "fresh router sheds nobody");
            }
            other => panic!("expected Probe tensors, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_tensors_assignment_family_freezes_loads() {
        use crate::hash::{RouterHandle, StrategySpec, TwoChoicesRouter};
        let handle = RouterHandle::new(StrategySpec::TwoChoices.build_router(3, 8, None));
        handle.route_key(b"warm");
        handle.loads().set(1, 7);
        // the frozen loads are the decayed signal in fixed point (legacy
        // signal: exactly raw << FRAC_BITS)
        let fp = 1u32 << crate::balancer::signal::FRAC_BITS;
        match snapshot_tensors(&handle.snapshot(), &mini_manifest()).unwrap() {
            SnapshotTensors::Assignment { keys, owners, len, loads, live, n_live } => {
                assert_eq!(len, 1);
                assert_eq!(n_live, 3);
                assert_eq!(live, vec![0, 1, 2, 0, 0, 0, 0, 0], "live ids, padded to P");
                assert_eq!(keys[0], crate::hash::murmur3_x86_32(b"warm"));
                assert!(keys[1..].iter().all(|&k| k == u32::MAX), "padding");
                assert!((owners[0] as usize) < 3);
                assert_eq!(loads, vec![0, 7 * fp, 0, 0, 0, 0, 0, 0], "frozen, padded to P");
            }
            other => panic!("expected Assignment tensors, got {other:?}"),
        }

        // u32 saturation of oversized loads
        let tc = TwoChoicesRouter::new(2);
        let loads = crate::hash::Loads::new(2);
        loads.set(0, u64::MAX);
        let snap = crate::hash::Router::snapshot(&tc, &loads);
        match snapshot_tensors(&snap, &mini_manifest()).unwrap() {
            SnapshotTensors::Assignment { loads, .. } => assert_eq!(loads[0], u32::MAX),
            other => panic!("expected Assignment tensors, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_tensors_assignment_carries_gapped_membership() {
        use crate::hash::{RouterHandle, StrategySpec};
        let handle = RouterHandle::new(StrategySpec::TwoChoices.build_router(4, 8, None));
        handle.retire_node(1);
        match snapshot_tensors(&handle.snapshot(), &mini_manifest()).unwrap() {
            SnapshotTensors::Assignment { live, n_live, .. } => {
                assert_eq!(n_live, 3);
                assert_eq!(live, vec![0, 2, 3, 0, 0, 0, 0, 0], "gap at the retired id");
            }
            other => panic!("expected Assignment tensors, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_tensors_table_family_pads_to_pt() {
        use crate::hash::{RouterHandle, StrategySpec};
        let handle = RouterHandle::new(
            StrategySpec::Ptable { bits: 4, replicas: 1 }.build_router(3, 8, None),
        );
        let snap = handle.snapshot();
        match snapshot_tensors(&snap, &mini_manifest()).unwrap() {
            SnapshotTensors::Table { table, bits } => {
                assert_eq!(bits, 4);
                assert_eq!(table.len(), 64, "padded to the manifest PT capacity");
                assert!(table[..16].iter().all(|&n| (0..3).contains(&n)), "live entries own nodes");
                assert!(table[16..].iter().all(|&n| n == 0), "padding");
                // the lowered table is the same one the scalar route reads
                let (raw, b) = snap.partition_table().unwrap();
                assert_eq!(b, 4);
                for (i, &n) in raw.iter().enumerate() {
                    assert_eq!(table[i], n as i32);
                }
            }
            other => panic!("expected Table tensors, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_tensors_table_beyond_pt_is_typed() {
        use crate::hash::{RouterHandle, StrategySpec};
        // default bits=10 → 1024 entries > the mini manifest's PT=64
        let handle = RouterHandle::new(
            "ptable".parse::<StrategySpec>().unwrap().build_router(3, 8, None),
        );
        let err = snapshot_tensors(&handle.snapshot(), &mini_manifest()).unwrap_err();
        match err.downcast_ref::<Error>() {
            Some(Error::CapacityExceeded { program, what, have, cap }) => {
                assert_eq!(
                    (*program, *what, *have, *cap),
                    ("route_table", "partition table", 1024, 64)
                );
            }
            other => panic!("expected CapacityExceeded, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_tensors_capacity_errors_are_typed() {
        use crate::hash::{RouterHandle, StrategySpec};
        // probe count above the compiled K
        let probing =
            RouterHandle::new(StrategySpec::MultiProbe { probes: 9 }.build_router(3, 8, None));
        let err = snapshot_tensors(&probing.snapshot(), &mini_manifest()).unwrap_err();
        match err.downcast_ref::<Error>() {
            Some(Error::CapacityExceeded { program, what, have, cap }) => {
                assert_eq!((*program, *what, *have, *cap), ("route_probe", "probe count", 9, 4));
            }
            other => panic!("expected CapacityExceeded, got {other:?}"),
        }
        // more nodes than the compiled P
        let wide =
            RouterHandle::new(StrategySpec::MultiProbe { probes: 2 }.build_router(9, 8, None));
        assert!(snapshot_tensors(&wide.snapshot(), &mini_manifest())
            .unwrap_err()
            .downcast_ref::<Error>()
            .is_some());
        // token table beyond T still errors typed through the ring path
        let ring = RouterHandle::token_ring(Ring::new(4, 8), crate::hash::RingOp::NoOp);
        let err = snapshot_tensors(&ring.snapshot(), &mini_manifest()).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<Error>(),
            Some(Error::CapacityExceeded { program: "route", .. })
        ));
    }

    #[test]
    fn snapshot_tensors_split_family_is_typed_unsupported() {
        use crate::hash::{RouterHandle, StrategySpec};
        let handle = RouterHandle::new(StrategySpec::SplitKey { d: 2 }.build_router(3, 8, None));
        let err = snapshot_tensors(&handle.snapshot(), &mini_manifest()).unwrap_err();
        match err.downcast_ref::<Error>() {
            Some(Error::UnsupportedSnapshot { router, reason }) => {
                assert_eq!(router, "split-key");
                assert!(reason.contains("scalar fallback"), "{reason}");
            }
            other => panic!("expected UnsupportedSnapshot, got {other:?}"),
        }
    }

    #[test]
    fn unsupported_snapshot_error_renders_router_name() {
        let e = Error::UnsupportedSnapshot {
            router: "two-choices".into(),
            reason: "artifacts lack route_assign.hlo.txt".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("two-choices") && msg.contains("route_assign"), "{msg}");
    }
}
