//! Typed wrappers over the four compiled programs, plus the host-side
//! packing that must agree bit-for-bit with `python/compile/model.py`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::hash::{Ring, RouteSnapshot, Token};

use super::artifacts::Manifest;
use super::client::RuntimeClient;

/// Pack a key's bytes into little-endian u32 words (zero padded) plus its
/// byte length — the exact layout the murmur3 Pallas kernel consumes.
/// Returns `None` for keys longer than `4*w` bytes (callers fall back to
/// the native rust hash; see DESIGN.md).
pub fn pack_key(key: &[u8], w: usize) -> Option<(Vec<u32>, i32)> {
    if key.len() > w * 4 {
        return None;
    }
    let mut words = vec![0u32; w];
    for (i, chunk) in key.chunks(4).enumerate() {
        let mut b = [0u8; 4];
        b[..chunk.len()].copy_from_slice(chunk);
        words[i] = u32::from_le_bytes(b);
    }
    Some((words, key.len() as i32))
}

/// Token table as the padded tensors the `route` program takes: sorted
/// token hashes (padded with `u32::MAX`), owners (padded with 0) and the
/// live token count.
fn token_tensors(tokens: &[Token], t: usize) -> crate::Result<(Vec<u32>, Vec<i32>, i32)> {
    if tokens.len() > t {
        bail!(
            "ring has {} tokens but the route program was compiled for T={t}",
            tokens.len()
        );
    }
    let mut hashes = vec![u32::MAX; t];
    let mut owners = vec![0i32; t];
    for (i, tok) in tokens.iter().enumerate() {
        hashes[i] = tok.hash;
        owners[i] = tok.node as i32;
    }
    Ok((hashes, owners, tokens.len() as i32))
}

/// Ring state as the padded `route`-program tensors.
pub fn ring_tensors(ring: &Ring, t: usize) -> crate::Result<(Vec<u32>, Vec<i32>, i32)> {
    token_tensors(ring.sorted_tokens(), t)
}

/// Host-side clockwise lookup over a snapshot's token table — the native
/// fallback for keys the compiled program cannot take. Delegates to the
/// same successor walk as `Ring::lookup_hash` (the table is sorted by
/// `(hash, node, idx)`), so the two paths cannot drift.
fn lookup_token_table(tokens: &[Token], h: u32) -> usize {
    tokens[crate::hash::ring::clockwise_successor_by(tokens, h, |t| t.hash)].node as usize
}

/// Router-snapshot state as the padded `route`-program tensors. Only the
/// token-ring family has a token table the compiled program can consume;
/// probe routers (multi-probe, two-choices) fail here and must route
/// host-side.
pub fn snapshot_tensors(
    snap: &RouteSnapshot,
    t: usize,
) -> crate::Result<(Vec<u32>, Vec<i32>, i32)> {
    let tokens = snap.tokens.as_ref().with_context(|| {
        format!(
            "router '{}' has no token table; the XLA route program only serves \
             token-ring routers",
            snap.router
        )
    })?;
    token_tensors(tokens, t)
}

/// Opaque handle to a device-resident reducer state (`u32[V]` counts
/// buffer living in PJRT device memory). Created/updated/read through the
/// runtime; the §Perf device-resident path keeps the state on device
/// across batches so only the `B`-sized id batch crosses the host
/// boundary per flush.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CountsHandle(u64);

/// The loaded + compiled data plane.
pub struct Runtime {
    client: RuntimeClient,
    pub manifest: Manifest,
    pub dir: PathBuf,
    hash_only: xla::PjRtLoadedExecutable,
    route: xla::PjRtLoadedExecutable,
    reduce_count: xla::PjRtLoadedExecutable,
    /// Untupled variant whose output buffer feeds back as the next
    /// call's input (device-resident state path).
    reduce_count_raw: xla::PjRtLoadedExecutable,
    merge_state: xla::PjRtLoadedExecutable,
    /// Live device-resident count states.
    device_counts: std::collections::HashMap<u64, xla::PjRtBuffer>,
    next_handle: u64,
}

impl Runtime {
    /// Load all artifacts from `dir` and compile them on the CPU PJRT
    /// client. Expensive (one-time); share the result via `Arc`.
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = RuntimeClient::cpu()?;
        let compile = |name: &str| client.compile_hlo_text(&dir.join(name));
        Ok(Runtime {
            hash_only: compile("hash_only.hlo.txt")?,
            route: compile("route.hlo.txt")?,
            reduce_count: compile("reduce_count.hlo.txt")?,
            reduce_count_raw: compile("reduce_count_raw.hlo.txt")?,
            merge_state: compile("merge_state.hlo.txt")?,
            client,
            manifest,
            dir: dir.to_path_buf(),
            device_counts: std::collections::HashMap::new(),
            next_handle: 0,
        })
    }

    /// Allocate a zeroed device-resident counts state.
    pub fn counts_create(&mut self) -> crate::Result<CountsHandle> {
        let zeros = vec![0u32; self.manifest.v];
        self.counts_create_from(&zeros)
    }

    /// Allocate a device-resident counts state from host values.
    pub fn counts_create_from(&mut self, values: &[u32]) -> crate::Result<CountsHandle> {
        if values.len() != self.manifest.v {
            bail!("counts length {} != V {}", values.len(), self.manifest.v);
        }
        let buf = self
            .client
            .pjrt()
            .buffer_from_host_buffer(values, &[self.manifest.v], None)
            .context("uploading counts state")?;
        let h = CountsHandle(self.next_handle);
        self.next_handle += 1;
        self.device_counts.insert(h.0, buf);
        Ok(h)
    }

    /// Fold a batch of ids into a device-resident state. Only the ids
    /// cross the host boundary; the counts stay on device — the output
    /// buffer of the untupled program becomes the new state.
    pub fn counts_update(&mut self, h: CountsHandle, ids: &[i32]) -> crate::Result<()> {
        let b = self.manifest.b;
        if ids.len() > b {
            bail!("batch of {} ids exceeds B {}", ids.len(), b);
        }
        let mut padded = vec![-1i32; b];
        padded[..ids.len()].copy_from_slice(ids);
        let ids_buf = self
            .client
            .pjrt()
            .buffer_from_host_buffer(&padded, &[b], None)
            .context("uploading id batch")?;
        let counts_buf = self
            .device_counts
            .get(&h.0)
            .context("counts handle already freed")?;
        let outs = {
            let args: [&xla::PjRtBuffer; 2] = [counts_buf, &ids_buf];
            self.reduce_count_raw
                .execute_b(&args)
                .context("executing reduce_count_raw")?
        };
        let new_buf = outs
            .into_iter()
            .next()
            .and_then(|mut replica| {
                if replica.is_empty() {
                    None
                } else {
                    Some(replica.remove(0))
                }
            })
            .context("reduce_count_raw returned no output")?;
        self.device_counts.insert(h.0, new_buf);
        Ok(())
    }

    /// Read a device-resident state back to the host.
    pub fn counts_read(&self, h: CountsHandle) -> crate::Result<Vec<u32>> {
        let buf = self
            .device_counts
            .get(&h.0)
            .context("counts handle already freed")?;
        let lit = buf.to_literal_sync().context("device-to-host transfer")?;
        Ok(lit.to_vec()?)
    }

    /// Overwrite a device-resident state with host values.
    pub fn counts_write(&mut self, h: CountsHandle, values: &[u32]) -> crate::Result<()> {
        if values.len() != self.manifest.v {
            bail!("counts length {} != V {}", values.len(), self.manifest.v);
        }
        let buf = self
            .client
            .pjrt()
            .buffer_from_host_buffer(values, &[self.manifest.v], None)
            .context("uploading counts state")?;
        self.device_counts.insert(h.0, buf);
        Ok(())
    }

    /// Release a device-resident state.
    pub fn counts_free(&mut self, h: CountsHandle) {
        self.device_counts.remove(&h.0);
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> crate::Result<Self> {
        let dir = super::artifacts::default_artifacts_dir()
            .context("artifacts directory not found — run `make artifacts`")?;
        Self::load(&dir)
    }

    /// MurmurHash3 of each key via the Pallas kernel, batched to `B`.
    /// Keys longer than `4*W` bytes are hashed with the bit-identical
    /// native implementation.
    pub fn hash_batch(&self, keys: &[&[u8]]) -> crate::Result<Vec<u32>> {
        let (b, w) = (self.manifest.b, self.manifest.w);
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(b) {
            let mut words = vec![0u32; b * w];
            let mut lens = vec![0i32; b];
            let mut native = vec![None; chunk.len()];
            for (i, key) in chunk.iter().enumerate() {
                match pack_key(key, w) {
                    Some((kw, len)) => {
                        words[i * w..(i + 1) * w].copy_from_slice(&kw);
                        lens[i] = len;
                    }
                    None => native[i] = Some(crate::hash::murmur3_x86_32(key)),
                }
            }
            let words_lit = xla::Literal::vec1(&words).reshape(&[b as i64, w as i64])?;
            let lens_lit = xla::Literal::vec1(&lens);
            let outs = self
                .client
                .execute_tuple(&self.hash_only, &[words_lit, lens_lit])?;
            let hashes: Vec<u32> = outs[0].to_vec()?;
            for (i, h) in hashes.iter().take(chunk.len()).enumerate() {
                out.push(native[i].unwrap_or(*h));
            }
        }
        Ok(out)
    }

    /// Hash + ring lookup via the compiled route program. Returns
    /// `(hash, owner)` per key.
    pub fn route_batch(&self, keys: &[&[u8]], ring: &Ring) -> crate::Result<Vec<(u32, usize)>> {
        let tensors = ring_tensors(ring, self.manifest.t)?;
        self.route_batch_with(keys, tensors, &|h| ring.lookup_hash(h))
    }

    /// Hash + lookup via the compiled route program, driven by a router
    /// [`RouteSnapshot`] instead of a raw ring — the trait-layer entry
    /// point ([`crate::hash::RouterCache::snapshot`] feeds it). Fails for
    /// probe routers, which have no token table the program can consume.
    pub fn route_batch_snapshot(
        &self,
        keys: &[&[u8]],
        snap: &RouteSnapshot,
    ) -> crate::Result<Vec<(u32, usize)>> {
        let tensors = snapshot_tensors(snap, self.manifest.t)?;
        let tokens = snap.tokens.as_ref().expect("snapshot_tensors checked");
        self.route_batch_with(keys, tensors, &|h| lookup_token_table(tokens, h))
    }

    /// Shared body of the two `route_batch` entry points: `tensors` are
    /// the padded route-program inputs, `native_lookup` resolves keys too
    /// long for the kernel (host-side fallback, bit-identical semantics).
    fn route_batch_with(
        &self,
        keys: &[&[u8]],
        tensors: (Vec<u32>, Vec<i32>, i32),
        native_lookup: &dyn Fn(u32) -> usize,
    ) -> crate::Result<Vec<(u32, usize)>> {
        let (b, w) = (self.manifest.b, self.manifest.w);
        let (hashes, owners, len) = tensors;
        let ring_h = xla::Literal::vec1(&hashes);
        let ring_o = xla::Literal::vec1(&owners);
        let ring_n = xla::Literal::scalar(len);

        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(b) {
            let mut words = vec![0u32; b * w];
            let mut lens = vec![0i32; b];
            let mut native = vec![None; chunk.len()];
            for (i, key) in chunk.iter().enumerate() {
                match pack_key(key, w) {
                    Some((kw, l)) => {
                        words[i * w..(i + 1) * w].copy_from_slice(&kw);
                        lens[i] = l;
                    }
                    None => {
                        let h = crate::hash::murmur3_x86_32(key);
                        native[i] = Some((h, native_lookup(h)));
                    }
                }
            }
            let words_lit = xla::Literal::vec1(&words).reshape(&[b as i64, w as i64])?;
            let lens_lit = xla::Literal::vec1(&lens);
            let outs = self.client.execute_tuple(
                &self.route,
                &[
                    words_lit,
                    lens_lit,
                    ring_h.clone(),
                    ring_o.clone(),
                    ring_n.clone(),
                ],
            )?;
            let hs: Vec<u32> = outs[0].to_vec()?;
            let os: Vec<i32> = outs[1].to_vec()?;
            for i in 0..chunk.len() {
                out.push(native[i].unwrap_or((hs[i], os[i] as usize)));
            }
        }
        Ok(out)
    }

    /// Histogram-update `counts` with a batch of vocab ids (`-1` = skip).
    /// `ids.len()` must be ≤ B; shorter batches are padded.
    pub fn reduce_counts(&self, counts: &[u32], ids: &[i32]) -> crate::Result<Vec<u32>> {
        let (b, v) = (self.manifest.b, self.manifest.v);
        if counts.len() != v {
            bail!("counts length {} != V {}", counts.len(), v);
        }
        if ids.len() > b {
            bail!("batch of {} ids exceeds B {}", ids.len(), b);
        }
        let mut padded = vec![-1i32; b];
        padded[..ids.len()].copy_from_slice(ids);
        let counts_lit = xla::Literal::vec1(counts);
        let ids_lit = xla::Literal::vec1(&padded);
        let outs = self
            .client
            .execute_tuple(&self.reduce_count, &[counts_lit, ids_lit])?;
        Ok(outs[0].to_vec()?)
    }

    /// The §2 state-merge step over two dense states.
    pub fn merge_states(&self, a: &[u32], b: &[u32]) -> crate::Result<Vec<u32>> {
        let v = self.manifest.v;
        if a.len() != v || b.len() != v {
            bail!("merge inputs must be length V={v}");
        }
        let outs = self.client.execute_tuple(
            &self.merge_state,
            &[xla::Literal::vec1(a), xla::Literal::vec1(b)],
        )?;
        Ok(outs[0].to_vec()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform()
    }
}

/// Thread-shareable runtime handle.
///
/// The `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` wrappers hold
/// non-atomic `Rc` bookkeeping, so they are `!Send + !Sync` even though
/// the underlying PJRT CPU client is thread-safe. `SharedRuntime` restores
/// shareability by serializing *every* access behind one mutex: no two
/// threads ever touch the wrappers (or their `Rc` counts) concurrently.
/// Contention is acceptable because callers batch (one lock per `B=256`
/// records, not per record).
pub struct SharedRuntime {
    inner: std::sync::Mutex<Runtime>,
    manifest: Manifest,
}

// SAFETY: all access to the inner Runtime (and its Rc-based wrappers) is
// serialized by the mutex; the raw PJRT objects themselves are documented
// thread-safe in the PJRT C API.
unsafe impl Send for SharedRuntime {}
unsafe impl Sync for SharedRuntime {}

impl SharedRuntime {
    pub fn load(dir: &Path) -> crate::Result<std::sync::Arc<Self>> {
        let rt = Runtime::load(dir)?;
        Ok(std::sync::Arc::new(SharedRuntime {
            manifest: rt.manifest,
            inner: std::sync::Mutex::new(rt),
        }))
    }

    pub fn load_default() -> crate::Result<std::sync::Arc<Self>> {
        let dir = super::artifacts::default_artifacts_dir()
            .context("artifacts directory not found — run `make artifacts`")?;
        Self::load(&dir)
    }

    pub fn manifest(&self) -> Manifest {
        self.manifest
    }

    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().platform()
    }

    pub fn hash_batch(&self, keys: &[&[u8]]) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().hash_batch(keys)
    }

    pub fn route_batch(&self, keys: &[&[u8]], ring: &Ring) -> crate::Result<Vec<(u32, usize)>> {
        self.inner.lock().unwrap().route_batch(keys, ring)
    }

    pub fn route_batch_snapshot(
        &self,
        keys: &[&[u8]],
        snap: &RouteSnapshot,
    ) -> crate::Result<Vec<(u32, usize)>> {
        self.inner.lock().unwrap().route_batch_snapshot(keys, snap)
    }

    pub fn reduce_counts(&self, counts: &[u32], ids: &[i32]) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().reduce_counts(counts, ids)
    }

    pub fn merge_states(&self, a: &[u32], b: &[u32]) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().merge_states(a, b)
    }

    // -- device-resident counts states (§Perf iteration 2) ----------------

    pub fn counts_create(&self) -> crate::Result<CountsHandle> {
        self.inner.lock().unwrap().counts_create()
    }

    pub fn counts_create_from(&self, values: &[u32]) -> crate::Result<CountsHandle> {
        self.inner.lock().unwrap().counts_create_from(values)
    }

    pub fn counts_update(&self, h: CountsHandle, ids: &[i32]) -> crate::Result<()> {
        self.inner.lock().unwrap().counts_update(h, ids)
    }

    pub fn counts_read(&self, h: CountsHandle) -> crate::Result<Vec<u32>> {
        self.inner.lock().unwrap().counts_read(h)
    }

    pub fn counts_write(&self, h: CountsHandle, values: &[u32]) -> crate::Result<()> {
        self.inner.lock().unwrap().counts_write(h, values)
    }

    pub fn counts_free(&self, h: CountsHandle) {
        self.inner.lock().unwrap().counts_free(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_key_layout() {
        let (words, len) = pack_key(b"abcdef", 8).unwrap();
        assert_eq!(len, 6);
        assert_eq!(words[0], u32::from_le_bytes(*b"abcd"));
        assert_eq!(words[1], u32::from_le_bytes([b'e', b'f', 0, 0]));
        assert!(words[2..].iter().all(|&w| w == 0));
    }

    #[test]
    fn pack_key_empty_and_exact() {
        let (words, len) = pack_key(b"", 2).unwrap();
        assert_eq!(len, 0);
        assert!(words.iter().all(|&w| w == 0));
        let (_, len) = pack_key(b"12345678", 2).unwrap();
        assert_eq!(len, 8);
        assert!(pack_key(b"123456789", 2).is_none(), "too long");
    }

    #[test]
    fn ring_tensor_layout() {
        let ring = Ring::new(3, 2);
        let (hashes, owners, len) = ring_tensors(&ring, 16).unwrap();
        assert_eq!(len, 6);
        // live prefix is sorted, padding is MAX
        for i in 0..5 {
            assert!(hashes[i] <= hashes[i + 1]);
        }
        assert!(hashes[6..].iter().all(|&h| h == u32::MAX));
        assert!(owners[..6].iter().all(|&o| (0..3).contains(&o)));
    }

    #[test]
    fn ring_too_big_errors() {
        let ring = Ring::new(4, 8);
        assert!(ring_tensors(&ring, 8).is_err());
    }

    #[test]
    fn token_table_lookup_matches_ring() {
        let mut ring = Ring::new(4, 8);
        ring.halve(2);
        let tokens = ring.sorted_tokens();
        for i in 0..4096u32 {
            let h = i.wrapping_mul(0x9E37_79B9);
            assert_eq!(lookup_token_table(tokens, h), ring.lookup_hash(h), "h={h:#x}");
        }
        for t in tokens.to_vec() {
            for h in [t.hash.wrapping_sub(1), t.hash, t.hash.wrapping_add(1)] {
                assert_eq!(lookup_token_table(ring.sorted_tokens(), h), ring.lookup_hash(h));
            }
        }
    }

    #[test]
    fn snapshot_tensors_serve_token_ring_only() {
        use crate::hash::{RingOp, RouterHandle, StrategySpec};
        let handle = RouterHandle::token_ring(Ring::new(3, 2), RingOp::NoOp);
        let (hashes, owners, len) = snapshot_tensors(&handle.snapshot(), 16).unwrap();
        let (rh, ro, rl) = handle.with_ring(|r| ring_tensors(r, 16)).unwrap().unwrap();
        assert_eq!((hashes, owners, len), (rh, ro, rl), "same packing as ring_tensors");

        let probing =
            RouterHandle::new(StrategySpec::MultiProbe { probes: 3 }.build_router(3, 8, None));
        assert!(snapshot_tensors(&probing.snapshot(), 16).is_err());
    }
}
