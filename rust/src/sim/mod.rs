//! Deterministic discrete-event simulation driver.
//!
//! A thin *scheduler* over the shared [`ExecCore`] runtime: the core owns
//! the topology (task pool, envelope queues, actor cores' step logic), the
//! reducer state-machine, the drain condition and the final merge; this
//! module contributes only virtual time — a seeded event heap that decides
//! *when* each actor steps and charges per-step costs with jitter. Same
//! seed ⇒ identical schedule, identical `S`, identical LB events; seed
//! sweeps reproduce the run-to-run variation the paper attributes to "the
//! indeterminate nature of our distributed systems".
//!
//! Cost model (virtual ticks): fetching a task, mapping an item, reducing
//! a record, forwarding a record and idle re-polls each cost a configurable
//! number of ticks, with multiplicative jitter. Reducers are slower than
//! mappers by default (`reduce_cost > map_cost`) — the compute-heavy
//! regime whose queue buildup the balancer watches.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::balancer::state_forward::ConsistencyMode;
use crate::balancer::BalancerCore;
use crate::exec::{MapExecutor, ReduceFactory, Task};
use crate::mapper::MapperCore;
use crate::metrics::{MembershipChange, RunReport};
use crate::reducer::ReducerCore;
use crate::runtime::exec::{ExecCore, ExecParams, LoadReport, ReducerStep};
use crate::testkit::chaos::{ChaosConfig, ChaosController, FaultAction};
use crate::util::prng::Xoshiro256;

/// Virtual-time costs for the simulation.
#[derive(Clone, Debug)]
pub struct SimCosts {
    /// Ticks for a mapper to fetch a task from the coordinator.
    pub fetch_cost: u64,
    /// Ticks to map one input item (and enqueue its records).
    pub map_cost: u64,
    /// Ticks for a reducer to reduce one record.
    pub reduce_cost: u64,
    /// Ticks for a reducer to forward one record.
    pub forward_cost: u64,
    /// Ticks an idle reducer waits before re-polling its queue.
    pub poll_interval: u64,
    /// Multiplicative cost jitter fraction in `[0, 1)`: each cost is
    /// scaled by `1 + jitter * (2u - 1)`, `u ~ U[0,1)`. Models the
    /// scheduling noise of a real cluster.
    pub cost_jitter: f64,
}

impl Default for SimCosts {
    fn default() -> Self {
        SimCosts {
            fetch_cost: 2,
            // the paper's mappers make a remote call to the LB per item
            // (§3), so mapping is only modestly faster than reducing; with
            // 4 mappers ≈ 4 reducers this keeps uniform-load queues short
            // (no growth-phase false triggers) while genuinely skewed
            // queues still build up on the hot reducer.
            map_cost: 4,
            reduce_cost: 5,
            forward_cost: 1,
            poll_interval: 5,
            cost_jitter: 0.1,
        }
    }
}

/// Sim-driver parameters beyond the shared pipeline config.
#[derive(Clone, Debug)]
pub struct SimParams {
    pub costs: SimCosts,
    pub seed: u64,
    /// Load report every N handled messages (§3 "periodically").
    pub report_interval: u64,
    pub chunk_size: usize,
    pub mode: ConsistencyMode,
    /// Elastic reducer-id ceiling (0 = fixed membership). The scheduler
    /// spawns a new reducer actor when the balancer emits an `Added`
    /// membership event.
    pub max_reducers: usize,
    /// Fault-injection plan + checkpoint cadence (testkit::chaos).
    /// `None` = no chaos hooks on the step loop at all.
    pub chaos: Option<ChaosConfig>,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            costs: SimCosts::default(),
            seed: 0,
            report_interval: 2,
            chunk_size: 10,
            mode: ConsistencyMode::MergeAtEnd,
            max_reducers: 0,
            chaos: None,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum ActorId {
    Mapper(usize),
    Reducer(usize),
}

/// One pipeline execution under the DES.
pub struct SimDriver {
    pub params: SimParams,
}

impl SimDriver {
    pub fn new(params: SimParams) -> Self {
        SimDriver { params }
    }

    /// Run the pipeline: `items` through `n_mappers` mappers and
    /// `balancer.router().nodes()` reducers. The balancer carries the
    /// strategy/policy/router; executors come from the factories.
    pub fn run(
        &self,
        map_exec: Arc<dyn MapExecutor>,
        reduce_factory: &ReduceFactory,
        n_mappers: usize,
        mut balancer: BalancerCore,
        items: impl Into<Arc<[String]>>,
    ) -> RunReport {
        let p = &self.params;
        let router = balancer.router().clone();
        let n_reducers = router.nodes();

        let core = ExecCore::build(
            &router,
            n_mappers,
            items,
            ExecParams {
                chunk_size: p.chunk_size,
                // a single-threaded scheduler must never block on
                // backpressure
                queue_capacity: usize::MAX,
                report_interval: p.report_interval,
                mode: p.mode,
                coordinated_stop: false,
                max_reducers: p.max_reducers,
            },
        );
        let core = match &p.chaos {
            Some(cfg) => {
                // one WAL/slot per pre-allocated queue, so respawns and
                // elastic joiners log from their first step
                let cap = core.queues.len();
                core.with_chaos(Arc::new(ChaosController::new(cfg, cap)))
            }
            None => core,
        };
        let mut rng = Xoshiro256::new(p.seed);

        // actors
        let mut mappers: Vec<MapperCore> = (0..n_mappers)
            .map(|i| MapperCore::new(i, map_exec.clone(), router.clone()))
            .collect();
        let mut mapper_task: Vec<Option<(Task, usize)>> = vec![None; n_mappers];
        let mut mapper_done: Vec<bool> = vec![false; n_mappers];
        let mut reducers: Vec<ReducerCore> = (0..n_reducers)
            .map(|i| ReducerCore::new(i, reduce_factory(i), router.clone()))
            .collect();
        let mut reducers_running = n_reducers;

        // event heap: (time, seq, actor) — seq breaks ties deterministically
        let mut heap: BinaryHeap<Reverse<(u64, u64, ActorId)>> = BinaryHeap::new();
        let mut seq: u64 = 0;
        let push = |heap: &mut BinaryHeap<_>, seq: &mut u64, t: u64, a: ActorId| {
            *seq += 1;
            heap.push(Reverse((t, *seq, a)));
        };
        for i in 0..n_mappers {
            push(&mut heap, &mut seq, 0, ActorId::Mapper(i));
        }
        for i in 0..n_reducers {
            push(&mut heap, &mut seq, 1, ActorId::Reducer(i));
        }

        let jitter = |rng: &mut Xoshiro256, base: u64, frac: f64| -> u64 {
            if frac <= 0.0 || base == 0 {
                return base.max(1);
            }
            let scale = 1.0 + frac * (2.0 * rng.next_f64() - 1.0);
            ((base as f64 * scale).round() as u64).max(1)
        };

        let mut now: u64 = 0;
        while let Some(Reverse((t, _, actor))) = heap.pop() {
            now = t;
            // crash recovery: a queued kill retires-and-respawns once the
            // §7 tracker is synchronized and no prior re-homed transfer is
            // still in flight; while waiting, keep settling the corpse's
            // queue so a mid-kill epoch cannot wedge on it
            if let Some(ch) = core.chaos() {
                if ch.recovery_queued() {
                    for v in 0..core.queues.len() {
                        if ch.was_killed(v) {
                            core.chaos_drain_dead(v);
                        }
                    }
                    if core.synced() && core.tracker.transfers_settled() {
                        if let Some(rec) = ch.take_recovery() {
                            if let Some(id) = balancer.replace_faulted(rec.victim, now) {
                                debug_assert_eq!(id, reducers.len());
                                core.tracker.activate(id);
                                reducers.push(ReducerCore::new(
                                    id,
                                    reduce_factory(id),
                                    router.clone(),
                                ));
                                reducers_running += 1;
                                push(&mut heap, &mut seq, now + 1, ActorId::Reducer(id));
                            }
                            if p.mode == ConsistencyMode::StateForward {
                                // survivors may now hold state the respawn
                                // owns: re-home it the §7 way
                                core.tracker.begin_epoch(router.epoch());
                            }
                            core.chaos_requeue_dead(rec.victim, &router);
                            core.chaos_rehome(rec.victim, &router, reduce_factory);
                            ch.recovery_done(rec.at, now);
                        }
                    }
                }
            }
            match actor {
                ActorId::Mapper(i) => {
                    if mapper_done[i] {
                        continue;
                    }
                    match &mut mapper_task[i] {
                        None => {
                            // fetch a task from the coordinator
                            match core.pool.fetch() {
                                Some(task) => {
                                    mapper_task[i] = Some((task, 0));
                                    let c =
                                        jitter(&mut rng, p.costs.fetch_cost, p.costs.cost_jitter);
                                    push(&mut heap, &mut seq, now + c, actor);
                                }
                                None => {
                                    mapper_done[i] = true;
                                    core.monitor.mapper_done();
                                }
                            }
                        }
                        Some((task, cursor)) => {
                            if *cursor < task.items.len() {
                                let routed = mappers[i].process_item(&task.items[*cursor]);
                                *cursor += 1;
                                for (dest, rec) in routed {
                                    rec.set_stamp(now); // virtual enqueue time
                                    core.push_mapped(dest, rec);
                                }
                                let c = jitter(&mut rng, p.costs.map_cost, p.costs.cost_jitter);
                                push(&mut heap, &mut seq, now + c, actor);
                            } else {
                                mapper_task[i] = None;
                                push(&mut heap, &mut seq, now + 1, actor);
                            }
                        }
                    }
                }
                ActorId::Reducer(i) => {
                    if let Some(ch) = core.chaos() {
                        match ch.poll_fault(i, now) {
                            Some(FaultAction::Kill) => {
                                // fail-stop at the step boundary (the
                                // paper's fault model): the executor state
                                // dies with the actor — the checkpoint +
                                // WAL lane is now the only copy
                                core.chaos_fail_stop(i);
                                reducers[i].exec = reduce_factory(i);
                                reducers_running -= 1;
                                continue; // dead: never rescheduled
                            }
                            Some(FaultAction::Stall(ticks)) => {
                                push(&mut heap, &mut seq, now + ticks.max(1), actor);
                                continue;
                            }
                            None => {}
                        }
                    }
                    match core.reducer_step(&mut reducers[i], i, now, |q| q.try_pop()) {
                        ReducerStep::StateExtracted { .. } | ReducerStep::StateAbsorbed => {
                            let c = jitter(&mut rng, p.costs.forward_cost, p.costs.cost_jitter);
                            push(&mut heap, &mut seq, now + c, actor);
                        }
                        ReducerStep::Deferred => {
                            push(&mut heap, &mut seq, now + 1, actor);
                        }
                        step @ (ReducerStep::Reduced | ReducerStep::Forwarded) => {
                            let base = match step {
                                ReducerStep::Reduced => p.costs.reduce_cost,
                                _ => p.costs.forward_cost,
                            };
                            // a Slow fault multiplies this reducer's costs
                            let base =
                                core.chaos().map_or(base, |c| base * c.slow_factor(i));
                            let c = jitter(&mut rng, base, p.costs.cost_jitter);
                            push(&mut heap, &mut seq, now + c, actor);
                            // periodic load report (§3), applied inline —
                            // the sim IS the balancer's owner
                            if reducers[i].due_report(p.report_interval) {
                                let event = core.apply_report(
                                    &mut balancer,
                                    LoadReport {
                                        reducer: i,
                                        qlen: core.queues[i].len(),
                                        at: now,
                                        evaluate: true,
                                    },
                                );
                                // elastic scale-up: schedule the brand-new
                                // reducer actor (its pre-allocated queue may
                                // already hold records routed at the new
                                // epoch); retires need no scheduler action —
                                // the retiree drains by ordinary forwarding
                                if let Some(MembershipChange::Added { id }) =
                                    event.and_then(|e| e.membership)
                                {
                                    let id = id as usize;
                                    debug_assert_eq!(id, reducers.len());
                                    reducers.push(ReducerCore::new(
                                        id,
                                        reduce_factory(id),
                                        router.clone(),
                                    ));
                                    reducers_running += 1;
                                    push(&mut heap, &mut seq, now + 1, ActorId::Reducer(id));
                                }
                            }
                        }
                        ReducerStep::Idle { stop } => {
                            // idle: report emptiness, then either stop (if
                            // globally drained + synchronized) or re-poll
                            balancer.observe(i, 0);
                            if stop {
                                reducers_running -= 1;
                                // stopped: no reschedule
                            } else {
                                push(&mut heap, &mut seq, now + p.costs.poll_interval, actor);
                            }
                        }
                    }
                }
            }
        }

        debug_assert_eq!(reducers_running, 0);
        debug_assert!(core.monitor.drained());

        core.finish(
            &mappers,
            &mut reducers,
            &mut balancer,
            reduce_factory,
            std::time::Duration::ZERO,
            now,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::{IdentityMap, WordCount};
    use crate::hash::{RouterHandle, Strategy};

    fn wordcount_factory() -> ReduceFactory {
        Arc::new(|_| Box::new(WordCount::new()) as Box<dyn crate::exec::ReduceExecutor>)
    }

    fn balancer(strategy: Strategy, max_rounds: u32) -> BalancerCore {
        let router = RouterHandle::new(strategy.build_router(4, 8, None));
        BalancerCore::new(router, strategy, 0.2, 8, max_rounds, 50)
    }

    fn run(items: Vec<String>, strategy: Strategy, seed: u64) -> RunReport {
        let driver = SimDriver::new(SimParams { seed, ..Default::default() });
        driver.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(strategy, 1),
            items,
        )
    }

    fn wordcount_oracle(items: &[String]) -> Vec<(String, i64)> {
        let mut m = std::collections::HashMap::new();
        for i in items {
            *m.entry(i.clone()).or_insert(0i64) += 1;
        }
        let mut v: Vec<(String, i64)> = m.into_iter().collect();
        v.sort();
        v
    }

    #[test]
    fn no_lb_counts_are_exact() {
        let items: Vec<String> = (0..100).map(|i| format!("k{}", i % 7)).collect();
        let r = run(items.clone(), Strategy::None, 1);
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.result, wordcount_oracle(&items));
        assert!(r.lb_events.is_empty());
        assert_eq!(r.total_processed(), 100);
    }

    #[test]
    fn skewed_input_triggers_doubling_and_stays_correct() {
        // all items on one doubling node: WL1-style
        let w = crate::workload::paperwl::wl1();
        let r = run(w.items.clone(), Strategy::Doubling, 2);
        assert!(!r.lb_events.is_empty(), "LB should fire on WL1/doubling");
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.result, wordcount_oracle(&w.items));
        // skew should improve vs the static S=1.0
        assert!(r.skew() < 1.0, "S = {}", r.skew());
        assert!(r.total_forwarded() > 0, "old-scheme records were forwarded");
    }

    #[test]
    fn deterministic_same_seed() {
        let w = crate::workload::paperwl::wl4();
        let a = run(w.items.clone(), Strategy::Doubling, 7);
        let b = run(w.items.clone(), Strategy::Doubling, 7);
        assert_eq!(a.processed, b.processed);
        assert_eq!(a.virtual_end, b.virtual_end);
        assert_eq!(a.lb_events.len(), b.lb_events.len());
        assert_eq!(a.result, b.result);
    }

    #[test]
    fn different_seeds_vary_schedule() {
        let w = crate::workload::paperwl::wl4();
        let a = run(w.items.clone(), Strategy::Doubling, 1);
        let b = run(w.items.clone(), Strategy::Doubling, 99);
        // results identical (correctness) even if schedule differs
        assert_eq!(a.result, b.result);
    }

    #[test]
    fn state_forwarding_keeps_state_disjoint() {
        let w = crate::workload::paperwl::wl1();
        let driver = SimDriver::new(SimParams {
            seed: 3,
            mode: ConsistencyMode::StateForward,
            ..Default::default()
        });
        let r = driver.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(Strategy::Doubling, 2),
            w.items.clone(),
        );
        // merge_states() inside run() asserts disjointness; also validate
        // the final answer
        assert_eq!(r.result, wordcount_oracle(&w.items));
        assert!(r.check_conservation().is_ok());
    }

    #[test]
    fn empty_input_terminates() {
        let r = run(vec![], Strategy::Doubling, 5);
        assert_eq!(r.total_processed(), 0);
        assert!(r.result.is_empty());
    }

    #[test]
    fn probe_routers_are_correct_and_deterministic() {
        let w = crate::workload::paperwl::wl4();
        for strategy in [Strategy::MultiProbe { probes: 5 }, Strategy::TwoChoices] {
            let a = run(w.items.clone(), strategy, 7);
            let b = run(w.items.clone(), strategy, 7);
            assert!(a.check_conservation().is_ok(), "{strategy}");
            assert_eq!(a.result, wordcount_oracle(&w.items), "{strategy}");
            assert_eq!(a.processed, b.processed, "{strategy}: sim not deterministic");
            assert_eq!(a.virtual_end, b.virtual_end, "{strategy}");
            // any event a probe router fires moves zero tokens
            for e in &a.lb_events {
                assert!(e.delta.zero_token_churn(), "{strategy}");
            }
        }
    }

    #[test]
    fn single_item_terminates() {
        let r = run(vec!["x".into()], Strategy::Halving, 5);
        assert_eq!(r.total_processed(), 1);
        assert_eq!(r.result, vec![("x".into(), 1)]);
    }

    #[test]
    fn chaos_kill_recovers_exactly_with_checkpointing() {
        use crate::testkit::chaos::{ChaosConfig, ChaosPlan};
        let items: Vec<String> = (0..400).map(|i| format!("k{}", i % 29)).collect();
        let mut cfg = ChaosConfig::new(ChaosPlan::parse("kill@1:10").unwrap());
        cfg.checkpoint_interval = 8;
        let router = RouterHandle::builder(Strategy::Doubling.build_router(4, 8, None))
            .signal(&crate::balancer::signal::SignalConfig::default())
            .capacity(5) // one slot of respawn headroom
            .build();
        let balancer = BalancerCore::new(router, Strategy::Doubling, 0.2, 8, 2, 50);
        let driver = SimDriver::new(SimParams {
            seed: 11,
            mode: ConsistencyMode::StateForward,
            max_reducers: 5,
            chaos: Some(cfg),
            ..Default::default()
        });
        let r = driver.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer,
            items.clone(),
        );
        assert_eq!(r.result, wordcount_oracle(&items), "kill lost state");
        assert!(r.check_conservation().is_ok());
        assert_eq!(r.recovery.kills, 1);
        assert_eq!(r.recovery.respawns, 1);
        assert!(r.recovery.checkpoints >= 1, "cadence 8 must have cut checkpoints");
        assert!(r.recovery_latency.is_some());
        assert_eq!(r.fault_events.len(), 1);
        assert_eq!(r.fault_events[0].reducer, 1);
    }

    #[test]
    fn chaos_slow_and_stall_never_change_the_answer() {
        use crate::testkit::chaos::{ChaosConfig, ChaosPlan};
        // uniform spread: every reducer sees plenty of steps, so both
        // latency faults reliably cross their thresholds and fire
        let items: Vec<String> = (0..400).map(|i| format!("k{}", i % 29)).collect();
        let baseline = run(items.clone(), Strategy::Doubling, 3);
        let cfg = ChaosConfig::new(ChaosPlan::parse("slow:4@0:5,stall:60@2:8").unwrap());
        let driver =
            SimDriver::new(SimParams { seed: 3, chaos: Some(cfg), ..Default::default() });
        let r = driver.run(
            Arc::new(IdentityMap),
            &wordcount_factory(),
            4,
            balancer(Strategy::Doubling, 1),
            items.clone(),
        );
        assert_eq!(r.result, baseline.result, "latency faults must not lose records");
        assert_eq!(r.result, wordcount_oracle(&items));
        assert_eq!(r.recovery.kills, 0);
        assert_eq!(r.fault_events.len(), 2, "both faults fired");
    }
}
