//! The concurrency shim every hot-path module imports its primitives
//! through — the seam that makes the whole concurrent core
//! **model-checkable**.
//!
//! Under a normal build this module is a zero-cost re-export of
//! `std::sync`; under `RUSTFLAGS="--cfg loom"` it re-exports
//! [loom](https://docs.rs/loom)'s API-compatible doubles instead, so the
//! bounded model suite (`rust/tests/loom_models.rs`) can *exhaustively*
//! explore every thread interleaving and memory-ordering outcome of the
//! structures built on top: the lock-free `AssignTable`, the
//! snapshot-before-epoch `RouterHandle` publication, the two-lane
//! `DataQueue`, the relaxed `Histogram` counters, the `ShutdownMonitor`
//! drain condition and the `LoadSignal`/`StageTracker` counters.
//!
//! **Rules of the shim** (enforced by `tools/sync_lint.py` in CI):
//!
//! * No module under `rust/src` may name `std::sync::atomic` (or use a
//!   memory-`Ordering` constant without importing it from here) except
//!   this file and the explicit allowlist. Raw atomics that bypass the
//!   shim are invisible to loom — they would silently shrink the verified
//!   surface.
//! * The core concurrent modules take `Mutex`/`RwLock`/`Condvar` from
//!   here too, so lock interleavings are explored as well.
//! * `loom::` itself must not be imported outside this file (tests may —
//!   the model suite drives `loom::model`/`loom::thread` directly).
//!
//! **What stays `std` even under loom, and why:**
//!
//! * [`Arc`] — loom's `Arc` cannot coerce to `Arc<dyn Trait>` on stable
//!   (unsized coercion is not implementable outside `std`), and the crate
//!   publishes `Arc<dyn Router>` snapshots. `Arc` is used strictly for
//!   reference-counted *sharing*, never as a publication primitive on its
//!   own: every cross-thread hand-off of an `Arc` pointer goes through a
//!   shim lock or atomic (e.g. `RouterHandle::publish` swaps the
//!   published `Arc` under the `RwLock` re-exported here), so the
//!   orderings that matter are still modeled.
//! * `cell::Cell`/`cell::RefCell` — `!Sync` by construction, so no
//!   interleaving exists for loom to explore; `Record`'s enqueue stamp
//!   rides through queues by value. (`UnsafeCell` is deliberately *not*
//!   re-exported: loom's `UnsafeCell` has a different, closure-based API.
//!   If hot-path code ever needs one, add it here with the loom access
//!   protocol, not at the use site.)
//! * `once_cell::sync::OnceCell` (the `AssignTable` segment-growth
//!   latch) — not loom-aware; the loom models bound their key counts far
//!   below one probe window so the growth path is never taken inside a
//!   model. A loom-visible replacement is the first thing to reach for if
//!   a future model needs to cross a segment boundary.

#![forbid(unsafe_code)]

/// Atomic integer/bool types and the memory-`Ordering` enum.
///
/// Import orderings as `use crate::sync::atomic::Ordering` — the lint
/// treats a bare `Ordering::Acquire` in a file without that import as a
/// shim bypass.
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{
        AtomicBool, AtomicI64, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };

    #[cfg(loom)]
    pub use loom::sync::atomic::{
        AtomicBool, AtomicI64, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };
}

/// Single-threaded interior mutability (`!Sync`: nothing to model).
pub mod cell {
    pub use std::cell::{Cell, RefCell};
}

// Reference counting stays `std` under loom — see the module docs.
pub use std::sync::Arc;

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
