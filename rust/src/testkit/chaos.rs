//! Fault injection for both drivers: deterministic, seeded chaos plans
//! (`Kill`, `Slow`, `Stall`, `DropReports`) plus the replication machinery
//! that makes a `Kill` survivable — a per-reducer write-ahead log of every
//! fold, periodic checkpoint-to-peer over the §7 priority transfer lane,
//! and the recovery bookkeeping the drivers' retire-and-respawn sequence
//! consumes.
//!
//! The paper's fault model is fail-stop at a step boundary (§7): a reducer
//! dies *between* records, never mid-fold. [`ChaosPlan`] events therefore
//! trigger on per-victim handled-record counts, not wall clock — the same
//! plan is meaningful on the deterministic sim and on real threads, and a
//! plan's *output* effect (none, for Slow/Stall/DropReports; none for Kill
//! when checkpointing is on) is testable on both.
//!
//! Recovery correctness argument, in one paragraph: every mutation of a
//! reducer's state is one of {fold a data record, absorb a §7 transfer,
//! extract a disowned key}. The first two are logged as [`WalEntry::Fold`]
//! *before* the driver can observe the step boundary; the third as
//! [`WalEntry::Extract`]. A checkpoint with sequence number S snapshots
//! the state covering exactly the entries tagged `< S`. Replaying the
//! newest installed checkpoint plus the `>= S` log tail into a fresh
//! executor ([`ChaosController::recovered_state`]) therefore reproduces
//! the victim's state at the kill boundary exactly — for *any*
//! [`ReduceExecutor`](crate::exec::ReduceExecutor), not just sums —
//! and the driver re-homes it through ordinary `Envelope::State`
//! transfers. Records still queued at the victim were never folded, so
//! they are not in the log: the driver re-routes the queue itself.

use crate::exec::ReduceFactory;
use crate::metrics::{FaultRecord, Histogram, LatencyStats, RecoveryCounts};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::Mutex;

/// One fault, as scheduled by a [`ChaosPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail-stop the victim at its next step boundary. Its state is lost
    /// (recovered from the replication lane) and the driver runs the
    /// retire-and-respawn sequence.
    Kill,
    /// Multiply the victim's per-record reduce cost from this point on
    /// (a chaos-induced straggler — indistinguishable, to the balancer,
    /// from data skew).
    Slow {
        /// Cost multiplier (≥ 2 to matter).
        factor: u32,
    },
    /// One-shot pause: the victim goes silent for this long, then
    /// resumes untouched. Units are driver ticks on the sim and
    /// milliseconds on threads.
    Stall {
        /// Pause length (sim ticks / threads ms).
        ticks: u64,
    },
    /// Suppress the victim's next N evaluated load reports (the balancer
    /// flies blind on that reducer).
    DropReports {
        /// How many reports to swallow.
        count: u32,
    },
}

impl FaultKind {
    /// Short stable name (fault logs, CLI tables, JSON keys).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Kill => "kill",
            FaultKind::Slow { .. } => "slow",
            FaultKind::Stall { .. } => "stall",
            FaultKind::DropReports { .. } => "drop",
        }
    }
}

/// One scheduled fault: `kind` fires once `reducer` has handled
/// `after_steps` data records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Victim reducer id (initial id space).
    pub reducer: usize,
    /// Handled-record count at which the fault triggers.
    pub after_steps: u64,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic schedule of faults. Parsed from a spec string
/// (`kill@1:40,slow:3@0:20`), generated from a seed
/// ([`ChaosPlan::seeded`]), or built directly (property tests).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    /// The scheduled faults (order irrelevant; each triggers on its own
    /// victim's step count).
    pub events: Vec<FaultEvent>,
}

impl ChaosPlan {
    /// Parse a comma-separated spec. Each event is
    /// `KIND[:ARG]@REDUCER:STEPS`:
    ///
    /// * `kill@1:40` — kill reducer 1 after it handled 40 records
    /// * `slow:4@0:20` — 4× reduce cost on reducer 0 from record 20 on
    /// * `stall:80@2:10` — reducer 2 pauses 80 ticks (sim) / ms (threads)
    /// * `drop:3@1:5` — swallow reducer 1's next 3 load reports
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut events = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (head, target) = part
                .split_once('@')
                .ok_or_else(|| format!("chaos event '{part}': expected KIND[:ARG]@REDUCER:STEPS"))?;
            let (reducer, steps) = target
                .split_once(':')
                .ok_or_else(|| format!("chaos event '{part}': expected REDUCER:STEPS after '@'"))?;
            let reducer: usize = reducer
                .trim()
                .parse()
                .map_err(|e| format!("chaos event '{part}': bad reducer id: {e}"))?;
            let after_steps: u64 = steps
                .trim()
                .parse()
                .map_err(|e| format!("chaos event '{part}': bad step count: {e}"))?;
            let (kind, arg) = match head.split_once(':') {
                Some((k, a)) => (k.trim(), Some(a.trim())),
                None => (head.trim(), None),
            };
            let parse_arg = |what: &str| -> Result<u64, String> {
                arg.ok_or_else(|| format!("chaos event '{part}': '{kind}' needs :{what}"))?
                    .parse()
                    .map_err(|e| format!("chaos event '{part}': bad {what}: {e}"))
            };
            let kind = match kind {
                "kill" => {
                    if arg.is_some() {
                        return Err(format!("chaos event '{part}': 'kill' takes no argument"));
                    }
                    FaultKind::Kill
                }
                "slow" => FaultKind::Slow { factor: parse_arg("factor")?.max(1) as u32 },
                "stall" => FaultKind::Stall { ticks: parse_arg("ticks")? },
                "drop" => FaultKind::DropReports { count: parse_arg("count")?.max(1) as u32 },
                other => {
                    return Err(format!(
                        "chaos event '{part}': unknown kind '{other}' \
                         (expected kill|slow|stall|drop)"
                    ))
                }
            };
            events.push(FaultEvent { reducer, after_steps, kind });
        }
        Ok(ChaosPlan { events })
    }

    /// Render back to the spec grammar `parse` accepts (round-trips).
    pub fn spec(&self) -> String {
        self.events
            .iter()
            .map(|e| {
                let head = match e.kind {
                    FaultKind::Kill => "kill".to_string(),
                    FaultKind::Slow { factor } => format!("slow:{factor}"),
                    FaultKind::Stall { ticks } => format!("stall:{ticks}"),
                    FaultKind::DropReports { count } => format!("drop:{count}"),
                };
                format!("{head}@{}:{}", e.reducer, e.after_steps)
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// A deterministic single-fault plan derived from a seed — the `dpa
    /// chaos` matrix cell generator. `fault` is a [`FaultKind::name`];
    /// the victim and trigger point are seed-derived so different seeds
    /// hit different reducers at different phases of the run.
    pub fn seeded(fault: &str, seed: u64, reducers: usize) -> Result<Self, String> {
        let mut s = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut next = move || {
            // splitmix64: tiny, seedable, no external deps
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let reducer = (next() % reducers.max(1) as u64) as usize;
        let after_steps = 8 + next() % 24;
        let kind = match fault {
            "kill" => FaultKind::Kill,
            "slow" => FaultKind::Slow { factor: 2 + (next() % 3) as u32 },
            "stall" => FaultKind::Stall { ticks: 30 + next() % 60 },
            "drop" => FaultKind::DropReports { count: 1 + (next() % 3) as u32 },
            other => return Err(format!("unknown fault kind '{other}'")),
        };
        Ok(ChaosPlan { events: vec![FaultEvent { reducer, after_steps, kind }] })
    }

    /// How many kills the plan schedules (the extra reducer-id capacity a
    /// run must pre-allocate: every kill consumes one respawn id).
    pub fn kill_count(&self) -> usize {
        self.events.iter().filter(|e| e.kind == FaultKind::Kill).count()
    }

    /// Largest victim id any event targets.
    pub fn max_victim(&self) -> Option<usize> {
        self.events.iter().map(|e| e.reducer).max()
    }
}

/// Chaos knobs a run carries (driver params / pipeline config).
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// The fault schedule.
    pub plan: ChaosPlan,
    /// Cut a checkpoint to a peer every N folded records per reducer.
    /// Smaller = tighter replication lag = shorter WAL replays.
    pub checkpoint_interval: u64,
}

impl ChaosConfig {
    /// A plan with the default checkpoint cadence.
    pub fn new(plan: ChaosPlan) -> Self {
        ChaosConfig { plan, checkpoint_interval: 16 }
    }
}

/// What the driver must do about a fault that just fired on its reducer.
/// `Slow`/`DropReports` are absorbed inside the controller (they only
/// change multipliers the hooks read); `Kill` and `Stall` need the
/// scheduler's cooperation, so they surface here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Fail-stop this reducer now (step boundary). The driver must not
    /// process further envelopes on it and must clear its executor state.
    Kill,
    /// Pause this reducer (sim: reschedule `ticks` later; threads: sleep
    /// that many ms), then resume normally.
    Stall(u64),
}

/// A kill awaiting retire-and-respawn. Queued at kill time; the driver
/// pops it once the §7 tracker is synchronized (membership surgery is
/// illegal mid-epoch).
#[derive(Clone, Copy, Debug)]
pub struct Recovery {
    /// The killed reducer id.
    pub victim: usize,
    /// Driver clock at the kill (recovery latency = done − this).
    pub at: u64,
}

/// One entry of a reducer's write-ahead log, tagged with the checkpoint
/// sequence number current at append time.
#[derive(Clone, Debug)]
enum WalEntry {
    /// A record (or absorbed §7 transfer) folded into the state.
    Fold { seq: u64, key: String, value: i64 },
    /// A key extracted away by §7 state forwarding (its partial now lives
    /// on another reducer — replaying it here would double count).
    Extract { seq: u64, key: String },
}

/// A checkpoint installed on the replication lane.
#[derive(Clone, Debug)]
struct Installed {
    seq: u64,
    state: Vec<(String, i64)>,
}

/// Per-reducer-slot fault state, pre-allocated to the run's id capacity.
struct Slot {
    /// Fast-path gate: false once no events can ever fire on this slot.
    armed: AtomicBool,
    /// Data records folded so far (the fault trigger clock).
    steps: AtomicU64,
    /// Current reduce-cost multiplier (1 = healthy).
    slow: AtomicU64,
    /// Evaluated load reports still to swallow.
    drop_reports: AtomicU64,
    /// Fail-stopped?
    killed: AtomicBool,
    /// Checkpoint sequence number (entries tag with the current value;
    /// a checkpoint with seq S covers exactly tags < S).
    seq: AtomicU64,
}

impl Slot {
    fn new(armed: bool) -> Self {
        Slot {
            armed: AtomicBool::new(armed),
            steps: AtomicU64::new(0),
            slow: AtomicU64::new(1),
            drop_reports: AtomicU64::new(0),
            killed: AtomicBool::new(false),
            seq: AtomicU64::new(0),
        }
    }
}

/// The shared fault-injection and replication controller. One per run,
/// `Arc`-shared between the drivers' reducer loops, the balancer's
/// recovery sequence and [`ExecCore`](crate::runtime::exec::ExecCore)'s
/// step function. All state goes through `crate::sync` so the hooks stay
/// loom-modelable.
pub struct ChaosController {
    interval: u64,
    slots: Vec<Slot>,
    events: Mutex<Vec<FaultEvent>>,
    queued: Mutex<Vec<Recovery>>,
    /// Kills not yet fully recovered — quiescence gate for shutdown.
    unrecovered: AtomicU64,
    wal: Mutex<Vec<Vec<WalEntry>>>,
    checkpoints: Mutex<Vec<Option<Installed>>>,
    log: Mutex<Vec<FaultRecord>>,
    latency: Histogram,
    kills: AtomicU64,
    respawns: AtomicU64,
    checkpoints_cut: AtomicU64,
    state_restored: AtomicU64,
    wal_replayed: AtomicU64,
    requeued: AtomicU64,
}

impl ChaosController {
    /// Build the controller for a run with `capacity` reducer-id slots
    /// (initial reducers + respawn/elastic headroom).
    pub fn new(cfg: &ChaosConfig, capacity: usize) -> Self {
        let slots = (0..capacity)
            .map(|i| Slot::new(cfg.plan.events.iter().any(|e| e.reducer == i)))
            .collect();
        ChaosController {
            interval: cfg.checkpoint_interval.max(1),
            slots,
            events: Mutex::new(cfg.plan.events.clone()),
            queued: Mutex::new(Vec::new()),
            unrecovered: AtomicU64::new(0),
            wal: Mutex::new(vec![Vec::new(); capacity]),
            checkpoints: Mutex::new(vec![None; capacity]),
            log: Mutex::new(Vec::new()),
            latency: Histogram::new(),
            kills: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            checkpoints_cut: AtomicU64::new(0),
            state_restored: AtomicU64::new(0),
            wal_replayed: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
        }
    }

    /// Check for a fault due on reducer `i` at driver time `now`.
    /// `Slow`/`DropReports` are applied internally and return `None`;
    /// `Kill`/`Stall` are returned for the scheduler to act on. At most
    /// one action per call; remaining due events fire on later polls
    /// (a killed slot's leftovers are discarded).
    pub fn poll_fault(&self, i: usize, now: u64) -> Option<FaultAction> {
        let slot = self.slots.get(i)?;
        if !slot.armed.load(Ordering::Acquire) {
            return None;
        }
        let steps = slot.steps.load(Ordering::Acquire);
        let mut events = self.events.lock().unwrap();
        let mut action = None;
        let mut fired = Vec::new();
        events.retain(|e| {
            if e.reducer != i || action.is_some() {
                return true;
            }
            if slot.killed.load(Ordering::Acquire) {
                return false; // dead slots drop their leftover schedule
            }
            if steps < e.after_steps {
                return true;
            }
            match e.kind {
                FaultKind::Slow { factor } => {
                    slot.slow.store(u64::from(factor), Ordering::Release);
                }
                FaultKind::DropReports { count } => {
                    slot.drop_reports.fetch_add(u64::from(count), Ordering::AcqRel);
                }
                FaultKind::Stall { ticks } => action = Some(FaultAction::Stall(ticks)),
                FaultKind::Kill => {
                    slot.killed.store(true, Ordering::Release);
                    self.kills.fetch_add(1, Ordering::Relaxed);
                    self.unrecovered.fetch_add(1, Ordering::AcqRel);
                    self.queued.lock().unwrap().push(Recovery { victim: i, at: now });
                    action = Some(FaultAction::Kill);
                }
            }
            fired.push(e.kind);
            false
        });
        if !events.iter().any(|e| e.reducer == i) {
            slot.armed.store(false, Ordering::Release);
        }
        drop(events);
        if !fired.is_empty() {
            let mut log = self.log.lock().unwrap();
            for kind in fired {
                log.push(FaultRecord { at: now, reducer: i, kind: kind.name().to_string() });
            }
        }
        action
    }

    /// Log a folded data record on reducer `i` and advance its fault
    /// clock. Returns true when a checkpoint is due (the caller cuts it
    /// via [`begin_checkpoint`](Self::begin_checkpoint) + a snapshot
    /// shipped over the peer's priority lane).
    pub fn on_reduced(&self, i: usize, key: &str, value: i64) -> bool {
        let slot = &self.slots[i];
        let seq = slot.seq.load(Ordering::Acquire);
        self.wal.lock().unwrap()[i].push(WalEntry::Fold {
            seq,
            key: key.to_string(),
            value,
        });
        let steps = slot.steps.fetch_add(1, Ordering::AcqRel) + 1;
        steps % self.interval == 0
    }

    /// Log a §7 state transfer absorbed by reducer `i` (also replayed on
    /// recovery — absorbed partials are part of the victim's state).
    pub fn on_absorbed(&self, i: usize, key: &str, value: i64) {
        let slot = &self.slots[i];
        let seq = slot.seq.load(Ordering::Acquire);
        self.wal.lock().unwrap()[i].push(WalEntry::Fold {
            seq,
            key: key.to_string(),
            value,
        });
    }

    /// Log a key extracted away from reducer `i` by §7 forwarding: its
    /// partial left, so a replay must remove it again.
    pub fn on_extracted(&self, i: usize, key: &str) {
        let slot = &self.slots[i];
        let seq = slot.seq.load(Ordering::Acquire);
        self.wal.lock().unwrap()[i].push(WalEntry::Extract { seq, key: key.to_string() });
    }

    /// Open checkpoint `seq+1` on reducer `i`: entries logged from now on
    /// tag with the new sequence number and are NOT covered by the
    /// snapshot the caller is about to cut. Returns the new sequence.
    pub fn begin_checkpoint(&self, i: usize) -> u64 {
        self.checkpoints_cut.fetch_add(1, Ordering::Relaxed);
        self.slots[i].seq.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Install a checkpoint shipped over the transfer lane (higher seq
    /// wins; the covered WAL prefix is pruned).
    pub fn install_checkpoint(&self, origin: usize, seq: u64, state: Vec<(String, i64)>) {
        let mut cps = self.checkpoints.lock().unwrap();
        let cur = &mut cps[origin];
        if cur.as_ref().is_some_and(|c| c.seq >= seq) {
            return;
        }
        *cur = Some(Installed { seq, state });
        drop(cps);
        self.wal.lock().unwrap()[origin].retain(|e| match e {
            WalEntry::Fold { seq: s, .. } | WalEntry::Extract { seq: s, .. } => *s >= seq,
        });
    }

    /// Rebuild the victim's state at its kill boundary: newest installed
    /// checkpoint + the WAL tail, replayed in order into a fresh executor
    /// from the run's factory. The returned records are what the driver
    /// re-homes as ordinary `Envelope::State` transfers.
    pub fn recovered_state(&self, victim: usize, factory: &ReduceFactory) -> Vec<(String, i64)> {
        let mut ghost = factory(victim);
        let base_seq = {
            let cps = self.checkpoints.lock().unwrap();
            match &cps[victim] {
                Some(cp) => {
                    for (k, v) in &cp.state {
                        ghost.absorb_key(k, *v);
                    }
                    cp.seq
                }
                None => 0,
            }
        };
        let mut replayed = 0u64;
        for entry in self.wal.lock().unwrap()[victim].iter() {
            match entry {
                WalEntry::Fold { seq, key, value } if *seq >= base_seq => {
                    ghost.absorb_key(key, *value);
                    replayed += 1;
                }
                WalEntry::Extract { seq, key } if *seq >= base_seq => {
                    ghost.extract_key(key);
                    replayed += 1;
                }
                _ => {}
            }
        }
        self.wal_replayed.fetch_add(replayed, Ordering::Relaxed);
        ghost.flush();
        let state = ghost.snapshot();
        self.state_restored.fetch_add(state.len() as u64, Ordering::Relaxed);
        state
    }

    /// Current reduce-cost multiplier for reducer `i` (1 = healthy).
    pub fn slow_factor(&self, i: usize) -> u64 {
        self.slots.get(i).map_or(1, |s| s.slow.load(Ordering::Acquire))
    }

    /// Swallow one of reducer `i`'s evaluated load reports?
    pub fn should_drop_report(&self, i: usize) -> bool {
        let Some(slot) = self.slots.get(i) else { return false };
        loop {
            let n = slot.drop_reports.load(Ordering::Acquire);
            if n == 0 {
                return false;
            }
            if slot
                .drop_reports
                .compare_exchange(n, n - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return true;
            }
        }
    }

    /// Was reducer `i` fail-stopped? (A finished thread on a killed slot
    /// is the fault model working, not a panic.)
    pub fn was_killed(&self, i: usize) -> bool {
        self.slots.get(i).is_some_and(|s| s.killed.load(Ordering::Acquire))
    }

    /// No kill is pending, due, or mid-recovery — the shutdown gates (sim
    /// `reducer_can_stop`, threads balancer stop check) require this so a
    /// run can't declare itself drained while a victim's state is still
    /// in the replication lane. "Due" matters: a kill whose step
    /// threshold has been crossed but whose victim has not polled yet
    /// must hold the peers open, or they could exit in the instant before
    /// the kill fires and leave nobody to absorb the recovered state.
    pub fn quiescent(&self) -> bool {
        if self.unrecovered.load(Ordering::Acquire) != 0 {
            return false;
        }
        let events = self.events.lock().unwrap();
        !events.iter().any(|e| {
            e.kind == FaultKind::Kill
                && self.slots.get(e.reducer).is_some_and(|s| {
                    s.steps.load(Ordering::Acquire) >= e.after_steps
                        && !s.killed.load(Ordering::Acquire)
                })
        })
    }

    /// Pop one queued recovery (the driver calls this only once the §7
    /// tracker is synchronized). The quiescence gate stays up until
    /// [`recovery_done`](Self::recovery_done).
    pub fn take_recovery(&self) -> Option<Recovery> {
        let mut q = self.queued.lock().unwrap();
        if q.is_empty() {
            None
        } else {
            Some(q.remove(0))
        }
    }

    /// A kill is queued but not yet popped.
    pub fn recovery_queued(&self) -> bool {
        !self.queued.lock().unwrap().is_empty()
    }

    /// Retire-and-respawn for `victim` finished at driver time `now`
    /// (kill happened at `at`): records the recovery latency and drops
    /// the quiescence gate.
    pub fn recovery_done(&self, at: u64, now: u64) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
        self.latency.record(now.saturating_sub(at));
        self.unrecovered.fetch_sub(1, Ordering::AcqRel);
    }

    /// Count envelopes re-routed out of a dead reducer's queue.
    pub fn note_requeued(&self, n: u64) {
        self.requeued.fetch_add(n, Ordering::Relaxed);
    }

    /// Freeze the run's fault history for the [`RunReport`]
    /// (`fault_events`, `recovery` counts, recovery-latency percentiles).
    ///
    /// [`RunReport`]: crate::metrics::RunReport
    pub fn summary(&self) -> (Vec<FaultRecord>, RecoveryCounts, Option<LatencyStats>) {
        let events = self.log.lock().unwrap().clone();
        let counts = RecoveryCounts {
            kills: self.kills.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            checkpoints: self.checkpoints_cut.load(Ordering::Relaxed),
            state_restored: self.state_restored.load(Ordering::Relaxed),
            wal_replayed: self.wal_replayed.load(Ordering::Relaxed),
            requeued: self.requeued.load(Ordering::Relaxed),
        };
        let latency = if self.latency.is_empty() { None } else { Some(self.latency.stats()) };
        (events, counts, latency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::builtin::WordCount;
    use crate::sync::Arc;

    fn factory() -> ReduceFactory {
        Arc::new(|_| Box::new(WordCount::new()) as _)
    }

    #[test]
    fn plan_spec_round_trips() {
        let spec = "kill@1:40,slow:4@0:20,stall:80@2:10,drop:3@1:5";
        let plan = ChaosPlan::parse(spec).unwrap();
        assert_eq!(plan.events.len(), 4);
        assert_eq!(plan.events[0].kind, FaultKind::Kill);
        assert_eq!(plan.events[1].kind, FaultKind::Slow { factor: 4 });
        assert_eq!(plan.events[2].kind, FaultKind::Stall { ticks: 80 });
        assert_eq!(plan.events[3].kind, FaultKind::DropReports { count: 3 });
        assert_eq!(plan.kill_count(), 1);
        assert_eq!(plan.max_victim(), Some(2));
        assert_eq!(ChaosPlan::parse(&plan.spec()).unwrap(), plan);
    }

    #[test]
    fn plan_parse_rejects_malformed_specs() {
        for bad in [
            "kill",            // no target
            "kill@1",          // no step count
            "kill:2@1:5",      // kill takes no argument
            "slow@1:5",        // slow needs a factor
            "frob@1:5",        // unknown kind
            "kill@x:5",        // bad reducer id
            "kill@1:y",        // bad steps
        ] {
            assert!(ChaosPlan::parse(bad).is_err(), "'{bad}' should be rejected");
        }
        assert!(ChaosPlan::parse("").unwrap().events.is_empty());
    }

    #[test]
    fn seeded_plans_are_deterministic_and_seed_sensitive() {
        for fault in ["kill", "slow", "stall", "drop"] {
            let a = ChaosPlan::seeded(fault, 7, 4).unwrap();
            let b = ChaosPlan::seeded(fault, 7, 4).unwrap();
            assert_eq!(a, b, "{fault}: same seed must reproduce");
            assert_eq!(a.events.len(), 1);
            assert!(a.events[0].reducer < 4);
        }
        let seeds: Vec<ChaosPlan> =
            (0..16).map(|s| ChaosPlan::seeded("kill", s, 4).unwrap()).collect();
        assert!(
            seeds.windows(2).any(|w| w[0] != w[1]),
            "16 consecutive seeds produced identical plans"
        );
        assert!(ChaosPlan::seeded("meteor", 0, 4).is_err());
    }

    #[test]
    fn kill_fires_at_step_threshold_and_queues_recovery() {
        let cfg = ChaosConfig::new(ChaosPlan::parse("kill@1:3").unwrap());
        let c = ChaosController::new(&cfg, 4);
        assert_eq!(c.poll_fault(1, 0), None, "not enough steps yet");
        for step in 0..3 {
            assert!(!c.on_reduced(1, &format!("k{step}"), 1));
        }
        assert_eq!(c.poll_fault(0, 5), None, "wrong reducer");
        assert_eq!(c.poll_fault(1, 5), Some(FaultAction::Kill));
        assert!(c.was_killed(1));
        assert!(!c.quiescent());
        assert!(c.recovery_queued());
        let rec = c.take_recovery().unwrap();
        assert_eq!((rec.victim, rec.at), (1, 5));
        assert!(!c.quiescent(), "gate holds until recovery_done");
        c.recovery_done(rec.at, 25);
        assert!(c.quiescent());
        let (events, counts, latency) = c.summary();
        assert_eq!(events.len(), 1);
        assert_eq!((counts.kills, counts.respawns), (1, 1));
        assert_eq!(latency.unwrap().count, 1);
    }

    #[test]
    fn slow_and_drop_apply_internally() {
        let cfg = ChaosConfig::new(ChaosPlan::parse("slow:5@0:1,drop:2@0:1").unwrap());
        let c = ChaosController::new(&cfg, 2);
        assert_eq!(c.slow_factor(0), 1);
        c.on_reduced(0, "k", 1);
        assert_eq!(c.poll_fault(0, 0), None, "slow/drop absorb internally");
        assert_eq!(c.poll_fault(0, 0), None);
        assert_eq!(c.slow_factor(0), 5);
        assert!(c.should_drop_report(0));
        assert!(c.should_drop_report(0));
        assert!(!c.should_drop_report(0), "budget of 2 exhausted");
        assert!(c.quiescent(), "no kills: run may stop freely");
    }

    #[test]
    fn recovery_replays_checkpoint_plus_wal_tail_exactly() {
        let cfg = ChaosConfig { plan: ChaosPlan::default(), checkpoint_interval: 4 };
        let c = ChaosController::new(&cfg, 2);
        // 4 folds -> checkpoint due; cut it and install on the peer lane
        for i in 0..4 {
            let due = c.on_reduced(0, &format!("k{}", i % 2), 1);
            assert_eq!(due, i == 3);
        }
        let seq = c.begin_checkpoint(0);
        assert_eq!(seq, 1);
        // the snapshot covering tags < 1 (k0: 2, k1: 2)
        c.install_checkpoint(0, seq, vec![("k0".into(), 2), ("k1".into(), 2)]);
        // post-checkpoint activity: folds, an absorbed transfer, an extract
        c.on_reduced(0, "k0", 1);
        c.on_absorbed(0, "k2", 7);
        c.on_extracted(0, "k1");
        let mut state = c.recovered_state(0, &factory());
        state.sort();
        assert_eq!(
            state,
            vec![("k0".to_string(), 3), ("k2".to_string(), 7)],
            "checkpoint + tail replay must be exact (k1 extracted away)"
        );
        let (_, counts, _) = c.summary();
        assert_eq!(counts.checkpoints, 1);
        assert_eq!(counts.wal_replayed, 3, "only the >= seq tail replays");
        assert_eq!(counts.state_restored, 2);
    }

    #[test]
    fn recovery_without_any_checkpoint_replays_the_whole_wal() {
        let cfg = ChaosConfig::new(ChaosPlan::default());
        let c = ChaosController::new(&cfg, 1);
        c.on_reduced(0, "a", 1);
        c.on_reduced(0, "a", 1);
        c.on_reduced(0, "b", 1);
        let mut state = c.recovered_state(0, &factory());
        state.sort();
        assert_eq!(state, vec![("a".to_string(), 2), ("b".to_string(), 1)]);
    }

    #[test]
    fn install_checkpoint_keeps_the_newest_and_prunes_the_wal() {
        let cfg = ChaosConfig { plan: ChaosPlan::default(), checkpoint_interval: 2 };
        let c = ChaosController::new(&cfg, 1);
        c.on_reduced(0, "a", 1);
        c.on_reduced(0, "a", 1);
        let s1 = c.begin_checkpoint(0);
        c.on_reduced(0, "a", 1);
        c.on_reduced(0, "a", 1);
        let s2 = c.begin_checkpoint(0);
        assert_eq!((s1, s2), (1, 2));
        c.install_checkpoint(0, s2, vec![("a".into(), 4)]);
        // a stale checkpoint arriving late must not clobber the newer one
        c.install_checkpoint(0, s1, vec![("a".into(), 2)]);
        c.on_reduced(0, "a", 1);
        let state = c.recovered_state(0, &factory());
        assert_eq!(state, vec![("a".to_string(), 5)]);
    }

    #[test]
    fn stall_is_one_shot() {
        let cfg = ChaosConfig::new(ChaosPlan::parse("stall:40@0:0").unwrap());
        let c = ChaosController::new(&cfg, 1);
        assert_eq!(c.poll_fault(0, 0), Some(FaultAction::Stall(40)));
        assert_eq!(c.poll_fault(0, 1), None, "stall consumed");
    }
}
