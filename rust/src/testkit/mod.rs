//! A tiny property-testing toolkit (offline build: no proptest), plus
//! cross-driver parity helpers.
//!
//! [`forall`] runs a property over N seeded random cases; on failure it
//! retries the failing case with progressively "smaller" regenerations
//! (halved size parameter) to report a compact counterexample. Generators
//! are plain functions over [`Gen`].
//!
//! [`assert_driver_parity`] is the unified-runtime contract check: the
//! deterministic sim and the threads driver must produce identical merged
//! results (both equal to the serial word-count oracle) for the same
//! workload × strategy × consistency mode.

pub mod chaos;

use crate::balancer::state_forward::ConsistencyMode;
use crate::hash::Strategy;
use crate::pipeline::{DriverKind, Pipeline, PipelineConfig};
use crate::util::prng::Xoshiro256;

/// Serial word-count oracle: what any driver must compute.
pub fn wordcount_oracle(items: &[String]) -> Vec<(String, i64)> {
    let mut m = std::collections::HashMap::new();
    for i in items {
        *m.entry(i.clone()).or_insert(0i64) += 1;
    }
    let mut v: Vec<(String, i64)> = m.into_iter().collect();
    v.sort();
    v
}

/// Run the word-count pipeline on `items` under both drivers and assert
/// that each result matches the serial oracle (hence each other), that
/// message conservation holds, and — when `mode` is
/// [`ConsistencyMode::StateForward`] — that the key-disjoint snapshot
/// invariant (asserted inside the shared runtime's merge) survives real
/// concurrency. `label` names the workload in failure messages.
pub fn assert_driver_parity(
    label: &str,
    items: &[String],
    strategy: Strategy,
    mode: ConsistencyMode,
) {
    let oracle = wordcount_oracle(items);
    let shared: std::sync::Arc<[String]> = items.into();
    for driver in [DriverKind::Sim, DriverKind::Threads] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = driver;
        cfg.strategy = strategy;
        if strategy.is_token_ring() {
            cfg.initial_tokens = Some(strategy.initial_tokens(cfg.halving_init_tokens));
        }
        cfg.mode = mode;
        cfg.max_rounds = 2;
        // keep the threads runs fast; LB firing is workload-dependent and
        // parity must hold either way
        cfg.reduce_delay_us = 50;
        let r = Pipeline::wordcount(cfg)
            .run(shared.clone())
            .unwrap_or_else(|e| panic!("{label}/{strategy}/{mode:?}/{driver:?}: {e}"));
        r.check_conservation()
            .unwrap_or_else(|e| panic!("{label}/{strategy}/{mode:?}/{driver:?}: {e}"));
        assert_eq!(
            r.result, oracle,
            "{label}/{strategy}/{mode:?}/{driver:?}: result != oracle"
        );
    }
}

/// Random-input generator context: a seeded PRNG plus a size budget that
/// shrinking reduces.
pub struct Gen {
    pub rng: Xoshiro256,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Xoshiro256::new(seed), size }
    }

    /// Uniform usize in `[lo, hi]`, clamped by the size budget.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        lo + self.rng.index(hi - lo + 1)
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u64() as u32
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Lowercase ASCII string of length in `[0, max_len]`.
    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.usize_in(0, max_len);
        (0..len)
            .map(|_| (b'a' + self.rng.index(26) as u8) as char)
            .collect()
    }

    /// Arbitrary bytes of length in `[0, max_len]`.
    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| self.rng.next_u64() as u8).collect()
    }

    /// Vector built from a generator function.
    pub fn vec_of<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Outcome of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` seeded random cases. On failure, regenerate the
/// failing seed at smaller sizes to find a more compact counterexample,
/// then panic with seed + message (re-run with `forall_seeded` to debug).
pub fn forall(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    let base_seed = 0xD9A_0000u64;
    for case in 0..cases {
        let seed = base_seed + case as u64;
        let mut g = Gen::new(seed, 64);
        if let Err(msg) = prop(&mut g) {
            // shrink: same seed, smaller sizes
            let mut best = (64usize, msg);
            for size in [32usize, 16, 8, 4, 2, 1] {
                let mut g = Gen::new(seed, size);
                if let Err(m) = prop(&mut g) {
                    best = (size, m);
                }
            }
            panic!(
                "property '{name}' failed (seed {seed:#x}, size {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Re-run a single case for debugging.
pub fn forall_seeded(seed: u64, size: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    let mut g = Gen::new(seed, size);
    if let Err(msg) = prop(&mut g) {
        panic!("property failed (seed {seed:#x}, size {size}): {msg}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("addition commutes", 50, |g| {
            let a = g.u32() as u64;
            let b = g.u32() as u64;
            prop_assert!(a + b == b + a, "{a} + {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        forall("always fails", 5, |g| {
            let v = g.vec_of(10, |g| g.u32());
            prop_assert!(v.len() == usize::MAX, "len {}", v.len());
            Ok(())
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall("bounds", 100, |g| {
            let x = g.usize_in(3, 10);
            prop_assert!((3..=10).contains(&x), "x = {x}");
            let s = g.string(12);
            prop_assert!(s.len() <= 12, "len {}", s.len());
            prop_assert!(s.bytes().all(|b| b.is_ascii_lowercase()), "{s}");
            Ok(())
        });
    }

    #[test]
    fn shrinking_reports_smaller_size() {
        let result = std::panic::catch_unwind(|| {
            forall("long vecs fail", 3, |g| {
                let v = g.vec_of(64, |g| g.u32());
                prop_assert!(v.len() < 2, "vec of len {}", v.len());
                Ok(())
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the shrink loop should have found a failure at a reduced size
        assert!(msg.contains("size"), "{msg}");
    }
}
