//! Minimal `log` facade backend (the offline build has no `env_logger`).
//!
//! Level comes from `DPA_LOG` (error|warn|info|debug|trace), default
//! `warn`. Install with [`init`] — idempotent, safe to call from tests,
//! examples and the CLI alike.

use std::io::Write;
// sync-lint allowlist: the install latch is a `static`, and loom atomics
// are not const-constructible. Nothing here is hot-path or modeled.
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    max: Level,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed();
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:>9.3}s {:5} {}] {}",
            t.as_secs_f64(),
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

fn level_from_env() -> Level {
    match std::env::var("DPA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    }
}

/// Install the stderr logger. Idempotent.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = level_from_env();
    let logger = Box::new(StderrLogger { max: level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::from(level.to_level_filter()));
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logger smoke test");
    }
}
