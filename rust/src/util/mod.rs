//! Small shared utilities: deterministic PRNG, logging, statistics and
//! table formatting. These stand in for crates (rand, env_logger,
//! statistical helpers) that are unavailable in the offline build.

pub mod prng;
pub mod logger;
pub mod stats;
pub mod table;

/// Integer ceiling division: `ceil(a / b)` for non-negative integers.
///
/// Used by the paper's ideal-load term `U = ceil(M / R)`.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(100, 4), 25);
        assert_eq!(ceil_div(101, 4), 26);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 1), 1);
        assert_eq!(ceil_div(7, 8), 1);
    }
}
