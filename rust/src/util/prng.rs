//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we carry our own generators:
//! [`SplitMix64`] for seeding and [`Xoshiro256`] (xoshiro256**) as the
//! workhorse. Both are well-known public-domain algorithms with published
//! reference outputs (tested below). Every stochastic component in the
//! system (sim scheduler, workload generators, property tests) takes an
//! explicit seed so runs are reproducible.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift rejection-free
    /// variant (tiny bias at u64 scale is irrelevant for our use).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

/// A Zipf(s) sampler over ranks `1..=n` using precomputed CDF + binary
/// search. Zipfian key popularity is the canonical skewed-workload model.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    /// Sample a 0-based rank (0 = most popular).
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference outputs for seed 1234567 (from the published C code).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        let mut c = Xoshiro256::new(43);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn xoshiro_known_vector() {
        // xoshiro256** with state all-zero is invalid; with s = [1,2,3,4]
        // the first output is rotl(2*5,7)*9 = rotl(10,7)*9 = 1280*9 = 11520.
        let mut g = Xoshiro256::from_state([1, 2, 3, 4]);
        assert_eq!(g.next_u64(), 11520);
    }

    #[test]
    fn next_below_in_range() {
        let mut g = Xoshiro256::new(7);
        for _ in 0..1000 {
            let v = g.next_below(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut g = Xoshiro256::new(9);
        for _ in 0..1000 {
            let v = g.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Xoshiro256::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_zero_weights() {
        let mut g = Xoshiro256::new(11);
        for _ in 0..200 {
            let i = g.weighted_index(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let z = Zipf::new(100, 1.2);
        let mut g = Xoshiro256::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut g)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[99] * 5);
    }

    #[test]
    fn zipf_uniform_when_s_zero() {
        let z = Zipf::new(4, 0.0);
        let mut g = Xoshiro256::new(6);
        let mut counts = vec![0usize; 4];
        for _ in 0..40_000 {
            counts[z.sample(&mut g)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 800.0, "count {c}");
        }
    }
}
