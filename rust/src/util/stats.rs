//! Summary statistics over `f64` samples — mean/variance/percentiles —
//! used by the bench harness and by experiment reports (the paper reports
//! 3-run means and notes "very small" variance; we compute it).

/// Online + batch summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Unbiased sample variance (n-1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            let frac = rank - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan_mean() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_slice(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert!((s.percentile(0.0) - 10.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 50.0).abs() < 1e-12);
        assert!((s.median() - 30.0).abs() < 1e-12);
        assert!((s.percentile(25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let s = Summary::from_slice(&[3.0, -1.0, 7.0]);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 7.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_slice(&[42.0]);
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.median(), 42.0);
    }
}
