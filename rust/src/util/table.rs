//! Plain-text table rendering for experiment and benchmark reports.
//!
//! Produces aligned, markdown-compatible tables so bench output can be
//! pasted straight into EXPERIMENTS.md.

/// A simple column-aligned table builder.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as a markdown table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push('|');
        for wi in &w {
            out.push_str(&format!("{:-<width$}|", "", width = wi + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals, right-trimmed to be table friendly.
pub fn f2(x: f64) -> String {
    format!("{:.2}", x)
}

/// Format a signed delta with explicit sign, 2 decimals.
pub fn delta2(x: f64) -> String {
    if x >= 0.0 {
        format!("+{:.2}", x)
    } else {
        format!("{:.2}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(["Workload", "S"]);
        t.row(["WL1", "0.00"]);
        t.row(["WL4-long-name", "0.80"]);
        let r = t.render();
        assert!(r.contains("| Workload"));
        assert!(r.contains("| WL4-long-name | 0.80 |"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f2(0.8), "0.80");
        assert_eq!(delta2(0.25), "+0.25");
        assert_eq!(delta2(-0.08), "-0.08");
    }
}
