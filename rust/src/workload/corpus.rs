//! Synthetic text corpus for the end-to-end example: zipf-distributed
//! words drawn from a fixed common-English word list, assembled into
//! sentences. This stands in for the paper's "counting English words"
//! motivating workload (no proprietary corpus needed — the rank-frequency
//! shape is what matters and zipf is the standard model for it).

use crate::util::prng::{Xoshiro256, Zipf};

use super::Workload;

/// 200 common English words, frequency-ranked (a standard head-of-Zipf
/// list). Rank order matters: rank 0 is sampled most.
pub const WORDS: [&str; 200] = [
    "the", "be", "to", "of", "and", "a", "in", "that", "have", "i",
    "it", "for", "not", "on", "with", "he", "as", "you", "do", "at",
    "this", "but", "his", "by", "from", "they", "we", "say", "her", "she",
    "or", "an", "will", "my", "one", "all", "would", "there", "their", "what",
    "so", "up", "out", "if", "about", "who", "get", "which", "go", "me",
    "when", "make", "can", "like", "time", "no", "just", "him", "know", "take",
    "people", "into", "year", "your", "good", "some", "could", "them", "see", "other",
    "than", "then", "now", "look", "only", "come", "its", "over", "think", "also",
    "back", "after", "use", "two", "how", "our", "work", "first", "well", "way",
    "even", "new", "want", "because", "any", "these", "give", "day", "most", "us",
    "is", "was", "are", "been", "has", "had", "were", "said", "did", "having",
    "may", "should", "each", "such", "where", "much", "before", "right", "too", "means",
    "old", "same", "tell", "does", "set", "three", "must", "state", "never", "become",
    "between", "high", "really", "something", "most", "another", "much", "family", "own", "leave",
    "put", "old", "while", "mean", "keep", "student", "why", "let", "great", "same",
    "big", "group", "begin", "seem", "country", "help", "talk", "where", "turn", "problem",
    "every", "start", "hand", "might", "american", "show", "part", "against", "place", "such",
    "again", "few", "case", "week", "company", "system", "each", "program", "question", "during",
    "play", "government", "run", "small", "number", "off", "always", "move", "night", "live",
    "point", "believe", "hold", "today", "bring", "happen", "next", "without", "before", "large",
];

/// Generate a corpus of `n_words` words with zipf exponent `s` (1.0 ≈
/// natural language), as whitespace-joined sentences of 5–15 words.
pub fn generate(n_words: usize, s: f64, seed: u64) -> String {
    let dist = Zipf::new(WORDS.len(), s);
    let mut rng = Xoshiro256::new(seed);
    let mut out = String::with_capacity(n_words * 6);
    let mut in_sentence = 0usize;
    let mut sentence_len = 5 + rng.index(11);
    for i in 0..n_words {
        if i > 0 {
            out.push(if in_sentence == 0 { '\n' } else { ' ' });
        }
        out.push_str(WORDS[dist.sample(&mut rng)]);
        in_sentence += 1;
        if in_sentence >= sentence_len {
            in_sentence = 0;
            sentence_len = 5 + rng.index(11);
        }
    }
    out
}

/// A word-stream workload over the synthetic corpus: each item is a word
/// (the e2e example's mapper splits lines instead; this is the pre-split
/// form used by benches).
pub fn workload(n_words: usize, s: f64, seed: u64) -> Workload {
    let text = generate(n_words, s, seed);
    let items: Vec<String> = text.split_whitespace().map(str::to_string).collect();
    Workload::new(format!("corpus-{n_words}"), items)
        .with_description(format!("synthetic zipf({s}) corpus, {n_words} words, seed {seed}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_requested_words() {
        let w = workload(1000, 1.0, 1);
        assert_eq!(w.len(), 1000);
    }

    #[test]
    fn corpus_is_zipfian() {
        let w = workload(20_000, 1.0, 2);
        let mut counts = std::collections::HashMap::new();
        for k in &w.items {
            *counts.entry(k.as_str()).or_insert(0usize) += 1;
        }
        let the = counts.get("the").copied().unwrap_or(0);
        // rank-0 word should dominate any tail word
        let tail = counts.get("large").copied().unwrap_or(0);
        assert!(the > tail * 3, "the={the} large={tail}");
    }

    #[test]
    fn sentences_have_linebreaks() {
        let text = generate(200, 1.0, 3);
        assert!(text.contains('\n'));
        assert!(!text.starts_with('\n'));
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(100, 1.0, 9), generate(100, 1.0, 9));
        assert_ne!(generate(100, 1.0, 9), generate(100, 1.0, 10));
    }
}
