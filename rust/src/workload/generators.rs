//! Generic workload generators: uniform, zipfian, hot-key and ring-aware
//! adversarial streams. All are seeded and deterministic.

use crate::hash::Ring;
use crate::util::prng::{Xoshiro256, Zipf};

use super::Workload;

/// The key pool the generators (and the WL solver) draw from: `a`..`z`,
/// then `aa`..`zz` — 702 short string keys, mirroring the paper's
/// letter-counting workloads.
pub fn key_pool() -> Vec<String> {
    let mut pool = Vec::with_capacity(26 + 26 * 26);
    for c in b'a'..=b'z' {
        pool.push((c as char).to_string());
    }
    for c1 in b'a'..=b'z' {
        for c2 in b'a'..=b'z' {
            pool.push(format!("{}{}", c1 as char, c2 as char));
        }
    }
    pool
}

/// `n_items` keys drawn uniformly from the first `n_keys` pool entries.
pub fn uniform(n_items: usize, n_keys: usize, seed: u64) -> Workload {
    let pool = key_pool();
    let n_keys = n_keys.min(pool.len());
    let mut rng = Xoshiro256::new(seed);
    let items = (0..n_items)
        .map(|_| pool[rng.index(n_keys)].clone())
        .collect();
    Workload::new(format!("uniform-{n_items}x{n_keys}"), items)
        .with_description(format!("{n_items} items uniform over {n_keys} keys, seed {seed}"))
}

/// `n_items` keys drawn Zipf(`s`) over `n_keys` ranked keys — the
/// canonical skewed stream ("h is a lot more common than z").
pub fn zipf(n_items: usize, n_keys: usize, s: f64, seed: u64) -> Workload {
    let pool = key_pool();
    let n_keys = n_keys.min(pool.len());
    let dist = Zipf::new(n_keys, s);
    let mut rng = Xoshiro256::new(seed);
    let items = (0..n_items)
        .map(|_| pool[dist.sample(&mut rng)].clone())
        .collect();
    Workload::new(format!("zipf{s}-{n_items}x{n_keys}"), items)
        .with_description(format!("{n_items} items zipf(s={s}) over {n_keys} keys, seed {seed}"))
}

/// Zipf(`s`) over a *synthetic* key space of `n_keys` ranked keys
/// (`k0`, `k1`, …) instead of the 702-entry letter pool — production-scale
/// workloads for the throughput bench, where the key cardinality itself
/// (sticky-table growth, route-cache memo pressure) is what is being
/// measured. Scales to million-key spaces: cost is one `f64` CDF entry
/// per key plus the sampled items.
pub fn zipf_keyspace(n_items: usize, n_keys: usize, s: f64, seed: u64) -> Workload {
    assert!(n_keys > 0, "zipf_keyspace needs a non-empty key space");
    let dist = Zipf::new(n_keys, s);
    let mut rng = Xoshiro256::new(seed);
    let items = (0..n_items)
        .map(|_| format!("k{}", dist.sample(&mut rng)))
        .collect();
    Workload::new(format!("zipfkeys{s}-{n_items}x{n_keys}"), items).with_description(
        format!("{n_items} items zipf(s={s}) over {n_keys} synthetic keys, seed {seed}"),
    )
}

/// A stream where a fraction `hot_frac` of items share one hot key and the
/// rest are uniform over `n_cold_keys` cold keys.
pub fn hot_key(n_items: usize, hot_frac: f64, n_cold_keys: usize, seed: u64) -> Workload {
    assert!((0.0..=1.0).contains(&hot_frac));
    let pool = key_pool();
    let n_cold = n_cold_keys.min(pool.len() - 1);
    let mut rng = Xoshiro256::new(seed);
    let hot = pool[0].clone();
    let mut items = Vec::with_capacity(n_items);
    for _ in 0..n_items {
        if rng.next_f64() < hot_frac {
            items.push(hot.clone());
        } else {
            items.push(pool[1 + rng.index(n_cold)].clone());
        }
    }
    Workload::new(format!("hotkey-{hot_frac}"), items).with_description(format!(
        "{n_items} items, {:.0}% on one hot key, rest uniform over {n_cold} keys, seed {seed}",
        hot_frac * 100.0
    ))
}

/// Adversarial: every key in the stream is owned by `node` under `ring`
/// (distinct keys, so repartitioning *can* split the load). Panics if the
/// pool has fewer than `distinct` keys on that node.
pub fn adversarial(
    ring: &Ring,
    node: usize,
    n_items: usize,
    distinct: usize,
    seed: u64,
) -> Workload {
    let pool = key_pool();
    let owned: Vec<String> = pool
        .into_iter()
        .filter(|k| ring.lookup(k.as_bytes()) == node)
        .take(distinct)
        .collect();
    assert!(
        owned.len() >= distinct,
        "pool only has {} keys on node {node}, wanted {distinct}",
        owned.len()
    );
    let mut rng = Xoshiro256::new(seed);
    let items = (0..n_items)
        .map(|_| owned[rng.index(owned.len())].clone())
        .collect();
    Workload::new(format!("adversarial-n{node}"), items).with_description(format!(
        "{n_items} items over {distinct} distinct keys all owned by node {node}"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::skew;

    #[test]
    fn pool_is_distinct() {
        let pool = key_pool();
        let mut dedup = pool.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(pool.len(), dedup.len());
        assert_eq!(pool.len(), 26 + 676);
    }

    #[test]
    fn uniform_has_low_static_skew() {
        let w = uniform(10_000, 200, 1);
        let ring = Ring::new(4, 64);
        assert!(w.static_skew(&ring) < 0.15, "S = {}", w.static_skew(&ring));
    }

    #[test]
    fn zipf_is_more_skewed_than_uniform() {
        let ring = Ring::new(4, 8);
        let u = uniform(5_000, 200, 2).static_skew(&ring);
        let z = zipf(5_000, 200, 1.5, 2).static_skew(&ring);
        assert!(z > u, "zipf {z} <= uniform {u}");
    }

    #[test]
    fn hot_key_all_hot_is_max_skew() {
        let w = hot_key(500, 1.0, 10, 3);
        let ring = Ring::new(4, 8);
        assert_eq!(w.static_skew(&ring), 1.0);
    }

    #[test]
    fn adversarial_targets_one_node() {
        let ring = Ring::new(4, 8);
        for node in 0..4 {
            let w = adversarial(&ring, node, 200, 5, 4);
            let loads = w.static_loads(&ring);
            assert_eq!(loads[node], 200, "loads {loads:?}");
            assert_eq!(skew(&loads), 1.0);
            assert!(w.distinct_keys().len() > 1);
        }
    }

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(zipf(100, 50, 1.1, 7).items, zipf(100, 50, 1.1, 7).items);
        assert_ne!(zipf(100, 50, 1.1, 7).items, zipf(100, 50, 1.1, 8).items);
    }

    #[test]
    fn zipf_keyspace_scales_past_the_letter_pool() {
        let w = zipf_keyspace(20_000, 1_000_000, 1.1, 5);
        assert_eq!(w.items.len(), 20_000);
        let distinct = w.distinct_keys().len();
        assert!(
            distinct > 702,
            "only {distinct} distinct keys — stuck at letter-pool scale"
        );
        // rank-0 is the hottest key under Zipf
        let hot = w.items.iter().filter(|i| i.as_str() == "k0").count();
        let cold = w.items.iter().filter(|i| i.as_str() == "k999").count();
        assert!(hot > cold, "zipf head not hot: k0={hot} k999={cold}");
        assert_eq!(
            zipf_keyspace(100, 10_000, 1.3, 9).items,
            zipf_keyspace(100, 10_000, 1.3, 9).items
        );
    }
}
