//! Workloads: the paper's contrived WL1–WL5 (§6.2), generic skew
//! generators, a synthetic text corpus for the end-to-end example, and
//! trace file I/O.
//!
//! The paper defines WL1–WL5 only by their designed no-LB skew under each
//! initial token layout (e.g. WL1: `S = 0` for halving, `S = 1` for
//! doubling). [`paperwl`] *solves* for key sets with those properties
//! against the actual initial rings, so the "No LB" column of Table 1
//! holds by construction.

pub mod generators;
pub mod paperwl;
pub mod corpus;
pub mod trace;

/// A named input workload: a sequence of keys (the paper's "letters").
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub items: Vec<String>,
    /// Human description of how it was constructed.
    pub description: String,
}

impl Workload {
    pub fn new(name: impl Into<String>, items: Vec<String>) -> Self {
        Workload {
            name: name.into(),
            items,
            description: String::new(),
        }
    }

    pub fn with_description(mut self, d: impl Into<String>) -> Self {
        self.description = d.into();
        self
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Distinct keys in first-appearance order.
    pub fn distinct_keys(&self) -> Vec<&str> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for k in &self.items {
            if seen.insert(k.as_str()) {
                out.push(k.as_str());
            }
        }
        out
    }

    /// Per-node message counts if routed with `ring` and never rebalanced
    /// — the analytic "No LB" load vector.
    pub fn static_loads(&self, ring: &crate::hash::Ring) -> Vec<u64> {
        let mut loads = vec![0u64; ring.nodes()];
        for k in &self.items {
            loads[ring.lookup(k.as_bytes())] += 1;
        }
        loads
    }

    /// Analytic no-LB skew under `ring`.
    pub fn static_skew(&self, ring: &crate::hash::Ring) -> f64 {
        crate::metrics::skew(&self.static_loads(ring))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Ring;

    #[test]
    fn distinct_keys_in_order() {
        let w = Workload::new(
            "t",
            vec!["b".into(), "a".into(), "b".into(), "c".into()],
        );
        assert_eq!(w.distinct_keys(), vec!["b", "a", "c"]);
    }

    #[test]
    fn static_loads_sum_to_len() {
        let w = Workload::new("t", (0..50).map(|i| format!("k{i}")).collect());
        let ring = Ring::new(4, 8);
        let loads = w.static_loads(&ring);
        assert_eq!(loads.iter().sum::<u64>(), 50);
    }
}
