//! The paper's five contrived workloads (§6.2), constructed by *solving*
//! for key sets against the actual initial rings.
//!
//! The paper fixes 4 mappers + 4 reducers and 100 items and specifies each
//! workload by its designed no-LB skew under the two methods' initial
//! token layouts (halving: `N` tokens/node; doubling: 1 token/node):
//!
//! | WL  | halving S | doubling S | construction                          |
//! |-----|-----------|------------|---------------------------------------|
//! | WL1 | 0         | 1          | 4 keys uniform across halving ring, all on one doubling node |
//! | WL2 | 0         | 0          | 4 keys uniform across both rings       |
//! | WL3 | 1         | 1          | one key repeated 100×                  |
//! | WL4 | 0.8       | (emergent) | loads (85,5,5,5) on the halving ring   |
//! | WL5 | 0.2       | (emergent) | loads (40,20,20,20) on the halving ring|
//!
//! For WL4/WL5 the paper reports the *measured* doubling-layout skews
//! (0.49 and 0.55); with our solver those values are emergent from the key
//! choice and are reported as measured, not constructed.

use std::collections::HashMap;

use crate::hash::{Ring, Strategy};
use crate::util::prng::Xoshiro256;

use super::generators::key_pool;
use super::Workload;

/// Number of reducers fixed by the paper's evaluation.
pub const PAPER_REDUCERS: usize = 4;
/// Items per workload fixed by the paper's evaluation.
pub const PAPER_ITEMS: usize = 100;
/// Initial tokens per node for the halving method (a power of two, §4.2).
pub const HALVING_INIT_TOKENS: u32 = 8;

/// The two initial rings the workloads are constructed against.
pub fn initial_rings() -> (Ring, Ring) {
    (
        Ring::for_strategy(PAPER_REDUCERS, Strategy::Halving, HALVING_INIT_TOKENS),
        Ring::for_strategy(PAPER_REDUCERS, Strategy::Doubling, HALVING_INIT_TOKENS),
    )
}

/// Group the key pool by `(halving_owner, doubling_owner)`.
fn owner_index(ring_h: &Ring, ring_d: &Ring) -> HashMap<(usize, usize), Vec<String>> {
    let mut idx: HashMap<(usize, usize), Vec<String>> = HashMap::new();
    for k in key_pool() {
        let h = ring_h.lookup(k.as_bytes());
        let d = ring_d.lookup(k.as_bytes());
        idx.entry((h, d)).or_default().push(k);
    }
    idx
}

/// Deterministically interleave per-key repetition counts into one stream
/// so hot keys are spread through the input (round-robin by remaining
/// count, seeded shuffle of ties).
fn interleave(counts: &[(String, usize)], seed: u64) -> Vec<String> {
    let mut remaining: Vec<(String, usize)> = counts.to_vec();
    let mut rng = Xoshiro256::new(seed);
    let total: usize = remaining.iter().map(|(_, c)| c).sum();
    let mut out = Vec::with_capacity(total);
    while out.len() < total {
        // emit one pass over keys with remaining counts, in seeded order
        let mut order: Vec<usize> = (0..remaining.len()).filter(|&i| remaining[i].1 > 0).collect();
        rng.shuffle(&mut order);
        for i in order {
            if remaining[i].1 > 0 {
                out.push(remaining[i].0.clone());
                remaining[i].1 -= 1;
            }
        }
    }
    out
}

/// WL1 — skewless for halving (4 keys, one per halving node, 25 each) but
/// perfectly skewed for doubling (all 4 keys on a single doubling node).
pub fn wl1() -> Workload {
    let (ring_h, ring_d) = initial_rings();
    let idx = owner_index(&ring_h, &ring_d);
    // find a doubling node that hosts keys covering all 4 halving nodes
    for d in 0..PAPER_REDUCERS {
        let mut pick: Vec<Option<&String>> = vec![None; PAPER_REDUCERS];
        for h in 0..PAPER_REDUCERS {
            if let Some(ks) = idx.get(&(h, d)) {
                pick[h] = ks.first();
            }
        }
        if pick.iter().all(Option::is_some) {
            let counts: Vec<(String, usize)> = pick
                .into_iter()
                .map(|k| (k.unwrap().clone(), PAPER_ITEMS / PAPER_REDUCERS))
                .collect();
            return Workload::new("WL1", interleave(&counts, 0x571))
                .with_description(format!(
                    "S=0 halving / S=1 doubling: keys {:?} all on doubling node {d}",
                    counts.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>()
                ));
        }
    }
    panic!("WL1 solver: key pool cannot realize the WL1 spec (unexpected)");
}

/// WL2 — skewless for both methods: 4 keys whose halving owners are a
/// permutation of nodes AND whose doubling owners are a permutation too.
pub fn wl2() -> Workload {
    let (ring_h, ring_d) = initial_rings();
    let idx = owner_index(&ring_h, &ring_d);
    // backtracking perfect matching: halving node h -> doubling node d
    fn solve(
        h: usize,
        used_d: &mut [bool],
        idx: &HashMap<(usize, usize), Vec<String>>,
        picked: &mut Vec<String>,
    ) -> bool {
        if h == PAPER_REDUCERS {
            return true;
        }
        for d in 0..PAPER_REDUCERS {
            if used_d[d] {
                continue;
            }
            if let Some(ks) = idx.get(&(h, d)) {
                used_d[d] = true;
                picked.push(ks[0].clone());
                if solve(h + 1, used_d, idx, picked) {
                    return true;
                }
                picked.pop();
                used_d[d] = false;
            }
        }
        false
    }
    let mut used_d = vec![false; PAPER_REDUCERS];
    let mut picked = Vec::new();
    assert!(
        solve(0, &mut used_d, &idx, &mut picked),
        "WL2 solver: no perfect matching in key pool (unexpected)"
    );
    let counts: Vec<(String, usize)> = picked
        .into_iter()
        .map(|k| (k, PAPER_ITEMS / PAPER_REDUCERS))
        .collect();
    Workload::new("WL2", interleave(&counts, 0x572)).with_description(format!(
        "S=0 for both methods: keys {:?}",
        counts.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>()
    ))
}

/// WL3 — the degenerate case: one key repeated 100 times (`S = 1` by
/// design for both methods; no repartition can *split* a single key, at
/// best it relocates mid-run).
///
/// Whether the key relocates after a redistribution is fully determined
/// by the ring layout. The paper's run showed doubling relocating it
/// (S dropped to 0.75); we therefore pick a key that one doubling event
/// *would* move off its initial doubling-layout owner, so the same
/// phenomenon is observable.
pub fn wl3() -> Workload {
    let (_, ring_d) = initial_rings();
    let key = key_pool()
        .into_iter()
        .find(|k| {
            let owner = ring_d.lookup(k.as_bytes());
            let mut after = ring_d.clone();
            after.double_others(owner);
            after.lookup(k.as_bytes()) != owner
        })
        .unwrap_or_else(|| "a".to_string());
    let counts = vec![(key.clone(), PAPER_ITEMS)];
    Workload::new("WL3", interleave(&counts, 0x573))
        .with_description(format!("S=1 by design: ['{key}'; 100]"))
}

/// Build a workload with target per-halving-node loads, using `spread`
/// distinct keys on the hot node so LB can split it.
///
/// Key choice for the hot node is *doubling-aware*: among the pool keys
/// owned by the hot halving-node, we prefer keys that (a) share one
/// doubling-layout owner `d*` (so the doubling run's trigger targets a
/// well-defined hot reducer) and (b) would relocate when
/// `double_others(d*)` fires. The paper's WL4/WL5 are likewise *designed*
/// sequences whose skew responds to both methods; which keys respond is a
/// deterministic property of the hash ring, so we solve for it.
fn targeted(name: &str, loads: [usize; 4], spread: usize, seed: u64) -> Workload {
    let (ring_h, ring_d) = initial_rings();
    // bucket pool keys by halving owner
    let mut by_h: Vec<Vec<String>> = vec![Vec::new(); PAPER_REDUCERS];
    for k in key_pool() {
        by_h[ring_h.lookup(k.as_bytes())].push(k);
    }
    let hot = loads
        .iter()
        .enumerate()
        .max_by_key(|(_, &l)| l)
        .unwrap()
        .0;
    // doubling-aware ordering of the hot node's candidates
    let hot_candidates: Vec<String> = {
        let cands = &by_h[hot];
        // d* = doubling owner hosting the most candidates
        let mut per_d: Vec<Vec<&String>> = vec![Vec::new(); PAPER_REDUCERS];
        for k in cands {
            per_d[ring_d.lookup(k.as_bytes())].push(k);
        }
        let d_star = (0..PAPER_REDUCERS)
            .max_by_key(|&d| per_d[d].len())
            .unwrap();
        // destinations after one redistribution event per method: halving
        // the hot halving-node / doubling around d*. A workload whose hot
        // keys all land on ONE destination would merely migrate the
        // bottleneck (the paper's own §4.2 caveat); the paper's designed
        // workloads respond by *spreading*, so we greedily pick keys whose
        // post-event destinations are diverse under both methods.
        let mut after_d = ring_d.clone();
        after_d.double_others(d_star);
        let mut after_h = ring_h.clone();
        after_h.halve(hot);
        let mut remaining: Vec<&String> = per_d[d_star].clone();
        let mut dest_h_count = vec![0usize; PAPER_REDUCERS];
        let mut dest_d_count = vec![0usize; PAPER_REDUCERS];
        let mut ordered: Vec<String> = Vec::new();
        while !remaining.is_empty() {
            let (best_idx, _) = remaining
                .iter()
                .enumerate()
                .min_by_key(|(_, k)| {
                    let dh = after_h.lookup(k.as_bytes());
                    let dd = after_d.lookup(k.as_bytes());
                    // prefer unseen destinations; penalize "stays hot"
                    dest_h_count[dh] * 2
                        + dest_d_count[dd] * 2
                        + usize::from(dh == hot)
                        + usize::from(dd == d_star)
                })
                .unwrap();
            let k = remaining.swap_remove(best_idx);
            dest_h_count[after_h.lookup(k.as_bytes())] += 1;
            dest_d_count[after_d.lookup(k.as_bytes())] += 1;
            ordered.push(k.clone());
        }
        // backfill with keys from other doubling owners if d* runs dry
        ordered.extend(
            (0..PAPER_REDUCERS)
                .filter(|&d| d != d_star)
                .flat_map(|d| per_d[d].iter().map(|k| (*k).clone())),
        );
        ordered
    };
    let mut counts: Vec<(String, usize)> = Vec::new();
    for (node, &load) in loads.iter().enumerate() {
        if load == 0 {
            continue;
        }
        let nkeys = if node == hot { spread } else { 2.min(load) };
        let keys: &[String] = if node == hot { &hot_candidates } else { &by_h[node] };
        assert!(
            keys.len() >= nkeys,
            "node {node} has only {} pool keys, wanted {nkeys}",
            keys.len()
        );
        let base = load / nkeys;
        let extra = load % nkeys;
        for (i, k) in keys.iter().take(nkeys).enumerate() {
            let c = base + usize::from(i < extra);
            if c > 0 {
                counts.push((k.clone(), c));
            }
        }
    }
    let total: usize = counts.iter().map(|(_, c)| c).sum();
    assert_eq!(total, loads.iter().sum::<usize>());
    Workload::new(name, interleave(&counts, seed)).with_description(format!(
        "halving-node loads {loads:?} via {} distinct keys",
        counts.len()
    ))
}

/// WL4 — heavily skewed: halving-ring loads (85, 5, 5, 5) ⇒ `S = 0.8` for
/// halving; the doubling-layout skew is emergent (paper measured 0.49).
pub fn wl4() -> Workload {
    targeted("WL4", [85, 5, 5, 5], 5, 0x574)
}

/// WL5 — mildly skewed: halving-ring loads (40, 20, 20, 20) ⇒ `S = 0.2`
/// for halving; doubling-layout skew emergent (paper measured 0.55).
pub fn wl5() -> Workload {
    targeted("WL5", [40, 20, 20, 20], 4, 0x575)
}

/// All five paper workloads, in order.
pub fn all() -> Vec<Workload> {
    vec![wl1(), wl2(), wl3(), wl4(), wl5()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wl1_spec() {
        let w = wl1();
        let (rh, rd) = initial_rings();
        assert_eq!(w.len(), PAPER_ITEMS);
        assert_eq!(w.static_skew(&rh), 0.0, "halving no-LB skew");
        assert_eq!(w.static_skew(&rd), 1.0, "doubling no-LB skew");
        assert_eq!(w.distinct_keys().len(), 4);
    }

    #[test]
    fn wl2_spec() {
        let w = wl2();
        let (rh, rd) = initial_rings();
        assert_eq!(w.len(), PAPER_ITEMS);
        assert_eq!(w.static_skew(&rh), 0.0);
        assert_eq!(w.static_skew(&rd), 0.0);
    }

    #[test]
    fn wl3_spec() {
        let w = wl3();
        let (rh, rd) = initial_rings();
        assert_eq!(w.len(), PAPER_ITEMS);
        assert_eq!(w.static_skew(&rh), 1.0);
        assert_eq!(w.static_skew(&rd), 1.0);
        assert_eq!(w.distinct_keys().len(), 1);
    }

    #[test]
    fn wl4_spec() {
        let w = wl4();
        let (rh, _) = initial_rings();
        let s = w.static_skew(&rh);
        assert!((s - 0.8).abs() < 1e-12, "S = {s}");
        // multiple distinct hot keys so LB can actually help
        assert!(w.distinct_keys().len() >= 8);
    }

    #[test]
    fn wl5_spec() {
        let w = wl5();
        let (rh, _) = initial_rings();
        let s = w.static_skew(&rh);
        assert!((s - 0.2).abs() < 1e-12, "S = {s}");
    }

    #[test]
    fn wl4_wl5_doubling_layout_is_skewed() {
        // not pinned by construction, but the heavy workloads should show
        // nonzero doubling-layout skew for Table 1 to be interesting
        let (_, rd) = initial_rings();
        assert!(wl4().static_skew(&rd) > 0.05);
        assert!(wl5().static_skew(&rd) > 0.05);
    }

    #[test]
    fn workloads_are_deterministic() {
        assert_eq!(wl1().items, wl1().items);
        assert_eq!(wl4().items, wl4().items);
    }

    #[test]
    fn all_returns_five() {
        let ws = all();
        assert_eq!(ws.len(), 5);
        for w in &ws {
            assert_eq!(w.len(), PAPER_ITEMS);
        }
    }
}
