//! Workload trace I/O: persist a workload as a plain text file (one key
//! per line, `#` comments) so experiments can be replayed byte-for-byte
//! and external traces can be fed to the pipeline.

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::Context;

use super::Workload;

/// Save a workload to `path`.
pub fn save(w: &Workload, path: &Path) -> crate::Result<()> {
    let mut f = fs::File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    writeln!(f, "# workload: {}", w.name)?;
    if !w.description.is_empty() {
        writeln!(f, "# {}", w.description)?;
    }
    for item in &w.items {
        writeln!(f, "{item}")?;
    }
    Ok(())
}

/// Load a workload from `path`. The name is taken from a
/// `# workload: <name>` header if present, else the file stem.
pub fn load(path: &Path) -> crate::Result<Workload> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading trace file {}", path.display()))?;
    let mut name: Option<String> = None;
    let mut items = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim();
            if let Some(n) = rest.strip_prefix("workload:") {
                name = Some(n.trim().to_string());
            }
            continue;
        }
        items.push(line.to_string());
    }
    let name = name.unwrap_or_else(|| {
        path.file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "trace".into())
    });
    Ok(Workload::new(name, items))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("dpa-trace-test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wl.txt");
        let w = Workload::new("roundtrip", vec!["a".into(), "bb".into(), "a".into()])
            .with_description("test");
        save(&w, &path).unwrap();
        let r = load(&path).unwrap();
        assert_eq!(r.name, "roundtrip");
        assert_eq!(r.items, w.items);
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(load(Path::new("/nonexistent/definitely/not.txt")).is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let dir = std::env::temp_dir().join("dpa-trace-test2");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wl2.txt");
        fs::write(&path, "# comment\n\nx\n# another\ny\n").unwrap();
        let r = load(&path).unwrap();
        assert_eq!(r.items, vec!["x".to_string(), "y".to_string()]);
        assert_eq!(r.name, "wl2");
        fs::remove_file(&path).unwrap();
    }
}
