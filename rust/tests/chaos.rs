//! Chaos integration on the threads driver — the nondeterministic end of
//! the fault-injection testkit. The deterministic sim-side equivalents
//! live in `sim::tests`; the cross-driver kill-recovery parity row lives
//! in `driver_parity.rs`.

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::balancer::state_forward::ConsistencyMode;
use dpa::hash::Strategy;
use dpa::pipeline::{DriverKind, Pipeline, PipelineConfig};
use dpa::testkit::chaos::ChaosPlan;
use dpa::testkit::wordcount_oracle;

fn uniform_items(n: usize, keys: usize) -> Vec<String> {
    (0..n).map(|i| format!("k{}", i % keys)).collect()
}

#[test]
fn threads_stall_longer_than_pop_timeout_is_not_mistaken_for_shutdown() {
    // ISSUE 9 satellite fix: a chaos Stall parks a reducer for far
    // longer than the queue-poll timeout. The peers' pop_timeout-based
    // loops and the balancer thread's drain/quorum checks must consult
    // the live-and-not-faulted set instead of reading the silence as
    // idle shutdown (or a panicked thread): the run completes with the
    // exact answer rather than hanging or stopping early.
    let items = uniform_items(300, 29);
    let oracle = wordcount_oracle(&items);
    let mut cfg = PipelineConfig::default();
    cfg.driver = DriverKind::Threads;
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(8);
    cfg.mode = ConsistencyMode::StateForward;
    cfg.chaos = Some("stall:40@1:5,stall:40@1:25".into());
    cfg.pop_timeout_ms = 2; // each stall is 20× the poll timeout
    cfg.reduce_delay_us = 100;
    let r = Pipeline::wordcount(cfg).run(items.clone()).unwrap();
    r.check_conservation().unwrap();
    assert_eq!(r.result, oracle, "stalls changed the answer");
    assert_eq!(r.recovery.kills, 0);
    assert_eq!(r.fault_events.len(), 2, "fault log wrong: {:?}", r.fault_events);
}

#[test]
fn threads_kill_loses_zero_state_with_checkpointing() {
    // ISSUE 9 acceptance: a mid-run kill on real threads loses nothing —
    // the victim's folded partials come back from the peer-held
    // checkpoint plus WAL tail replay, and the respawned reducer picks
    // up the re-homed keys through the §7 transfer lane.
    let items = uniform_items(400, 29);
    let oracle = wordcount_oracle(&items);
    let mut cfg = PipelineConfig::default();
    cfg.driver = DriverKind::Threads;
    cfg.strategy = Strategy::TwoChoices;
    cfg.mode = ConsistencyMode::StateForward;
    cfg.max_rounds = 2;
    cfg.chaos = Some("kill@0:12".into());
    cfg.checkpoint_interval = 4;
    cfg.reduce_delay_us = 150;
    let r = Pipeline::wordcount(cfg).run(items.clone()).unwrap();
    r.check_conservation().unwrap();
    assert_eq!(r.result, oracle, "the kill lost or duplicated state");
    assert_eq!(r.recovery.kills, 1, "the scheduled kill never fired");
    assert_eq!(r.recovery.respawns, 1, "the victim never respawned");
    assert!(r.recovery.checkpoints >= 1, "the checkpoint lane was never used");
    assert!(
        r.recovery.state_restored > 0 || r.recovery.wal_replayed > 0,
        "recovery rebuilt no state at all: {:?}",
        r.recovery
    );
    assert!(r.recovery_latency.is_some(), "no recovery latency recorded");
}

#[test]
fn seeded_plans_are_deterministic() {
    // the `dpa chaos` matrix relies on seed → plan being a pure function
    for fault in ["kill", "slow", "stall", "drop"] {
        for seed in 0..4 {
            let a = ChaosPlan::seeded(fault, seed, 4).unwrap();
            let b = ChaosPlan::seeded(fault, seed, 4).unwrap();
            assert_eq!(a.spec(), b.spec(), "{fault} seed {seed}");
            assert!(a.max_victim().unwrap() < 4);
        }
    }
    assert!(ChaosPlan::seeded("explode", 0, 4).is_err());
}
