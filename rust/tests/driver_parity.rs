//! Cross-driver parity: the deterministic sim and the threads driver are
//! thin schedulers over one shared runtime (`runtime::exec`), so for every
//! paper workload × strategy × consistency mode they must produce the same
//! merged result — equal to the serial word-count oracle. This includes §7
//! state forwarding on real threads, which the pre-unification code base
//! rejected outright.

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::balancer::state_forward::ConsistencyMode;
use dpa::hash::Strategy;
use dpa::pipeline::{DriverKind, Pipeline, PipelineConfig};
use dpa::testkit::{assert_driver_parity, wordcount_oracle};
use dpa::workload::paperwl;

#[test]
fn paper_workloads_parity_merge_at_end() {
    for w in paperwl::all() {
        for strategy in Strategy::all() {
            assert_driver_parity(&w.name, &w.items, strategy, ConsistencyMode::MergeAtEnd);
        }
    }
}

#[test]
fn paper_workloads_parity_state_forward() {
    for w in paperwl::all() {
        for strategy in Strategy::methods() {
            assert_driver_parity(&w.name, &w.items, strategy, ConsistencyMode::StateForward);
        }
    }
}

#[test]
fn multiprobe_parity_both_modes() {
    // zero-token-churn router: sim and threads must still agree with the
    // serial oracle under plain forwarding AND §7 state forwarding
    let strategy = Strategy::MultiProbe { probes: 5 };
    for w in paperwl::all() {
        for mode in [ConsistencyMode::MergeAtEnd, ConsistencyMode::StateForward] {
            assert_driver_parity(&w.name, &w.items, strategy, mode);
        }
    }
}

#[test]
fn ptable_parity_both_modes() {
    // O(1) flat-table router: one indexed load per key, no ring walk —
    // sim and threads must agree with the serial oracle in both modes
    let strategy = Strategy::Ptable { bits: 8, replicas: 1 };
    for w in paperwl::all() {
        for mode in [ConsistencyMode::MergeAtEnd, ConsistencyMode::StateForward] {
            assert_driver_parity(&w.name, &w.items, strategy, mode);
        }
    }
}

#[test]
fn ptable_kill_recovers_via_a_cross_zone_checkpoint() {
    // ISSUE 10 tentpole: under a zone map, a killed ptable primary
    // recovers from its checkpoint through the cross-zone peer
    // preference (StageTracker::next_live_peer walks distinct failure
    // domains first) and the merged output stays oracle-exact
    let items: Vec<String> = (0..400).map(|i| format!("k{}", i % 29)).collect();
    let oracle = wordcount_oracle(&items);
    for driver in [DriverKind::Sim, DriverKind::Threads] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = driver;
        cfg.strategy = Strategy::Ptable { bits: 8, replicas: 2 };
        cfg.zones = Some("0,1;2,3".into());
        cfg.mode = ConsistencyMode::StateForward;
        cfg.max_rounds = 2;
        cfg.chaos = Some("kill@2:10".into());
        cfg.checkpoint_interval = 4;
        if driver == DriverKind::Threads {
            cfg.reduce_delay_us = 150;
        }
        let r = Pipeline::wordcount(cfg).run(items.clone()).unwrap();
        r.check_conservation().unwrap();
        assert_eq!(r.result, oracle, "{driver:?}: zoned kill-recovery diverged from the oracle");
        assert_eq!(r.recovery.kills, 1, "{driver:?}: the scheduled kill never fired");
        assert_eq!(r.recovery.respawns, 1, "{driver:?}: the victim never respawned");
        assert_eq!(r.fault_events.len(), 1, "{driver:?}: fault log wrong: {:?}", r.fault_events);
        assert_eq!(r.fault_events[0].reducer, 2);
    }
}

#[test]
fn twochoices_parity_both_modes() {
    // sticky-assignment router: the key-splitting guard must hold on real
    // threads too — StateForward's disjoint-merge assertion checks it
    for w in paperwl::all() {
        for mode in [ConsistencyMode::MergeAtEnd, ConsistencyMode::StateForward] {
            assert_driver_parity(&w.name, &w.items, Strategy::TwoChoices, mode);
        }
    }
}

#[test]
fn state_forward_on_threads_wl1_skewed() {
    // the acceptance case: WL1 (all load on one doubling node) on real
    // threads with §7 state forwarding. Compute-heavy reducers make the
    // hot queue build so the balancer genuinely repartitions mid-run; the
    // shared runtime's merge then asserts the key-disjoint snapshot
    // invariant, and the answer must still be exact.
    let w = paperwl::wl1();
    let mut cfg = PipelineConfig::default();
    cfg.driver = DriverKind::Threads;
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(Strategy::Doubling.initial_tokens(cfg.halving_init_tokens));
    cfg.mode = ConsistencyMode::StateForward;
    cfg.max_rounds = 2;
    cfg.reduce_delay_us = 500;
    let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
    r.check_conservation().unwrap();
    assert_eq!(r.result, wordcount_oracle(&w.items));
    assert_eq!(r.total_processed(), w.items.len() as u64);
}

#[test]
fn wl3_split_key_breaks_the_single_key_floor_on_both_drivers() {
    // ISSUE 8 acceptance: WL3 (one key × 100) is the workload no
    // relocating balancer can help — every disjoint-contract family has
    // S = 1 as a floor, because at best the whole key migrates. splitkey:4
    // promotes the mega-hot key to a 4-way split once its decayed load
    // crosses the watermark, so records routed after the promotion fan out
    // across candidate reducers: the measured skew must drop below 1 while
    // the associative merge still reproduces the serial oracle exactly,
    // on the deterministic sim AND on real threads under §7 state
    // forwarding (shard partials stay resident through the sync epochs).
    let w = paperwl::wl3();
    let oracle = wordcount_oracle(&w.items);
    for driver in [DriverKind::Sim, DriverKind::Threads] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = driver;
        cfg.strategy = Strategy::SplitKey { d: 4 };
        cfg.mode = ConsistencyMode::StateForward;
        cfg.split_watermark = 1.0; // promote on the first genuine backlog
        cfg.max_rounds = 2;
        cfg.seed = 7;
        // threads: slow both stages so the split lands while most of the
        // stream is still unrouted (the sim's costs already interleave)
        cfg.map_delay_us = 400;
        cfg.reduce_delay_us = 500;
        let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
        r.check_conservation().unwrap();
        assert_eq!(r.result, oracle, "{driver:?}: split merge diverged from the oracle");
        assert!(
            r.skew() < 1.0,
            "{driver:?}: splitkey left WL3 at S = {} (processed {:?})",
            r.skew(),
            r.processed
        );
    }
}

#[test]
fn elastic_scale_schedule_parity_state_forward_wl1() {
    // ISSUE 5 satellite: an identical scale-up + scale-down SCHEDULE (the
    // deterministic elastic controller) on WL1 under §7 state forwarding,
    // on both drivers. Reducers join mid-run, the retiree's keys re-home
    // and its state ships; the merged output must equal the serial oracle
    // on the sim AND on real threads (where the §7 disjoint-merge
    // assertion also guards against lost/duplicated state merges).
    use std::sync::Arc;

    use dpa::balancer::elastic::{ElasticController, ScaleOp};
    use dpa::balancer::BalancerCore;
    use dpa::driver::{ThreadDriver, ThreadParams};
    use dpa::exec::builtin::{IdentityMap, WordCount};
    use dpa::exec::ReduceFactory;
    use dpa::hash::RouterHandle;
    use dpa::sim::{SimDriver, SimParams};

    let w = paperwl::wl1();
    let oracle = wordcount_oracle(&w.items);
    let factory: ReduceFactory = Arc::new(|_| Box::new(WordCount::new()) as _);
    let schedule = || {
        vec![ScaleOp::Up, ScaleOp::Up, ScaleOp::Down(0), ScaleOp::Down(0)]
    };
    let mk_balancer = || {
        let router = RouterHandle::builder(Strategy::Doubling.build_router(4, 8, Some(1)))
            .signal(&dpa::balancer::signal::SignalConfig::default())
            .capacity(8)
            .build();
        BalancerCore::new(router, Strategy::Doubling, 0.2, 8, 2, 30)
            .with_elastic(ElasticController::from_schedule(schedule(), 6, 4, 8))
    };

    let sim = SimDriver::new(SimParams {
        mode: ConsistencyMode::StateForward,
        max_reducers: 8,
        seed: 5,
        ..Default::default()
    });
    let r = sim.run(Arc::new(IdentityMap), &factory, 4, mk_balancer(), w.items.clone());
    r.check_conservation().unwrap();
    assert_eq!(r.result, oracle, "sim elastic schedule diverged from the oracle");
    let (added, retired) = r.scale_counts();
    assert!(added > 0, "the schedule never scaled up on the sim");
    assert!(retired > 0, "the schedule never scaled down on the sim");

    let threads = ThreadDriver::new(ThreadParams {
        mode: ConsistencyMode::StateForward,
        max_reducers: 8,
        reduce_delay_us: 100, // queues must build so reports keep flowing
        ..Default::default()
    });
    let r = threads.run(Arc::new(IdentityMap), &factory, 4, mk_balancer(), w.items.clone());
    r.check_conservation().unwrap();
    assert_eq!(r.result, oracle, "threads elastic schedule diverged from the oracle");
}

#[test]
fn mid_run_reducer_kill_recovers_and_matches_the_no_fault_oracle() {
    // ISSUE 9 tentpole: a reducer killed mid-run under §7 state
    // forwarding recovers via retire + respawn with checkpoint restore —
    // on BOTH drivers — and the merged output still equals the serial
    // oracle, i.e. the answer a fault-free run produces.
    let items: Vec<String> = (0..400).map(|i| format!("k{}", i % 29)).collect();
    let oracle = wordcount_oracle(&items);
    for driver in [DriverKind::Sim, DriverKind::Threads] {
        let mut cfg = PipelineConfig::default();
        cfg.driver = driver;
        cfg.strategy = Strategy::Doubling;
        cfg.initial_tokens = Some(8); // dense ring: every reducer folds plenty
        cfg.mode = ConsistencyMode::StateForward;
        cfg.max_rounds = 2;
        cfg.chaos = Some("kill@2:10".into());
        cfg.checkpoint_interval = 4;
        if driver == DriverKind::Threads {
            cfg.reduce_delay_us = 150;
        }
        let r = Pipeline::wordcount(cfg).run(items.clone()).unwrap();
        r.check_conservation().unwrap();
        assert_eq!(r.result, oracle, "{driver:?}: kill-recovery run diverged from the oracle");
        assert_eq!(r.recovery.kills, 1, "{driver:?}: the scheduled kill never fired");
        assert_eq!(r.recovery.respawns, 1, "{driver:?}: the victim never respawned");
        assert!(r.recovery_latency.is_some(), "{driver:?}: no recovery latency recorded");
        assert_eq!(r.fault_events.len(), 1, "{driver:?}: fault log wrong: {:?}", r.fault_events);
        assert_eq!(r.fault_events[0].reducer, 2);
    }
}

#[test]
fn shared_input_runs_do_not_clone_per_seed() {
    // run_seeds shares one Arc'd input across seeds; results stay exact
    let w = paperwl::wl4();
    let mut cfg = PipelineConfig::default();
    cfg.strategy = Strategy::Doubling;
    cfg.initial_tokens = Some(1);
    let p = Pipeline::wordcount(cfg);
    let reports = p.run_seeds(&w.items, &[0, 1, 2, 3]).unwrap();
    let oracle = wordcount_oracle(&w.items);
    for r in &reports {
        assert_eq!(r.result, oracle);
    }
}
