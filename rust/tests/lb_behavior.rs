//! Behavioural tests of the load balancer inside the full pipeline: when
//! it fires, what it changes, how rounds/τ interact, and the §7
//! extensions (state forwarding, elastic scale-out).

// experiment configs override one default knob at a time (see lib.rs)
#![allow(clippy::field_reassign_with_default)]

use dpa::balancer::signal::SignalConfig;
use dpa::balancer::state_forward::ConsistencyMode;
use dpa::balancer::BalancerCore;
use dpa::hash::{Ring, RouterHandle, SharedRing, Strategy};
use dpa::metrics::skew;
use dpa::pipeline::{Pipeline, PipelineConfig};
use dpa::workload::paperwl;

fn cfg_for(strategy: Strategy) -> PipelineConfig {
    let mut cfg = PipelineConfig::default();
    cfg.strategy = strategy;
    cfg.initial_tokens = Some(strategy.initial_tokens(cfg.halving_init_tokens));
    cfg
}

#[test]
fn wl1_doubling_fires_and_reduces_skew() {
    let w = paperwl::wl1();
    // baseline: no LB on the doubling layout -> S = 1
    let mut nolb = cfg_for(Strategy::Doubling);
    nolb.strategy = Strategy::None;
    let base = Pipeline::wordcount(nolb).run(w.items.clone()).unwrap();
    assert_eq!(base.skew(), 1.0);
    assert!(base.lb_events.is_empty());

    let r = Pipeline::wordcount(cfg_for(Strategy::Doubling))
        .run(w.items.clone())
        .unwrap();
    assert!(!r.lb_events.is_empty(), "LB must fire on WL1/doubling");
    assert!(r.skew() < base.skew(), "S improved: {} < 1", r.skew());
    assert!(r.total_forwarded() > 0, "stale queued records were forwarded");
    // the event targeted the overloaded reducer (the one with max qlen)
    let e = &r.lb_events[0];
    let max_q = e.qlens.iter().max().unwrap();
    assert_eq!(e.qlens[e.target as usize], *max_q);
}

#[test]
fn wl2_uniform_rarely_needs_lb_with_high_tau() {
    // τ high enough tolerates the skew noise -> no event
    let w = paperwl::wl2();
    let mut cfg = cfg_for(Strategy::Halving);
    cfg.tau = 5.0;
    let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
    assert!(r.lb_events.is_empty(), "τ=5 tolerates everything");
    assert_eq!(r.skew(), 0.0);
}

#[test]
fn tau_zero_is_most_sensitive() {
    let w = paperwl::wl5();
    let mut sensitive = cfg_for(Strategy::Doubling);
    sensitive.tau = 0.0;
    sensitive.max_rounds = 4;
    let mut tolerant = sensitive.clone();
    tolerant.tau = 10.0;
    let rs = Pipeline::wordcount(sensitive).run(w.items.clone()).unwrap();
    let rt = Pipeline::wordcount(tolerant).run(w.items.clone()).unwrap();
    assert!(
        rs.lb_events.len() >= rt.lb_events.len(),
        "τ=0 fires at least as often as τ=10 ({} vs {})",
        rs.lb_events.len(),
        rt.lb_events.len()
    );
}

#[test]
fn rounds_cap_limits_events_per_reducer() {
    let w = paperwl::wl3(); // keeps re-overloading whoever owns the key
    for max_rounds in [1u32, 2, 3] {
        let mut cfg = cfg_for(Strategy::Doubling);
        cfg.max_rounds = max_rounds;
        cfg.cooldown = 10;
        let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
        // count events per target
        let mut per = std::collections::HashMap::new();
        for e in &r.lb_events {
            *per.entry(e.target).or_insert(0u32) += 1;
        }
        for (t, n) in per {
            assert!(n <= max_rounds, "reducer {t} fired {n} > cap {max_rounds}");
        }
    }
}

#[test]
fn halving_events_shrink_only_target_tokens() {
    let w = paperwl::wl4();
    let mut cfg = cfg_for(Strategy::Halving);
    cfg.max_rounds = 2;
    let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
    assert!(!r.lb_events.is_empty(), "WL4/halving should fire");
    // reconstruct: replay the strategy on a fresh ring
    let mut ring = Ring::new(4, 8);
    for e in &r.lb_events {
        let before: Vec<u32> = (0..4).map(|n| ring.tokens_of(n)).collect();
        assert!(ring.halve(e.target as usize));
        for n in 0..4 {
            if n == e.target as usize {
                assert_eq!(ring.tokens_of(n), before[n] / 2);
            } else {
                assert_eq!(ring.tokens_of(n), before[n]);
            }
        }
    }
}

#[test]
fn forwarded_records_counted_at_destination() {
    // total processed must equal input regardless of how much forwarding
    // happened; forwarded counts live on the *origin* reducer
    let w = paperwl::wl1();
    let mut cfg = cfg_for(Strategy::Doubling);
    cfg.max_rounds = 3;
    let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
    assert_eq!(r.total_processed(), 100);
    if !r.lb_events.is_empty() {
        assert!(r.total_forwarded() > 0);
    }
}

#[test]
fn state_forward_mode_keeps_state_disjoint_under_many_rounds() {
    let w = paperwl::wl4();
    let mut cfg = cfg_for(Strategy::Doubling);
    cfg.mode = ConsistencyMode::StateForward;
    cfg.max_rounds = 3;
    cfg.cooldown = 100;
    // merge_states() inside the run asserts pairwise-disjoint snapshots;
    // reaching here without panic IS the invariant
    let r = Pipeline::wordcount(cfg).run(w.items.clone()).unwrap();
    r.check_conservation().unwrap();
    assert_eq!(r.total_processed(), 100);
}

#[test]
fn elastic_scale_out_ring_level() {
    // §7: a new reducer claims tokens; forwarding redirects its keys
    let ring = SharedRing::new(Ring::new(4, 8));
    let keys: Vec<String> = (0..400).map(|i| format!("key{i}")).collect();
    let before: Vec<usize> = keys.iter().map(|k| ring.lookup(k.as_bytes())).collect();
    let new_node = ring.update(|r| r.add_node(8));
    assert_eq!(new_node, 4);
    let mut moved = 0;
    for (k, &b) in keys.iter().zip(&before) {
        let now = ring.lookup(k.as_bytes());
        if now != b {
            assert_eq!(now, new_node);
            moved += 1;
        }
    }
    assert!(moved > 20, "new node claimed a meaningful share ({moved})");
}

/// The paper's §7 hash-join hazard, end to end.
///
/// Build rows install per-key state; probe rows that find no local build
/// state are dropped. When a repartition moves a key *between* its build
/// and probe phases, merge-at-end loses those probes — while §7 state
/// forwarding ships the build state ahead of the probes and stays exact.
#[test]
fn join_hazard_merge_at_end_vs_state_forwarding() {
    use dpa::exec::join::{join_oracle, HashJoin, JoinMap};
    use std::sync::Arc;

    // solve for keys that (a) all live on one node of the doubling-layout
    // ring, so the trigger fires, and (b) relocate after one doubling
    let ring = dpa::hash::Ring::new(4, 1);
    let pool = dpa::workload::generators::key_pool();
    let mut hot_movable: Vec<String> = Vec::new();
    'outer: for node in 0..4 {
        let mut after = ring.clone();
        after.double_others(node);
        let movable: Vec<String> = pool
            .iter()
            .filter(|k| {
                ring.lookup(k.as_bytes()) == node && after.lookup(k.as_bytes()) != node
            })
            .take(4)
            .cloned()
            .collect();
        if movable.len() == 4 {
            hot_movable = movable;
            break 'outer;
        }
    }
    assert_eq!(hot_movable.len(), 4, "solver found movable hot keys");

    // stream in three phases:
    //   1. builds for the hot keys (install state on their owner X);
    //   2. ballast routed to *other* nodes — gives X time to fully
    //      process the builds, so the build state exists only as
    //      *processed state*, not as forwardable queued rows;
    //   3. a probe flood on the hot keys — its queue buildup triggers the
    //      LB, relocating the keys mid-flood.
    // After relocation, probes reach the new owner Y. Under merge-at-end
    // Y has no build state (it is stranded on X) and drops them — the §7
    // hazard. Under state forwarding the state ships to Y before Y may
    // process any data, so every probe matches.
    let ballast: Vec<String> = pool
        .iter()
        .filter(|k| {
            let owner = ring.lookup(k.as_bytes());
            !hot_movable.contains(k) && owner != ring.lookup(hot_movable[0].as_bytes())
        })
        .take(10)
        .cloned()
        .collect();
    let mut items: Vec<String> = Vec::new();
    for (i, k) in hot_movable.iter().enumerate() {
        items.push(format!("B:{k}:{}", 100 + i));
    }
    for _ in 0..4 {
        for k in &ballast {
            items.push(format!("B:{k}:1"));
        }
    }
    for round in 0..30 {
        for k in &hot_movable {
            items.push(format!("P:{k}:{round}"));
        }
    }
    let (oracle, oracle_dropped) = join_oracle(&items);
    assert_eq!(oracle_dropped, 0, "serial execution drops nothing");

    let run = |mode: ConsistencyMode| {
        let mut cfg = cfg_for(Strategy::Doubling);
        cfg.mode = mode;
        cfg.max_rounds = 2;
        // one mapper: stream order is preserved into the queues, so the
        // probe phase cannot overtake the build phase at the mapper level
        cfg.mappers = 1;
        let p = dpa::pipeline::Pipeline::new(
            cfg,
            Arc::new(JoinMap),
            Arc::new(|_| Box::new(HashJoin::new()) as _),
        );
        p.run(items.clone()).unwrap()
    };

    let sf = run(ConsistencyMode::StateForward);
    assert!(
        !sf.lb_events.is_empty(),
        "LB must fire for the hazard to be exercised"
    );
    assert_eq!(
        sf.result, oracle,
        "state forwarding keeps the join exact across repartitions"
    );

    let mae = run(ConsistencyMode::MergeAtEnd);
    if !mae.lb_events.is_empty() {
        // some probes arrived at the key's new owner before its build
        // state could ever get there — merge-at-end cannot repair that
        let merged_matches: i64 = mae.result.iter().map(|(_, v)| v).sum();
        let oracle_matches: i64 = oracle.iter().map(|(_, v)| v).sum();
        assert!(
            merged_matches < oracle_matches,
            "expected lost probes under merge-at-end ({merged_matches} vs {oracle_matches})"
        );
    }
}

/// Solve for a hot key whose ownership actually *can* move under the
/// given probe-router strategy: overload its owner on a throwaway
/// (legacy-signal) router, redistribute, and check the route changed.
/// WL3's adversarial property needs a movable key — an immovable one
/// (e.g. two-choices candidates colliding) cannot ping-pong at all.
fn movable_hot_key(strategy: Strategy) -> String {
    dpa::workload::generators::key_pool()
        .into_iter()
        .find(|k| {
            let h = RouterHandle::new(strategy.build_router(4, 8, None));
            let owner = h.route_key(k.as_bytes());
            for n in 0..4 {
                h.loads().set(n, if n == owner { 50 } else { 1 });
            }
            h.redistribute(owner);
            h.route_key(k.as_bytes()) != owner
        })
        .expect("key pool has a movable key for every probe router")
}

/// Drive the WL3 adversary against a balancer + probe router: whoever
/// owns the hot key instantly becomes the hot reducer (queue 50), every
/// other reducer drains to 1 — the exact drift that makes raw frozen
/// loads chase the key around. Returns how many redistributions actually
/// changed the routing.
fn adversarial_drift_migrations(strategy: Strategy, signal: &SignalConfig, key: &str) -> usize {
    let router = RouterHandle::builder(strategy.build_router(4, 8, None))
        .signal(signal)
        .build();
    let mut b =
        BalancerCore::new(router.clone(), strategy, 0.2, 4, 100, 0).without_warmup();
    let mut events = 0;
    for t in 0..16u64 {
        let owner = router.route_key(key.as_bytes());
        for n in 0..4 {
            if n != owner {
                b.observe(n, 1);
            }
        }
        if b.report(owner, 50, t).is_some() {
            events += 1;
        }
    }
    events
}

#[test]
fn wl3_drift_hysteresis_cuts_ping_pong_migrations() {
    // ISSUE 4 tentpole regression: under adversarial single-hot-key drift
    // the frozen-raw-load behavior redistributes on (nearly) every policy
    // evaluation — the signal inverts the instant the key moves — while
    // the decayed + hysteresis + min-gain signal must produce strictly
    // fewer migrations for BOTH probe-router families.
    let smoothed = SignalConfig { decay_alpha: 0.2, hysteresis: 0.75, min_gain: 0.5 };
    for strategy in [Strategy::MultiProbe { probes: 5 }, Strategy::TwoChoices] {
        let key = movable_hot_key(strategy);
        let raw = adversarial_drift_migrations(strategy, &SignalConfig::legacy(), &key);
        let damped = adversarial_drift_migrations(strategy, &smoothed, &key);
        assert!(raw >= 3, "{strategy}: the adversary did not ping-pong (raw = {raw})");
        assert!(
            damped < raw,
            "{strategy}: hysteresis did not reduce migrations ({damped} !< {raw})"
        );
    }
}

#[test]
fn wl3_pipeline_exact_under_legacy_and_smoothed_signal() {
    // end-to-end: the full sim pipeline on the real WL3 stream stays
    // exact under BOTH signal configurations — migrations (however many
    // the drain dynamics allow) never lose or duplicate records, and the
    // merged result is routing-invariant. The strict fewer-migrations
    // inequality lives in the balancer-level test above, where the
    // adversary is undiluted by cooldowns and queue-drain timing.
    let w = paperwl::wl3();
    for strategy in [Strategy::MultiProbe { probes: 5 }, Strategy::TwoChoices] {
        let run = |signal: SignalConfig| {
            let mut cfg = cfg_for(strategy);
            cfg.signal = signal;
            cfg.max_rounds = 8;
            cfg.cooldown = 10;
            Pipeline::wordcount(cfg).run(w.items.clone()).unwrap()
        };
        let raw = run(SignalConfig::legacy());
        let damped = run(SignalConfig { decay_alpha: 0.2, hysteresis: 0.75, min_gain: 0.5 });
        for r in [&raw, &damped] {
            r.check_conservation().unwrap();
            assert_eq!(r.total_processed(), 100, "{strategy}");
            assert_eq!(r.result.len(), 1, "{strategy}: WL3 is a single key");
            assert!(
                r.migrations() <= 8 * 4,
                "{strategy}: rounds cap bounds migrations"
            );
        }
        assert_eq!(raw.result, damped.result, "{strategy}: result is routing-invariant");
    }
}

#[test]
fn skew_metric_improvement_is_monotone_in_observability() {
    // sanity: LB can only help if the workload has >1 distinct key
    let w = paperwl::wl3();
    let r = Pipeline::wordcount(cfg_for(Strategy::Halving))
        .run(w.items.clone())
        .unwrap();
    // halving the hot node cannot split a single key
    assert_eq!(r.skew(), 1.0);
}

#[test]
fn report_interval_affects_trigger_latency() {
    let w = paperwl::wl1();
    let mut fast = cfg_for(Strategy::Doubling);
    fast.report_interval = 1;
    let mut slow = fast.clone();
    slow.report_interval = 64;
    let rf = Pipeline::wordcount(fast).run(w.items.clone()).unwrap();
    let rs = Pipeline::wordcount(slow).run(w.items.clone()).unwrap();
    match (rf.lb_events.first(), rs.lb_events.first()) {
        (Some(ef), Some(es)) => assert!(
            ef.at <= es.at,
            "frequent reports trigger earlier ({} vs {})",
            ef.at,
            es.at
        ),
        (Some(_), None) => {} // slow reporting missed the window entirely
        other => panic!("unexpected trigger pattern {other:?}"),
    }
}

#[test]
fn min_trigger_qlen_gates_firing() {
    let w = paperwl::wl1();
    let mut gated = cfg_for(Strategy::Doubling);
    gated.min_trigger_qlen = 10_000; // unreachable for 100 items
    let r = Pipeline::wordcount(gated).run(w.items.clone()).unwrap();
    assert!(r.lb_events.is_empty());
    assert_eq!(r.skew(), 1.0);
}

#[test]
fn skew_helper_consistency() {
    // RunReport::skew is the paper metric over processed counts
    assert_eq!(skew(&[100, 0, 0, 0]), 1.0);
    let w = paperwl::wl1();
    let mut cfg = cfg_for(Strategy::Doubling);
    cfg.strategy = Strategy::None;
    let r = Pipeline::wordcount(cfg).run(w.items).unwrap();
    assert_eq!(r.skew(), skew(&r.processed));
}
